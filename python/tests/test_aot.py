"""AOT pipeline tests: artifacts are valid HLO text with the right
signatures, the manifest is consistent, and bucket dedup works."""

import json
import os

import pytest

from compile.aot import bucket_name, build, lower_conv, to_hlo_text
from compile.model import conv_layer_ref


def test_bucket_name_format():
    assert bucket_name("ref", 3, 64, 224, 224) == "ref_c3_h224_w224_k64"


def test_hlo_text_structure():
    lowered = lower_conv(conv_layer_ref, 2, 4, 8, 8)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Signature: x [2,8,8], w [4,2,3,3], b [4], tuple result [4,8,8].
    assert "f32[2,8,8]" in text
    assert "f32[4,2,3,3]" in text
    assert "->(f32[4,8,8]" in text  # tuple result (with layout annotation)


def test_build_manifest_roundtrip(tmp_path):
    outdir = str(tmp_path / "artifacts")
    manifest = build(outdir, [(32, ("ref",), None)], quiet=True)
    with open(os.path.join(outdir, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["artifacts"] == manifest["artifacts"]
    # Every artifact file exists and is parseable-looking HLO.
    for art in on_disk["artifacts"]:
        path = os.path.join(outdir, art["file"])
        assert os.path.exists(path)
        with open(path) as f:
            assert "HloModule" in f.read(200)
    # VGG-16 at one resolution has <= 13 distinct buckets.
    assert 0 < len(on_disk["artifacts"]) <= 13


def test_build_dedups_across_resolutions(tmp_path):
    outdir = str(tmp_path / "artifacts")
    manifest = build(outdir, [(32, ("ref",), None), (32, ("ref",), None)], quiet=True)
    names = [a["name"] for a in manifest["artifacts"]]
    assert len(names) == len(set(names))


def test_max_pallas_hw_filters(tmp_path):
    outdir = str(tmp_path / "artifacts")
    manifest = build(outdir, [(32, ("ref", "vscnn"), 16)], quiet=True)
    for art in manifest["artifacts"]:
        if art["kind"] == "vscnn":
            assert art["h"] <= 16


@pytest.mark.parametrize("c_in,c_out,h", [(3, 8, 16), (8, 4, 8)])
def test_pallas_artifact_lowers(c_in, c_out, h):
    """The Pallas path lowers to HLO text without Mosaic custom-calls
    (interpret=True ⇒ plain HLO the CPU PJRT client can run)."""
    from compile.model import conv_layer

    lowered = lower_conv(conv_layer, c_in, c_out, h, h)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "mosaic" not in text.lower()
