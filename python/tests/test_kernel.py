"""L1 correctness: the VSCNN Pallas kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute hot-spot: hypothesis
sweeps shapes/paddings/sparsity patterns and asserts allclose against
lax.conv. Failures here mean the column dataflow (and therefore the HLO the
rust runtime executes) is wrong.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import conv2d_ref, maxpool2x2_ref, relu_ref
from compile.kernels.vscnn_conv import vscnn_conv

RTOL = 2e-4
ATOL = 2e-4


def rand(rng, shape, density=1.0):
    x = rng.normal(size=shape).astype(np.float32)
    if density < 1.0:
        x = x * (rng.random(size=shape) < density)
    return jnp.asarray(x)


def assert_matches_ref(x, w, pad=1, **kw):
    got = vscnn_conv(x, w, pad=pad, **kw)
    want = conv2d_ref(x, w, pad=pad)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


class TestBasicShapes:
    def test_paper_example_5x5(self):
        """Fig 6: 5x5 input, pad 1, 3x3 kernel -> 5x5 output."""
        rng = np.random.default_rng(0)
        x = rand(rng, (1, 5, 5))
        w = rand(rng, (1, 1, 3, 3))
        out = vscnn_conv(x, w)
        assert out.shape == (1, 5, 5)
        assert_matches_ref(x, w)

    def test_vgg_first_layer_geometry(self):
        rng = np.random.default_rng(1)
        x = rand(rng, (3, 32, 32))
        w = rand(rng, (64, 3, 3, 3))
        assert_matches_ref(x, w)

    def test_many_channels(self):
        rng = np.random.default_rng(2)
        x = rand(rng, (32, 14, 14))
        w = rand(rng, (16, 32, 3, 3))
        assert_matches_ref(x, w)

    def test_pad_zero_valid_conv(self):
        rng = np.random.default_rng(3)
        x = rand(rng, (2, 9, 9))
        w = rand(rng, (4, 2, 3, 3))
        got = vscnn_conv(x, w, pad=0)
        want = conv2d_ref(x, w, pad=0)
        assert got.shape == (4, 7, 7)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_pad_two(self):
        rng = np.random.default_rng(4)
        x = rand(rng, (2, 6, 6))
        w = rand(rng, (3, 2, 3, 3))
        assert_matches_ref(x, w, pad=2)

    def test_5x5_kernel(self):
        rng = np.random.default_rng(5)
        x = rand(rng, (2, 10, 10))
        w = rand(rng, (3, 2, 5, 5))
        assert_matches_ref(x, w, pad=2)

    def test_1x1_kernel(self):
        rng = np.random.default_rng(6)
        x = rand(rng, (4, 7, 7))
        w = rand(rng, (5, 4, 1, 1))
        assert_matches_ref(x, w, pad=0)

    def test_non_square_input(self):
        rng = np.random.default_rng(7)
        x = rand(rng, (2, 11, 5))
        w = rand(rng, (3, 2, 3, 3))
        assert_matches_ref(x, w)


class TestKTiling:
    def test_k_not_multiple_of_tile(self):
        rng = np.random.default_rng(8)
        x = rand(rng, (2, 8, 8))
        w = rand(rng, (5, 2, 3, 3))
        assert_matches_ref(x, w, k_tile=2)

    def test_k_tile_one(self):
        rng = np.random.default_rng(9)
        x = rand(rng, (1, 6, 6))
        w = rand(rng, (3, 1, 3, 3))
        assert_matches_ref(x, w, k_tile=1)

    def test_k_tile_exceeds_k(self):
        rng = np.random.default_rng(10)
        x = rand(rng, (1, 6, 6))
        w = rand(rng, (2, 1, 3, 3))
        got = vscnn_conv(x, w, k_tile=8)
        want = conv2d_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


class TestColTiling:
    """The MXU row-fill variant (EXPERIMENTS.md §Perf): batching col_tile
    output columns per grid step must be numerically identical."""

    def test_col_tile_4(self):
        rng = np.random.default_rng(20)
        x = rand(rng, (4, 12, 10))
        w = rand(rng, (6, 4, 3, 3))
        assert_matches_ref(x, w, col_tile=4)

    def test_col_tile_not_dividing_w(self):
        rng = np.random.default_rng(21)
        x = rand(rng, (2, 8, 7))  # w_out=7, col_tile=3 -> padding path
        w = rand(rng, (3, 2, 3, 3))
        assert_matches_ref(x, w, col_tile=3)

    def test_col_tile_exceeds_w(self):
        rng = np.random.default_rng(22)
        x = rand(rng, (2, 6, 4))
        w = rand(rng, (3, 2, 3, 3))
        assert_matches_ref(x, w, col_tile=8)

    @settings(max_examples=15, deadline=None)
    @given(
        col_tile=st.integers(1, 6),
        h=st.integers(3, 12),
        w=st.integers(3, 12),
        pad=st.integers(0, 2),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_col_tile_sweep(self, col_tile, h, w, pad, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, (2, h, w), density=0.5)
        wt = rand(rng, (3, 2, 3, 3), density=0.5)
        got = vscnn_conv(x, wt, pad=pad, col_tile=col_tile)
        want = conv2d_ref(x, wt, pad=pad)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


class TestSparsity:
    """Vector-pruned weights / ReLU-sparse inputs (the paper's workload)."""

    def test_vector_pruned_weights(self):
        rng = np.random.default_rng(11)
        x = rand(rng, (4, 14, 14))
        w = np.asarray(rand(rng, (8, 4, 3, 3)))
        # Zero whole kernel columns (vector granularity).
        mask = rng.random(size=(8, 4, 1, 3)) < 0.7
        w = jnp.asarray(w * ~mask)
        assert_matches_ref(jnp.asarray(x), w)

    def test_sparse_input_activations(self):
        rng = np.random.default_rng(12)
        x = rand(rng, (4, 14, 14), density=0.3)
        w = rand(rng, (8, 4, 3, 3))
        assert_matches_ref(x, w)

    def test_all_zero_input(self):
        x = jnp.zeros((2, 8, 8), jnp.float32)
        rng = np.random.default_rng(13)
        w = rand(rng, (3, 2, 3, 3))
        out = vscnn_conv(x, w)
        assert float(jnp.abs(out).max()) == 0.0

    def test_all_zero_weights(self):
        rng = np.random.default_rng(14)
        x = rand(rng, (2, 8, 8))
        w = jnp.zeros((3, 2, 3, 3), jnp.float32)
        out = vscnn_conv(x, w)
        assert float(jnp.abs(out).max()) == 0.0


@settings(max_examples=40, deadline=None)
@given(
    c_in=st.integers(1, 6),
    k_out=st.integers(1, 8),
    h=st.integers(3, 16),
    w=st.integers(3, 16),
    pad=st.integers(0, 2),
    density=st.sampled_from([1.0, 0.5, 0.15]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep_matches_ref(c_in, k_out, h, w, pad, density, seed):
    """Property: kernel == oracle over random shapes/pads/sparsity."""
    rng = np.random.default_rng(seed)
    x = rand(rng, (c_in, h, w), density=density)
    wt = rand(rng, (k_out, c_in, 3, 3), density=density)
    got = vscnn_conv(x, wt, pad=pad)
    want = conv2d_ref(x, wt, pad=pad)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(2, 12),
    w=st.integers(2, 12),
    c=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_pool_and_relu_oracles(h, w, c, seed):
    """The helper oracles agree with numpy formulations."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(c, h, w)).astype(np.float32)
    np.testing.assert_allclose(relu_ref(jnp.asarray(x)), np.maximum(x, 0.0))
    got = maxpool2x2_ref(jnp.asarray(x))
    hh, ww = h // 2, w // 2
    want = np.full((c, hh, ww), -np.inf, np.float32)
    for i in range(hh):
        for j in range(ww):
            want[:, i, j] = x[:, 2 * i : 2 * i + 2, 2 * j : 2 * j + 2].max(axis=(1, 2))
    if hh and ww:
        np.testing.assert_allclose(got, want)
    else:
        assert got.shape == (c, hh, ww)


def test_dtype_is_float32():
    rng = np.random.default_rng(15)
    x = rand(rng, (1, 4, 4))
    w = rand(rng, (1, 1, 3, 3))
    assert vscnn_conv(x, w).dtype == jnp.float32


def test_rejects_channel_mismatch():
    rng = np.random.default_rng(16)
    x = rand(rng, (2, 4, 4))
    w = rand(rng, (1, 3, 3, 3))
    with pytest.raises(AssertionError):
        vscnn_conv(x, w)
