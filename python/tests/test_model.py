"""L2 correctness: VGG-16 graph shapes, kernel-vs-ref layer equivalence,
and the AOT bucket enumeration."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    POOL_AFTER,
    VGG16_CONVS,
    conv_layer,
    conv_layer_ref,
    init_params,
    layer_shapes,
    vgg16_forward,
)


def test_thirteen_convs_five_pools():
    assert len(VGG16_CONVS) == 13
    assert len(POOL_AFTER) == 5


def test_layer_shapes_at_224():
    shapes = layer_shapes(224)
    assert shapes[0] == ("conv1_1", 3, 64, 224, 224)
    assert shapes[-1] == ("conv5_3", 512, 512, 14, 14)
    # Heights divide both paper vector sizes.
    for _n, _ci, _co, h, _w in shapes:
        assert h % 14 == 0 and h % 7 == 0


def test_layer_shapes_reject_bad_res():
    with pytest.raises(AssertionError):
        layer_shapes(100)


def test_conv_layer_kernel_matches_ref():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 8, 3, 3)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(4,)).astype(np.float32))
    np.testing.assert_allclose(
        conv_layer(x, w, b), conv_layer_ref(x, w, b), rtol=2e-4, atol=2e-4
    )


def test_forward_shapes_and_activation_sparsity():
    params = init_params(32, seed=1)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(3, 32, 32)).astype(np.float32))
    acts, final = vgg16_forward(x, params)
    assert len(acts) == 13
    assert final.shape == (512, 1, 1)
    # Post-ReLU activations are nonnegative and ReLU-sparse.
    for a in acts:
        arr = np.asarray(a)
        assert arr.min() >= 0.0
        density = (arr != 0).mean()
        assert 0.05 < density < 0.95, f"density {density}"


def test_forward_kernel_path_matches_ref_path():
    """The full trunk through the Pallas kernel equals the lax path."""
    params = init_params(32, seed=3)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(3, 32, 32)).astype(np.float32))
    acts_ref, final_ref = vgg16_forward(x, params, use_kernel=False)
    acts_k, final_k = vgg16_forward(x, params, use_kernel=True)
    np.testing.assert_allclose(final_k, final_ref, rtol=5e-3, atol=5e-3)
    for a, b in zip(acts_k, acts_ref):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)
