"""L2: the VGG-16 compute graph in JAX, calling the L1 Pallas kernel.

Build-time only. `conv_layer` (Pallas path) and `conv_layer_ref` (lax path)
are the two per-layer functions AOT-lowered by aot.py; `vgg16_forward` runs
the whole trunk for end-to-end validation against the rust pipeline.

The layer geometry mirrors rust/src/model/vgg16.rs exactly — the rust side
is the source of truth for the network the experiments run.
"""

import jax.numpy as jnp

from .kernels.ref import conv2d_ref, maxpool2x2_ref, relu_ref
from .kernels.vscnn_conv import vscnn_conv

# (name, c_in, c_out) for the 13 VGG-16 convs; pools follow the block ends.
VGG16_CONVS = [
    ("conv1_1", 3, 64),
    ("conv1_2", 64, 64),
    ("conv2_1", 64, 128),
    ("conv2_2", 128, 128),
    ("conv3_1", 128, 256),
    ("conv3_2", 256, 256),
    ("conv3_3", 256, 256),
    ("conv4_1", 256, 512),
    ("conv4_2", 512, 512),
    ("conv4_3", 512, 512),
    ("conv5_1", 512, 512),
    ("conv5_2", 512, 512),
    ("conv5_3", 512, 512),
]
POOL_AFTER = {"conv1_2", "conv2_2", "conv3_3", "conv4_3", "conv5_3"}


def conv_layer(x, w, b):
    """One accelerator layer via the VSCNN Pallas kernel: conv + bias.

    Pre-ReLU, matching the hardware split: the PE array + accumulator
    produce this; ReLU/zero-detection live in the post-processing unit
    (rust/src/sim/postproc.rs).
    """
    return vscnn_conv(x, w) + b[:, None, None]


def conv_layer_ref(x, w, b):
    """Same layer via lax.conv — the fast functional path and the oracle."""
    return conv2d_ref(x, w, b)


def layer_shapes(res):
    """(name, c_in, c_out, h, w) for each conv at input resolution `res`."""
    assert res % 32 == 0, "resolution must be a multiple of 32"
    shapes = []
    h = w = res
    for name, c_in, c_out in VGG16_CONVS:
        shapes.append((name, c_in, c_out, h, w))
        if name in POOL_AFTER:
            h //= 2
            w //= 2
    return shapes


def vgg16_forward(x, params, *, use_kernel=False):
    """Full VGG-16 trunk forward pass.

    params: {name: (w, b)}. Returns the list of post-ReLU activations per
    conv layer (what the rust coordinator's sparsity propagation sees) and
    the final feature map.
    """
    acts = []
    layer = conv_layer if use_kernel else conv_layer_ref
    for name, _c_in, _c_out in VGG16_CONVS:
        w, b = params[name]
        x = relu_ref(layer(x, w, b))
        acts.append(x)
        if name in POOL_AFTER:
            x = maxpool2x2_ref(x)
    return acts, x


def init_params(res, seed=0):
    """He-initialized synthetic parameters (mirrors rust model/init.rs in
    spirit; exact values need not match — cross-checks exchange tensors)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    params = {}
    for name, c_in, c_out in VGG16_CONVS:
        fan_in = c_in * 9
        w = rng.normal(0.0, (2.0 / fan_in) ** 0.5, size=(c_out, c_in, 3, 3))
        b = rng.normal(0.0, 0.01, size=(c_out,))
        params[name] = (jnp.asarray(w, jnp.float32), jnp.asarray(b, jnp.float32))
    del res
    return params
