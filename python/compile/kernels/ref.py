"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is the "obviously correct" formulation via lax primitives;
the VSCNN column-dataflow kernel in vscnn_conv.py must match these to float
tolerance on every shape (pytest + hypothesis sweep in
python/tests/test_kernel.py). The rust golden conv (rust/src/tensor/conv.rs)
is the third corner of the cross-check triangle.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, w, b=None, *, pad=1, stride=1):
    """Reference conv: x [C,H,W], w [K,C,KH,KW], b [K] -> [K,H',W'].

    Cross-correlation (CNN convention), symmetric zero padding.
    """
    out = lax.conv_general_dilated(
        x[None],  # [1,C,H,W]
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    if b is not None:
        out = out + b[:, None, None]
    return out


def relu_ref(x):
    """ReLU oracle."""
    return jnp.maximum(x, 0.0)


def maxpool2x2_ref(x):
    """2x2 stride-2 max pooling oracle: x [C,H,W] -> [C,H//2,W//2]."""
    c, h, w = x.shape
    x = x[:, : h - h % 2, : w - w % 2]
    return x.reshape(c, h // 2, 2, w // 2, 2).max(axis=(2, 4))
