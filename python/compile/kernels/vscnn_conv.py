"""L1 Pallas kernel: the VSCNN column dataflow.

The paper's PE array consumes one 1-D input *column* vector and one 1-D
weight *column* vector per cycle and reduces their products diagonally into
one partial output column (Fig 4/5). On TPU we keep that column-centric
schedule but batch it MXU-shaped (DESIGN.md §Hardware-Adaptation):

* grid = (output-channel tiles, output columns) — one grid step produces
  one full output column for one tile of filters, mirroring "one output
  column per cycle per array";
* the three input columns feeding output column ``o`` are staged in VMEM
  (the ASIC's input SRAM) and unfolded into an ``[H, C*KH*KW]`` patch
  matrix — the 1-D broadcast + diagonal accumulation becomes one rank-2
  matmul against the ``[KT, C*KH*KW]`` weight tile, which is exactly the
  systolic-array-friendly form of the same reduction;
* zero-vector skipping is a *compile-time* property here: vector-pruned
  weight tiles multiply by zero columns, and XLA's sparsity comes from the
  rust coordinator scheduling (L3) — the kernel computes the dense tile the
  arrays would see after the index system has already dropped zero vectors.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO the rust runtime can run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, c_in, h, kh, kw, k_tile, col_tile):
    """One grid step: `col_tile` output columns for one tile of filters.

    x_ref: [C, H+kh-1, W+kw-1] padded input (whole plane staged; the TPU
           version would DMA only the halo window per step).
    w_ref: [KT, C, KH, KW] weight tile.
    o_ref: [KT, H, col_tile] output column block.

    col_tile > 1 is the MXU row-fill optimization (EXPERIMENTS.md §Perf):
    batching CT output columns grows the matmul's row dimension from H to
    CT*H, filling the 128-row systolic tile on deep layers where H < 128.
    """
    o = pl.program_id(1)
    # The col_tile+kw-1 input columns feeding this block of output columns.
    cols = x_ref[:, :, pl.dslice(o * col_tile, col_tile + kw - 1)]
    # Unfold row shifts and column offsets:
    # patches[t, hh, c, i, j] = cols[c, hh+i, t+j].
    shifts = [
        cols[:, i : i + h, t : t + kw]  # [C, H, kw]
        for t in range(col_tile)
        for i in range(kh)
    ]
    patches = jnp.stack(shifts, axis=0).reshape(col_tile, kh, c_in, h, kw)
    patches = patches.transpose(0, 3, 2, 1, 4).reshape(col_tile * h, c_in * kh * kw)
    wmat = w_ref[...].reshape(k_tile, c_in * kh * kw)
    # The diagonal reduction of the PE array, batched: one MXU matmul with
    # col_tile*H rows.
    out = jnp.dot(patches, wmat.T, preferred_element_type=jnp.float32)
    # [CT*H, KT] -> [KT, H, CT]
    o_ref[...] = out.reshape(col_tile, h, k_tile).transpose(2, 1, 0)


def vscnn_conv(x, w, *, pad=1, k_tile=None, col_tile=1, interpret=True):
    """VSCNN column-dataflow convolution via Pallas.

    x: [C, H, W] float32, w: [K, C, KH, KW] float32; stride 1 (the paper's
    optimized case). Returns [K, H_out, W_out].

    col_tile batches output columns per grid step (1 mirrors the paper's
    one-column-per-cycle dataflow; 4-8 fills the MXU rows on deep layers).
    """
    c_in, height, width = x.shape
    k_out, wc, kh, kw = w.shape
    assert wc == c_in, f"channel mismatch {wc} vs {c_in}"
    h_out = height + 2 * pad - kh + 1
    w_out = width + 2 * pad - kw + 1
    assert h_out > 0 and w_out > 0, "kernel larger than padded input"

    if k_tile is None:
        k_tile = min(k_out, 128)
    assert col_tile >= 1
    # Pad K up to a multiple of k_tile with zero filters, dropped at the end.
    k_pad = (-k_out) % k_tile
    if k_pad:
        w = jnp.concatenate([w, jnp.zeros((k_pad, c_in, kh, kw), w.dtype)], axis=0)
    k_total = k_out + k_pad
    # Pad W_out up to a multiple of col_tile; extra columns read zero
    # padding and are cropped at the end.
    w_pad = (-w_out) % col_tile
    w_total = w_out + w_pad

    # Stage the zero padding once so every grid step slices statically-sized
    # windows (the ASIC's boundary columns OB0/OB6 fall out of the padding).
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad + w_pad)))
    # The padded plane must cover h_out + kh - 1 rows and w_total + kw - 1 cols.
    xp = xp[:, : h_out + kh - 1, : w_total + kw - 1]

    kernel = functools.partial(
        _kernel, c_in=c_in, h=h_out, kh=kh, kw=kw, k_tile=k_tile, col_tile=col_tile
    )
    out = pl.pallas_call(
        kernel,
        grid=(k_total // k_tile, w_total // col_tile),
        in_specs=[
            # Whole padded input resident per step (VMEM budget documented
            # in DESIGN.md; a real-TPU variant would use a halo window).
            pl.BlockSpec(
                (c_in, h_out + kh - 1, w_total + kw - 1), lambda kt, o: (0, 0, 0)
            ),
            pl.BlockSpec((k_tile, c_in, kh, kw), lambda kt, o: (kt, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((k_tile, h_out, col_tile), lambda kt, o: (kt, 0, o)),
        out_shape=jax.ShapeDtypeStruct((k_total, h_out, w_total), jnp.float32),
        interpret=interpret,
    )(xp, w)
    return out[:k_out, :, :w_out]
