# L1: Pallas kernels for the VSCNN column dataflow (build-time only; the
# lowered HLO is executed from rust via PJRT, never this package).
from .ref import conv2d_ref, maxpool2x2_ref, relu_ref  # noqa: F401
from .vscnn_conv import vscnn_conv  # noqa: F401
