"""AOT bridge: lower the L2/L1 functions to HLO *text* artifacts.

Run once at build time (`make artifacts`); rust loads the text via
`HloModuleProto::from_text_file` and executes on the PJRT CPU client.

HLO text — NOT `lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()`
— is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published `xla` crate binds)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifacts (per conv shape bucket of the requested network/resolution):
  vscnn_cC_hH_wW_kK.hlo.txt  — the Pallas column-dataflow kernel + bias
  ref_cC_hH_wW_kK.hlo.txt    — the lax.conv reference (fast functional path)
plus manifest.json describing every artifact's shapes for the rust loader.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import conv_layer, conv_layer_ref, layer_shapes


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_conv(fn, c_in, c_out, h, w):
    """Lower a conv-layer function for one shape bucket."""
    x = jax.ShapeDtypeStruct((c_in, h, w), jnp.float32)
    wt = jax.ShapeDtypeStruct((c_out, c_in, 3, 3), jnp.float32)
    b = jax.ShapeDtypeStruct((c_out,), jnp.float32)
    return jax.jit(lambda x, wt, b: (fn(x, wt, b),)).lower(x, wt, b)


def bucket_name(kind, c_in, c_out, h, w):
    return f"{kind}_c{c_in}_h{h}_w{w}_k{c_out}"


def build(outdir, specs, quiet=False):
    """Emit artifacts for every distinct conv bucket of VGG-16.

    `specs` is a list of `(res, kinds, max_pallas_hw)` tuples; buckets are
    deduplicated across resolutions (the same `[C,H,W,K]` bucket serves any
    layer with that geometry).
    """
    os.makedirs(outdir, exist_ok=True)
    manifest = {"network": "vgg16", "artifacts": []}
    emitted = set()
    for res, kinds, max_hw in specs:
        buckets = []
        seen = set()
        for _name, c_in, c_out, h, w in layer_shapes(res):
            key = (c_in, c_out, h, w)
            if key not in seen:
                seen.add(key)
                buckets.append(key)

        for c_in, c_out, h, w in buckets:
            for kind in kinds:
                if max_hw is not None and kind == "vscnn" and h > max_hw:
                    # Pallas-interpret HLO for very large planes is slow to
                    # run; the functional path uses `ref` there. The kernel
                    # itself is still validated at these shapes by pytest
                    # (in-process, no HLO detour).
                    continue
                name = bucket_name(kind, c_in, c_out, h, w)
                if name in emitted:
                    continue
                emitted.add(name)
                fn = conv_layer_ref if kind == "ref" else conv_layer
                path = os.path.join(outdir, f"{name}.hlo.txt")
                lowered = lower_conv(fn, c_in, c_out, h, w)
                text = to_hlo_text(lowered)
                with open(path, "w") as f:
                    f.write(text)
                manifest["artifacts"].append(
                    {
                        "name": name,
                        "kind": kind,
                        "file": f"{name}.hlo.txt",
                        "c_in": c_in,
                        "c_out": c_out,
                        "h": h,
                        "w": w,
                        "pad": 1,
                        "stride": 1,
                    }
                )
                if not quiet:
                    print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if not quiet:
        print(f"wrote {outdir}/manifest.json ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--res",
        type=int,
        default=64,
        help="resolution for the ref+pallas validation buckets (multiple of 32)",
    )
    ap.add_argument(
        "--full-res",
        type=int,
        default=224,
        help="resolution for the ref-only full-network buckets (0 disables)",
    )
    ap.add_argument(
        "--max-pallas-hw",
        type=int,
        default=64,
        help="emit the pallas-kernel artifact only for planes up to this size",
    )
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    specs = [(args.res, ("ref", "vscnn"), args.max_pallas_hw)]
    if args.full_res:
        specs.append((args.full_res, ("ref",), None))
    build(args.outdir, specs, quiet=args.quiet)


if __name__ == "__main__":
    main()
