//! Quickstart: simulate one vector-pruned conv layer on the VSCNN
//! accelerator and print the paper's key numbers for it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use vscnn::baselines::ideal_speedups;
use vscnn::pruning::{prune_vectors, VectorGranularity};
use vscnn::sim::config::SimConfig;
use vscnn::sim::scheduler::{simulate_layer, Mode};
use vscnn::sim::trace::Trace;
use vscnn::sparse::encode::layer_report;
use vscnn::tensor::conv::{conv2d, ConvSpec};
use vscnn::tensor::Tensor;
use vscnn::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    // A conv3_2-sized VGG layer: 256 -> 256 channels at 56x56.
    let (c_in, k_out, hw) = (64usize, 64usize, 56usize);
    let mut rng = Pcg32::seeded(42);

    // ReLU-sparse input activations (~40% density) ...
    let mut input = vscnn::model::init::synthetic_image([c_in, hw, hw], 42);
    for x in input.data_mut() {
        *x = (*x - 0.25).max(0.0);
    }
    // ... and weights vector-pruned to the paper's 23.5% density.
    let n = k_out * c_in * 9;
    let mut weight = Tensor::from_vec(
        &[k_out, c_in, 3, 3],
        (0..n).map(|_| rng.normal() * 0.05).collect(),
    );
    prune_vectors(&mut weight, 0.235, VectorGranularity::KernelRow);

    // Simulate on the paper's [8,7,3] configuration (168 PEs).
    let cfg = SimConfig::paper_8_7_3();
    let spec = ConvSpec::default();
    let mut trace = Trace::disabled();
    let res = simulate_layer(
        &input, &weight, None, &cfg, spec, Mode::VectorSparse, true, &mut trace,
    );

    // The dataflow's functional output equals a plain convolution.
    let golden = conv2d(&input, &weight, None, spec);
    let out = res.output.as_ref().unwrap();
    assert!(golden.allclose(out, 1e-3, 1e-3), "dataflow must match conv");

    let report = layer_report(&input, &weight, spec, cfg.pe.rows);
    let (ideal_vec, ideal_fine) = ideal_speedups(&report);
    let speedup = res.dense_cycles as f64 / res.stats.cycles as f64;

    println!("VSCNN quickstart — one conv layer on {} (168 PEs)", cfg.pe.label());
    println!("  input  density: {:.3} elem | {:.3} vector (R={})", report.input_elem, report.input_vec, cfg.pe.rows);
    println!("  weight density: {:.3} elem | {:.3} vector (kernel cols)", report.weight_elem, report.weight_vec);
    println!("  dense cycles : {}", res.dense_cycles);
    println!("  sparse cycles: {} ({} pairs issued, {} skipped)", res.stats.cycles, res.stats.issued_pairs, res.stats.skipped_pairs());
    println!("  speedup      : {speedup:.3}x  (ideal vector {ideal_vec:.3}x, ideal fine {ideal_fine:.3}x)");
    println!("  utilization  : {:.1}%", 100.0 * res.stats.utilization());
    println!("  functional   : matches golden conv ✓");
    Ok(())
}
