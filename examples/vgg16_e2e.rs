//! End-to-end driver (EXPERIMENTS.md §E2E): the full three-layer stack on
//! the paper's workload.
//!
//! Runs vector-pruned, activation-calibrated VGG-16 inference over a batch
//! of synthetic images through BOTH paper PE configurations, with the
//! functional forward executed by the **PJRT runtime** (JAX/Pallas-lowered
//! HLO artifacts — L2/L1) when artifacts matching the resolution exist,
//! falling back to the rust conv otherwise; the cycle-level model (L3)
//! produces every per-layer figure series plus the headline speedups, and
//! cross-checks PJRT numerics against the rust golden conv on layer 1.
//!
//! ```bash
//! make artifacts && cargo run --release --example vgg16_e2e -- [res] [images]
//! # res must be a multiple of 32; artifacts ship ref buckets for 64 & 224
//! ```

use std::sync::Arc;
use vscnn::coordinator::{FunctionalBackend, RunOptions};
use vscnn::experiments::{workload, ExpContext};
use vscnn::runtime::Runtime;
use vscnn::sim::config::SimConfig;
use vscnn::util::stats::mean;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let res: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let images: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let ctx = ExpContext {
        res,
        images,
        ..Default::default()
    };

    println!("== VSCNN end-to-end: VGG-16 @ {res}x{res}, {images} image(s) ==");
    let t_setup = std::time::Instant::now();
    let (coord, batch, weight_density) = workload::prepare(&ctx)?;
    println!(
        "workload: 13 conv layers, {:.1} GMAC dense, weight density {:.3} (paper 0.235), setup {:?}",
        coord.net.total_conv_macs() as f64 / 1e9,
        weight_density,
        t_setup.elapsed()
    );

    // Prefer the PJRT/HLO functional path (the real three-layer stack).
    let pjrt = match Runtime::new("artifacts") {
        Ok(rt) if rt.manifest().find("ref", 3, 64, res, res).is_some() => {
            println!("functional path: PJRT ({} artifacts, platform {})",
                rt.manifest().artifacts.len(), rt.platform());
            Some(Arc::new(rt))
        }
        Ok(_) => {
            println!("functional path: rust im2col (no ref buckets at res {res}; re-run `make artifacts`)");
            None
        }
        Err(e) => {
            println!("functional path: rust im2col (PJRT unavailable: {e})");
            None
        }
    };

    for sim in [SimConfig::paper_4_14_3(), SimConfig::paper_8_7_3()] {
        let mut opts = RunOptions::new(sim);
        if let Some(rt) = &pjrt {
            opts.backend = FunctionalBackend::Pjrt(rt.clone(), "ref".to_string());
        }
        let t0 = std::time::Instant::now();
        let reports = coord.run_batch(&batch, &opts)?;
        let wall = t0.elapsed();

        let speedups: Vec<f64> = reports.iter().map(|r| r.overall_speedup()).collect();
        let series = reports[0].overall_series();
        println!("\n-- config {} --", sim.pe.label());
        println!("per-layer (image 0):");
        println!(
            "{}",
            vscnn::coordinator::report::ascii_table(
                &reports[0]
                    .layers
                    .iter()
                    .map(|l| (
                        l.name.clone(),
                        vec![
                            ("speedup".to_string(), l.speedups.ours),
                            ("ideal_vec".to_string(), l.speedups.ideal_vector),
                            ("ideal_fine".to_string(), l.speedups.ideal_fine),
                            ("util".to_string(), l.sparse.utilization()),
                        ],
                    ))
                    .collect::<Vec<_>>()
            )
        );
        println!(
            "overall speedup {:.3}x (batch mean {:.3}x) | ideal vec {:.3}x | vector-skip eff {:.1}% | dram {:.1} MB | wall {:?}",
            series.ours,
            mean(&speedups),
            series.ideal_vector,
            100.0 * series.vector_skip_efficiency(),
            reports[0].totals.dram.total() as f64 / 1e6,
            wall,
        );

        // Persist the e2e record.
        std::fs::create_dir_all("reports")?;
        let path = format!("reports/e2e_{}_res{res}.json", sim.pe.label().replace(['[', ']', ','], "_"));
        std::fs::write(&path, reports[0].to_json().pretty())?;
        println!("wrote {path}");
    }

    println!("\npaper reference: 1.871x [4,14,3], 1.93x [8,7,3] on ImageNet-trained VGG-16");
    Ok(())
}
