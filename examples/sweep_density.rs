//! Ablation sweep: how speedup scales with weight density, vector length R
//! and pruning granularity — the design-space exploration behind the
//! paper's §IV observations ("small zero vector enables more zero
//! skipping") and DESIGN.md's granularity-mismatch analysis.
//!
//! ```bash
//! cargo run --release --example sweep_density
//! ```

use vscnn::coordinator::{Coordinator, FunctionalBackend, RunOptions};
use vscnn::model::init::{synthetic_image, synthetic_params};
use vscnn::model::vgg16::vgg16_at;
use vscnn::pruning::{self, sensitivity::flat_schedule, VectorGranularity};
use vscnn::sim::config::SimConfig;

fn run_case(
    res: usize,
    density: f64,
    gran: VectorGranularity,
    arrays: usize,
    rows: usize,
) -> anyhow::Result<f64> {
    let net = vgg16_at(res);
    let mut params = synthetic_params(&net, 11, 0.0);
    pruning::prune_network_vectors_with(&mut params, &flat_schedule(&net, density), gran);
    let cal = synthetic_image(net.input_shape, 12);
    vscnn::model::calibrate::calibrate_activations(&net, &mut params, &cal, 1.0, 4);
    let img = synthetic_image(net.input_shape, 13);
    let mut cfg = SimConfig::paper_4_14_3();
    cfg.pe.arrays = arrays;
    cfg.pe.rows = rows;
    let coord = Coordinator::new(net, params);
    let opts = RunOptions {
        sim: cfg,
        backend: FunctionalBackend::Im2colMt(vscnn::util::default_threads()),
        verify_dataflow: false,
    };
    Ok(coord.run(&img, &opts)?.overall_speedup())
}

fn main() -> anyhow::Result<()> {
    let res: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);

    println!("== sweep 1: weight density (paper granularity, [8,7,3]) ==");
    println!("{:>8} | {:>9}", "density", "speedup");
    for density in [0.1, 0.235, 0.4, 0.6, 0.8, 1.0] {
        let s = run_case(res, density, VectorGranularity::KernelRow, 8, 7)?;
        println!("{density:>8.3} | {s:>8.3}x");
    }

    println!("\n== sweep 2: pruning granularity at density 0.235 ([8,7,3]) ==");
    for (label, gran) in [
        ("kernel rows (Mao [18], paper)", VectorGranularity::KernelRow),
        ("kernel cols (hardware-aligned)", VectorGranularity::KernelCol),
    ] {
        let s = run_case(res, 0.235, gran, 8, 7)?;
        println!("{label:>32} | {s:>8.3}x");
    }

    println!("\n== sweep 3: vector length R at 168 PEs, density 0.235 ==");
    println!("{:>12} | {:>9}", "config", "speedup");
    for (arrays, rows) in [(2usize, 28usize), (4, 14), (8, 7), (14, 4), (28, 2)] {
        let s = run_case(res, 0.235, VectorGranularity::KernelRow, arrays, rows)?;
        println!("[{arrays},{rows},3]{:>4} | {s:>8.3}x", "");
    }
    println!("\n(paper: [8,7,3] 1.93x > [4,14,3] 1.871x — smaller vectors skip more,\n wider groups pay more sync; the sweep shows both forces.)");
    Ok(())
}
