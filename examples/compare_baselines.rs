//! Baseline shoot-out on one workload: dense flow, VSCNN, the two ideal
//! machines, and the SCNN-like fine-grained comparator — the §IV
//! comparison as one table, plus area-normalized efficiency.
//!
//! ```bash
//! cargo run --release --example compare_baselines
//! ```

use vscnn::baselines::scnn_like::{vscnn_speedup_per_area, ScnnModel};
use vscnn::coordinator::RunOptions;
use vscnn::experiments::{workload, ExpContext};
use vscnn::sim::config::SimConfig;

fn main() -> anyhow::Result<()> {
    let res: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    let ctx = ExpContext {
        res,
        ..Default::default()
    };
    let (coord, images, _) = workload::prepare(&ctx)?;

    println!("VGG-16 @ {res} | vector-pruned 23.5% | one synthetic image\n");
    println!(
        "{:<26} | {:>9} | {:>12} | {:>14}",
        "design", "speedup", "vs ideal", "speedup/area"
    );
    println!("{}", "-".repeat(72));

    for sim in [SimConfig::paper_4_14_3(), SimConfig::paper_8_7_3()] {
        let report = coord.run(&images[0], &RunOptions::new(sim))?;
        let series = report.overall_series();

        if sim.pe.arrays == 4 {
            println!("{:<26} | {:>8.3}x | {:>12} | {:>14}", "dense (same array)", 1.0, "-", "1.000x");
        }
        println!(
            "{:<26} | {:>8.3}x | {:>11.1}% | {:>13.3}x",
            format!("VSCNN {}", sim.pe.label()),
            series.ours,
            100.0 * series.vector_skip_efficiency(),
            vscnn_speedup_per_area(series.ours),
        );
        if sim.pe.arrays == 8 {
            // Ideal machines and SCNN on the same aggregate work profile.
            let mut macs_t = 0u64;
            let mut macs_nz = 0u64;
            let mut pairs_t = 0u64;
            let mut pairs_nz = 0u64;
            for l in &report.layers {
                macs_t += l.density.macs_total;
                macs_nz += l.density.macs_nonzero;
                pairs_t += l.density.pairs_total;
                pairs_nz += l.density.pairs_nonzero;
            }
            let agg = vscnn::sparse::encode::DensityReport {
                input_elem: 0.0,
                weight_elem: 0.0,
                work_elem: macs_nz as f64 / macs_t as f64,
                input_vec: 0.0,
                weight_vec: 0.0,
                work_vec: pairs_nz as f64 / pairs_t as f64,
                macs_total: macs_t,
                macs_nonzero: macs_nz,
                pairs_total: pairs_t,
                pairs_nonzero: pairs_nz,
            };
            let scnn = ScnnModel::default();
            println!(
                "{:<26} | {:>8.3}x | {:>11.1}% | {:>13.3}x",
                "SCNN-like [16] (66% eff)",
                scnn.speedup(&agg),
                100.0 * scnn.skip_efficiency,
                scnn.speedup_per_area(&agg),
            );
            println!(
                "{:<26} | {:>8.3}x | {:>12} | {:>14}",
                "ideal vector-sparse",
                pairs_t as f64 / pairs_nz.max(1) as f64,
                "100.0%",
                "-"
            );
            println!(
                "{:<26} | {:>8.3}x | {:>12} | {:>14}",
                "ideal fine-grained",
                macs_t as f64 / macs_nz.max(1) as f64,
                "100.0%",
                "-"
            );
        }
    }
    println!(
        "\npaper §IV: VSCNN 1.93x with ~5% index-area overhead vs SCNN ~3x with\n\
         ~30% index/crossbar overhead — \"more hardware efficient than the\n\
         previous design\" on speedup-per-area."
    );
    Ok(())
}
