//! Data-integrity property fuzz (ISSUE 10): encode → mutate → decode
//! must *detect* the corruption or leave only a bounded payload error —
//! and must never panic. Exercises the typed [`CvfError`] validation
//! walk, the payload stream checksum, and the ABFT column checksums on
//! the matmul panel kernel, all with seeded [`Pcg32`] streams so every
//! "random" case is reproducible.

use vscnn::sim::config::Precision;
use vscnn::sim::sdc::abft_unit_round;
use vscnn::sparse::vector_format::{VectorActivations, VectorWeights};
use vscnn::tensor::ops::{abft_check, matmul};
use vscnn::tensor::Tensor;
use vscnn::util::rng::Pcg32;

/// Random `[C,H,W]` activation tensor at roughly the given density.
fn rand_act(rng: &mut Pcg32, c: usize, h: usize, w: usize, density: f64) -> Tensor {
    let n = c * h * w;
    let data = (0..n)
        .map(|_| {
            if rng.bernoulli(density) {
                rng.normal()
            } else {
                0.0
            }
        })
        .collect();
    Tensor::from_vec(&[c, h, w], data)
}

/// Random `[K,C,Kh,Kw]` weight tensor at roughly the given density.
fn rand_weight(rng: &mut Pcg32, k: usize, c: usize, ks: usize, density: f64) -> Tensor {
    let n = k * c * ks * ks;
    let data = (0..n)
        .map(|_| {
            if rng.bernoulli(density) {
                rng.normal()
            } else {
                0.0
            }
        })
        .collect();
    Tensor::from_vec(&[k, c, ks, ks], data)
}

/// Precision-aware stream-checksum floor, the same shape the engine
/// charges: `(words + 2) * unit_round * (abs_sum + 1)`.
fn checksum_floor(words: usize, clean_abs: f64) -> f64 {
    (words as f64 + 2.0) * abft_unit_round(Precision::F32) * (clean_abs + 1.0)
}

#[test]
fn activation_index_and_offset_flips_are_always_detected() {
    // Index words are cross-checked against the occupancy bitset
    // (bounds, strict monotonicity, popcount equality), so *every*
    // single-bit index or offset upset must surface as a CvfError.
    let mut rng = Pcg32::seeded(0x1D10);
    let mut cases = 0;
    while cases < 30 {
        let c = 1 + rng.below(3) as usize;
        let h = 4 + rng.below(9) as usize;
        let w = 4 + rng.below(9) as usize;
        let r = [4usize, 7][rng.below(2) as usize];
        let t = rand_act(&mut rng, c, h, w, 0.4);
        let clean = VectorActivations::from_tensor(&t, r);
        clean.validate().expect("clean encode validates");
        if clean.index_words() == 0 {
            continue;
        }
        cases += 1;

        let mut va = clean.clone();
        va.flip_index_bit(rng.below(va.index_words() as u32) as usize, rng.below(16));
        assert!(
            va.validate().is_err(),
            "index flip slipped past validation (case {cases})"
        );

        // Offsets: any bit of any offset word, including the sentinel.
        let mut vo = clean.clone();
        let groups = c * clean.strips + 1;
        vo.flip_offset_bit(rng.below(groups as u32) as usize, rng.below(32));
        assert!(
            vo.validate().is_err(),
            "offset flip slipped past validation (case {cases})"
        );
    }
}

#[test]
fn activation_payload_flips_detected_or_bounded_never_panic() {
    // A payload upset has no structural witness: detection is the
    // non-finite walk plus the stream checksum. Whatever a flip does, it
    // must either trip one of those or perturb the stream by less than
    // the precision floor — and the accessors must stay walkable.
    let mut rng = Pcg32::seeded(0x1D11);
    let (mut detected, mut bounded) = (0u32, 0u32);
    let mut cases = 0;
    while cases < 40 {
        let c = 1 + rng.below(3) as usize;
        let h = 4 + rng.below(9) as usize;
        let w = 4 + rng.below(9) as usize;
        let r = [4usize, 7][rng.below(2) as usize];
        let t = rand_act(&mut rng, c, h, w, 0.4);
        let clean = VectorActivations::from_tensor(&t, r);
        if clean.payload_words() == 0 {
            continue;
        }
        cases += 1;
        let (clean_sum, clean_abs) = clean.payload_checksum();

        let mut va = clean.clone();
        va.flip_payload_bit(rng.below(va.payload_words() as u32) as usize, rng.below(32));
        let (sum, _) = va.payload_checksum();
        let delta = (sum - clean_sum).abs();
        let floor = checksum_floor(va.payload_words(), clean_abs);
        let caught = va.validate().is_err() || delta.is_nan() || delta > floor;
        if caught {
            detected += 1;
        } else {
            // Undetected ⇒ the corruption is smaller than one rounding
            // unit of the whole stream: bounded blast radius.
            assert!(delta <= floor, "case {cases}: unbounded escape {delta}");
            bounded += 1;
        }
        // Structurally valid or not, the group walks must never panic.
        for ch in 0..c {
            for s in 0..va.strips {
                let _ = va.nz_cols(ch, s);
            }
        }
    }
    assert_eq!(detected + bounded, 40);
    // A uniform 32-bit flip often lands in low mantissa bits (or on a
    // zero-padded lane) where the perturbation is sub-floor by
    // construction; both verdicts must occur across 40 cases, and
    // neither side may be empty.
    assert!(detected >= 1, "no payload flip was ever detected");
    assert!(bounded >= 1, "no payload flip ever stayed sub-floor");
}

#[test]
fn weight_cvf_flips_detected_or_bounded_never_panic() {
    let mut rng = Pcg32::seeded(0x1D12);
    let mut cases = 0;
    while cases < 30 {
        let k = 2 + rng.below(4) as usize;
        let c = 1 + rng.below(3) as usize;
        let t = rand_weight(&mut rng, k, c, 3, 0.5);
        let clean = VectorWeights::from_tensor(&t);
        clean.validate().expect("clean weight encode validates");
        if clean.index_words() == 0 || clean.payload_words() == 0 {
            continue;
        }
        cases += 1;

        let mut wi = clean.clone();
        wi.flip_index_bit(rng.below(wi.index_words() as u32) as usize, rng.below(8));
        assert!(wi.validate().is_err(), "weight index flip undetected");

        let (clean_sum, clean_abs) = clean.payload_checksum();
        let mut wp = clean.clone();
        wp.flip_payload_bit(rng.below(wp.payload_words() as u32) as usize, rng.below(32));
        let (sum, _) = wp.payload_checksum();
        let delta = (sum - clean_sum).abs();
        let floor = checksum_floor(wp.payload_words(), clean_abs);
        if wp.validate().is_ok() && !delta.is_nan() && delta <= floor {
            // Escaped the checksum: must be sub-rounding-unit noise, and
            // the payload accessors must still walk cleanly.
            for kk in 0..k {
                for cc in 0..c {
                    let _ = wp.nz_vals(kk, cc);
                }
            }
        }
    }
}

#[test]
fn multi_flip_storms_never_panic_and_rarely_escape() {
    // Three simultaneous upsets of random kinds on one encode: harder to
    // mask than a single flip, and the validator must stay total.
    let mut rng = Pcg32::seeded(0x1D13);
    let mut detected = 0u32;
    for case in 0..20 {
        let t = rand_act(&mut rng, 2, 10, 10, 0.4);
        let clean = VectorActivations::from_tensor(&t, 4);
        if clean.index_words() == 0 || clean.payload_words() == 0 {
            continue;
        }
        let (clean_sum, clean_abs) = clean.payload_checksum();
        let mut va = clean.clone();
        for _ in 0..3 {
            match rng.below(3) {
                0 => va.flip_index_bit(
                    rng.below(va.index_words() as u32) as usize,
                    rng.below(16),
                ),
                1 => va.flip_payload_bit(
                    rng.below(va.payload_words() as u32) as usize,
                    rng.below(32),
                ),
                _ => {
                    let groups = va.c * va.strips + 1;
                    va.flip_offset_bit(rng.below(groups as u32) as usize, rng.below(32));
                }
            }
        }
        let (sum, _) = va.payload_checksum();
        let delta = (sum - clean_sum).abs();
        let floor = checksum_floor(clean.payload_words(), clean_abs);
        if va.validate().is_err() || delta.is_nan() || delta > floor {
            detected += 1;
        } else {
            assert!(delta <= floor, "storm case {case}: unbounded escape");
        }
    }
    // At least one of the three flips lands on structure in almost every
    // storm; demand a strong majority without betting on every tail.
    assert!(detected >= 15, "only {detected}/20 storms detected");
}

#[test]
fn abft_checksums_catch_gross_corruption_and_pass_rounding_noise() {
    let mut rng = Pcg32::seeded(0x1D14);
    let unit = abft_unit_round(Precision::F32);
    for case in 0..10 {
        let (m, k, n) = (
            2 + rng.below(6) as usize,
            3 + rng.below(10) as usize,
            2 + rng.below(8) as usize,
        );
        let a = Tensor::from_vec(&[m, k], (0..m * k).map(|_| rng.normal()).collect());
        let b = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.normal()).collect());
        let out = matmul(&a, &b);

        // Clean product passes within the precision budget.
        abft_check(a.data(), b.data(), out.data(), m, k, n, None, unit)
            .unwrap_or_else(|f| panic!("case {case}: clean product flagged: {f:?}"));

        // A gross single-element upset (far above any rounding budget)
        // must be flagged, and on the right column.
        let mut bad = out.clone();
        let word = rng.below((m * n) as u32) as usize;
        bad.data_mut()[word] += 64.0;
        let fault = abft_check(a.data(), b.data(), bad.data(), m, k, n, None, unit)
            .expect_err("gross corruption slipped past ABFT");
        assert_eq!(fault.col, word % n, "case {case}: wrong column blamed");
        assert!(fault.delta > fault.budget);

        // A NaN anywhere in the product is a violation, not a false pass.
        let mut nan = out.clone();
        nan.data_mut()[word] = f32::NAN;
        abft_check(a.data(), b.data(), nan.data(), m, k, n, None, unit)
            .expect_err("NaN output slipped past ABFT");
    }
}

#[test]
fn index_only_encodes_validate_without_payload_rules() {
    // index_only encodes carry no payload stream; validation must apply
    // the structural rules and skip the payload ones (not reject the
    // empty payload as a size mismatch).
    let mut rng = Pcg32::seeded(0x1D15);
    for _ in 0..5 {
        let t = rand_act(&mut rng, 2, 8, 8, 0.4);
        let va = VectorActivations::index_only(&t, 4);
        assert_eq!(va.payload_words(), 0);
        va.validate().expect("index-only encode validates");
        let vw = VectorWeights::index_only(&rand_weight(&mut rng, 3, 2, 3, 0.5));
        vw.validate().expect("index-only weights validate");
    }
}
