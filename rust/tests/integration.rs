//! Cross-module integration: pruning → calibration → coordinator →
//! experiments, on CPU backends (no artifacts needed).

use vscnn::baselines::{ideal_speedups, skip_efficiency};
use vscnn::coordinator::{Coordinator, FunctionalBackend, RunOptions};
use vscnn::experiments::{self, ExpContext};
use vscnn::model::init::{synthetic_batch, synthetic_params};
use vscnn::model::vgg16::{tiny_vgg, vgg16_at};
use vscnn::pruning;
use vscnn::pruning::sensitivity::{flat_schedule, paper_schedule};
use vscnn::sim::config::SimConfig;
use vscnn::sim::scheduler::{simulate_layer, Mode};
use vscnn::sim::trace::Trace;
use vscnn::tensor::conv::ConvSpec;

fn tiny_ctx() -> ExpContext {
    ExpContext {
        res: 32,
        images: 2,
        ..Default::default()
    }
}

#[test]
fn all_experiments_run_and_report() {
    let ctx = tiny_ctx();
    let outputs = experiments::run_all(&ctx).expect("run_all");
    assert_eq!(outputs.len(), experiments::list().len());
    for out in &outputs {
        assert!(!out.text.is_empty(), "{} text empty", out.id);
        // JSON round-trips.
        let text = out.json.pretty();
        assert_eq!(
            vscnn::util::json::Json::parse(&text).unwrap(),
            out.json,
            "{} json",
            out.id
        );
    }
}

#[test]
fn whole_network_speedup_consistent_with_layer_records() {
    let ctx = tiny_ctx();
    let reports =
        experiments::workload::run_config(&ctx, SimConfig::paper_4_14_3()).expect("run");
    for report in &reports {
        let sum_cycles: u64 = report.layers.iter().map(|l| l.sparse.cycles).sum();
        let sum_dense: u64 = report.layers.iter().map(|l| l.dense_cycles).sum();
        assert_eq!(sum_cycles, report.totals.cycles);
        assert_eq!(sum_dense, report.total_dense_cycles);
        let series = report.overall_series();
        assert!(series.ours <= series.ideal_vector + 1e-6);
        assert!(series.vector_skip_efficiency() <= 1.0 + 1e-9);
        assert!(skip_efficiency(series.ours, series.ideal_fine) <= 1.0 + 1e-9);
    }
}

#[test]
fn multi_image_batch_varies_but_stays_in_band() {
    let ctx = ExpContext {
        res: 32,
        images: 3,
        ..Default::default()
    };
    let reports = experiments::workload::run_config(&ctx, SimConfig::paper_8_7_3()).unwrap();
    assert_eq!(reports.len(), 3);
    let speedups: Vec<f64> = reports.iter().map(|r| r.overall_speedup()).collect();
    for s in &speedups {
        assert!(*s > 1.0 && *s < 50.0, "speedup {s}");
    }
    // Different images → (almost surely) different cycle counts.
    assert!(
        reports[0].totals.cycles != reports[1].totals.cycles
            || reports[1].totals.cycles != reports[2].totals.cycles
    );
}

#[test]
fn hardware_aligned_pruning_ablation_beats_row_pruning() {
    // DESIGN.md §4 ablation: pruning at the hardware's kernel-column
    // granularity exposes every pruned vector to the skipper; Mao row
    // pruning at the same element density leaves columns denser
    // (1-(1-d)^3) and must be slower.
    let net = tiny_vgg(8);
    let img = vscnn::model::init::synthetic_image(net.input_shape, 5);
    let mut cfg = SimConfig::paper_4_14_3();
    cfg.pe.arrays = 2;
    cfg.pe.rows = 4;
    let opts = RunOptions {
        sim: cfg,
        backend: FunctionalBackend::Golden,
        verify_dataflow: false,
        fuse: false,
        sdc: None,
    };
    let sched = flat_schedule(&net, 0.25);

    let mut cycles = Vec::new();
    for gran in [
        pruning::VectorGranularity::KernelCol,
        pruning::VectorGranularity::KernelRow,
    ] {
        let mut params = synthetic_params(&net, 5, 0.0);
        pruning::prune_network_vectors_with(&mut params, &sched, gran);
        let coord = Coordinator::new(net.clone(), params);
        cycles.push(coord.run(&img, &opts).unwrap().totals.cycles);
    }
    assert!(
        cycles[0] < cycles[1],
        "aligned {} !< row {}",
        cycles[0],
        cycles[1]
    );
}

#[test]
fn dense_mode_is_exact_dense_reference() {
    // Simulating in Dense mode must cost exactly the closed-form dense
    // cycles and produce the same functional output as sparse mode.
    let net = tiny_vgg(8);
    let mut params = synthetic_params(&net, 6, 0.0);
    pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.3));
    let img = vscnn::model::init::synthetic_image(net.input_shape, 6);
    let mut cfg = SimConfig::paper_4_14_3();
    cfg.pe.arrays = 2;
    cfg.pe.rows = 4;

    let lp = &params["c1_1"];
    let mut tr = Trace::disabled();
    let dense = simulate_layer(
        &img,
        &lp.weight,
        Some(&lp.bias),
        &cfg,
        ConvSpec::default(),
        Mode::Dense,
        true,
        &mut tr,
    );
    let sparse = simulate_layer(
        &img,
        &lp.weight,
        Some(&lp.bias),
        &cfg,
        ConvSpec::default(),
        Mode::VectorSparse,
        true,
        &mut tr,
    );
    assert_eq!(dense.stats.cycles, dense.dense_cycles);
    assert_eq!(dense.dense_cycles, sparse.dense_cycles);
    let (a, b) = (dense.output.unwrap(), sparse.output.unwrap());
    assert!(a.allclose(&b, 1e-4, 1e-4), "diff {}", a.max_abs_diff(&b));
}

#[test]
fn ideal_baselines_bracket_reality_on_vgg_slice() {
    // On a real VGG-16 slice: ours <= ideal_vector <= ideal_fine.
    // Pure-compute comparison: the analytic (unfloored) ideal machines
    // bracket the compute cycle model, so this runs under MemModel::Ideal
    // (the tiled bracketing with transfer floors is covered by
    // engine::execute tests and tests/memory_model.rs).
    let ctx = tiny_ctx();
    let (coord, images, _) = experiments::workload::prepare(&ctx).unwrap();
    let mut opts = RunOptions::new(SimConfig::paper_8_7_3());
    opts.sim.mem_model = vscnn::sim::config::MemModel::Ideal;
    let report = coord.run(&images[0], &opts).unwrap();
    for l in &report.layers {
        let rep = l.density;
        let (iv, ifg) = ideal_speedups(&rep);
        assert!(l.speedups.ours <= iv + 1e-6, "{}: {} > {iv}", l.name, l.speedups.ours);
        assert!(iv <= ifg + 1e-6, "{}: vec {iv} > fine {ifg}", l.name);
    }
}

#[test]
fn activation_calibration_survives_pipeline() {
    // After workload::prepare, deep-layer activations must stay alive
    // through the actual coordinator run (not just the calibration image).
    let ctx = tiny_ctx();
    let (coord, images, _) = experiments::workload::prepare(&ctx).unwrap();
    let opts = RunOptions::new(SimConfig::paper_4_14_3());
    let report = coord.run(&images[0], &opts).unwrap();
    let last = report.layers.last().unwrap();
    assert!(
        last.output_density_elem > 0.02,
        "conv5_3 output density {} — dead activations",
        last.output_density_elem
    );
}

#[test]
fn sram_budgets_hold_for_vgg16() {
    // The paper's buffers must actually hold the working sets the
    // scheduler assumes: psum and weight-group peaks within the default
    // SRAM configuration on every VGG layer.
    let ctx = tiny_ctx();
    let (coord, images, _) = experiments::workload::prepare(&ctx).unwrap();
    for sim in [SimConfig::paper_4_14_3(), SimConfig::paper_8_7_3()] {
        let report = coord.run(&images[0], &RunOptions::new(sim)).unwrap();
        for l in &report.layers {
            assert!(
                l.sparse.sram_psum_peak <= sim.sram.psum_bytes as u64,
                "{} [{}]: psum peak {} > {}",
                l.name,
                sim.pe.label(),
                l.sparse.sram_psum_peak,
                sim.sram.psum_bytes
            );
            assert!(l.sparse.sram_input_peak <= sim.sram.input_bytes as u64);
            assert!(l.sparse.sram_weight_peak > 0);
        }
    }
}

#[test]
fn mapped_kernels_extend_the_array() {
    // §II-B extension: 1x1 and 5x5 kernels via the mapping layer produce
    // exact functional results on the same array.
    use vscnn::sim::mapping::simulate_layer_mapped;
    use vscnn::sim::scheduler::Mode;
    use vscnn::sim::trace::Trace;
    use vscnn::util::rng::Pcg32;
    let mut rng = Pcg32::seeded(99);
    let mut cfg = SimConfig::paper_4_14_3();
    cfg.pe.arrays = 2;
    cfg.pe.rows = 5;
    for (k, pad) in [(1usize, 0usize), (5, 2), (7, 3)] {
        let n = 2 * 9 * 9;
        let input = vscnn::tensor::Tensor::from_vec(
            &[2, 9, 9],
            (0..n).map(|_| rng.normal()).collect(),
        );
        let wn = 3 * 2 * k * k;
        let weight = vscnn::tensor::Tensor::from_vec(
            &[3, 2, k, k],
            (0..wn).map(|_| rng.normal()).collect(),
        );
        let spec = ConvSpec { stride: 1, pad };
        let golden = vscnn::tensor::conv::conv2d(&input, &weight, None, spec);
        let mut tr = Trace::disabled();
        let res = simulate_layer_mapped(
            &input,
            &weight,
            None,
            &cfg,
            spec,
            Mode::VectorSparse,
            true,
            &mut tr,
        );
        let out = res.output.unwrap();
        assert!(
            golden.allclose(&out, 1e-3, 1e-3),
            "k={k}: diff {}",
            golden.max_abs_diff(&out)
        );
    }
}

#[test]
fn reduced_resolution_network_is_consistent() {
    for res in [32usize, 64] {
        let net = vgg16_at(res);
        let mut params = synthetic_params(&net, 9, 0.0);
        pruning::prune_network_vectors(&mut params, &paper_schedule(&net));
        let images = synthetic_batch(net.input_shape, 1, 9);
        let coord = Coordinator::new(net, params);
        let report = coord
            .run(&images[0], &RunOptions::new(SimConfig::paper_8_7_3()))
            .unwrap();
        assert_eq!(report.layers.len(), 13);
    }
}
