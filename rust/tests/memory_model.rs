//! Tiled memory-model suite (ISSUE 3): property tests for the
//! tile/double-buffer cycle accounting across random layer geometries,
//! plus the pin that `MemModel::Ideal` reproduces the pre-refactor
//! (pure-compute) scheduler output bit-for-bit.

use vscnn::sim::config::{MemModel, SimConfig};
use vscnn::sim::mapping::simulate_layer_any;
use vscnn::sim::scheduler::{simulate_layer, Mode};
use vscnn::sim::stats::MemBound;
use vscnn::sim::trace::Trace;
use vscnn::tensor::conv::ConvSpec;
use vscnn::tensor::Tensor;
use vscnn::util::rng::Pcg32;

fn random_sparse(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(
        shape,
        (0..n)
            .map(|_| {
                if density > 0.0 && rng.bernoulli(density) {
                    rng.normal()
                } else {
                    0.0
                }
            })
            .collect(),
    )
}

/// Property (ISSUE 3 satellite): across random layer shapes, kernels and
/// strides, tiled cycles >= max(compute lower bound, transfer lower
/// bound), the dense baseline carries the same floor, and the sparse flow
/// never loses to dense.
#[test]
fn tiled_cycles_dominate_compute_and_transfer_lower_bounds() {
    let mut rng = Pcg32::seeded(0x713D);
    let kernels: [(usize, usize, usize); 4] = [(3, 1, 1), (1, 1, 0), (5, 1, 2), (3, 2, 1)];
    for case in 0..16 {
        let (k, stride, pad) = kernels[case % kernels.len()];
        let c_in = rng.range(1, 4);
        let k_out = rng.range(1, 7);
        let hw = rng.range(6, 16);
        let spec = ConvSpec { stride, pad };
        let input = random_sparse(&mut rng, &[c_in, hw, hw], 0.5);
        let weight = random_sparse(&mut rng, &[k_out, c_in, k, k], 0.5);

        let mut icfg = SimConfig::paper_4_14_3();
        icfg.pe.arrays = rng.range(1, 4);
        icfg.pe.rows = rng.range(2, 7);
        icfg.mem_model = MemModel::Ideal;
        let mut tcfg = icfg;
        tcfg.mem_model = MemModel::Tiled;
        // Starve SRAM and bandwidth so the memory terms actually bind.
        tcfg.sram.input_bytes = rng.range(64, 1024);
        tcfg.sram.weight_bytes = rng.range(64, 1024);
        tcfg.dram_bytes_per_cycle = [0.5f64, 1.0, 4.0][case % 3];

        let mut tr = Trace::disabled();
        let ideal = simulate_layer_any(
            &input,
            &weight,
            None,
            &icfg,
            spec,
            Mode::VectorSparse,
            false,
            &mut tr,
        );
        let tiled = simulate_layer_any(
            &input,
            &weight,
            None,
            &tcfg,
            spec,
            Mode::VectorSparse,
            false,
            &mut tr,
        );
        let t = &tiled.stats;
        // cycles >= max(compute, transfer); compute >= the ideal
        // (group-synced, zero-memory) count.
        assert!(t.cycles >= t.compute_cycles, "case {case}");
        assert!(t.cycles >= t.transfer_cycles, "case {case}");
        assert!(t.compute_cycles >= ideal.stats.cycles, "case {case}");
        assert!(t.tiles > 0, "case {case}");
        assert!(t.fill_cycles <= t.transfer_cycles, "case {case}");
        assert!(t.bw_utilization() <= 1.0 + 1e-12, "case {case}");

        // Same memory floor on the dense denominator, and the sparse flow
        // (compressed traffic + raw-format escape) never loses to dense.
        assert!(tiled.dense_cycles >= ideal.dense_cycles, "case {case}");
        let dense = simulate_layer_any(
            &input,
            &weight,
            None,
            &tcfg,
            spec,
            Mode::Dense,
            false,
            &mut tr,
        );
        assert_eq!(dense.stats.cycles, dense.dense_cycles, "case {case}");
        assert!(t.cycles <= dense.stats.cycles, "case {case}");
    }
}

/// Pin: `MemModel::Ideal` reproduces the pre-refactor scheduler output
/// bit-for-bit — the hand-computed `[B=2, R=2, C=3]` snapshot (see
/// tests/equivalence.rs for the derivation) with every memory counter
/// zero.
#[test]
fn ideal_model_is_bit_identical_to_pre_refactor_scheduler() {
    let mut cfg = SimConfig::paper_4_14_3();
    cfg.pe.arrays = 2;
    cfg.pe.rows = 2;
    cfg.context_switch_cycles = 2;
    cfg.mem_model = MemModel::Ideal;
    let spec = ConvSpec { stride: 1, pad: 1 };
    let mut input = Tensor::zeros(&[1, 4, 3]);
    *input.at3_mut(0, 0, 0) = 1.5;
    *input.at3_mut(0, 1, 2) = -2.0;
    *input.at3_mut(0, 3, 1) = 0.5;
    let mut weight = Tensor::zeros(&[2, 1, 3, 3]);
    *weight.at4_mut(0, 0, 0, 0) = 1.0;
    *weight.at4_mut(0, 0, 1, 1) = -1.0;
    *weight.at4_mut(1, 0, 2, 2) = 2.0;

    let mut tr = Trace::disabled();
    let res = simulate_layer(
        &input,
        &weight,
        None,
        &cfg,
        spec,
        Mode::VectorSparse,
        false,
        &mut tr,
    );
    // The pre-refactor cycle model, unchanged.
    assert_eq!(res.stats.cycles, 10);
    assert_eq!(res.dense_cycles, 22);
    assert_eq!(res.stats.sync_stall_slots, 3);
    assert_eq!(res.stats.overhead_cycles, 4);
    assert_eq!(res.stats.issued_pairs, 9);
    // The memory side stays inert under Ideal.
    assert_eq!(res.stats.compute_cycles, 10);
    assert_eq!(res.stats.transfer_cycles, 0);
    assert_eq!(res.stats.fill_cycles, 0);
    assert_eq!(res.stats.tiles, 0);
    assert_eq!(res.stats.sram_overflows, 0);
    assert_eq!(res.stats.mem_stall_cycles(), 0);
    assert_eq!(res.stats.bound(), MemBound::Compute);
    assert_eq!(res.stats.bw_utilization(), 0.0);
}

/// A bandwidth-starved layer classifies as memory-bound with cycles
/// pinned near its transfer demand; a bandwidth-rich one is
/// compute-bound with cycles near the ideal count.
#[test]
fn bound_classification_follows_the_roofline() {
    let mut rng = Pcg32::seeded(0xB0D1);
    let input = random_sparse(&mut rng, &[4, 16, 12], 0.6);
    let weight = random_sparse(&mut rng, &[8, 4, 3, 3], 0.6);
    let spec = ConvSpec { stride: 1, pad: 1 };

    let mut slow = SimConfig::paper_4_14_3();
    slow.pe.arrays = 2;
    slow.pe.rows = 4;
    slow.dram_bytes_per_cycle = 0.05;
    let mut tr = Trace::disabled();
    let starved = simulate_layer(
        &input,
        &weight,
        None,
        &slow,
        spec,
        Mode::VectorSparse,
        false,
        &mut tr,
    );
    assert_eq!(starved.stats.bound(), MemBound::Memory);
    assert!(starved.stats.mem_stall_cycles() > 0);
    assert!(starved.stats.bw_utilization() > 0.5);

    let mut fast = slow;
    fast.dram_bytes_per_cycle = 1e6;
    let rich = simulate_layer(
        &input,
        &weight,
        None,
        &fast,
        spec,
        Mode::VectorSparse,
        false,
        &mut tr,
    );
    assert_eq!(rich.stats.bound(), MemBound::Compute);
    assert!(rich.stats.cycles < starved.stats.cycles);
}
