//! ISSUE 5 pinning: the persistent worker pool, the per-worker scratch
//! arenas, the SoA CVF functional loop and the analytic scheduler change
//! *speed only*. Reports must be bit-identical:
//!
//! * across `--threads 1 / 2 / 8`;
//! * between the pool and the scoped-spawn baseline (`force_scoped`);
//! * between the analytic fast paths and the exact walk
//!   (`SimConfig::exact_scheduler`);
//! * across repeated runs on one live pool, interleaved with runs of a
//!   different workload — i.e. no scratch-arena state leaks between
//!   images.

use std::sync::Arc;
use vscnn::engine::{compile, CompileOptions, Engine, FunctionalBackend, RunOptions};
use vscnn::model::init::{synthetic_image, synthetic_params};
use vscnn::model::vgg16::tiny_vgg;
use vscnn::pruning;
use vscnn::pruning::sensitivity::flat_schedule;
use vscnn::sim::config::SimConfig;
use vscnn::tensor::Tensor;
use vscnn::util::parallel::{force_scoped, scoped_test_lock};

fn engine_and_image(seed: u64) -> (Engine, Tensor) {
    let net = tiny_vgg(16);
    let mut params = synthetic_params(&net, seed, 0.0);
    pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.4));
    let img = synthetic_image(net.input_shape, seed ^ 1);
    let prepared = Arc::new(compile(&net, params, &CompileOptions::new(3)));
    (Engine::new(prepared), img)
}

#[test]
fn network_report_bit_identical_across_threads_pool_and_exactness() {
    // Hold the mode lock for the whole matrix so a concurrent test can't
    // flip the execution mode mid-comparison.
    let _mode = scoped_test_lock();
    let (engine, img) = engine_and_image(31);
    let mut reference: Option<String> = None;
    for exact in [false, true] {
        for scoped in [false, true] {
            for threads in [1usize, 2, 8] {
                let mut opts = RunOptions::new(SimConfig::paper_8_7_3());
                opts.sim.threads = threads;
                opts.sim.exact_scheduler = exact;
                opts.backend = FunctionalBackend::Im2colMt(threads);
                force_scoped(scoped);
                let json = engine.run_image(&img, &opts).unwrap().to_json().pretty();
                match &reference {
                    None => reference = Some(json),
                    Some(want) => assert_eq!(
                        &json, want,
                        "report diverged at exact={exact} scoped={scoped} threads={threads}"
                    ),
                }
            }
        }
    }
}

#[test]
fn batch_reports_match_per_image_runs_on_the_pool() {
    // Pin pooled execution (the property under test) against concurrent
    // mode toggles.
    let _mode = scoped_test_lock();
    let (engine, _) = engine_and_image(33);
    let images: Vec<Tensor> = (0..5)
        .map(|i| synthetic_image(engine.prepared().net.input_shape, 100 + i))
        .collect();
    for threads in [1usize, 3, 8] {
        let mut opts = RunOptions::new(SimConfig::paper_4_14_3());
        opts.sim.threads = threads;
        opts.backend = FunctionalBackend::Im2colMt(threads);
        let batch = engine.run_batch(&images, &opts).unwrap();
        assert_eq!(batch.len(), images.len());
        for (img, report) in images.iter().zip(&batch) {
            let solo = engine.run_image(img, &opts).unwrap();
            assert_eq!(
                solo.to_json().pretty(),
                report.to_json().pretty(),
                "threads={threads}"
            );
        }
    }
}

/// Scratch-arena hygiene: repeated runs of the same image on one live
/// pool — interleaved with a different workload that dirties every
/// per-worker buffer — must stay bit-identical.
#[test]
fn repeated_runs_on_one_pool_leak_no_scratch_state() {
    // The leak property lives in the *pooled* arenas — hold the mode lock
    // so this actually runs pooled, not scoped-by-a-neighbour.
    let _mode = scoped_test_lock();
    let (engine, img) = engine_and_image(32);
    let (other_engine, other_img) = engine_and_image(77);
    let opts = RunOptions::new(SimConfig::paper_8_7_3());
    let first = engine.run_image(&img, &opts).unwrap().to_json().pretty();
    for round in 0..3 {
        // Dirty the arenas with different data (and shapes of scratch use).
        let _ = other_engine.run_image(&other_img, &opts).unwrap();
        let again = engine.run_image(&img, &opts).unwrap().to_json().pretty();
        assert_eq!(first, again, "round {round}");
    }
}
