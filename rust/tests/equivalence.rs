//! Property-style equivalence suite: every functional path in the repo —
//! golden `conv2d`, `conv2d_im2col`, the blocked multithreaded
//! `conv2d_im2col_mt`, and the simulator's parallel functional dataflow —
//! must agree on random shapes and densities, and the cycle model must be
//! pinned by a hand-computed snapshot so the perf refactor provably
//! changes no semantics (ISSUE 1 satellite).

use std::sync::Arc;
use vscnn::engine::{compile, CompileOptions, Engine, PreparedNetwork, PAPER_COLS};
use vscnn::model::LayerKind;
use vscnn::sim::config::SimConfig;
use vscnn::sim::mapping::simulate_compiled;
use vscnn::sim::scheduler::{simulate_layer, Mode};
use vscnn::sim::trace::Trace;
use vscnn::tensor::conv::{conv2d, maxpool2x2, relu_inplace, ConvSpec};
use vscnn::tensor::ops::{conv2d_im2col, conv2d_im2col_mt};
use vscnn::tensor::Tensor;
use vscnn::util::rng::Pcg32;

fn random_sparse(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(
        shape,
        (0..n)
            .map(|_| {
                if density > 0.0 && rng.bernoulli(density) {
                    rng.normal()
                } else {
                    0.0
                }
            })
            .collect(),
    )
}

/// ~20 random shapes × densities {0.0, 0.3, 1.0}: golden conv2d ==
/// im2col == im2col_mt == simulator functional output (both dataflow
/// modes, random PE geometry and simulator worker counts).
#[test]
fn conv_paths_equivalent_across_shapes_and_densities() {
    let mut rng = Pcg32::seeded(0x2607);
    let spec = ConvSpec { stride: 1, pad: 1 };
    for case in 0..20 {
        let c_in = rng.range(1, 5);
        let k_out = rng.range(1, 7);
        let h = rng.range(4, 14);
        let w = rng.range(4, 14);
        for density in [0.0f32, 0.3, 1.0] {
            let input = random_sparse(&mut rng, &[c_in, h, w], density);
            let weight = random_sparse(&mut rng, &[k_out, c_in, 3, 3], density);
            let bias: Vec<f32> = (0..k_out).map(|_| rng.normal()).collect();

            let golden = conv2d(&input, &weight, Some(&bias), spec);
            let im2col = conv2d_im2col(&input, &weight, Some(&bias), spec);
            assert!(
                golden.allclose(&im2col, 1e-4, 1e-4),
                "case {case} d={density}: im2col diff {}",
                golden.max_abs_diff(&im2col)
            );
            let mt = conv2d_im2col_mt(&input, &weight, Some(&bias), spec, rng.range(1, 6));
            assert!(
                golden.allclose(&mt, 1e-4, 1e-4),
                "case {case} d={density}: im2col_mt diff {}",
                golden.max_abs_diff(&mt)
            );

            let mut cfg = SimConfig::paper_4_14_3();
            cfg.pe.arrays = rng.range(1, 4);
            cfg.pe.rows = rng.range(2, 8);
            cfg.threads = rng.range(1, 6);
            let mut tr = Trace::disabled();
            for mode in [Mode::VectorSparse, Mode::Dense] {
                let res = simulate_layer(
                    &input,
                    &weight,
                    Some(&bias),
                    &cfg,
                    spec,
                    mode,
                    true,
                    &mut tr,
                );
                let out = res.output.expect("functional mode");
                assert!(
                    golden.allclose(&out, 1e-3, 1e-3),
                    "case {case} d={density} {mode:?}: sim diff {}",
                    golden.max_abs_diff(&out)
                );
            }
        }
    }
}

/// ISSUE 8: the dispatching payload kernels (std::simd under
/// `--features simd`, 8-wide unrolled scalar otherwise) are bit-identical
/// to the plain scalar references on random lengths, and the functional
/// dataflow that calls them stays bit-identical across worker counts
/// 1/2/8 on random shapes. Run under both feature settings in CI; the
/// pinned exact path must not move in either.
#[test]
fn simd_kernels_and_functional_path_bit_identical_across_threads() {
    use vscnn::util::simd::{
        add_assign, add_assign_scalar, axpy, axpy_scalar, or_abs_bits, or_abs_bits_scalar,
    };
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    let mut rng = Pcg32::seeded(0x51AD);
    // Kernel-level: random lengths (SIMD tails included), exact u32 bits.
    for _ in 0..16 {
        let n = rng.range(1, 600);
        let src: Vec<f32> = (0..n).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let mut a: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let mut b = a.clone();
        add_assign(&mut a, &src);
        add_assign_scalar(&mut b, &src);
        assert_eq!(bits(&a), bits(&b));
        let s = rng.f32_range(-1.5, 1.5);
        axpy(&mut a, s, &src);
        axpy_scalar(&mut b, s, &src);
        assert_eq!(bits(&a), bits(&b));
        let mut occ_a = vec![0u32; n];
        let mut occ_b = vec![0u32; n];
        or_abs_bits(&mut occ_a, &src);
        or_abs_bits_scalar(&mut occ_b, &src);
        assert_eq!(occ_a, occ_b);
    }
    // Engine-level: the functional dataflow (its clipped-diagonal
    // accumulation runs through add_assign) pinned across 1/2/8 workers.
    let spec = ConvSpec { stride: 1, pad: 1 };
    for _ in 0..4 {
        let c_in = rng.range(1, 4);
        let k_out = rng.range(2, 7);
        let h = rng.range(5, 18);
        let w = rng.range(5, 18);
        let input = random_sparse(&mut rng, &[c_in, h, w], 0.5);
        let weight = random_sparse(&mut rng, &[k_out, c_in, 3, 3], 0.4);
        let mut cfg = SimConfig::paper_8_7_3();
        cfg.pe.arrays = 2;
        let mut outs: Vec<Tensor> = Vec::new();
        for threads in [1usize, 2, 8] {
            cfg.threads = threads;
            let mut tr = Trace::disabled();
            let res = simulate_layer(
                &input,
                &weight,
                None,
                &cfg,
                spec,
                Mode::VectorSparse,
                true,
                &mut tr,
            );
            outs.push(res.output.expect("functional mode"));
        }
        assert_eq!(bits(outs[0].data()), bits(outs[1].data()));
        assert_eq!(bits(outs[0].data()), bits(outs[2].data()));
    }
}

/// Compile a pruned zoo network for the engine (paper 3-column mapping).
fn compiled_zoo_net(name: &str, res: usize, seed: u64) -> Arc<PreparedNetwork> {
    use vscnn::pruning::{self, sensitivity::flat_schedule};
    let net = vscnn::model::zoo::by_name(name, res).unwrap();
    let mut params = vscnn::model::init::synthetic_params(&net, seed, 0.0);
    pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.4));
    Arc::new(compile(&net, params, &CompileOptions::new(PAPER_COLS)))
}

/// Network-level equivalence of the §II-B mapped paths: walk AlexNet and
/// the ResNet-style trunk layer by layer, feeding each conv layer the real
/// (golden-computed) activations, and assert the engine's compiled
/// vector-sparse dataflow reproduces the golden conv per layer — covering
/// 1×1, 5×5, 11×11 (stride 4), 7×7 (stride 2) and padded stride-2 3×3
/// geometries end to end, not just unit shapes.
#[test]
fn zoo_networks_match_golden_conv_per_layer() {
    let mut cfg = SimConfig::paper_8_7_3();
    cfg.pe.arrays = 2;
    for name in ["alexnet", "resnet10"] {
        let prepared = compiled_zoo_net(name, 32, 0x5EED);
        let net = &prepared.net;
        let mut act = vscnn::model::init::synthetic_image(net.input_shape, 0x1317);
        let mut kernels_seen: Vec<(usize, usize)> = Vec::new();
        for layer in &net.layers {
            match &layer.kind {
                LayerKind::Conv { k, spec, .. } => {
                    let cl = &prepared.layers[&layer.name];
                    kernels_seen.push((*k, spec.stride));
                    let golden =
                        conv2d(&act, &cl.weight, Some(cl.bias.as_slice()), cl.spec);
                    let mut tr = Trace::disabled();
                    let res = simulate_compiled(
                        &act,
                        &cl.conv,
                        Some(cl.bias.as_slice()),
                        &cfg,
                        Mode::VectorSparse,
                        true,
                        &mut tr,
                    );
                    let out = res.output.expect("functional mode");
                    assert!(
                        golden.allclose(&out, 1e-2, 1e-3),
                        "{name}/{}: mapped dataflow diff {}",
                        layer.name,
                        golden.max_abs_diff(&out)
                    );
                    assert!(
                        res.stats.cycles <= res.dense_cycles,
                        "{name}/{}: sparse slower than dense",
                        layer.name
                    );
                    // Continue the walk on the golden activations.
                    let mut next = golden;
                    relu_inplace(&mut next);
                    act = next;
                }
                LayerKind::MaxPool2 => act = maxpool2x2(&act),
                _ => {}
            }
        }
        // The walk must actually have exercised the mapped geometries.
        if name == "alexnet" {
            assert!(kernels_seen.contains(&(11, 4)), "{kernels_seen:?}");
            assert!(kernels_seen.contains(&(5, 1)), "{kernels_seen:?}");
            assert!(kernels_seen.contains(&(3, 1)), "{kernels_seen:?}");
        } else {
            assert!(kernels_seen.contains(&(7, 2)), "{kernels_seen:?}");
            assert!(kernels_seen.contains(&(1, 1)), "{kernels_seen:?}");
            assert!(kernels_seen.contains(&(3, 2)), "{kernels_seen:?}");
        }
    }
}

/// The engine's own end-to-end run (timing + densities + post-processing)
/// agrees with its backend on every mapped geometry: `verify_dataflow`
/// asserts per-layer equality inside the engine, and the report stays in
/// the sane band.
#[test]
fn zoo_networks_run_end_to_end_through_engine() {
    for name in ["alexnet", "resnet10"] {
        let prepared = compiled_zoo_net(name, 32, 0xA11E);
        let net_input = prepared.net.input_shape;
        let engine = Engine::new(prepared);
        let img = vscnn::model::init::synthetic_image(net_input, 7);
        let mut cfg = SimConfig::paper_8_7_3();
        cfg.pe.arrays = 2;
        let opts = vscnn::coordinator::RunOptions {
            sim: cfg,
            backend: vscnn::coordinator::FunctionalBackend::Golden,
            verify_dataflow: true,
            fuse: false,
            sdc: None,
        };
        let report = engine.run_image(&img, &opts).unwrap();
        let expect = if name == "alexnet" { 5 } else { 9 };
        assert_eq!(report.layers.len(), expect, "{name}");
        assert!(
            report.overall_speedup() >= 1.0,
            "{name}: speedup {}",
            report.overall_speedup()
        );
    }
}

/// Build the hand-computed snapshot layer: `[B=2, R=2, C=3]`, ctx = 2,
/// one input channel `[1,4,3]`, two filters. Every expected number below
/// is derived by hand in the comments (and mirrored in the scheduler's
/// `sync_stall_pinned_for_two_filter_group` unit test).
///
/// Runs under `MemModel::Ideal`: the pre-refactor scheduler had no memory
/// model, so the ideal setting is by definition the path these pinned
/// numbers must keep reproducing bit-for-bit (ISSUE 3 satellite; the
/// tiled model's own pins live in tests/memory_model.rs).
fn snapshot_layer() -> (Tensor, Tensor, SimConfig, ConvSpec) {
    let mut cfg = SimConfig::paper_4_14_3();
    cfg.pe.arrays = 2;
    cfg.pe.rows = 2;
    cfg.context_switch_cycles = 2;
    cfg.mem_model = vscnn::sim::config::MemModel::Ideal;
    let spec = ConvSpec { stride: 1, pad: 1 };
    let mut input = Tensor::zeros(&[1, 4, 3]);
    *input.at3_mut(0, 0, 0) = 1.5; // strip 0, col 0
    *input.at3_mut(0, 1, 2) = -2.0; // strip 0, col 2
    *input.at3_mut(0, 3, 1) = 0.5; // strip 1, col 1
    let mut weight = Tensor::zeros(&[2, 1, 3, 3]);
    *weight.at4_mut(0, 0, 0, 0) = 1.0; // filter 0: kernel cols {0, 1}
    *weight.at4_mut(0, 0, 1, 1) = -1.0;
    *weight.at4_mut(1, 0, 2, 2) = 2.0; // filter 1: kernel col {2}
    (input, weight, cfg, spec)
}

/// Cycle-count snapshot: pins the dense and sparse cycle model for one
/// small layer. If any scheduler change shifts these numbers, the timing
/// semantics changed — not just the implementation.
///
/// Hand computation (one group of 2 filters, Σ_s|nzI| = 3, 2 live strips):
///   work_0 = |nzW|·ΣnzI + ctx·strips = 2·3 + 2·2 = 10
///   work_1 = 1·3 + 4 = 7
///   sparse cycles = max = 10; sync stall = 10 − 7 = 3; overhead = 4
///   dense cycles = blocks(2) · W·KW(9) + blocks · ctx = 18 + 4 = 22
///   issued = ΣnzI · Σ|nzW| = 3·3 = 9; macs = 9 · R·C = 54
///   skipped_input = zero-input-vector pairs = (3−2)·6 + (3−1)·6 = 18
///   skipped_weight = nz inputs × zero weight cols = 3 · (6−3) = 9
///   boundary: strip0 col2×WA(j0) X, col0×WC(j2) X → 2
#[test]
fn cycle_snapshot_pinned_small_layer() {
    let (input, weight, cfg, spec) = snapshot_layer();
    let mut tr = Trace::disabled();
    let sparse = simulate_layer(
        &input,
        &weight,
        None,
        &cfg,
        spec,
        Mode::VectorSparse,
        true,
        &mut tr,
    );
    assert_eq!(sparse.stats.cycles, 10);
    assert_eq!(sparse.dense_cycles, 22);
    assert_eq!(sparse.stats.sync_stall_slots, 3);
    assert_eq!(sparse.stats.overhead_cycles, 4);
    assert_eq!(sparse.stats.issued_pairs, 9);
    assert_eq!(sparse.stats.macs, 54);
    assert_eq!(sparse.stats.skipped_input, 18);
    assert_eq!(sparse.stats.skipped_weight, 9);
    assert_eq!(sparse.stats.boundary_pairs, 2);
    // Ideal memory model: zero transfer time, no tiles, compute == cycles
    // (the pre-refactor accounting, bit-for-bit).
    assert_eq!(sparse.stats.compute_cycles, sparse.stats.cycles);
    assert_eq!(sparse.stats.transfer_cycles, 0);
    assert_eq!(sparse.stats.fill_cycles, 0);
    assert_eq!(sparse.stats.tiles, 0);
    assert_eq!(sparse.stats.sram_overflows, 0);

    let dense = simulate_layer(
        &input,
        &weight,
        None,
        &cfg,
        spec,
        Mode::Dense,
        true,
        &mut tr,
    );
    assert_eq!(dense.stats.cycles, 22);
    assert_eq!(dense.stats.cycles, dense.dense_cycles);
    assert_eq!(dense.stats.sync_stall_slots, 0);

    // And both functional outputs still reproduce the golden conv.
    let golden = conv2d(&input, &weight, None, spec);
    for out in [sparse.output.unwrap(), dense.output.unwrap()] {
        assert!(
            golden.allclose(&out, 1e-5, 1e-5),
            "diff {}",
            golden.max_abs_diff(&out)
        );
    }
}
