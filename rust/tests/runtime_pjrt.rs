//! Integration: the PJRT runtime executing real AOT artifacts, cross-
//! checked against the rust golden conv — the three-corner check
//! (rust golden ⇄ lax.conv HLO ⇄ Pallas-kernel HLO).
//!
//! Requires `make artifacts`; tests skip (with a notice) when the
//! artifacts directory is absent so plain `cargo test` stays green.

use vscnn::runtime::Runtime;
use vscnn::tensor::conv::{conv2d, ConvSpec};
use vscnn::tensor::Tensor;
use vscnn::util::rng::Pcg32;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

fn random_tensor(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(
        shape,
        (0..n)
            .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
            .collect(),
    )
}

#[test]
fn pjrt_ref_artifact_matches_rust_golden_conv() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::seeded(1);
    // Smallest ref bucket present: find one with h <= 32 to keep golden fast.
    let art = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "ref")
        .min_by_key(|a| a.c_in * a.h * a.w * a.c_out)
        .expect("at least one ref artifact")
        .clone();
    let x = random_tensor(&mut rng, &[art.c_in, art.h, art.w], 0.6);
    let w = random_tensor(&mut rng, &[art.c_out, art.c_in, 3, 3], 0.5);
    let b: Vec<f32> = (0..art.c_out).map(|_| rng.normal()).collect();

    let got = rt.run_conv(&art, &x, &w, &b).expect("pjrt exec");
    let want = conv2d(&x, &w, Some(&b), ConvSpec { stride: 1, pad: 1 });
    assert!(
        want.allclose(&got, 1e-3, 1e-3),
        "PJRT ref vs golden: max diff {}",
        want.max_abs_diff(&got)
    );
}

#[test]
fn pjrt_pallas_kernel_matches_ref_artifact() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::seeded(2);
    let Some(vscnn_art) = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "vscnn")
        .min_by_key(|a| a.c_in * a.h * a.w * a.c_out)
        .cloned()
    else {
        eprintln!("SKIP: no vscnn artifacts in manifest");
        return;
    };
    let ref_art = rt
        .manifest()
        .find("ref", vscnn_art.c_in, vscnn_art.c_out, vscnn_art.h, vscnn_art.w)
        .expect("matching ref bucket")
        .clone();

    let x = random_tensor(&mut rng, &[vscnn_art.c_in, vscnn_art.h, vscnn_art.w], 0.5);
    let w = random_tensor(&mut rng, &[vscnn_art.c_out, vscnn_art.c_in, 3, 3], 0.4);
    let b: Vec<f32> = (0..vscnn_art.c_out).map(|_| rng.normal()).collect();

    let a = rt.run_conv(&vscnn_art, &x, &w, &b).expect("pallas artifact");
    let r = rt.run_conv(&ref_art, &x, &w, &b).expect("ref artifact");
    assert!(
        r.allclose(&a, 1e-3, 1e-3),
        "Pallas-kernel HLO vs lax HLO: max diff {}",
        r.max_abs_diff(&a)
    );
}

#[test]
fn pjrt_shape_mismatch_is_clean_error() {
    let Some(rt) = runtime() else { return };
    let art = rt.manifest().artifacts[0].clone();
    let x = Tensor::zeros(&[art.c_in, art.h + 1, art.w]);
    let w = Tensor::zeros(&[art.c_out, art.c_in, 3, 3]);
    let b = vec![0.0; art.c_out];
    let err = rt.run_conv(&art, &x, &w, &b).unwrap_err();
    assert!(format!("{err:#}").contains("input shape"));
}

#[test]
fn coordinator_runs_on_pjrt_backend() {
    // Full pipeline with the PJRT functional path at the artifact
    // resolution (res 64 buckets are emitted by `make artifacts`).
    let Some(rt) = runtime() else { return };
    let has_res64 = rt.manifest().find("ref", 3, 64, 64, 64).is_some();
    if !has_res64 {
        eprintln!("SKIP: no res-64 ref buckets in manifest");
        return;
    }
    use vscnn::coordinator::{FunctionalBackend, RunOptions};
    use vscnn::experiments::workload;
    use vscnn::experiments::ExpContext;

    let ctx = ExpContext {
        res: 64,
        images: 1,
        ..Default::default()
    };
    let (coord, images, _) = workload::prepare(&ctx);
    let mut opts = RunOptions::new(vscnn::sim::config::SimConfig::paper_8_7_3());
    let report_cpu = coord.run(&images[0], &opts).unwrap();
    opts.backend = FunctionalBackend::Pjrt(std::sync::Arc::new(rt), "ref".to_string());
    let report_pjrt = coord.run(&images[0], &opts).unwrap();

    // XLA's conv and the rust im2col path differ by ~1e-6 per element;
    // values sitting exactly at the ReLU threshold can flip, so zero
    // patterns (and cycles) agree to a tolerance rather than exactly.
    let (ca, cb) = (report_cpu.totals.cycles as f64, report_pjrt.totals.cycles as f64);
    assert!(
        (ca - cb).abs() / ca < 1e-3,
        "cycle divergence: cpu {ca} vs pjrt {cb}"
    );
    assert_eq!(report_cpu.layers.len(), report_pjrt.layers.len());
    for (a, b) in report_cpu.layers.iter().zip(&report_pjrt.layers) {
        assert!(
            (a.output_density_elem - b.output_density_elem).abs() < 1e-3,
            "{}: {} vs {}",
            a.name,
            a.output_density_elem,
            b.output_density_elem
        );
    }
}
