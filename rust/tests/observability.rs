//! Behavioral tests for the observability layer (`util::metrics`,
//! `util::trace_span`) and its end-to-end guarantees:
//!
//! * disabled collectors record nothing and cost nothing observable;
//! * the Chrome `trace_event` export has a fixed, parseable shape;
//! * two same-seed traced serve runs export byte-identical timelines;
//! * reports are byte-identical with observability on and off.
//!
//! The metrics registry and the trace sink are process-global, so every
//! test here — each flips global collector state — serializes on one
//! gate mutex. They live in their own integration binary because the
//! library's unit tests run instrumented engine/pool/serve code
//! concurrently and would race exact-count assertions.

#![cfg(not(feature = "no-obs"))]

use vscnn::engine::{compile, CompileOptions, Engine, RunOptions};
use vscnn::model::init::{synthetic_image, synthetic_params};
use vscnn::model::vgg16::tiny_vgg;
use vscnn::pruning::{self, sensitivity::flat_schedule};
use vscnn::serve::{
    simulate, BatchPolicy, DispatchPolicy, FaultSpec, InstanceSpec, RobustnessPolicy, ServeReport,
    ServeSpec, ServiceProfile, Tenant, TrafficModel,
};
use vscnn::sim::config::SimConfig;
use vscnn::util::json::Json;
use vscnn::util::{metrics, trace_span};

/// Serialize every test in this binary: they all mutate the global
/// collector state. Poison-tolerant so one failure doesn't cascade.
fn gate() -> std::sync::MutexGuard<'static, ()> {
    static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Reset collectors to the pristine default (off, empty buffer).
fn reset() {
    trace_span::disable();
    trace_span::clear();
    metrics::set_enabled(false);
}

fn parse_export() -> Json {
    let s = trace_span::export_string();
    Json::parse(&s).unwrap_or_else(|e| panic!("export is not valid JSON: {e:?}\n{s}"))
}

fn dropped_events(j: &Json) -> f64 {
    let other = j.get("otherData").unwrap();
    other.get("dropped_events").unwrap().as_f64().unwrap()
}

#[test]
fn disabled_collectors_record_nothing() {
    let _g = gate();
    reset();
    assert!(trace_span::span("test", "noop").is_none());
    trace_span::complete_cycles(trace_span::CYCLES_PID, 0, "test", "noop", 0, 10, Vec::new());
    trace_span::instant_cycles(trace_span::CYCLES_PID, 0, "test", "noop", 5);
    trace_span::counter_cycles(trace_span::CYCLES_PID, "noop.q", 5, "queued", 1);
    trace_span::name_track(trace_span::CYCLES_PID, 0, "noop");
    assert_eq!(trace_span::pe_budget(), 0, "budget reads 0 while disabled");
    let j = parse_export();
    assert_eq!(j.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
    reset();
}

#[test]
fn export_has_fixed_parseable_shape() {
    let _g = gate();
    reset();
    trace_span::enable(1024, false, true);
    trace_span::name_track(trace_span::CYCLES_PID, 7, "lane seven");
    trace_span::complete_cycles(
        trace_span::CYCLES_PID,
        7,
        "layer",
        "conv1_1",
        100,
        50,
        vec![
            ("compute_cycles", trace_span::Arg::U(40)),
            ("note", trace_span::Arg::S("a \"quoted\" name".to_string())),
        ],
    );
    trace_span::instant_cycles(trace_span::CYCLES_PID, 7, "fault", "crash", 120);
    trace_span::counter_cycles(trace_span::CYCLES_PID, "inst007.queue", 120, "queued", 3);
    let first = trace_span::export_string();
    assert_eq!(first, trace_span::export_string(), "export is replayable");

    let j = Json::parse(&first).expect("valid JSON");
    assert!(j.get("displayTimeUnit").is_some());
    assert_eq!(dropped_events(&j), 0.0);
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
    // process_name metadata + thread_name + X + i + C.
    assert_eq!(evs.len(), 5);
    for ev in evs {
        for key in ["name", "cat", "ph", "pid", "tid", "ts"] {
            assert!(ev.get(key).is_some(), "missing {key} in {}", ev.to_string());
        }
    }
    let ph_of = |i: usize| evs[i].get("ph").unwrap().as_str().unwrap().to_string();
    assert_eq!(ph_of(0), "M", "process_name metadata leads");
    let x = &evs[2];
    assert_eq!(x.get("ph").unwrap().as_str(), Some("X"));
    assert_eq!(x.get("ts").unwrap().as_f64(), Some(100.0));
    assert_eq!(x.get("dur").unwrap().as_f64(), Some(50.0));
    let args = x.get("args").unwrap();
    assert_eq!(args.get("compute_cycles").unwrap().as_f64(), Some(40.0));
    let i_ev = &evs[3];
    assert_eq!(i_ev.get("ph").unwrap().as_str(), Some("i"));
    assert_eq!(i_ev.get("s").unwrap().as_str(), Some("t"), "instant scope");
    assert!(i_ev.get("dur").is_none(), "instants carry no dur");
    let c_ev = &evs[4];
    assert_eq!(c_ev.get("ph").unwrap().as_str(), Some("C"));
    let cargs = c_ev.get("args").unwrap();
    assert_eq!(cargs.get("queued").unwrap().as_f64(), Some(3.0));
    reset();
}

#[test]
fn wall_spans_record_on_drop_with_thread_lane() {
    let _g = gate();
    reset();
    trace_span::enable(1024, true, false);
    {
        let _outer = trace_span::span("test", "outer");
        let _inner = trace_span::span("test", "inner");
    }
    let j = parse_export();
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
    let xs: Vec<&Json> = evs
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
        .collect();
    assert_eq!(xs.len(), 2);
    // RAII: inner drops (and records) first; both on the same wall lane.
    assert_eq!(xs[0].get("name").unwrap().as_str(), Some("inner"));
    assert_eq!(xs[1].get("name").unwrap().as_str(), Some("outer"));
    assert_eq!(xs[0].get("tid").unwrap().as_f64(), xs[1].get("tid").unwrap().as_f64());
    for x in &xs {
        assert_eq!(x.get("pid").unwrap().as_f64(), Some(trace_span::WALL_PID as f64));
    }
    // The lane carries a thread_name metadata event.
    assert!(evs.iter().any(|e| {
        e.get("ph").unwrap().as_str() == Some("M")
            && e.get("name").unwrap().as_str() == Some("thread_name")
    }));
    reset();
}

#[test]
fn trace_limit_drops_and_reports_excess() {
    let _g = gate();
    reset();
    trace_span::enable(3, false, true);
    for t in 0..10u64 {
        trace_span::complete_cycles(trace_span::CYCLES_PID, 0, "test", "e", t, 1, Vec::new());
    }
    assert_eq!(trace_span::dropped(), 7);
    let j = parse_export();
    assert_eq!(j.get("traceEvents").unwrap().as_arr().unwrap().len(), 3 + 1);
    assert_eq!(dropped_events(&j), 7.0);
    reset();
}

#[test]
fn pe_budget_is_consumed_and_gated_on_cycles() {
    let _g = gate();
    reset();
    trace_span::set_pe_budget(100);
    assert_eq!(trace_span::pe_budget(), 0, "cycles off -> budget reads 0");
    trace_span::enable(64, false, true);
    trace_span::set_pe_budget(100);
    assert_eq!(trace_span::pe_budget(), 100);
    trace_span::pe_consume(30);
    assert_eq!(trace_span::pe_budget(), 70);
    trace_span::pe_consume(1000);
    assert_eq!(trace_span::pe_budget(), 0, "saturating consume");
    reset();
}

#[test]
fn metrics_off_then_on_counts_only_while_enabled() {
    let _g = gate();
    reset();
    metrics::add("obs_test.hits", 5);
    metrics::observe("obs_test.lat", 10);
    metrics::set_enabled(true);
    metrics::add("obs_test.hits", 2);
    metrics::observe("obs_test.lat", 7);
    metrics::set_enabled(false);
    metrics::add("obs_test.hits", 100);
    assert_eq!(metrics::counter("obs_test.hits").get(), 2);
    assert_eq!(metrics::histogram("obs_test.lat").count(), 1);
    reset();
}

// ------------------------------------------------------------ end to end

fn faulted_spec() -> (ServeSpec, Vec<Vec<ServiceProfile>>) {
    let spec = ServeSpec {
        tenants: vec![Tenant::new("vgg16", 32, 0.6), Tenant::new("resnet10", 16, 0.4)],
        instances: vec![
            InstanceSpec {
                config: SimConfig::paper_8_7_3(),
            },
            InstanceSpec {
                config: SimConfig::paper_4_14_3(),
            },
            InstanceSpec {
                config: SimConfig::paper_4_14_3(),
            },
        ],
        traffic: TrafficModel::OpenLoop { rps: 2_000.0 },
        policy: DispatchPolicy::NetworkAffinity,
        batch: BatchPolicy {
            max_batch: 4,
            max_wait_cycles: 100_000,
        },
        queue_cap: 16,
        racks: 1,
        duration_cycles: 100_000_000,
        clock_mhz: 500.0,
        seed: 9,
        faults: FaultSpec::parse("crash:60,mttr:2").unwrap(),
        robust: RobustnessPolicy {
            timeout_cycles: 5_000_000,
            max_retries: 2,
            backoff_cycles: 10_000,
            hedge_cycles: 0,
            shed: false,
        },
        sdc: vscnn::sim::sdc::SdcSpec::none(),
    };
    let prof = ServiceProfile {
        single_cycles: 800_000,
        marginal_cycles: 500_000,
        switch_cycles: 300_000,
    };
    let profiles = vec![vec![prof; 3]; 2];
    (spec, profiles)
}

/// The headline guarantee: a faulted serve run traced twice with the
/// same seed exports byte-identical timelines (cycles-only tracing, tid
/// == instance index), containing exec spans, crash markers, and down
/// intervals.
#[test]
fn traced_faulted_serve_runs_are_byte_identical() {
    let _g = gate();
    reset();
    let (spec, profiles) = faulted_spec();

    trace_span::enable(1 << 20, false, true);
    let out_a = simulate(&spec, &profiles);
    let export_a = trace_span::export_string();
    trace_span::clear();
    let out_b = simulate(&spec, &profiles);
    let export_b = trace_span::export_string();
    assert_eq!(export_a, export_b, "same-seed traced runs must be identical");
    assert_eq!(
        ServeReport::new(&spec, &out_a).to_json().pretty(),
        ServeReport::new(&spec, &out_b).to_json().pretty()
    );

    let j = Json::parse(&export_a).expect("valid JSON");
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!evs.is_empty());
    let has = |ph: &str, cat: &str| {
        evs.iter().any(|e| {
            e.get("ph").unwrap().as_str() == Some(ph)
                && e.get("cat").and_then(|c| c.as_str()) == Some(cat)
        })
    };
    assert!(has("X", "exec"), "batch execution spans");
    assert!(has("i", "fault"), "crash/recover markers");
    assert!(has("X", "down"), "downtime intervals");
    assert!(has("C", "counter"), "queue-depth counters");
    // Every cycle-domain tid is an instance index.
    for e in evs {
        if e.get("ph").unwrap().as_str() == Some("M") {
            continue;
        }
        let tid = e.get("tid").unwrap().as_f64().unwrap();
        assert!((tid as usize) < spec.instances.len(), "tid {tid} out of fleet range");
    }
    reset();
}

/// Pinning the acceptance gate: with collectors enabled, the *reports*
/// (serve and network) are byte-identical to an untouched run —
/// observability reads simulation state, never alters it.
#[test]
fn reports_are_byte_identical_with_observability_enabled() {
    let _g = gate();
    reset();

    // Serve side.
    let (spec, profiles) = faulted_spec();
    let plain = ServeReport::new(&spec, &simulate(&spec, &profiles)).to_json().pretty();
    metrics::set_enabled(true);
    trace_span::enable(1 << 20, false, true);
    let observed = ServeReport::new(&spec, &simulate(&spec, &profiles)).to_json().pretty();
    assert_eq!(plain, observed, "serve report must not change under tracing");
    reset();

    // Engine side, PE issue tracing included.
    let net = tiny_vgg(8);
    let mut params = synthetic_params(&net, 5, 0.0);
    pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.4));
    let img = synthetic_image(net.input_shape, 5);
    let prepared = std::sync::Arc::new(compile(&net, params, &CompileOptions::new(3)));
    let mut cfg = SimConfig::paper_4_14_3();
    cfg.pe.arrays = 2;
    cfg.pe.rows = 4;
    let mut opts = RunOptions::new(cfg);
    opts.backend = vscnn::engine::FunctionalBackend::Golden;
    opts.verify_dataflow = false;
    let engine = Engine::new(prepared);
    let plain = engine.run_image(&img, &opts).unwrap().to_json().pretty();
    metrics::set_enabled(true);
    trace_span::enable(1 << 20, true, true);
    trace_span::set_pe_budget(10_000);
    let observed = engine.run_image(&img, &opts).unwrap().to_json().pretty();
    assert_eq!(plain, observed, "network report must not change under tracing");
    // And the trace actually captured the run: layer spans + PE issues.
    let j = parse_export();
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(evs.iter().any(|e| {
        e.get("cat").and_then(|c| c.as_str()) == Some("layer")
            && e.get("pid").unwrap().as_f64() == Some(trace_span::CYCLES_PID as f64)
    }));
    assert!(evs.iter().any(|e| {
        e.get("pid").unwrap().as_f64() == Some(trace_span::PE_PID as f64)
            && e.get("ph").unwrap().as_str() == Some("X")
    }));
    reset();
}
