//! Serving-simulator property tests: conservation (with and without
//! injected faults, up to 1000-instance racked fleets), the engine-cycle
//! latency floor, thread-budget determinism, same-cycle tie-break pins,
//! the calendar-queue/binary-heap equivalence storm, and the high-load
//! win of affinity + batching (ISSUE 4 + 6 + 7 acceptance criteria).

use vscnn::engine::{Engine, FunctionalBackend, RunOptions};
use vscnn::experiments::{self, ExpContext};
use vscnn::model::init::synthetic_image;
use vscnn::serve::{
    build_profiles, default_fleet, default_mix, profile_from_report, simulate, BatchPolicy,
    DispatchPolicy, FaultSpec, InstanceSpec, Outcome, RobustnessPolicy, ServeReport, ServeSpec,
    ServiceProfile, TrafficModel,
};
use vscnn::util::rng::Pcg32;

/// Two tiled instances (both paper geometries): the smallest fleet that
/// still exercises heterogeneity, cheap enough to engine-profile in a
/// debug test run.
fn small_fleet() -> Vec<InstanceSpec> {
    default_fleet(2)
}

fn base_spec(traffic: TrafficModel, policy: DispatchPolicy, batch: BatchPolicy) -> ServeSpec {
    ServeSpec {
        tenants: default_mix(32),
        instances: small_fleet(),
        traffic,
        policy,
        batch,
        queue_cap: 16,
        racks: 1,
        duration_cycles: 80_000_000,
        clock_mhz: 500.0,
        seed: 20190526,
        faults: FaultSpec::none(),
        robust: RobustnessPolicy::none(),
        sdc: vscnn::sim::sdc::SdcSpec::none(),
    }
}

/// The five-bucket request ledger must close under every interleaving:
/// every offered request sits in exactly one terminal (or in-flight)
/// bucket, and the per-record outcomes agree with the counters.
fn assert_ledger_closes(out: &vscnn::serve::ServeOutcome, tag: &str) {
    assert_eq!(
        out.offered,
        out.completed + out.rejected + out.timed_out + out.shed + out.in_flight,
        "{tag}: conservation"
    );
    assert_eq!(out.records.len() as u64, out.offered, "{tag}: records");
    let count = |o: Outcome| out.records.iter().filter(|r| r.outcome == o).count() as u64;
    assert_eq!(count(Outcome::Completed), out.completed, "{tag}: completed");
    assert_eq!(count(Outcome::Rejected), out.rejected, "{tag}: rejected");
    assert_eq!(count(Outcome::TimedOut), out.timed_out, "{tag}: timed_out");
    assert_eq!(count(Outcome::Shed), out.shed, "{tag}: shed");
    assert_eq!(count(Outcome::InFlight), out.in_flight, "{tag}: in_flight");
    // Hedge duplicates are attempts, not requests: a hedged request still
    // lands in exactly one bucket (checked above), wins are counted at
    // most once per request, and an instance-completed sum that matched
    // `completed` proves no double-served request was double-counted.
    let hedged = out.records.iter().filter(|r| r.hedged).count() as u64;
    let hedge_won = out.records.iter().filter(|r| r.hedge_won).count() as u64;
    assert_eq!(hedged, out.hedges, "{tag}: hedged records");
    assert_eq!(hedge_won, out.hedge_wins, "{tag}: hedge wins");
    assert!(out.hedge_wins <= out.hedges, "{tag}: wins<=hedges");
    let done: u64 = out.instances.iter().map(|i| i.completed).sum();
    assert_eq!(done, out.completed, "{tag}: instance completions");
}

#[test]
fn conservation_over_randomized_specs() {
    // Pure event-loop property: offered = completed + rejected + in-flight
    // for every policy / batching / load / seed combination. Toy profiles
    // keep the engine out of the loop so dozens of cases stay fast.
    let mut rng = Pcg32::seeded(77);
    let policies = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::LeastLoaded,
        DispatchPolicy::NetworkAffinity,
    ];
    for case in 0..40 {
        let policy = policies[rng.below(3) as usize];
        let max_batch = 1 + rng.below(8) as usize;
        let batch = BatchPolicy {
            max_batch,
            max_wait_cycles: 1 + rng.next_u32() as u64 % 400_000,
        };
        let rps = 100.0 * (1 + rng.below(200)) as f64;
        let traffic = if rng.bernoulli(0.3) {
            TrafficModel::ClosedLoop {
                clients: 1 + rng.below(8) as usize,
                think_cycles: rng.next_u32() as u64 % 200_000,
            }
        } else {
            TrafficModel::OpenLoop { rps }
        };
        let mut spec = base_spec(traffic, policy, batch);
        spec.queue_cap = 1 + rng.below(24) as usize;
        spec.seed = rng.next_u64();
        spec.duration_cycles = 10_000_000 + rng.next_u32() as u64 % 40_000_000;

        let prof = ServiceProfile {
            single_cycles: 200_000 + rng.next_u32() as u64 % 2_000_000,
            marginal_cycles: 0, // fixed up below
            switch_cycles: rng.next_u32() as u64 % 500_000,
        };
        let profiles: Vec<Vec<ServiceProfile>> = (0..spec.tenants.len())
            .map(|_| {
                (0..spec.instances.len())
                    .map(|_| {
                        let single = 200_000 + rng.next_u32() as u64 % 2_000_000;
                        ServiceProfile {
                            single_cycles: single,
                            marginal_cycles: (single / 2).max(1),
                            switch_cycles: prof.switch_cycles,
                        }
                    })
                    .collect()
            })
            .collect();

        let out = simulate(&spec, &profiles);
        assert_ledger_closes(&out, &format!("case {case}"));
        // Without faults or robustness knobs the fault ledger stays empty.
        assert_eq!(out.timed_out + out.shed, 0, "case {case}: no-fault buckets");
        assert_eq!(
            out.retries + out.hedges + out.crashes + out.faulted,
            0,
            "case {case}: no-fault counters"
        );
        for inst in &out.instances {
            assert!(
                inst.utilization(spec.duration_cycles) <= 1.0 + 1e-12,
                "case {case}: utilization"
            );
        }
        // Every completed request launched after it arrived and finished
        // after it launched.
        for r in &out.records {
            if let (Some(s), Some(c)) = (r.start, r.completion) {
                assert!(r.arrival <= s && s < c, "case {case}: ordering");
            }
        }
    }
}

#[test]
fn conservation_over_randomized_fault_specs() {
    // ISSUE 6 acceptance: the five-bucket ledger closes and hedge
    // duplicates are never double-counted for 40 random combinations of
    // crash/straggler/exec-fault injection and timeout/retry/hedge/shed
    // robustness — and every faulted run replays bit-identically from the
    // same seed (same ServeReport JSON, byte for byte).
    let mut rng = Pcg32::seeded(1234);
    let policies = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::LeastLoaded,
        DispatchPolicy::NetworkAffinity,
    ];
    for case in 0..40 {
        let policy = policies[rng.below(3) as usize];
        let batch = BatchPolicy {
            max_batch: 1 + rng.below(8) as usize,
            max_wait_cycles: 1 + rng.next_u32() as u64 % 400_000,
        };
        let traffic = if rng.bernoulli(0.3) {
            TrafficModel::ClosedLoop {
                clients: 1 + rng.below(8) as usize,
                think_cycles: rng.next_u32() as u64 % 200_000,
            }
        } else {
            TrafficModel::OpenLoop {
                rps: 100.0 * (1 + rng.below(200)) as f64,
            }
        };
        let mut spec = base_spec(traffic, policy, batch);
        spec.queue_cap = 1 + rng.below(24) as usize;
        spec.seed = rng.next_u64();
        spec.duration_cycles = 10_000_000 + rng.next_u32() as u64 % 40_000_000;
        spec.faults = FaultSpec {
            crash_per_sec: [0.0, 50.0, 200.0, 400.0][rng.below(4) as usize],
            mttr_ms: 0.5 + rng.below(4) as f64,
            straggler_per_sec: [0.0, 100.0, 300.0][rng.below(3) as usize],
            slowdown: 2.0 + rng.below(6) as f64,
            straggler_ms: 0.5 + rng.below(2) as f64,
            req_fault_prob: [0.0, 0.1, 0.3][rng.below(3) as usize],
        };
        spec.robust = RobustnessPolicy {
            timeout_cycles: [0, 300_000, 1_500_000][rng.below(3) as usize],
            max_retries: rng.below(3),
            backoff_cycles: 10_000 + rng.next_u32() as u64 % 90_000,
            hedge_cycles: [0, 200_000, 800_000][rng.below(3) as usize],
            shed: rng.bernoulli(0.5),
        };

        let profiles: Vec<Vec<ServiceProfile>> = (0..spec.tenants.len())
            .map(|_| {
                (0..spec.instances.len())
                    .map(|_| {
                        let single = 200_000 + rng.next_u32() as u64 % 2_000_000;
                        ServiceProfile {
                            single_cycles: single,
                            marginal_cycles: (single / 2).max(1),
                            switch_cycles: rng.next_u32() as u64 % 500_000,
                        }
                    })
                    .collect()
            })
            .collect();

        let out = simulate(&spec, &profiles);
        assert_ledger_closes(&out, &format!("fault case {case}"));
        // Bit-identical replay: the whole report, not just the counters.
        let again = simulate(&spec, &profiles);
        assert_eq!(
            ServeReport::new(&spec, &out).to_json().pretty(),
            ServeReport::new(&spec, &again).to_json().pretty(),
            "fault case {case}: replay diverged"
        );
    }
}

#[test]
fn calendar_queue_is_a_drop_in_for_the_binary_heap() {
    // ISSUE 7 satellite: the calendar queue must be observationally
    // identical to the BinaryHeap reference — same (cycle, FIFO-seq)
    // total order — under randomized storms mixing same-cycle ties,
    // bucket-spanning jitter, crash-epoch far-future pushes (MTTR-style
    // jumps that force calendar rebuilds), pops and whole-cycle drains.
    use vscnn::serve::events::{BinaryHeapQueue, EventQueue};
    let mut rng = Pcg32::seeded(0xCA1E);
    for round in 0..8 {
        let mut cal: EventQueue<u32> = EventQueue::new();
        let mut heap: BinaryHeapQueue<u32> = BinaryHeapQueue::new();
        let mut now = 0u64;
        let mut tag = 0u32;
        let mut cal_out: Vec<u32> = Vec::new();
        let mut heap_out: Vec<u32> = Vec::new();
        for step in 0..3_000 {
            match rng.below(100) {
                0..=59 => {
                    let jitter = match rng.below(10) {
                        // same-cycle ties: FIFO order is the contract
                        0..=2 => 0,
                        3..=6 => rng.below(50) as u64,
                        // spans several calendar buckets
                        7..=8 => rng.below(200_000) as u64,
                        // crash-epoch jump: far past the current day
                        _ => 1_000_000 + rng.below(4) as u64 * 10_000_000,
                    };
                    tag += 1;
                    cal.push(now + jitter, tag);
                    heap.push(now + jitter, tag);
                }
                60..=84 => {
                    assert_eq!(cal.peek_cycle(), heap.peek_cycle(), "round {round} step {step}");
                    let a = cal.pop();
                    let b = heap.pop();
                    assert_eq!(a, b, "round {round} step {step}: pop diverged");
                    if let Some((c, v)) = a {
                        now = now.max(c);
                        cal_out.push(v);
                        heap_out.push(b.unwrap().1);
                    }
                }
                _ => {
                    // Drain a whole cycle, exactly like the event loop.
                    assert_eq!(cal.peek_cycle(), heap.peek_cycle(), "round {round} step {step}");
                    if let Some(c) = heap.peek_cycle() {
                        let before = cal_out.len();
                        cal.drain_cycle(c, &mut cal_out);
                        heap.drain_cycle(c, &mut heap_out);
                        assert!(cal_out.len() > before, "empty drain at peeked cycle");
                        now = now.max(c);
                    }
                }
            }
            assert_eq!(cal.len(), heap.len(), "round {round} step {step}: len");
        }
        // Drain both to empty: the full popped sequences must be
        // byte-identical (order and payloads).
        while let Some(c) = heap.peek_cycle() {
            assert_eq!(cal.peek_cycle(), Some(c), "round {round}: final peek");
            cal.drain_cycle(c, &mut cal_out);
            heap.drain_cycle(c, &mut heap_out);
        }
        assert!(cal.is_empty(), "round {round}: calendar not empty");
        assert_eq!(cal_out, heap_out, "round {round}: sequences diverged");
        assert_eq!(cal_out.len(), tag as usize, "round {round}: lost events");
    }
}

#[test]
fn ledger_closes_at_scale_under_bursty_traffic_and_faults() {
    // ISSUE 7 satellite: the five-bucket conservation ledger must close
    // at fleet sizes 10 / 100 / 1000 on racked topologies, under MMPP
    // flash-crowd traffic with crashes, stragglers and request faults,
    // with timeouts/retries/hedging/shedding all armed — and the counters
    // must replay bit-identically from the same seed.
    let toy = ServiceProfile {
        single_cycles: 400_000,
        marginal_cycles: 200_000,
        switch_cycles: 100_000,
    };
    for &(n, racks) in &[(10usize, 2usize), (100, 8), (1000, 16)] {
        // ~2500 rps capacity per instance; base load ~30% with 8x bursts,
        // so the burst episodes overflow queues and shed/reject.
        let mut spec = base_spec(
            TrafficModel::Mmpp {
                rps: 800.0 * n as f64,
                burst_x: 8.0,
                mean_high_cycles: 500_000, // 1 ms at 500 MHz
                mean_low_cycles: 2_500_000, // 5 ms
            },
            DispatchPolicy::Hierarchical,
            BatchPolicy {
                max_batch: 8,
                max_wait_cycles: 100_000,
            },
        );
        spec.instances = default_fleet(n);
        spec.racks = racks;
        spec.queue_cap = 8;
        spec.duration_cycles = 25_000_000; // 50 simulated ms
        spec.faults = FaultSpec {
            crash_per_sec: 200.0,
            mttr_ms: 1.0,
            straggler_per_sec: 100.0,
            slowdown: 4.0,
            straggler_ms: 1.0,
            req_fault_prob: 0.05,
        };
        spec.robust = RobustnessPolicy {
            timeout_cycles: 2_000_000,
            max_retries: 1,
            backoff_cycles: 50_000,
            hedge_cycles: 400_000,
            shed: true,
        };
        let profiles = vec![vec![toy; n]; spec.tenants.len()];

        let out = simulate(&spec, &profiles);
        assert_ledger_closes(&out, &format!("fleet {n}"));
        assert!(out.offered > 0, "fleet {n}: no arrivals");
        assert!(out.completed > 0, "fleet {n}: nothing completed");
        assert!(out.crashes > 0, "fleet {n}: no crashes landed");

        let again = simulate(&spec, &profiles);
        assert_eq!(
            ServeReport::new(&spec, &out).to_json().pretty(),
            ServeReport::new(&spec, &again).to_json().pretty(),
            "fleet {n}: replay diverged"
        );
    }
}

#[test]
fn same_cycle_timeout_beats_completion_by_one_cycle() {
    // The documented drain_cycle tie-break (ISSUE 6 satellite): a Timeout
    // is pushed at dispatch, the Complete at launch — so when the timeout
    // window exactly equals the service time both land on the same cycle
    // and FIFO push order lets the *timeout* win; the completion arrives
    // stale. One extra cycle of budget flips every race the other way.
    let mk = |timeout_cycles: u64| {
        let mut spec = base_spec(
            // Single client, short think: a steady chain of solo requests
            // with an empty queue, so dispatch and launch share a cycle.
            TrafficModel::ClosedLoop {
                clients: 1,
                think_cycles: 10_000,
            },
            DispatchPolicy::LeastLoaded,
            BatchPolicy {
                max_batch: 1,
                max_wait_cycles: 1,
            },
        );
        spec.robust = RobustnessPolicy {
            timeout_cycles,
            max_retries: 0,
            backoff_cycles: 1,
            hedge_cycles: 0,
            shed: false,
        };
        spec
    };
    let prof = ServiceProfile {
        single_cycles: 1000,
        marginal_cycles: 1000,
        switch_cycles: 0,
    };
    let profiles = vec![vec![prof; 2]; 3];

    // timeout == service: every attempt times out on the very cycle its
    // batch completes, and the completion is discarded as stale.
    let out = simulate(&mk(1000), &profiles);
    assert_ledger_closes(&out, "tie");
    assert!(out.offered > 0, "no requests arrived");
    assert_eq!(out.completed, 0, "a completion beat its same-cycle timeout");
    assert!(out.timed_out > 0);
    assert_eq!(
        out.stale_completions, out.timed_out,
        "every timed-out attempt still completed (stale) on the same cycle"
    );

    // timeout == service + 1: the completion now precedes the timeout and
    // every request is served; the late timeout finds a stale token.
    let out = simulate(&mk(1001), &profiles);
    assert_ledger_closes(&out, "tie+1");
    assert!(out.completed > 0);
    assert_eq!(out.timed_out, 0, "a timeout beat an earlier completion");
    assert_eq!(out.stale_completions, 0);
}

#[test]
fn latency_floor_is_the_engine_single_image_cycles() {
    // Engine-profiled run: no served request may ever complete faster
    // than its tenant's full one-image engine cycles on the admitting
    // instance — queueing, batching and switching only ever add latency.
    let spec = base_spec(
        TrafficModel::OpenLoop { rps: 3_000.0 },
        DispatchPolicy::NetworkAffinity,
        BatchPolicy {
            max_batch: 8,
            max_wait_cycles: 100_000,
        },
    );
    let profiles = build_profiles(&spec, 0).expect("profiles");
    for row in &profiles {
        for p in row {
            assert!(p.single_cycles >= p.marginal_cycles);
            assert!(p.marginal_cycles >= 1);
            assert!(p.switch_cycles <= p.single_cycles);
        }
    }
    let out = simulate(&spec, &profiles);
    assert!(out.completed > 0, "nothing completed");
    for r in &out.records {
        if let Some(lat) = r.latency() {
            let inst = r.instance.expect("completed implies admitted");
            let floor = profiles[r.tenant][inst].single_cycles;
            assert!(
                lat >= floor,
                "tenant {} on instance {inst}: latency {lat} < engine cycles {floor}",
                r.tenant
            );
        }
    }
}

/// Profile every `(tenant, instance)` pair of `spec` with an explicit
/// thread budget, bypassing `service_profile`'s memoizer (whose key
/// deliberately omits threads) — so a thread-dependent engine would
/// actually be caught.
fn profiles_with_threads(spec: &ServeSpec, threads: usize) -> Vec<Vec<ServiceProfile>> {
    spec.tenants
        .iter()
        .map(|tenant| {
            let ctx = ExpContext {
                net: tenant.net.clone(),
                res: tenant.res,
                images: 1,
                threads,
                seed: spec.seed,
                ..ExpContext::default()
            };
            let prepared = experiments::workload::prepared(&ctx).expect("compile");
            let img = synthetic_image(prepared.net.input_shape, spec.seed ^ 0x5EA7);
            spec.instances
                .iter()
                .map(|inst| {
                    let mut sim = inst.config;
                    sim.threads = threads;
                    let opts = RunOptions {
                        sim,
                        backend: FunctionalBackend::Im2colMt(threads),
                        verify_dataflow: false,
                        fuse: false,
                        sdc: None,
                    };
                    let engine = Engine::new(prepared.clone());
                    let report = engine.run_image(&img, &opts).expect("run");
                    profile_from_report(&report, &inst.config)
                })
                .collect()
        })
        .collect()
}

#[test]
fn report_is_bit_identical_across_thread_budgets() {
    // The acceptance determinism bit: the ServeReport JSON for a fixed
    // seed must not depend on the host thread budget. Profiles are built
    // cache-free per thread budget, so this exercises the engine runs
    // themselves, not just the event loop.
    let spec = base_spec(
        TrafficModel::OpenLoop { rps: 1_500.0 },
        DispatchPolicy::LeastLoaded,
        BatchPolicy {
            max_batch: 4,
            max_wait_cycles: 150_000,
        },
    );
    let render = |threads: usize| {
        let profiles = profiles_with_threads(&spec, threads);
        let out = simulate(&spec, &profiles);
        ServeReport::new(&spec, &out).to_json().pretty()
    };
    // Hold the mode lock across the whole comparison so a concurrent
    // test can't flip pooled/scoped execution mid-measure.
    let _mode = vscnn::util::parallel::scoped_test_lock();
    vscnn::util::parallel::force_scoped(false);
    let a = render(1);
    let b = render(3);
    assert_eq!(a, b, "serve JSON varies with the thread budget");
    let c = render(8);
    assert_eq!(a, c, "serve JSON varies at 8 threads");

    // ISSUE 5: the persistent-pool engine and the scoped-spawn baseline
    // produce the same bits too.
    vscnn::util::parallel::force_scoped(true);
    let scoped = render(3);
    assert_eq!(a, scoped, "serve JSON differs between pool and scoped");

    // The public (memoized, tenant-parallel) profile path agrees with the
    // cache-free one.
    let cached = build_profiles(&spec, 2).expect("profiles");
    assert_eq!(cached, profiles_with_threads(&spec, 2));
}

#[test]
fn affinity_plus_batching_beats_naive_at_high_load() {
    // The acceptance capacity-curve bit, via the `exp serve` experiment
    // at smoke resolution: at the top of the curve the tuned fleet must
    // strictly beat naive round-robin/no-batching on p99 without losing
    // throughput.
    let ctx = ExpContext {
        res: 32,
        ..ExpContext::default()
    };
    let out = experiments::run("serve", &ctx).expect("exp serve");
    assert_eq!(
        out.json.get("wins_at_high_load").and_then(|j| j.as_bool()),
        Some(true),
        "tuned config does not win at high load:\n{}",
        out.text
    );
    // The curve itself is present and well-formed.
    let curve = out.json.get("curve").unwrap().as_arr().unwrap();
    assert!(curve.len() >= 4);
    for p in curve {
        for side in ["naive", "tuned"] {
            let s = p.get(side).unwrap();
            assert!(s.get("p99_ms").unwrap().as_f64().unwrap() >= 0.0);
            assert!(s.get("throughput_rps").unwrap().as_f64().unwrap() >= 0.0);
        }
    }
}
