//! Serving-simulator property tests: conservation, the engine-cycle
//! latency floor, thread-budget determinism, and the high-load win of
//! affinity + batching (the ISSUE 4 acceptance criteria).

use vscnn::engine::{Engine, FunctionalBackend, RunOptions};
use vscnn::experiments::{self, ExpContext};
use vscnn::model::init::synthetic_image;
use vscnn::serve::{
    build_profiles, default_fleet, default_mix, profile_from_report, simulate, BatchPolicy,
    DispatchPolicy, InstanceSpec, ServeReport, ServeSpec, ServiceProfile, TrafficModel,
};
use vscnn::util::rng::Pcg32;

/// Two tiled instances (both paper geometries): the smallest fleet that
/// still exercises heterogeneity, cheap enough to engine-profile in a
/// debug test run.
fn small_fleet() -> Vec<InstanceSpec> {
    default_fleet(2)
}

fn base_spec(traffic: TrafficModel, policy: DispatchPolicy, batch: BatchPolicy) -> ServeSpec {
    ServeSpec {
        tenants: default_mix(32),
        instances: small_fleet(),
        traffic,
        policy,
        batch,
        queue_cap: 16,
        duration_cycles: 80_000_000,
        clock_mhz: 500.0,
        seed: 20190526,
    }
}

#[test]
fn conservation_over_randomized_specs() {
    // Pure event-loop property: offered = completed + rejected + in-flight
    // for every policy / batching / load / seed combination. Toy profiles
    // keep the engine out of the loop so dozens of cases stay fast.
    let mut rng = Pcg32::seeded(77);
    let policies = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::LeastLoaded,
        DispatchPolicy::NetworkAffinity,
    ];
    for case in 0..40 {
        let policy = policies[rng.below(3) as usize];
        let max_batch = 1 + rng.below(8) as usize;
        let batch = BatchPolicy {
            max_batch,
            max_wait_cycles: 1 + rng.next_u32() as u64 % 400_000,
        };
        let rps = 100.0 * (1 + rng.below(200)) as f64;
        let traffic = if rng.bernoulli(0.3) {
            TrafficModel::ClosedLoop {
                clients: 1 + rng.below(8) as usize,
                think_cycles: rng.next_u32() as u64 % 200_000,
            }
        } else {
            TrafficModel::OpenLoop { rps }
        };
        let mut spec = base_spec(traffic, policy, batch);
        spec.queue_cap = 1 + rng.below(24) as usize;
        spec.seed = rng.next_u64();
        spec.duration_cycles = 10_000_000 + rng.next_u32() as u64 % 40_000_000;

        let prof = ServiceProfile {
            single_cycles: 200_000 + rng.next_u32() as u64 % 2_000_000,
            marginal_cycles: 0, // fixed up below
            switch_cycles: rng.next_u32() as u64 % 500_000,
        };
        let profiles: Vec<Vec<ServiceProfile>> = (0..spec.tenants.len())
            .map(|_| {
                (0..spec.instances.len())
                    .map(|_| {
                        let single = 200_000 + rng.next_u32() as u64 % 2_000_000;
                        ServiceProfile {
                            single_cycles: single,
                            marginal_cycles: (single / 2).max(1),
                            switch_cycles: prof.switch_cycles,
                        }
                    })
                    .collect()
            })
            .collect();

        let out = simulate(&spec, &profiles);
        assert_eq!(
            out.offered,
            out.completed + out.rejected + out.in_flight(),
            "case {case}: conservation"
        );
        assert_eq!(out.records.len() as u64, out.offered, "case {case}");
        let done: u64 = out.instances.iter().map(|i| i.completed).sum();
        assert_eq!(done, out.completed, "case {case}");
        for inst in &out.instances {
            assert!(
                inst.utilization(spec.duration_cycles) <= 1.0 + 1e-12,
                "case {case}: utilization"
            );
        }
        // Every completed request launched after it arrived and finished
        // after it launched.
        for r in &out.records {
            if let (Some(s), Some(c)) = (r.start, r.completion) {
                assert!(r.arrival <= s && s < c, "case {case}: ordering");
            }
        }
    }
}

#[test]
fn latency_floor_is_the_engine_single_image_cycles() {
    // Engine-profiled run: no served request may ever complete faster
    // than its tenant's full one-image engine cycles on the admitting
    // instance — queueing, batching and switching only ever add latency.
    let spec = base_spec(
        TrafficModel::OpenLoop { rps: 3_000.0 },
        DispatchPolicy::NetworkAffinity,
        BatchPolicy {
            max_batch: 8,
            max_wait_cycles: 100_000,
        },
    );
    let profiles = build_profiles(&spec, 0).expect("profiles");
    for row in &profiles {
        for p in row {
            assert!(p.single_cycles >= p.marginal_cycles);
            assert!(p.marginal_cycles >= 1);
            assert!(p.switch_cycles <= p.single_cycles);
        }
    }
    let out = simulate(&spec, &profiles);
    assert!(out.completed > 0, "nothing completed");
    for r in &out.records {
        if let Some(lat) = r.latency() {
            let inst = r.instance.expect("completed implies admitted");
            let floor = profiles[r.tenant][inst].single_cycles;
            assert!(
                lat >= floor,
                "tenant {} on instance {inst}: latency {lat} < engine cycles {floor}",
                r.tenant
            );
        }
    }
}

/// Profile every `(tenant, instance)` pair of `spec` with an explicit
/// thread budget, bypassing `service_profile`'s memoizer (whose key
/// deliberately omits threads) — so a thread-dependent engine would
/// actually be caught.
fn profiles_with_threads(spec: &ServeSpec, threads: usize) -> Vec<Vec<ServiceProfile>> {
    spec.tenants
        .iter()
        .map(|tenant| {
            let ctx = ExpContext {
                net: tenant.net.clone(),
                res: tenant.res,
                images: 1,
                threads,
                seed: spec.seed,
                ..ExpContext::default()
            };
            let prepared = experiments::workload::prepared(&ctx).expect("compile");
            let img = synthetic_image(prepared.net.input_shape, spec.seed ^ 0x5EA7);
            spec.instances
                .iter()
                .map(|inst| {
                    let mut sim = inst.config;
                    sim.threads = threads;
                    let opts = RunOptions {
                        sim,
                        backend: FunctionalBackend::Im2colMt(threads),
                        verify_dataflow: false,
                    };
                    let engine = Engine::new(prepared.clone());
                    let report = engine.run_image(&img, &opts).expect("run");
                    profile_from_report(&report, &inst.config)
                })
                .collect()
        })
        .collect()
}

#[test]
fn report_is_bit_identical_across_thread_budgets() {
    // The acceptance determinism bit: the ServeReport JSON for a fixed
    // seed must not depend on the host thread budget. Profiles are built
    // cache-free per thread budget, so this exercises the engine runs
    // themselves, not just the event loop.
    let spec = base_spec(
        TrafficModel::OpenLoop { rps: 1_500.0 },
        DispatchPolicy::LeastLoaded,
        BatchPolicy {
            max_batch: 4,
            max_wait_cycles: 150_000,
        },
    );
    let render = |threads: usize| {
        let profiles = profiles_with_threads(&spec, threads);
        let out = simulate(&spec, &profiles);
        ServeReport::new(&spec, &out).to_json().pretty()
    };
    // Hold the mode lock across the whole comparison so a concurrent
    // test can't flip pooled/scoped execution mid-measure.
    let _mode = vscnn::util::parallel::scoped_test_lock();
    vscnn::util::parallel::force_scoped(false);
    let a = render(1);
    let b = render(3);
    assert_eq!(a, b, "serve JSON varies with the thread budget");
    let c = render(8);
    assert_eq!(a, c, "serve JSON varies at 8 threads");

    // ISSUE 5: the persistent-pool engine and the scoped-spawn baseline
    // produce the same bits too.
    vscnn::util::parallel::force_scoped(true);
    let scoped = render(3);
    assert_eq!(a, scoped, "serve JSON differs between pool and scoped");

    // The public (memoized, tenant-parallel) profile path agrees with the
    // cache-free one.
    let cached = build_profiles(&spec, 2).expect("profiles");
    assert_eq!(cached, profiles_with_threads(&spec, 2));
}

#[test]
fn affinity_plus_batching_beats_naive_at_high_load() {
    // The acceptance capacity-curve bit, via the `exp serve` experiment
    // at smoke resolution: at the top of the curve the tuned fleet must
    // strictly beat naive round-robin/no-batching on p99 without losing
    // throughput.
    let ctx = ExpContext {
        res: 32,
        ..ExpContext::default()
    };
    let out = experiments::run("serve", &ctx).expect("exp serve");
    assert_eq!(
        out.json.get("wins_at_high_load").and_then(|j| j.as_bool()),
        Some(true),
        "tuned config does not win at high load:\n{}",
        out.text
    );
    // The curve itself is present and well-formed.
    let curve = out.json.get("curve").unwrap().as_arr().unwrap();
    assert!(curve.len() >= 4);
    for p in curve {
        for side in ["naive", "tuned"] {
            let s = p.get(side).unwrap();
            assert!(s.get("p99_ms").unwrap().as_f64().unwrap() >= 0.0);
            assert!(s.get("throughput_rps").unwrap().as_f64().unwrap() >= 0.0);
        }
    }
}
