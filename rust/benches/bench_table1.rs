//! Bench T1: regenerates the paper's Table I (5x5 worked example) and
//! times the simulator on it. Run: `cargo bench --bench bench_table1`.

use vscnn::experiments::{table1, ExpContext};
use vscnn::sim::config::SimConfig;
use vscnn::sim::scheduler::{simulate_layer, Mode};
use vscnn::sim::trace::Trace;
use vscnn::tensor::conv::ConvSpec;
use vscnn::util::bench::{bench, black_box};

fn main() {
    let ctx = ExpContext::default();
    let out = table1::run(&ctx).expect("table1");
    println!("{}", out.text);
    assert_eq!(out.json.get("dense_cycles").unwrap().as_usize(), Some(15));
    assert_eq!(out.json.get("sparse_cycles").unwrap().as_usize(), Some(8));

    // Micro-bench: the worked example, timing-only and functional.
    let (input, weight) = table1::example_tensors(ctx.seed);
    let mut cfg = SimConfig::paper_4_14_3();
    cfg.pe.arrays = 1;
    cfg.pe.rows = 5;
    cfg.context_switch_cycles = 0;
    let spec = ConvSpec { stride: 1, pad: 1 };

    for (name, functional) in [("table1/timing-only", false), ("table1/functional", true)] {
        let r = bench(name, 10, 100, || {
            let mut tr = Trace::disabled();
            let res = simulate_layer(
                &input,
                &weight,
                None,
                &cfg,
                spec,
                Mode::VectorSparse,
                functional,
                &mut tr,
            );
            black_box(res.stats.cycles);
        });
        println!("{}", r.line());
    }
}
