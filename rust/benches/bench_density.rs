//! Bench F9/F10/F11: regenerates the density figures at full resolution
//! and times the density-analysis path.
//! Run: `cargo bench --bench bench_density` (env `VSCNN_BENCH_RES` to
//! override the resolution; default 224 = paper).

use vscnn::experiments::{density, ExpContext};
use vscnn::util::bench::bench;

fn main() {
    let res: usize = std::env::var("VSCNN_BENCH_RES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(224);
    let ctx = ExpContext {
        res,
        ..Default::default()
    };

    type ExpFn = fn(&ExpContext) -> anyhow::Result<vscnn::experiments::ExpOutput>;
    for (fi, (id, f)) in [
        ("fig9", density::run_fig9 as ExpFn),
        ("fig10", density::run_fig10 as ExpFn),
        ("fig11", density::run_fig11 as ExpFn),
    ]
    .into_iter()
    .enumerate()
    {
        let out = f(&ctx).expect(id);
        println!("{}", out.text);
        // Vary the seed per figure AND iteration so the workload memoizer
        // doesn't short-circuit the timing (fig9/fig10 share a config).
        let mut seed = ctx.seed + 1000 * (fi as u64 + 1);
        let r = bench(&format!("{id}@res{res}"), 0, 3, || {
            seed += 1;
            let c = ExpContext { seed, ..ctx.clone() };
            let _ = f(&c).expect(id);
        });
        println!("{}\n", r.line());
    }
}
