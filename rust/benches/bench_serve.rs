//! Serving-simulator bench: event-loop throughput (simulated requests/s
//! of wall time) across load levels and policies, plus the one-time
//! profiling cost, written to `BENCH_serve_perf.json` so the serving hot
//! path stays measurable across PRs (the *capacity* numbers live in
//! `BENCH_serve.json`, emitted by `vscnn exp serve`).
//! Run: `cargo bench --bench bench_serve`.
//!
//! Env `VSCNN_BENCH_RES` overrides the profiling resolution (default 32:
//! the event loop, not the engine, is under test here).

use std::time::Instant;
use vscnn::serve::{
    build_profiles, default_fleet, default_mix, simulate, BatchPolicy, DispatchPolicy, FaultSpec,
    RobustnessPolicy, ServeSpec, ServiceProfile, TrafficModel,
};
use vscnn::util::bench::{bench, black_box, write_results, BenchResult};
use vscnn::util::json::Json;

fn spec_at(rps: f64, policy: DispatchPolicy, max_batch: usize) -> ServeSpec {
    ServeSpec {
        tenants: default_mix(32),
        instances: default_fleet(4),
        traffic: TrafficModel::OpenLoop { rps },
        policy,
        batch: BatchPolicy {
            max_batch,
            max_wait_cycles: 250_000,
        },
        queue_cap: 32,
        racks: 1,
        duration_cycles: 2_000_000_000, // 4 simulated seconds at 500 MHz
        clock_mhz: 500.0,
        seed: 7,
        faults: FaultSpec::none(),
        robust: RobustnessPolicy::none(),
        sdc: vscnn::sim::sdc::SdcSpec::none(),
    }
}

fn main() {
    let res: usize = std::env::var("VSCNN_BENCH_RES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    let threads = vscnn::util::default_threads();

    let mut results: Vec<BenchResult> = Vec::new();
    let mut derived = Json::obj();
    derived.set("threads", threads).set("res", res);

    // One-time profiling cost (engine-backed; dominated by the compile of
    // the three mix networks on a cold cache, cache hits afterwards).
    let mut spec = spec_at(1_000.0, DispatchPolicy::NetworkAffinity, 8);
    spec.tenants = default_mix(res);
    let t0 = Instant::now();
    let profiles = build_profiles(&spec, threads).expect("profiling");
    derived.set("profile_cold_ms", t0.elapsed().as_secs_f64() * 1e3);
    let t1 = Instant::now();
    let _ = build_profiles(&spec, threads).expect("profiling (warm)");
    derived.set("profile_warm_ms", t1.elapsed().as_secs_f64() * 1e3);

    // Event-loop throughput on synthetic profiles: independent of the
    // engine, scales with offered load.
    let toy = ServiceProfile {
        single_cycles: 900_000,
        marginal_cycles: 550_000,
        switch_cycles: 350_000,
    };
    let toy_profiles = vec![vec![toy; 4]; 3];
    for (label, rps, policy, max_batch) in [
        ("light/rr", 500.0, DispatchPolicy::RoundRobin, 1),
        ("heavy/rr", 8_000.0, DispatchPolicy::RoundRobin, 1),
        ("heavy/affinity-batch", 8_000.0, DispatchPolicy::NetworkAffinity, 8),
    ] {
        let spec = spec_at(rps, policy, max_batch);
        let mut offered = 0u64;
        let mut events = 0u64;
        let r = bench(&format!("serve-sim/{label}"), 1, 5, || {
            let out = simulate(&spec, &toy_profiles);
            offered = out.offered;
            events = out.events_processed;
            black_box(out.completed);
        });
        println!("{}", r.line());
        println!("{}", r.throughput(offered as f64, "req"));
        println!("{}", r.throughput(events as f64, "event"));
        if label == "heavy/affinity-batch" {
            // The headline event-loop throughput tracked across PRs
            // (batched draining + allocation-free dispatch snapshots).
            derived.set(
                "events_per_sec",
                events as f64 / r.median.as_secs_f64().max(1e-12),
            );
        }
        results.push(r);
    }

    // Fault-injected arm: crash/straggler plan plus timeouts, retries and
    // hedging, so the robustness machinery's event-loop overhead stays
    // visible across PRs next to the clean heavy run.
    let mut fspec = spec_at(8_000.0, DispatchPolicy::NetworkAffinity, 8);
    fspec.faults =
        FaultSpec::parse("crash:1,mttr:2,straggler:4,slow:4,slowms:1").expect("fault spec");
    fspec.robust = RobustnessPolicy {
        timeout_cycles: 25_000_000, // 50 ms at 500 MHz, generous vs queueing
        max_retries: 2,
        backoff_cycles: 500_000,
        hedge_cycles: 5_000_000,
        shed: true,
    };
    let mut fevents = 0u64;
    let r = bench("serve-sim/heavy/faulted", 1, 5, || {
        let out = simulate(&fspec, &toy_profiles);
        fevents = out.events_processed;
        black_box(out.completed);
    });
    println!("{}", r.line());
    println!("{}", r.throughput(fevents as f64, "event"));
    results.push(r);

    // Large-fleet arm (ISSUE 7): 4096 instances in 64 racks under MMPP
    // flash crowds with hierarchical dispatch. Toy profiles give each
    // instance ~900 rps of capacity, so 2.2M rps offered is ~60% load;
    // the horizon is trimmed so one iteration stays ~10^5 arrivals.
    let mut big = spec_at(2_200_000.0, DispatchPolicy::Hierarchical, 8);
    big.instances = default_fleet(4096);
    big.racks = 64;
    big.traffic = TrafficModel::Mmpp {
        rps: 2_200_000.0,
        burst_x: 3.0,
        mean_high_cycles: 500_000, // 1 ms at 500 MHz
        mean_low_cycles: 5_000_000, // 10 ms
    };
    big.duration_cycles = 40_000_000; // 80 simulated ms
    let big_profiles = vec![vec![toy; 4096]; 3];
    let mut big_events = 0u64;
    let r = bench("serve-sim/fleet4096/hier-mmpp", 1, 5, || {
        let out = simulate(&big, &big_profiles);
        big_events = out.events_processed;
        black_box(out.completed);
    });
    println!("{}", r.line());
    println!("{}", r.throughput(big_events as f64, "event"));
    derived.set(
        "fleet4096_events_per_sec",
        big_events as f64 / r.median.as_secs_f64().max(1e-12),
    );
    results.push(r);

    // And one engine-profiled run, end to end.
    let r = bench("serve-sim/engine-profiles", 1, 3, || {
        let out = simulate(&spec, &profiles);
        black_box(out.completed);
    });
    println!("{}", r.line());
    results.push(r);

    let path = "BENCH_serve_perf.json";
    match write_results(path, &results, derived) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
