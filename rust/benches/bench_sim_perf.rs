//! §Perf bench: simulator and functional-path throughput on representative
//! VGG-16 layers — the numbers tracked in EXPERIMENTS.md §Perf.
//! Run: `cargo bench --bench bench_sim_perf`.

use vscnn::model::init::synthetic_image;
use vscnn::pruning::{prune_vectors, VectorGranularity};
use vscnn::sim::config::SimConfig;
use vscnn::sim::scheduler::{simulate_layer, Mode};
use vscnn::sim::trace::Trace;
use vscnn::sparse::encode::layer_report;
use vscnn::tensor::conv::ConvSpec;
use vscnn::tensor::ops::conv2d_im2col_mt;
use vscnn::tensor::Tensor;
use vscnn::util::bench::{bench, black_box};
use vscnn::util::rng::Pcg32;

fn sparse_tensor(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(
        shape,
        (0..n)
            .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
            .collect(),
    )
}

fn main() {
    let mut rng = Pcg32::seeded(1234);
    let cfg = SimConfig::paper_8_7_3();
    let spec = ConvSpec::default();
    let threads = std::thread::available_parallelism().map_or(4, |n| n.get());

    // Representative layers: early (large plane, few channels) and late
    // (small plane, many channels).
    let cases = [
        ("conv2_1-like [64->128 @112]", 64usize, 128usize, 112usize),
        ("conv4_2-like [512->512 @28]", 512, 512, 28),
    ];

    for (name, c_in, k_out, hw) in cases {
        let mut input = synthetic_image([c_in, hw, hw], 7);
        // ReLU-like sparsity.
        for x in input.data_mut() {
            if *x < 0.2 {
                *x = 0.0;
            }
        }
        let mut weight = sparse_tensor(&mut rng, &[k_out, c_in, 3, 3], 1.0);
        prune_vectors(&mut weight, 0.235, VectorGranularity::KernelRow);

        // 1) timing-only simulation throughput (modelled dense pairs/s).
        let dense_pairs = (k_out * c_in * hw.div_ceil(cfg.pe.rows) * hw * 3) as f64;
        let r = bench(&format!("sim/{name}"), 1, 5, || {
            let mut tr = Trace::disabled();
            let res = simulate_layer(
                &input,
                &weight,
                None,
                &cfg,
                spec,
                Mode::VectorSparse,
                false,
                &mut tr,
            );
            black_box(res.stats.cycles);
        });
        println!("{}", r.line());
        println!("{}", r.throughput(dense_pairs, "modelled-pairs"));

        // 2) density analysis (fig 9-11 inner loop).
        let r = bench(&format!("density/{name}"), 1, 5, || {
            black_box(layer_report(&input, &weight, spec, cfg.pe.rows));
        });
        println!("{}", r.line());

        // 3) functional forward (im2col MT) in GMAC/s.
        let macs = (k_out * c_in * 9 * hw * hw) as f64;
        let r = bench(&format!("conv-mt{threads}/{name}"), 1, 5, || {
            black_box(conv2d_im2col_mt(&input, &weight, None, spec, threads));
        });
        println!("{}", r.line());
        println!("{}\n", r.throughput(macs, "MAC"));
    }
}
