//! §Perf bench: simulator and functional-path throughput on representative
//! VGG-16 layers — the numbers tracked in EXPERIMENTS.md §Perf and written
//! to `BENCH_sim_perf.json` so the perf trajectory is diffable across PRs.
//! Run: `cargo bench --bench bench_sim_perf`.
//!
//! * `sim-timing/*` — timing-only vector-sparse simulation (modelled
//!   pairs/s).
//! * `sim-functional-t1/*` vs `sim-functional-tN/*` — the functional
//!   dataflow pinned to one worker vs all cores; the ratio is recorded in
//!   the JSON `derived` block (`functional_speedup_*`).
//! * `density/*` — the Fig 9–11 analysis path.
//! * `conv-mt/*` — the blocked-matmul im2col forward.
//!
//! * `engine-compile` / `engine-execute` — the compile/execute split:
//!   one-time network compile cost (prune + calibrate + kernel mapping +
//!   CVF weight encoding) vs steady-state per-image execution against the
//!   shared `PreparedNetwork`. The JSON `derived` block records
//!   `compile_ms` and `steady_state_images_per_sec` so the weight-side
//!   caching win stays measurable across PRs.
//! * `engine-execute-t8/{pooled,scoped-baseline}` — ISSUE 5's acceptance
//!   pair at `--threads 8` on VGG-16 @ 32 (the CI smoke workload): the
//!   persistent-pool engine with the analytic scheduler vs the pre-pool
//!   baseline (`force_scoped` spawn-per-call + `exact_scheduler` walk).
//!   Reports are bit-identical between the two (tests/pool_determinism.rs)
//!   — only the wall clock differs. `derived` records `images_per_sec`,
//!   `scoped_baseline_images_per_sec` and `speedup_vs_scoped`.
//! * `obs/engine-execute-metrics-{off,on}` — ISSUE 9's observability cost
//!   pair: the same execute workload with the metrics registry disabled vs
//!   enabled. `derived` records `metrics_{off,on}_images_per_sec` and
//!   `metrics_overhead_frac`; check_bench_regression.py warns past 3%.
//!
//! Env `VSCNN_BENCH_SCALING=1` additionally sweeps the conv3_1 functional
//! case over 1/2/4/…/N workers (the thread-scaling curve in
//! EXPERIMENTS.md §Perf).

use std::sync::Arc;
use vscnn::coordinator::RunOptions;
use vscnn::engine::{compile, Calibration, CompileOptions, Engine, PAPER_COLS};
use vscnn::model::init::synthetic_image;
use vscnn::model::vgg16::vgg16_at;
use vscnn::pruning::sensitivity::paper_schedule;
use vscnn::pruning::{prune_vectors, VectorGranularity};
use vscnn::sim::config::{Precision, SimConfig};
use vscnn::sim::scheduler::{simulate_layer, Mode};
use vscnn::sim::trace::Trace;
use vscnn::sparse::encode::layer_report;
use vscnn::tensor::conv::ConvSpec;
use vscnn::tensor::ops::conv2d_im2col_mt;
use vscnn::tensor::Tensor;
use vscnn::util::bench::{bench, black_box, write_results, BenchResult};
use vscnn::util::json::Json;
use vscnn::util::rng::Pcg32;

fn sparse_tensor(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(
        shape,
        (0..n)
            .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
            .collect(),
    )
}

fn functional_case(
    label: &str,
    input: &Tensor,
    weight: &Tensor,
    cfg: &SimConfig,
    spec: ConvSpec,
    iters: usize,
) -> BenchResult {
    let r = bench(label, 0, iters, || {
        let mut tr = Trace::disabled();
        let res = simulate_layer(
            input,
            weight,
            None,
            cfg,
            spec,
            Mode::VectorSparse,
            true,
            &mut tr,
        );
        black_box(res.output.map(|t| t.len()));
    });
    println!("{}", r.line());
    r
}

fn main() {
    let mut rng = Pcg32::seeded(1234);
    let base_cfg = SimConfig::paper_8_7_3();
    let spec = ConvSpec::default();
    let threads = vscnn::util::default_threads();
    let scaling = std::env::var("VSCNN_BENCH_SCALING").is_ok();

    let mut results: Vec<BenchResult> = Vec::new();
    let mut derived = Json::obj();
    derived.set("threads", threads);

    // Representative layers: early (large plane, few channels), the
    // acceptance-tracked conv3_1 class, and late (small plane, many
    // channels).
    let cases = [
        ("conv2_1", 64usize, 128usize, 112usize),
        ("conv3_1", 128, 256, 56),
        ("conv4_2", 512, 512, 28),
    ];

    for (name, c_in, k_out, hw) in cases {
        let mut input = synthetic_image([c_in, hw, hw], 7);
        // ReLU-like sparsity.
        for x in input.data_mut() {
            if *x < 0.2 {
                *x = 0.0;
            }
        }
        let mut weight = sparse_tensor(&mut rng, &[k_out, c_in, 3, 3], 1.0);
        prune_vectors(&mut weight, 0.235, VectorGranularity::KernelRow);

        // 1) timing-only simulation throughput (modelled dense pairs/s).
        let dense_pairs = (k_out * c_in * hw.div_ceil(base_cfg.pe.rows) * hw * 3) as f64;
        let r = bench(&format!("sim-timing/{name}"), 1, 5, || {
            let mut tr = Trace::disabled();
            let res = simulate_layer(
                &input,
                &weight,
                None,
                &base_cfg,
                spec,
                Mode::VectorSparse,
                false,
                &mut tr,
            );
            black_box(res.stats.cycles);
        });
        println!("{}", r.line());
        println!("{}", r.throughput(dense_pairs, "modelled-pairs"));
        results.push(r);

        // 2) functional dataflow: one worker vs all cores. The ratio is the
        //    headline EXPERIMENTS.md §Perf number (the t1 path already
        //    benefits from the value-carrying CVF, so the speedup over the
        //    pre-refactor allocating engine is larger still).
        let mut cfg1 = base_cfg;
        cfg1.threads = 1;
        let r1 = functional_case(
            &format!("sim-functional-t1/{name}"),
            &input,
            &weight,
            &cfg1,
            spec,
            3,
        );
        let mut cfgn = base_cfg;
        cfgn.threads = threads;
        let rn = functional_case(
            &format!("sim-functional-t{threads}/{name}"),
            &input,
            &weight,
            &cfgn,
            spec,
            3,
        );
        let speedup = r1.median.as_secs_f64() / rn.median.as_secs_f64().max(1e-12);
        println!("functional speedup {name}: {speedup:.2}x on {threads} threads\n");
        derived.set(&format!("functional_speedup_{name}"), speedup);
        results.push(r1);
        results.push(rn);

        if scaling && name == "conv3_1" {
            // 1, 2, 4, …, plus the full-core point when N is not a power
            // of two (the most relevant point of the curve).
            let mut points: Vec<usize> = std::iter::successors(Some(1usize), |t| Some(t * 2))
                .take_while(|&t| t < threads)
                .collect();
            points.push(threads);
            for t in points {
                let mut cfg_t = base_cfg;
                cfg_t.threads = t;
                let rt = functional_case(
                    &format!("sim-functional-scaling-t{t}/{name}"),
                    &input,
                    &weight,
                    &cfg_t,
                    spec,
                    3,
                );
                results.push(rt);
            }
        }

        // 3) density analysis (fig 9-11 inner loop).
        let r = bench(&format!("density/{name}"), 1, 5, || {
            black_box(layer_report(&input, &weight, spec, base_cfg.pe.rows));
        });
        println!("{}", r.line());
        results.push(r);

        // 4) functional forward (blocked-matmul im2col MT) in MAC/s.
        let macs = (k_out * c_in * 9 * hw * hw) as f64;
        let r = bench(&format!("conv-mt{threads}/{name}"), 1, 5, || {
            black_box(conv2d_im2col_mt(&input, &weight, None, spec, threads));
        });
        println!("{}", r.line());
        println!("{}\n", r.throughput(macs, "MAC"));
        results.push(r);
    }

    // 5) compile/execute split: VGG-16 @ 64, paper pruning + calibration.
    //    Compile once (all weight-side work), then measure steady-state
    //    images/sec on repeated images against the shared prepared state.
    {
        let net = vgg16_at(64);
        let params = vscnn::model::init::synthetic_params(&net, 7, 0.0);
        let copts = CompileOptions {
            cols: PAPER_COLS,
            prune: Some(paper_schedule(&net)),
            calibration: Some(Calibration {
                image: synthetic_image(net.input_shape, 7 ^ 0xCA11),
                density_scale: 1.0,
                threads,
            }),
            precision: Precision::F32,
        };
        let t0 = std::time::Instant::now();
        let prepared = Arc::new(compile(&net, params, &copts));
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("engine-compile/vgg16-64: {compile_ms:.1} ms (once per network)");
        derived.set("compile_ms", compile_ms);

        let engine = Engine::new(prepared);
        let img = synthetic_image(net.input_shape, 7 ^ 0xDEAD);
        let mut opts = RunOptions::new(SimConfig::paper_8_7_3());
        opts.sim.threads = threads;
        let r = bench("engine-execute/vgg16-64", 1, 5, || {
            black_box(engine.run_image(&img, &opts).expect("engine run").totals.cycles);
        });
        println!("{}", r.line());
        let ips = 1.0 / r.median.as_secs_f64().max(1e-12);
        println!("engine steady state: {ips:.2} images/sec (weight side fully cached)\n");
        derived.set("steady_state_images_per_sec", ips);
        results.push(r);

        // Memory-model headline metrics (default tiled accounting): the
        // roofline shape of the workload, tracked across PRs.
        let report = engine.run_image(&img, &opts).expect("engine run");
        println!(
            "memory model [{}]: {:.0}% of layers memory-bound, {:.1}% effective bw util\n",
            report.mem_model.label(),
            100.0 * report.memory_bound_layer_frac(),
            100.0 * report.effective_bw_util()
        );
        derived.set("memory_bound_layer_frac", report.memory_bound_layer_frac());
        derived.set("effective_bw_util", report.effective_bw_util());
    }

    // 6) ISSUE 5 acceptance pair: pooled + analytic engine vs the pre-pool
    //    scoped + exact baseline, both at --threads 8, VGG-16 @ 32.
    {
        let net = vgg16_at(32);
        let params = vscnn::model::init::synthetic_params(&net, 7, 0.0);
        let copts = CompileOptions {
            cols: PAPER_COLS,
            prune: Some(paper_schedule(&net)),
            calibration: Some(Calibration {
                image: synthetic_image(net.input_shape, 7 ^ 0xCA11),
                density_scale: 1.0,
                threads,
            }),
            precision: Precision::F32,
        };
        let engine = Engine::new(Arc::new(compile(&net, params, &copts)));
        let img = synthetic_image(net.input_shape, 7 ^ 0xBEEF);

        let mut opts = RunOptions::new(SimConfig::paper_8_7_3());
        opts.sim.threads = 8;
        opts.backend = vscnn::engine::FunctionalBackend::Im2colMt(8);

        let r_pool = bench("engine-execute-t8/pooled", 2, 9, || {
            black_box(engine.run_image(&img, &opts).expect("engine run").totals.cycles);
        });
        println!("{}", r_pool.line());

        let mut base_opts = opts.clone();
        base_opts.sim.exact_scheduler = true;
        vscnn::util::parallel::force_scoped(true);
        let r_scoped = bench("engine-execute-t8/scoped-baseline", 2, 9, || {
            black_box(
                engine
                    .run_image(&img, &base_opts)
                    .expect("engine run")
                    .totals
                    .cycles,
            );
        });
        vscnn::util::parallel::force_scoped(false);
        println!("{}", r_scoped.line());

        let ips = 1.0 / r_pool.median.as_secs_f64().max(1e-12);
        let ips_scoped = 1.0 / r_scoped.median.as_secs_f64().max(1e-12);
        let speedup = ips / ips_scoped.max(1e-12);
        println!(
            "engine t8 (vgg16-32): {ips:.2} images/sec pooled vs {ips_scoped:.2} scoped \
             baseline ({speedup:.2}x)\n"
        );
        derived.set("images_per_sec", ips);
        derived.set("scoped_baseline_images_per_sec", ips_scoped);
        derived.set("speedup_vs_scoped", speedup);
        results.push(r_pool);
        results.push(r_scoped);
    }

    // 7) ISSUE 8 payload kernels: the dispatching hot loops (SIMD when
    //    built with `--features simd`, 8-wide unrolled scalar otherwise)
    //    paired against their plain scalar references. Bit-identical by
    //    construction (util/simd.rs tests); only the wall clock differs.
    {
        use vscnn::util::simd::{
            add_assign, add_assign_scalar, axpy, axpy_scalar, or_abs_bits, or_abs_bits_scalar,
        };
        let n = 1 << 16;
        let src: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let mut dst = vec![0.0f32; n];
        let mut bits = vec![0u32; n];
        // 64 passes per sample keeps each measurement well above timer
        // resolution; throughput keys land only on the dispatching side
        // (the scalar references are the comparison series).
        let mut run_kernel = |name: &str, results: &mut Vec<BenchResult>,
                              derived: &mut Json, f: &mut dyn FnMut()| {
            let r = bench(name, 3, 15, || {
                for _ in 0..64 {
                    f();
                }
            });
            println!("{}", r.line());
            if !name.ends_with("-scalar") {
                let eps = (n as f64) * 64.0 / r.median.as_secs_f64().max(1e-12);
                derived.set(
                    &format!("{}_elems_per_sec", &name["kernel/".len()..].replace('-', "_")),
                    eps,
                );
            }
            results.push(r);
        };
        run_kernel("kernel/add-assign", &mut results, &mut derived, &mut || {
            add_assign(&mut dst, &src)
        });
        run_kernel("kernel/axpy", &mut results, &mut derived, &mut || {
            axpy(&mut dst, 0.5, &src)
        });
        run_kernel("kernel/or-abs-bits", &mut results, &mut derived, &mut || {
            or_abs_bits(&mut bits, &src)
        });
        run_kernel("kernel/add-assign-scalar", &mut results, &mut derived, &mut || {
            add_assign_scalar(&mut dst, &src)
        });
        run_kernel("kernel/axpy-scalar", &mut results, &mut derived, &mut || {
            axpy_scalar(&mut dst, 0.5, &src)
        });
        run_kernel("kernel/or-abs-bits-scalar", &mut results, &mut derived, &mut || {
            or_abs_bits_scalar(&mut bits, &src)
        });
        black_box((&dst, &bits));
        println!();
    }

    // 8) ISSUE 8 precision axis: VGG-16 @ 32 compiled at each CVF payload
    //    precision, run under the tiled model. INT16 shares f32's 2-byte
    //    storage (quantization error only); INT8 halves every payload, so
    //    both the modeled DRAM bytes and transfer floor shrink.
    // 9) ISSUE 8 fused strip execution on the f32 engine: conv→conv
    //    strips stay SRAM-resident where they fit, eliminating the
    //    consumer's input traffic.
    {
        let net = vgg16_at(32);
        let img = synthetic_image(net.input_shape, 7 ^ 0xBEEF);
        let prepared_at = |precision: Precision| {
            let params = vscnn::model::init::synthetic_params(&net, 7, 0.0);
            let copts = CompileOptions {
                cols: PAPER_COLS,
                prune: Some(paper_schedule(&net)),
                calibration: Some(Calibration {
                    image: synthetic_image(net.input_shape, 7 ^ 0xCA11),
                    density_scale: 1.0,
                    threads,
                }),
                precision,
            };
            Engine::new(Arc::new(compile(&net, params, &copts)))
        };

        let mut f32_dram = 0u64;
        for precision in [Precision::F32, Precision::Int16, Precision::Int8] {
            let engine = prepared_at(precision);
            let mut opts = RunOptions::new(SimConfig::paper_8_7_3().with_precision(precision));
            opts.sim.threads = threads;
            let label = precision.label();
            let r = bench(&format!("precision/vgg16-32-{label}"), 1, 5, || {
                black_box(engine.run_image(&img, &opts).expect("engine run").totals.cycles);
            });
            println!("{}", r.line());
            let ips = 1.0 / r.median.as_secs_f64().max(1e-12);
            derived.set(&format!("precision_{label}_images_per_sec"), ips);
            let report = engine.run_image(&img, &opts).expect("engine run");
            let dram = report.totals.dram.input_read
                + report.totals.dram.weight_read
                + report.totals.dram.output_write;
            if precision == Precision::F32 {
                f32_dram = dram;
            } else {
                derived.set(
                    &format!("{label}_dram_bytes_vs_f32"),
                    dram as f64 / f32_dram.max(1) as f64,
                );
            }
            println!(
                "precision {label}: {ips:.2} images/sec, {dram} modeled DRAM bytes, \
                 transfer {} cycles",
                report.totals.transfer_cycles
            );
            results.push(r);
        }
        println!();

        let engine = prepared_at(Precision::F32);
        let mut opts = RunOptions::new(SimConfig::paper_8_7_3());
        opts.sim.threads = threads;
        let r_plain = bench("fused/vgg16-32-off", 1, 5, || {
            black_box(engine.run_image(&img, &opts).expect("engine run").totals.cycles);
        });
        println!("{}", r_plain.line());
        let plain = engine.run_image(&img, &opts).expect("engine run");
        opts.fuse = true;
        let r_fused = bench("fused/vgg16-32-on", 1, 5, || {
            black_box(engine.run_image(&img, &opts).expect("engine run").totals.cycles);
        });
        println!("{}", r_fused.line());
        let fused = engine.run_image(&img, &opts).expect("engine run");
        let ips = 1.0 / r_fused.median.as_secs_f64().max(1e-12);
        derived.set("fused_images_per_sec", ips);
        derived.set("fused_layers", fused.fused_layers);
        derived.set(
            "fused_transfer_cycles_saved",
            plain.totals.transfer_cycles.saturating_sub(fused.totals.transfer_cycles),
        );
        derived.set(
            "fused_modeled_cycles_ratio",
            fused.totals.cycles as f64 / plain.totals.cycles.max(1) as f64,
        );
        println!(
            "fusion (vgg16-32): {} layers fused, transfer {} -> {} cycles, total {} -> {}\n",
            fused.fused_layers,
            plain.totals.transfer_cycles,
            fused.totals.transfer_cycles,
            plain.totals.cycles,
            fused.totals.cycles
        );
        results.push(r_plain);
        results.push(r_fused);
    }

    // 10) ISSUE 9 observability overhead: the same engine-execute workload
    //     with the metrics registry disabled vs enabled (tracing stays off,
    //     the production default). The counters on the hot path are relaxed
    //     atomics behind one branch, so the pair should be near-equal;
    //     check_bench_regression.py surfaces it and warns past 3%.
    {
        let net = vgg16_at(32);
        let params = vscnn::model::init::synthetic_params(&net, 7, 0.0);
        let copts = CompileOptions {
            cols: PAPER_COLS,
            prune: Some(paper_schedule(&net)),
            calibration: Some(Calibration {
                image: synthetic_image(net.input_shape, 7 ^ 0xCA11),
                density_scale: 1.0,
                threads,
            }),
            precision: Precision::F32,
        };
        let engine = Engine::new(Arc::new(compile(&net, params, &copts)));
        let img = synthetic_image(net.input_shape, 7 ^ 0xBEEF);
        let mut opts = RunOptions::new(SimConfig::paper_8_7_3());
        opts.sim.threads = threads;

        vscnn::util::metrics::set_enabled(false);
        let r_off = bench("obs/engine-execute-metrics-off", 1, 7, || {
            black_box(engine.run_image(&img, &opts).expect("engine run").totals.cycles);
        });
        println!("{}", r_off.line());
        vscnn::util::metrics::set_enabled(true);
        let r_on = bench("obs/engine-execute-metrics-on", 1, 7, || {
            black_box(engine.run_image(&img, &opts).expect("engine run").totals.cycles);
        });
        vscnn::util::metrics::set_enabled(false);
        println!("{}", r_on.line());

        let ips_off = 1.0 / r_off.median.as_secs_f64().max(1e-12);
        let ips_on = 1.0 / r_on.median.as_secs_f64().max(1e-12);
        let overhead = r_on.median.as_secs_f64() / r_off.median.as_secs_f64().max(1e-12) - 1.0;
        println!(
            "observability (vgg16-32): {ips_off:.2} images/sec metrics off vs {ips_on:.2} on \
             ({:+.2}% overhead)\n",
            overhead * 100.0
        );
        derived.set("metrics_off_images_per_sec", ips_off);
        derived.set("metrics_on_images_per_sec", ips_on);
        derived.set("metrics_overhead_frac", overhead);
        results.push(r_off);
        results.push(r_on);
    }

    let path = "BENCH_sim_perf.json";
    match write_results(path, &results, derived) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
