//! Bench F12/F13/H1/H2: regenerates the speedup figures, the headline
//! numbers, and the SCNN comparison at full resolution, printing the same
//! series the paper plots next to the paper's own values.
//! Run: `cargo bench --bench bench_speedup` (env `VSCNN_BENCH_RES`
//! overrides resolution, `VSCNN_BENCH_IMAGES` the batch size).

use vscnn::experiments::{speedup, ExpContext};
use vscnn::util::bench::bench;

fn main() {
    let res: usize = std::env::var("VSCNN_BENCH_RES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(224);
    let images: usize = std::env::var("VSCNN_BENCH_IMAGES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let ctx = ExpContext {
        res,
        images,
        ..Default::default()
    };

    let f12 = speedup::run_fig(&ctx, true).expect("fig12");
    println!("{}", f12.text);
    let f13 = speedup::run_fig(&ctx, false).expect("fig13");
    println!("{}", f13.text);
    let h = speedup::run_headline(&ctx).expect("headline");
    println!("{}", h.text);
    let s = speedup::run_scnn(&ctx).expect("scnn");
    println!("{}", s.text);

    // Vary the seed per iteration so the workload memoizer doesn't
    // short-circuit the timing.
    let mut seed = ctx.seed;
    let r = bench(&format!("fig12+fig13@res{res}"), 0, 3, || {
        seed += 1;
        let c = ExpContext { seed, ..ctx.clone() };
        let _ = speedup::run_fig(&c, true).unwrap();
        let _ = speedup::run_fig(&c, false).unwrap();
    });
    println!("{}", r.line());
}
