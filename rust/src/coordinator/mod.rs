//! The run coordinator: drives whole networks through the accelerator
//! model, propagating *real* activations layer to layer (conv → ReLU/zero
//! detection → pool → next layer) exactly as the paper's system does, and
//! collecting the per-layer records every experiment consumes.
//!
//! Since the compile/execute split, the heavy lifting lives in
//! [`crate::engine`]: [`Coordinator`] is a compatibility shim that compiles
//! once at construction and delegates every run to the engine. The
//! functional forward pass runs on one of three interchangeable backends
//! (cross-checked in tests): the golden scalar conv, the multithreaded
//! im2col conv, or the PJRT runtime executing the JAX-lowered artifacts.

pub mod pipeline;
pub mod report;

pub use pipeline::{Coordinator, FunctionalBackend, NetworkReport, RunOptions};
pub use report::LayerRecord;
