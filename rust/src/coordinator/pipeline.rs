//! The layer pipeline: conv (PE arrays) → post-processing (ReLU + zero
//! detection) → pool → next layer, with real activation sparsity flowing
//! through, as in the paper's Fig 3 system loop.

use super::job::ConvJob;
use super::report::LayerRecord;
use crate::baselines::{ideal_speedups, SpeedupSeries};
use crate::model::init::Params;
use crate::model::{LayerKind, Network};
use crate::runtime::Runtime;
use crate::sim::config::SimConfig;
use crate::sim::postproc;
use crate::sim::mapping::simulate_layer_any;
use crate::sim::scheduler::Mode;
use crate::sim::stats::SimStats;
use crate::sim::trace::Trace;
use crate::sparse::encode::layer_report;
use crate::tensor::conv::maxpool2x2;
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Which engine computes the functional forward pass.
#[derive(Clone)]
pub enum FunctionalBackend {
    /// Scalar golden conv — slow, for tiny runs and tests.
    Golden,
    /// Multithreaded im2col conv (the default fast path).
    Im2colMt(usize),
    /// PJRT executing the AOT artifacts of the given kind
    /// (`"ref"` = lax.conv, `"vscnn"` = Pallas column kernel).
    Pjrt(Arc<Runtime>, String),
}

impl std::fmt::Debug for FunctionalBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FunctionalBackend::Golden => write!(f, "Golden"),
            FunctionalBackend::Im2colMt(t) => write!(f, "Im2colMt({t})"),
            FunctionalBackend::Pjrt(_, k) => write!(f, "Pjrt({k})"),
        }
    }
}

/// Options for one network run.
#[derive(Debug, Clone)]
pub struct RunOptions {
    pub sim: SimConfig,
    pub backend: FunctionalBackend,
    /// Also run the simulator's own functional dataflow per layer and
    /// assert it matches the backend (expensive; tests/small runs only).
    pub verify_dataflow: bool,
}

impl RunOptions {
    pub fn new(sim: SimConfig) -> RunOptions {
        RunOptions {
            sim,
            backend: FunctionalBackend::Im2colMt(
                std::thread::available_parallelism().map_or(4, |n| n.get()),
            ),
            verify_dataflow: false,
        }
    }
}

/// Result of running one image through the network on one configuration.
#[derive(Debug, Clone)]
pub struct NetworkReport {
    pub network: String,
    pub config_label: String,
    pub layers: Vec<LayerRecord>,
    pub totals: SimStats,
    pub total_dense_cycles: u64,
}

impl NetworkReport {
    /// Whole-network speedup over the dense flow (the paper's headline
    /// 1.871x / 1.93x metric).
    pub fn overall_speedup(&self) -> f64 {
        self.total_dense_cycles as f64 / self.totals.cycles.max(1) as f64
    }

    /// Whole-network ideal-machine speedups (cycle-weighted, same
    /// aggregation as the per-layer ones).
    pub fn overall_series(&self) -> SpeedupSeries {
        let (mut pairs_t, mut pairs_nz) = (0u64, 0u64);
        let (mut macs_t, mut macs_nz) = (0u64, 0u64);
        for l in &self.layers {
            pairs_t += l.density.pairs_total;
            pairs_nz += l.density.pairs_nonzero;
            macs_t += l.density.macs_total;
            macs_nz += l.density.macs_nonzero;
        }
        SpeedupSeries {
            ours: self.overall_speedup(),
            ideal_vector: pairs_t as f64 / pairs_nz.max(1) as f64,
            ideal_fine: macs_t as f64 / macs_nz.max(1) as f64,
        }
    }

    pub fn to_json(&self) -> Json {
        let series = self.overall_series();
        let mut o = Json::obj();
        o.set("network", self.network.as_str())
            .set("config", self.config_label.as_str())
            .set("overall_speedup", series.ours)
            .set("overall_ideal_vector", series.ideal_vector)
            .set("overall_ideal_fine", series.ideal_fine)
            .set("vector_skip_efficiency", series.vector_skip_efficiency())
            .set("fine_skip_efficiency", series.fine_skip_efficiency())
            .set("total_cycles", self.totals.cycles)
            .set("total_dense_cycles", self.total_dense_cycles)
            .set(
                "layers",
                Json::Arr(self.layers.iter().map(|l| l.to_json()).collect()),
            );
        o
    }
}

/// Drives a (pruned) network through the accelerator model.
pub struct Coordinator {
    pub net: Network,
    pub params: Params,
}

impl Coordinator {
    /// `params` must hold (possibly pruned) weights for every conv layer.
    pub fn new(net: Network, params: Params) -> Coordinator {
        Coordinator { net, params }
    }

    /// Run one image through the network; returns per-layer records with
    /// the activation sparsity produced by this very input.
    pub fn run(&self, input: &Tensor, opts: &RunOptions) -> Result<NetworkReport> {
        assert_eq!(
            input.shape(),
            &self.net.input_shape,
            "input shape mismatch"
        );
        let mut act = input.clone();
        let mut layers = Vec::new();
        let mut totals = SimStats::default();
        let mut total_dense = 0u64;

        for layer in &self.net.layers {
            match &layer.kind {
                LayerKind::Conv { .. } => {
                    let params = self
                        .params
                        .get(&layer.name)
                        .with_context(|| format!("missing params for {}", layer.name))?;
                    let job = ConvJob::new(&layer.name, &layer.kind, &act, params);

                    // --- timing (vector-sparse flow) --------------------
                    let mut trace = Trace::disabled();
                    let res = simulate_layer_any(
                        job.input,
                        &params.weight,
                        Some(&params.bias),
                        &opts.sim,
                        job.spec,
                        Mode::VectorSparse,
                        false,
                        &mut trace,
                    );

                    // --- densities / ideal baselines --------------------
                    let density =
                        layer_report(job.input, &params.weight, job.spec, opts.sim.pe.rows);
                    let (ideal_vector, ideal_fine) = ideal_speedups(&density);

                    // --- functional forward ------------------------------
                    let out = self.forward_conv(&job, opts)?;
                    if opts.verify_dataflow {
                        let mut tr = Trace::disabled();
                        let fres = simulate_layer_any(
                            job.input,
                            &params.weight,
                            Some(&params.bias),
                            &opts.sim,
                            job.spec,
                            Mode::VectorSparse,
                            true,
                            &mut tr,
                        );
                        let sim_out = fres.output.expect("functional mode");
                        anyhow::ensure!(
                            sim_out.allclose(&out, 1e-2, 1e-2),
                            "{}: dataflow output diverges from backend by {}",
                            layer.name,
                            sim_out.max_abs_diff(&out)
                        );
                    }

                    // --- post-processing (ReLU + zero detection) --------
                    let post = postproc::postprocess(out, opts.sim.pe.rows);
                    let mut stats = res.stats;
                    if let Some(va) = &post.compressed {
                        stats.dram.output_write =
                            postproc::output_dram_bytes(va, opts.sim.sram.bytes_per_elem, 2);
                    }

                    let record = LayerRecord {
                        name: layer.name.clone(),
                        density,
                        sparse: stats,
                        dense_cycles: res.dense_cycles,
                        speedups: SpeedupSeries {
                            ours: res.dense_cycles as f64 / stats.cycles.max(1) as f64,
                            ideal_vector,
                            ideal_fine,
                        },
                        output_density_elem: post.output.density(),
                    };
                    totals.merge(&record.sparse);
                    total_dense += record.dense_cycles;
                    layers.push(record);
                    act = post.output;
                }
                LayerKind::Relu => {
                    // ReLU already applied by the conv post-processing;
                    // applying again is a no-op (idempotent).
                }
                LayerKind::MaxPool2 => {
                    act = maxpool2x2(&act);
                }
                LayerKind::Linear { .. } => {
                    // FC head is out of the accelerator evaluation scope.
                }
            }
        }

        Ok(NetworkReport {
            network: self.net.name.clone(),
            config_label: opts.sim.pe.label(),
            layers,
            totals,
            total_dense_cycles: total_dense,
        })
    }

    fn forward_conv(&self, job: &ConvJob<'_>, opts: &RunOptions) -> Result<Tensor> {
        Ok(match &opts.backend {
            FunctionalBackend::Golden => crate::tensor::conv::conv2d(
                job.input,
                &job.params.weight,
                Some(&job.params.bias),
                job.spec,
            ),
            FunctionalBackend::Im2colMt(threads) => crate::tensor::ops::conv2d_im2col_mt(
                job.input,
                &job.params.weight,
                Some(&job.params.bias),
                job.spec,
                *threads,
            ),
            FunctionalBackend::Pjrt(rt, kind) => rt
                .run_conv_by_shape(kind, job.input, &job.params.weight, &job.params.bias)
                .with_context(|| format!("PJRT conv for {}", job.name))?,
        })
    }

    /// Run a batch of images, returning one report each.
    ///
    /// Images are independent, so the batch fans out across scoped worker
    /// threads. The run's thread budget is *split* across the batch
    /// workers (each per-image run gets `budget / workers` simulator and
    /// backend threads), so nested parallelism stays within the configured
    /// budget instead of multiplying it — `--threads 1` really is
    /// single-threaded. Each image's report is identical to a sequential
    /// `run`; the returned order matches the input order, and an error
    /// short-circuits the rest of its worker's chunk.
    pub fn run_batch(&self, inputs: &[Tensor], opts: &RunOptions) -> Result<Vec<NetworkReport>> {
        let budget = opts.sim.effective_threads();
        let workers = budget.min(inputs.len().max(1));
        let mut inner = opts.clone();
        inner.sim.threads = (budget / workers).max(1);
        if let FunctionalBackend::Im2colMt(t) = &mut inner.backend {
            *t = (*t / workers).max(1);
        }
        let inner = &inner;
        let chunks: Result<Vec<Vec<NetworkReport>>> =
            crate::util::par_chunk_map(inputs.len(), workers, |range| {
                inputs[range].iter().map(|x| self.run(x, inner)).collect()
            })
            .into_iter()
            .collect();
        Ok(chunks?.into_iter().flatten().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{synthetic_image, synthetic_params};
    use crate::model::vgg16::tiny_vgg;
    use crate::pruning;
    use crate::pruning::sensitivity::flat_schedule;

    fn setup(seed: u64) -> (Coordinator, Tensor) {
        let net = tiny_vgg(8);
        let mut params = synthetic_params(&net, seed, 0.0);
        let sched = flat_schedule(&net, 0.4);
        pruning::prune_network_vectors(&mut params, &sched);
        let img = synthetic_image(net.input_shape, seed);
        (Coordinator::new(net, params), img)
    }

    fn small_opts() -> RunOptions {
        let mut cfg = SimConfig::paper_4_14_3();
        cfg.pe.arrays = 2;
        cfg.pe.rows = 4;
        RunOptions {
            sim: cfg,
            backend: FunctionalBackend::Golden,
            verify_dataflow: true,
        }
    }

    #[test]
    fn run_produces_record_per_conv_and_verifies_dataflow() {
        let (coord, img) = setup(1);
        let report = coord.run(&img, &small_opts()).unwrap();
        assert_eq!(report.layers.len(), 4);
        assert!(report.overall_speedup() >= 1.0, "{}", report.overall_speedup());
        // Activation densities must be in (0,1] and recorded.
        for l in &report.layers {
            assert!(l.output_density_elem > 0.0 && l.output_density_elem <= 1.0);
            assert!(l.speedups.ours <= l.speedups.ideal_vector + 1e-9);
        }
    }

    #[test]
    fn backends_agree() {
        let (coord, img) = setup(2);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let golden = coord.run(&img, &opts).unwrap();
        opts.backend = FunctionalBackend::Im2colMt(3);
        let mt = coord.run(&img, &opts).unwrap();
        // Cycle counts are input-data dependent; identical backends must
        // produce identical sparsity → identical cycles.
        assert_eq!(golden.totals.cycles, mt.totals.cycles);
        for (a, b) in golden.layers.iter().zip(&mt.layers) {
            assert!((a.output_density_elem - b.output_density_elem).abs() < 1e-9);
        }
    }

    #[test]
    fn run_batch_parallel_matches_sequential() {
        let net = tiny_vgg(8);
        let mut params = synthetic_params(&net, 7, 0.0);
        pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.4));
        let imgs = crate::model::init::synthetic_batch(net.input_shape, 3, 7);
        let coord = Coordinator::new(net, params);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let batch = coord.run_batch(&imgs, &opts).unwrap();
        assert_eq!(batch.len(), 3);
        for (img, rep) in imgs.iter().zip(&batch) {
            let solo = coord.run(img, &opts).unwrap();
            assert_eq!(solo.totals.cycles, rep.totals.cycles);
            assert_eq!(solo.total_dense_cycles, rep.total_dense_cycles);
            assert_eq!(solo.network, rep.network);
        }
    }

    #[test]
    fn report_json_well_formed() {
        let (coord, img) = setup(3);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let report = coord.run(&img, &opts).unwrap();
        let j = report.to_json();
        assert!(j.get("overall_speedup").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(j.get("layers").unwrap().as_arr().unwrap().len(), 4);
        // Round-trips through the parser.
        let text = j.pretty();
        assert_eq!(crate::util::json::Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn denser_pruning_schedule_is_slower() {
        let net = tiny_vgg(8);
        let img = synthetic_image(net.input_shape, 4);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let mut cycles = Vec::new();
        for density in [0.2, 0.6, 1.0] {
            let mut params = synthetic_params(&net, 4, 0.0);
            let sched = flat_schedule(&net, density);
            pruning::prune_network_vectors(&mut params, &sched);
            let coord = Coordinator::new(net.clone(), params);
            cycles.push(coord.run(&img, &opts).unwrap().totals.cycles);
        }
        assert!(cycles[0] <= cycles[1] && cycles[1] <= cycles[2], "{cycles:?}");
    }
}
