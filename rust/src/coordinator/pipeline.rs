//! Compatibility shim over the compile/execute engine.
//!
//! Historically this module *was* the pipeline: it re-encoded every conv
//! layer's weights into CVF and recomputed the weight-side densities per
//! image. That work now happens exactly once in [`crate::engine::compile`];
//! [`Coordinator`] keeps the old construct-and-run API on top of the
//! engine (same reports, bit-identical numbers) for callers that don't
//! need to manage [`PreparedNetwork`]s themselves.

use crate::engine::{self, CompileOptions, Engine, PreparedNetwork, PAPER_COLS};
use crate::model::init::Params;
use crate::model::Network;
use crate::tensor::Tensor;
use anyhow::Result;
use std::sync::{Arc, Mutex};

// Re-exported from the engine for source compatibility with pre-split
// callers (`coordinator::{RunOptions, FunctionalBackend, NetworkReport}`).
pub use crate::engine::{FunctionalBackend, NetworkReport, RunOptions};

/// Drives a (pruned) network through the accelerator model.
///
/// Construction compiles the network once (CVF weight encoding, kernel
/// mapping, weight-side stats) for the paper's 3-column array geometry;
/// runs against other column counts recompile the mapping plans lazily and
/// cache them. Use [`crate::engine`] directly to share one compile across
/// coordinators or to control pruning/calibration at compile time.
pub struct Coordinator {
    pub net: Network,
    /// Compiled plans by PE-column count (index 0 = construction compile).
    prepared: Mutex<Vec<Arc<PreparedNetwork>>>,
}

impl Coordinator {
    /// `params` must hold (possibly pruned) weights for every conv layer.
    pub fn new(net: Network, params: Params) -> Coordinator {
        let prepared = engine::compile(&net, params, &CompileOptions::new(PAPER_COLS));
        Coordinator {
            net,
            prepared: Mutex::new(vec![Arc::new(prepared)]),
        }
    }

    /// Wrap an already-compiled network (shares the compile, no re-work).
    pub fn from_prepared(prepared: Arc<PreparedNetwork>) -> Coordinator {
        Coordinator {
            net: prepared.net.clone(),
            prepared: Mutex::new(vec![prepared]),
        }
    }

    fn engine_for(&self, cols: usize) -> Engine {
        // Fast path: short lock, no work held under it.
        let base = {
            let cache = self.prepared.lock().unwrap();
            if let Some(p) = cache.iter().find(|p| p.cols == cols) {
                return Engine::new(p.clone());
            }
            cache[0].clone()
        };
        // Recompile outside the lock so concurrent runs at an
        // already-compiled geometry never block on it; re-check before
        // inserting in case another thread raced us to the same cols.
        let p = Arc::new(base.recompiled(cols));
        let mut cache = self.prepared.lock().unwrap();
        if let Some(existing) = cache.iter().find(|p| p.cols == cols) {
            return Engine::new(existing.clone());
        }
        cache.push(p.clone());
        Engine::new(p)
    }

    /// Run one image through the network; returns per-layer records with
    /// the activation sparsity produced by this very input.
    pub fn run(&self, input: &Tensor, opts: &RunOptions) -> Result<NetworkReport> {
        self.engine_for(opts.sim.pe.cols).run_image(input, opts)
    }

    /// Run a batch of images, returning one report each (see
    /// [`Engine::run_batch`] for the threading contract).
    pub fn run_batch(&self, inputs: &[Tensor], opts: &RunOptions) -> Result<Vec<NetworkReport>> {
        self.engine_for(opts.sim.pe.cols).run_batch(inputs, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{synthetic_image, synthetic_params};
    use crate::model::vgg16::tiny_vgg;
    use crate::pruning;
    use crate::pruning::sensitivity::flat_schedule;
    use crate::sim::config::SimConfig;

    fn setup(seed: u64) -> (Coordinator, Tensor) {
        let net = tiny_vgg(8);
        let mut params = synthetic_params(&net, seed, 0.0);
        let sched = flat_schedule(&net, 0.4);
        pruning::prune_network_vectors(&mut params, &sched);
        let img = synthetic_image(net.input_shape, seed);
        (Coordinator::new(net, params), img)
    }

    fn small_opts() -> RunOptions {
        let mut cfg = SimConfig::paper_4_14_3();
        cfg.pe.arrays = 2;
        cfg.pe.rows = 4;
        RunOptions {
            sim: cfg,
            backend: FunctionalBackend::Golden,
            verify_dataflow: true,
            fuse: false,
            sdc: None,
        }
    }

    #[test]
    fn run_produces_record_per_conv_and_verifies_dataflow() {
        let (coord, img) = setup(1);
        let report = coord.run(&img, &small_opts()).unwrap();
        assert_eq!(report.layers.len(), 4);
        assert!(report.overall_speedup() >= 1.0, "{}", report.overall_speedup());
        // Activation densities must be in (0,1] and recorded.
        for l in &report.layers {
            assert!(l.output_density_elem > 0.0 && l.output_density_elem <= 1.0);
            assert!(l.speedups.ours <= l.speedups.ideal_vector + 1e-9);
        }
    }

    #[test]
    fn backends_agree() {
        let (coord, img) = setup(2);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let golden = coord.run(&img, &opts).unwrap();
        opts.backend = FunctionalBackend::Im2colMt(3);
        let mt = coord.run(&img, &opts).unwrap();
        // Cycle counts are input-data dependent; identical backends must
        // produce identical sparsity → identical cycles.
        assert_eq!(golden.totals.cycles, mt.totals.cycles);
        for (a, b) in golden.layers.iter().zip(&mt.layers) {
            assert!((a.output_density_elem - b.output_density_elem).abs() < 1e-9);
        }
    }

    #[test]
    fn run_batch_parallel_matches_sequential() {
        let net = tiny_vgg(8);
        let mut params = synthetic_params(&net, 7, 0.0);
        pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.4));
        let imgs = crate::model::init::synthetic_batch(net.input_shape, 3, 7);
        let coord = Coordinator::new(net, params);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let batch = coord.run_batch(&imgs, &opts).unwrap();
        assert_eq!(batch.len(), 3);
        for (img, rep) in imgs.iter().zip(&batch) {
            let solo = coord.run(img, &opts).unwrap();
            assert_eq!(solo.totals.cycles, rep.totals.cycles);
            assert_eq!(solo.total_dense_cycles, rep.total_dense_cycles);
            assert_eq!(solo.network, rep.network);
        }
    }

    #[test]
    fn report_json_well_formed() {
        let (coord, img) = setup(3);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let report = coord.run(&img, &opts).unwrap();
        let j = report.to_json();
        assert!(j.get("overall_speedup").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(j.get("layers").unwrap().as_arr().unwrap().len(), 4);
        // Round-trips through the parser.
        let text = j.pretty();
        assert_eq!(crate::util::json::Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn denser_pruning_schedule_is_slower() {
        let net = tiny_vgg(8);
        let img = synthetic_image(net.input_shape, 4);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let mut cycles = Vec::new();
        for density in [0.2, 0.6, 1.0] {
            let mut params = synthetic_params(&net, 4, 0.0);
            let sched = flat_schedule(&net, density);
            pruning::prune_network_vectors(&mut params, &sched);
            let coord = Coordinator::new(net.clone(), params);
            cycles.push(coord.run(&img, &opts).unwrap().totals.cycles);
        }
        assert!(cycles[0] <= cycles[1] && cycles[1] <= cycles[2], "{cycles:?}");
    }

    #[test]
    fn shim_recompiles_for_non_paper_columns() {
        // The compatibility shim transparently serves a 4-column run from
        // the same coordinator (recompiled mapping plans, shared weights).
        let (coord, img) = setup(5);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let c3 = coord.run(&img, &opts).unwrap();
        opts.sim.pe.cols = 4;
        let c4 = coord.run(&img, &opts).unwrap();
        assert_eq!(c3.layers.len(), c4.layers.len());
        // 3-tall kernels on a 4-column array waste the 4th column — never
        // faster than the native geometry on the same data.
        assert!(c4.totals.cycles >= c3.totals.cycles);
    }
}
