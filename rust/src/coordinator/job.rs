//! Per-layer job descriptors: which conv layer, which tensors, which PE
//! configuration — the unit of work the pipeline hands to the simulator.

use crate::model::init::LayerParams;
use crate::model::LayerKind;
use crate::tensor::conv::ConvSpec;
use crate::tensor::Tensor;

/// One conv layer ready to simulate.
#[derive(Debug)]
pub struct ConvJob<'a> {
    pub name: &'a str,
    pub input: &'a Tensor,
    pub params: &'a LayerParams,
    pub spec: ConvSpec,
}

impl<'a> ConvJob<'a> {
    /// Build a job from a layer descriptor, checking geometry.
    pub fn new(
        name: &'a str,
        kind: &LayerKind,
        input: &'a Tensor,
        params: &'a LayerParams,
    ) -> ConvJob<'a> {
        let LayerKind::Conv { c_in, c_out, k, spec } = kind else {
            panic!("ConvJob on non-conv layer {name}");
        };
        assert_eq!(input.shape()[0], *c_in, "{name}: input channels");
        assert_eq!(params.weight.shape(), &[*c_out, *c_in, *k, *k], "{name}: weight shape");
        assert_eq!(params.bias.len(), *c_out, "{name}: bias length");
        ConvJob {
            name,
            input,
            params,
            spec: *spec,
        }
    }

    /// Dense MACs of this job.
    pub fn macs(&self) -> u64 {
        let [_, h, w] = [self.input.shape()[0], self.input.shape()[1], self.input.shape()[2]];
        let ws = self.params.weight.shape();
        let ho = crate::tensor::conv::out_dim(h, ws[2], self.spec) as u64;
        let wo = crate::tensor::conv::out_dim(w, ws[3], self.spec) as u64;
        ws[0] as u64 * ws[1] as u64 * ws[2] as u64 * ws[3] as u64 * ho * wo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::synthetic_params;
    use crate::model::vgg16::tiny_vgg;

    #[test]
    fn job_checks_geometry() {
        let net = tiny_vgg(8);
        let params = synthetic_params(&net, 1, 0.0);
        let input = Tensor::zeros(&[3, 8, 8]);
        let layer = &net.layers[0];
        let job = ConvJob::new(&layer.name, &layer.kind, &input, &params["c1_1"]);
        assert_eq!(job.macs(), 8 * 3 * 9 * 64);
    }

    #[test]
    #[should_panic(expected = "input channels")]
    fn job_rejects_wrong_channels() {
        let net = tiny_vgg(8);
        let params = synthetic_params(&net, 1, 0.0);
        let input = Tensor::zeros(&[4, 8, 8]);
        let layer = &net.layers[0];
        let _ = ConvJob::new(&layer.name, &layer.kind, &input, &params["c1_1"]);
    }
}
