//! Per-layer and per-network records — the data behind every figure.
//!
//! The record types live in [`crate::engine`] (the layer that produces
//! them); this module re-exports them for source compatibility and keeps
//! the rendering helpers.

pub use crate::engine::LayerRecord;

/// Render an ASCII table of layer records with selected columns.
pub fn ascii_table(rows: &[(String, Vec<(String, f64)>)]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let headers: Vec<&str> = std::iter::once("layer")
        .chain(rows[0].1.iter().map(|(h, _)| h.as_str()))
        .collect();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    let mut body: Vec<Vec<String>> = Vec::new();
    for (name, cols) in rows {
        let mut line = vec![name.clone()];
        for (_, v) in cols {
            line.push(format!("{v:.3}"));
        }
        for (i, cell) in line.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
        body.push(line);
    }
    let render_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{c:>w$}", w = widths[i]))
            .collect::<Vec<_>>()
            .join(" | ")
    };
    let header_line = render_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let sep = widths
        .iter()
        .map(|w| "-".repeat(*w))
        .collect::<Vec<_>>()
        .join("-+-");
    let mut out = format!("{header_line}\n{sep}\n");
    for line in body {
        out.push_str(&render_row(&line));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_table_alignment() {
        let rows = vec![
            ("conv1_1".to_string(), vec![("speedup".to_string(), 1.871)]),
            ("c2".to_string(), vec![("speedup".to_string(), 12.0)]),
        ];
        let t = ascii_table(&rows);
        assert!(t.contains("layer"));
        assert!(t.contains("speedup"));
        assert!(t.contains("1.871"));
        assert!(t.contains("12.000"));
        // All lines equal width.
        let lens: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{lens:?}");
    }

    #[test]
    fn empty_table_is_empty() {
        assert_eq!(ascii_table(&[]), "");
    }
}
