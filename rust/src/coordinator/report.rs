//! Per-layer and per-network records — the data behind every figure.

use crate::baselines::SpeedupSeries;
use crate::sim::stats::SimStats;
use crate::sparse::encode::DensityReport;
use crate::util::json::Json;

/// Everything measured for one conv layer in one run.
#[derive(Debug, Clone)]
pub struct LayerRecord {
    pub name: String,
    /// Input/weight/work densities at both granularities.
    pub density: DensityReport,
    /// Vector-sparse flow stats (the design under test).
    pub sparse: SimStats,
    /// Dense-flow cycle count (speedup denominator).
    pub dense_cycles: u64,
    /// Speedups: ours vs the ideal machines.
    pub speedups: SpeedupSeries,
    /// Post-ReLU output density (what the next layer sees).
    pub output_density_elem: f64,
}

impl LayerRecord {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("input_density_elem", self.density.input_elem)
            .set("weight_density_elem", self.density.weight_elem)
            .set("work_density_elem", self.density.work_elem)
            .set("input_density_vec", self.density.input_vec)
            .set("weight_density_vec", self.density.weight_vec)
            .set("work_density_vec", self.density.work_vec)
            .set("cycles", self.sparse.cycles)
            .set("dense_cycles", self.dense_cycles)
            .set("speedup", self.speedups.ours)
            .set("speedup_ideal_vector", self.speedups.ideal_vector)
            .set("speedup_ideal_fine", self.speedups.ideal_fine)
            .set("utilization", self.sparse.utilization())
            .set("output_density_elem", self.output_density_elem)
            .set("stats", self.sparse.to_json());
        o
    }
}

/// Render an ASCII table of layer records with selected columns.
pub fn ascii_table(rows: &[(String, Vec<(String, f64)>)]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let headers: Vec<&str> = std::iter::once("layer")
        .chain(rows[0].1.iter().map(|(h, _)| h.as_str()))
        .collect();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    let mut body: Vec<Vec<String>> = Vec::new();
    for (name, cols) in rows {
        let mut line = vec![name.clone()];
        for (_, v) in cols {
            line.push(format!("{v:.3}"));
        }
        for (i, cell) in line.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
        body.push(line);
    }
    let render_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{c:>w$}", w = widths[i]))
            .collect::<Vec<_>>()
            .join(" | ")
    };
    let header_line = render_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let sep = widths
        .iter()
        .map(|w| "-".repeat(*w))
        .collect::<Vec<_>>()
        .join("-+-");
    let mut out = format!("{header_line}\n{sep}\n");
    for line in body {
        out.push_str(&render_row(&line));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_table_alignment() {
        let rows = vec![
            ("conv1_1".to_string(), vec![("speedup".to_string(), 1.871)]),
            ("c2".to_string(), vec![("speedup".to_string(), 12.0)]),
        ];
        let t = ascii_table(&rows);
        assert!(t.contains("layer"));
        assert!(t.contains("speedup"));
        assert!(t.contains("1.871"));
        assert!(t.contains("12.000"));
        // All lines equal width.
        let lens: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{lens:?}");
    }

    #[test]
    fn empty_table_is_empty() {
        assert_eq!(ascii_table(&[]), "");
    }
}
