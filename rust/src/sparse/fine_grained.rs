//! Fine-grained (element-granularity) sparse format — the structure the
//! comparison designs (Cambricon-X [15], SCNN [16]) index at, used here for
//! the ideal fine-grained baseline and the Fig 9 density series.

use crate::tensor::Tensor;

/// CSR-like element-sparse view of a flat tensor: per-row nonzero column
/// indices. For activations a "row" is one `(c, h)` scanline; for weights,
/// one `(k, c, kh)` kernel row.
#[derive(Debug, Clone)]
pub struct FineGrained {
    pub rows: usize,
    pub cols: usize,
    /// Row-pointer array (CSR `indptr`), len `rows + 1`.
    indptr: Vec<usize>,
    /// Column indices of nonzeros, grouped by row.
    indices: Vec<u32>,
    /// Nonzero values (same order as `indices`).
    values: Vec<f32>,
}

impl FineGrained {
    /// Encode any tensor as a 2-D CSR by flattening all but the last dim.
    pub fn from_tensor(t: &Tensor) -> FineGrained {
        let cols = *t.shape().last().expect("scalar tensor");
        let rows = t.len() / cols.max(1);
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                let v = t.data()[r * cols + c];
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        FineGrained {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Element-granularity density (the Fig 9 series).
    pub fn density(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    /// Nonzero `(col, value)` pairs of row `r`.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi]
            .iter()
            .copied()
            .zip(self.values[lo..hi].iter().copied())
    }

    /// Reconstruct the dense tensor (for round-trip tests).
    pub fn to_tensor(&self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.rows * self.cols);
        let mut t = Tensor::zeros(shape);
        for r in 0..self.rows {
            for (c, v) in self.row(r) {
                t.data_mut()[r * self.cols + c as usize] = v;
            }
        }
        t
    }

    /// Storage cost in elements + index entries (for the overhead
    /// comparison against the vector format in the ablation bench).
    pub fn storage_entries(&self) -> (usize, usize) {
        (self.values.len(), self.indices.len() + self.indptr.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn roundtrip_dense() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        let fg = FineGrained::from_tensor(&t);
        assert_eq!(fg.nnz(), 3);
        assert!((fg.density() - 0.5).abs() < 1e-12);
        assert_eq!(fg.to_tensor(&[2, 3]), t);
    }

    #[test]
    fn row_iteration() {
        let t = Tensor::from_vec(&[2, 3], vec![0.0, 5.0, 0.0, 7.0, 0.0, 9.0]);
        let fg = FineGrained::from_tensor(&t);
        let r0: Vec<(u32, f32)> = fg.row(0).collect();
        assert_eq!(r0, vec![(1, 5.0)]);
        let r1: Vec<(u32, f32)> = fg.row(1).collect();
        assert_eq!(r1, vec![(0, 7.0), (2, 9.0)]);
    }

    #[test]
    fn empty_rows_ok() {
        let t = Tensor::zeros(&[3, 4]);
        let fg = FineGrained::from_tensor(&t);
        assert_eq!(fg.nnz(), 0);
        assert_eq!(fg.density(), 0.0);
        assert_eq!(fg.row(1).count(), 0);
        assert_eq!(fg.to_tensor(&[3, 4]), t);
    }

    #[test]
    fn randomized_roundtrip() {
        let mut rng = Pcg32::seeded(55);
        for _ in 0..30 {
            let rows = rng.range(1, 16);
            let cols = rng.range(1, 16);
            let data: Vec<f32> = (0..rows * cols)
                .map(|_| if rng.bernoulli(0.35) { rng.normal() } else { 0.0 })
                .collect();
            let t = Tensor::from_vec(&[rows, cols], data);
            let fg = FineGrained::from_tensor(&t);
            assert_eq!(fg.to_tensor(&[rows, cols]), t);
            assert_eq!(fg.nnz(), t.count_nonzero());
        }
    }

    #[test]
    fn four_dim_weights_flatten() {
        let mut w = Tensor::zeros(&[2, 2, 3, 3]);
        *w.at4_mut(1, 0, 2, 1) = 4.0;
        let fg = FineGrained::from_tensor(&w);
        assert_eq!(fg.nnz(), 1);
        assert_eq!(fg.to_tensor(&[2, 2, 3, 3]), w);
    }
}
