//! Sparse data formats for the VSCNN index system.
//!
//! The paper's key idea is **vector sparsity**: instead of tracking single
//! zero elements (fine-grained, Fig 1), zeros are tracked at the granularity
//! of whole 1-D vectors (Fig 2):
//!
//! * an **input activation vector** is an `R`-element column strip — `R` =
//!   PE-array rows (14 or 7) — of one channel at one spatial column;
//! * a **weight vector** is one kernel column (`KH` elements, 3 for VGG) of
//!   one `(k_out, c_in)` filter plane.
//!
//! All-zero vectors are *not stored in SRAM* and are never issued to the PE
//! array; a per-vector index keeps accumulation correct. This module holds
//! the compressed-vector format ([`vector_format`]), the fine-grained CSR
//! used by the comparison baselines ([`fine_grained`]), the encoders and the
//! density statistics behind Figs 9–11 ([`encode`]).

pub mod bitset;
pub mod encode;
pub mod fine_grained;
pub mod vector_format;

pub use bitset::Bitset;
pub use vector_format::{VectorActivations, VectorWeights};
