//! Compact bitset used for vector-occupancy maps.
//!
//! The simulator precomputes, per layer, one occupancy bit per candidate
//! vector; scheduling then iterates set bits instead of scanning floats —
//! this is the software analogue of the paper's "only nonzero vectors are
//! in SRAM" property and is also the simulator's main speed lever.

/// Fixed-size bitset backed by `u64` words.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitset {
    len: usize,
    words: Vec<u64>,
}

impl Bitset {
    /// All-zeros bitset of `len` bits.
    pub fn new(len: usize) -> Bitset {
        Bitset {
            len,
            words: vec![0; len.div_ceil(64)],
        }
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the bitset holds no bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set bit `i` to `v`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Fraction of set bits (vector-granularity density).
    pub fn density(&self) -> f64 {
        if self.len == 0 {
            0.0
        } else {
            self.count_ones() as f64 / self.len as f64
        }
    }

    /// Iterate indices of set bits in increasing order.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(move |(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Count of set bits within `[lo, hi)`.
    pub fn count_ones_in(&self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi && hi <= self.len);
        (lo..hi).filter(|&i| self.get(i)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut b = Bitset::new(130);
        for i in [0, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!b.get(i));
            b.set(i, true);
            assert!(b.get(i));
        }
        assert_eq!(b.count_ones(), 8);
        b.set(64, false);
        assert!(!b.get(64));
        assert_eq!(b.count_ones(), 7);
    }

    #[test]
    fn iter_ones_in_order() {
        let mut b = Bitset::new(200);
        let set = [3usize, 64, 65, 130, 199];
        for &i in &set {
            b.set(i, true);
        }
        let got: Vec<usize> = b.iter_ones().collect();
        assert_eq!(got, set);
    }

    #[test]
    fn density_and_range_count() {
        let mut b = Bitset::new(10);
        b.set(2, true);
        b.set(7, true);
        assert!((b.density() - 0.2).abs() < 1e-12);
        assert_eq!(b.count_ones_in(0, 5), 1);
        assert_eq!(b.count_ones_in(5, 10), 1);
        assert_eq!(b.count_ones_in(3, 7), 0);
    }

    #[test]
    fn empty_bitset() {
        let b = Bitset::new(0);
        assert!(b.is_empty());
        assert_eq!(b.count_ones(), 0);
        assert_eq!(b.density(), 0.0);
        assert_eq!(b.iter_ones().count(), 0);
    }

    #[test]
    fn randomized_matches_reference_vec_bool() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(31);
        for _ in 0..20 {
            let n = rng.range(1, 300);
            let mut b = Bitset::new(n);
            let mut r = vec![false; n];
            for _ in 0..n {
                let i = rng.range(0, n);
                let v = rng.bernoulli(0.5);
                b.set(i, v);
                r[i] = v;
            }
            assert_eq!(b.count_ones(), r.iter().filter(|&&x| x).count());
            let got: Vec<usize> = b.iter_ones().collect();
            let want: Vec<usize> =
                r.iter().enumerate().filter(|(_, &x)| x).map(|(i, _)| i).collect();
            assert_eq!(got, want);
        }
    }
}
