//! Density and work statistics at element and vector granularity — the
//! quantities plotted in the paper's Figs 9, 10 and 11 and consumed by the
//! ideal baselines in [`crate::baselines`].
//!
//! * *density* — fraction of nonzero entries (elements or vectors);
//! * *work*   — fraction of MAC work that remains when zeros are skipped at
//!   the given granularity. At element granularity a MAC survives iff both
//!   its operands are nonzero; at vector granularity a PE-array cycle
//!   survives iff both its input vector and weight vector are nonzero.

use crate::sparse::vector_format::{VectorActivations, VectorWeights};
use crate::tensor::conv::ConvSpec;
use crate::tensor::Tensor;

/// Per-layer sparsity/work report (one layer of Fig 9/10/11 + the work
/// totals the speedup figures divide).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DensityReport {
    /// Element-granularity input activation density (Fig 9 "input").
    pub input_elem: f64,
    /// Element-granularity weight density (Fig 9 "weight").
    pub weight_elem: f64,
    /// Element-granularity surviving-work fraction (Fig 9 "work").
    pub work_elem: f64,
    /// Vector-granularity input density (Fig 10/11 "input").
    pub input_vec: f64,
    /// Vector-granularity weight density (Fig 10/11 "weight").
    pub weight_vec: f64,
    /// Vector-granularity surviving-work fraction (Fig 10/11 "work").
    pub work_vec: f64,
    /// Total MACs of the dense layer.
    pub macs_total: u64,
    /// MACs surviving fine-grained skipping.
    pub macs_nonzero: u64,
    /// Total (input vector × weight vector) issue pairs of the dense layer.
    pub pairs_total: u64,
    /// Pairs surviving vector skipping.
    pub pairs_nonzero: u64,
}

/// 2-D inclusive prefix-sum of a nonzero-indicator plane, for O(1)
/// "nonzeros inside rectangle" queries during the exact fine-grained work
/// count.
struct PrefixNnz {
    h: usize,
    w: usize,
    /// `(h+1) x (w+1)` summed-area table.
    sat: Vec<u32>,
}

impl PrefixNnz {
    fn from_channel(t: &Tensor, c: usize) -> PrefixNnz {
        let (h, w) = (t.shape()[1], t.shape()[2]);
        let mut sat = vec![0u32; (h + 1) * (w + 1)];
        for i in 0..h {
            for j in 0..w {
                let nz = (t.at3(c, i, j) != 0.0) as u32;
                sat[(i + 1) * (w + 1) + (j + 1)] = nz
                    + sat[i * (w + 1) + (j + 1)]
                    + sat[(i + 1) * (w + 1) + j]
                    - sat[i * (w + 1) + j];
            }
        }
        PrefixNnz { h, w, sat }
    }

    /// Nonzeros in rows `[r0, r1]` × cols `[c0, c1]`, inclusive, clamped.
    fn rect(&self, r0: isize, r1: isize, c0: isize, c1: isize) -> u64 {
        let r0 = r0.max(0) as usize;
        let c0 = c0.max(0) as usize;
        let r1 = (r1.min(self.h as isize - 1)).max(-1);
        let c1 = (c1.min(self.w as isize - 1)).max(-1);
        if r1 < r0 as isize || c1 < c0 as isize {
            return 0;
        }
        let (r1, c1) = (r1 as usize, c1 as usize);
        let w1 = self.w + 1;
        (self.sat[(r1 + 1) * w1 + (c1 + 1)] + self.sat[r0 * w1 + c0]
            - self.sat[r0 * w1 + (c1 + 1)]
            - self.sat[(r1 + 1) * w1 + c0]) as u64
    }
}

/// Input-independent side of a [`DensityReport`]: everything derivable from
/// the weight tensor alone, computed once at compile time and reused for
/// every image (see `engine::compile`). [`layer_report_cached`] consumes it.
#[derive(Debug, Clone)]
pub struct WeightSideStats {
    /// Weight tensor dims `[K, C, KH, KW]`.
    pub k: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    /// Element-granularity weight density (Fig 9 "weight").
    pub weight_elem: f64,
    /// Vector-granularity weight density (Fig 10/11 "weight").
    pub weight_vec: f64,
    /// Per channel: Σ_k |nzW(k, c)| — the weight factor of the surviving
    /// vector-pair count.
    pub w_nz_per_c: Vec<u64>,
    /// Filters with a nonzero tap at `(c, i, j)`, laid out
    /// `(c*KH + i)*KW + j` — the weight factor of the fine-grained work
    /// count.
    pub filters_nz_at: Vec<u32>,
}

/// Compute the weight-side stats from a weight tensor and its CVF encode
/// (the encode may be value-carrying or index-only; only indices are read).
pub fn weight_side_stats(weight: &Tensor, vw: &VectorWeights) -> WeightSideStats {
    assert_eq!(weight.ndim(), 4, "weights must be [K,C,KH,KW]");
    let (k, c, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    assert_eq!(vw.k, k, "CVF encode does not match the weight tensor");
    assert_eq!(vw.c, c, "CVF encode does not match the weight tensor");
    let w_nz_per_c = (0..c)
        .map(|ci| (0..k).map(|ki| vw.nz_cols(ki, ci).len() as u64).sum())
        .collect();
    WeightSideStats {
        k,
        c,
        kh,
        kw,
        weight_elem: weight.density(),
        weight_vec: vw.density(),
        w_nz_per_c,
        filters_nz_at: nz_tap_histogram(weight),
    }
}

/// How many filters have a nonzero tap at `(c, i, j)`? One contiguous
/// pass over the weight tensor (perf: this loop visits K*C*KH*KW
/// elements and dominated layer_report before being linearized —
/// EXPERIMENTS.md §Perf).
fn nz_tap_histogram(weight: &Tensor) -> Vec<u32> {
    let (c_in, kh, kw) = (weight.shape()[1], weight.shape()[2], weight.shape()[3]);
    let taps = kh * kw;
    let mut filters_nz_at = vec![0u32; c_in * taps];
    for filt in weight.data().chunks_exact(c_in * taps) {
        for (off, &v) in filt.iter().enumerate() {
            if v != 0.0 {
                filters_nz_at[off] += 1;
            }
        }
    }
    filters_nz_at
}

/// Exact count of surviving fine-grained MACs for a conv layer.
///
/// A MAC indexed `(k, c, oh, ow, i, j)` survives iff `weight[k,c,i,j] != 0`
/// and the input pixel `(c, oh*s+i-p, ow*s+j-p)` is in-bounds and nonzero.
/// Computed as: for every nonzero weight tap, count the nonzero input pixels
/// whose position maps to a valid output — an O(1) summed-area query.
pub fn fine_grained_work(input: &Tensor, weight: &Tensor, spec: ConvSpec) -> u64 {
    let (kh, kw) = (weight.shape()[2], weight.shape()[3]);
    fine_work_from_taps(input, &nz_tap_histogram(weight), kh, kw, spec)
}

/// [`fine_grained_work`] with the weight-side tap histogram precomputed.
fn fine_work_from_taps(
    input: &Tensor,
    filters_nz_at: &[u32],
    kh: usize,
    kw: usize,
    spec: ConvSpec,
) -> u64 {
    let c_in = input.shape()[0];
    assert_eq!(filters_nz_at.len(), c_in * kh * kw, "tap histogram size");
    let h_out = crate::tensor::conv::out_dim(input.shape()[1], kh, spec) as isize;
    let w_out = crate::tensor::conv::out_dim(input.shape()[2], kw, spec) as isize;
    let (s, p) = (spec.stride as isize, spec.pad as isize);

    let mut total = 0u64;
    for c in 0..c_in {
        let sat = PrefixNnz::from_channel(input, c);
        for i in 0..kh {
            for j in 0..kw {
                let filters_nz = filters_nz_at[(c * kh + i) * kw + j] as u64;
                if filters_nz == 0 {
                    continue;
                }
                if s == 1 {
                    // Valid input rows: ih = oh + i - p for oh in [0, h_out).
                    let r0 = i as isize - p;
                    let r1 = r0 + h_out - 1;
                    let c0 = j as isize - p;
                    let c1 = c0 + w_out - 1;
                    total += filters_nz * sat.rect(r0, r1, c0, c1);
                } else {
                    // General stride: count nonzero inputs on the stride
                    // lattice row by row (rare path; VGG is stride 1).
                    let mut cnt = 0u64;
                    for oh in 0..h_out {
                        let ih = oh * s + i as isize - p;
                        if ih < 0 || ih >= sat.h as isize {
                            continue;
                        }
                        for ow in 0..w_out {
                            let iw = ow * s + j as isize - p;
                            if iw < 0 || iw >= sat.w as isize {
                                continue;
                            }
                            cnt += sat.rect(ih, ih, iw, iw);
                        }
                    }
                    total += filters_nz * cnt;
                }
            }
        }
    }
    total
}

/// Total dense MACs of a conv layer (every output × every tap).
pub fn dense_macs(input: &Tensor, weight: &Tensor, spec: ConvSpec) -> u64 {
    let (k_out, c_in, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    dense_macs_dims(input, k_out, c_in, kh, kw, spec)
}

/// [`dense_macs`] from weight dims alone (no weight tensor needed).
fn dense_macs_dims(
    input: &Tensor,
    k_out: usize,
    c_in: usize,
    kh: usize,
    kw: usize,
    spec: ConvSpec,
) -> u64 {
    let h_out = crate::tensor::conv::out_dim(input.shape()[1], kh, spec) as u64;
    let w_out = crate::tensor::conv::out_dim(input.shape()[2], kw, spec) as u64;
    k_out as u64 * c_in as u64 * kh as u64 * kw as u64 * h_out * w_out
}

/// Vector-granularity pair counts: `(pairs_total, pairs_nonzero)`.
///
/// One *pair* is one PE-array issue slot: (input vector `(c, strip, col)`)
/// × (weight vector `(k, c, kcol)`). Dense hardware issues every pair
/// (`C · strips · W · K · KW`); the VSCNN flow issues only pairs whose two
/// vectors are both nonzero (boundary pairs with out-of-range output index
/// still issue, exactly as in Table I's `X` slots).
pub fn vector_pairs(va: &VectorActivations, vw: &VectorWeights) -> (u64, u64) {
    assert_eq!(va.c, vw.c, "channel mismatch");
    let total =
        va.c as u64 * va.strips as u64 * va.w as u64 * vw.k as u64 * vw.kw as u64;
    let mut nonzero = 0u64;
    for c in 0..va.c {
        // Σ_k |nzW(k,c)| — weight vectors surviving for this channel.
        let w_nz: u64 = (0..vw.k).map(|k| vw.nz_cols(k, c).len() as u64).sum();
        if w_nz == 0 {
            continue;
        }
        let i_nz: u64 = (0..va.strips)
            .map(|s| va.nz_cols(c, s).len() as u64)
            .sum();
        nonzero += w_nz * i_nz;
    }
    (total, nonzero)
}

/// Full per-layer report at vector length `r`.
pub fn layer_report(input: &Tensor, weight: &Tensor, spec: ConvSpec, r: usize) -> DensityReport {
    let vw = VectorWeights::index_only(weight);
    layer_report_cached(input, &weight_side_stats(weight, &vw), spec, r)
}

/// [`layer_report`] against precomputed weight-side stats: only the
/// input-side quantities (activation encode, summed-area tables, pair
/// products) are computed per image — the per-image half of the
/// compile/execute split. Produces numbers identical to [`layer_report`].
pub fn layer_report_cached(
    input: &Tensor,
    ws: &WeightSideStats,
    spec: ConvSpec,
    r: usize,
) -> DensityReport {
    assert_eq!(input.shape()[0], ws.c, "channel mismatch");
    // Density analysis never reads payloads — index-only encode.
    let va = VectorActivations::index_only(input, r);
    let macs_total = dense_macs_dims(input, ws.k, ws.c, ws.kh, ws.kw, spec);
    let macs_nonzero = fine_work_from_taps(input, &ws.filters_nz_at, ws.kh, ws.kw, spec);

    // Surviving vector pairs: Σ_c (Σ_k |nzW(k,c)|) · (Σ_s |nzI(c,s)|) —
    // the weight factor comes from the cache.
    let pairs_total =
        va.c as u64 * va.strips as u64 * va.w as u64 * ws.k as u64 * ws.kw as u64;
    let mut pairs_nonzero = 0u64;
    for c in 0..va.c {
        let w_nz = ws.w_nz_per_c[c];
        if w_nz == 0 {
            continue;
        }
        let i_nz: u64 = (0..va.strips)
            .map(|s| va.nz_cols(c, s).len() as u64)
            .sum();
        pairs_nonzero += w_nz * i_nz;
    }

    DensityReport {
        input_elem: input.density(),
        weight_elem: ws.weight_elem,
        work_elem: if macs_total == 0 {
            0.0
        } else {
            macs_nonzero as f64 / macs_total as f64
        },
        input_vec: va.density(),
        weight_vec: ws.weight_vec,
        work_vec: if pairs_total == 0 {
            0.0
        } else {
            pairs_nonzero as f64 / pairs_total as f64
        },
        macs_total,
        macs_nonzero,
        pairs_total,
        pairs_nonzero,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::conv::conv2d;
    use crate::util::rng::Pcg32;

    fn random_sparse(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n)
            .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
            .collect();
        Tensor::from_vec(shape, data)
    }

    /// Brute-force fine-grained work counter for validation.
    fn brute_work(input: &Tensor, weight: &Tensor, spec: ConvSpec) -> u64 {
        let (k_out, c_in, kh, kw) = (
            weight.shape()[0],
            weight.shape()[1],
            weight.shape()[2],
            weight.shape()[3],
        );
        let (h, w) = (input.shape()[1], input.shape()[2]);
        let h_out = crate::tensor::conv::out_dim(h, kh, spec);
        let w_out = crate::tensor::conv::out_dim(w, kw, spec);
        let mut cnt = 0u64;
        for k in 0..k_out {
            for c in 0..c_in {
                for oh in 0..h_out {
                    for ow in 0..w_out {
                        for i in 0..kh {
                            for j in 0..kw {
                                let ih = (oh * spec.stride + i) as isize - spec.pad as isize;
                                let iw = (ow * spec.stride + j) as isize - spec.pad as isize;
                                if ih < 0 || iw < 0 || ih >= h as isize || iw >= w as isize {
                                    continue;
                                }
                                if weight.at4(k, c, i, j) != 0.0
                                    && input.at3(c, ih as usize, iw as usize) != 0.0
                                {
                                    cnt += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
        cnt
    }

    #[test]
    fn fine_grained_work_matches_brute_force() {
        let mut rng = Pcg32::seeded(404);
        for _ in 0..15 {
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 4);
            let h = rng.range(3, 9);
            let w = rng.range(3, 9);
            let spec = ConvSpec {
                stride: rng.range(1, 3),
                pad: rng.range(0, 2),
            };
            if h + 2 * spec.pad < 3 || w + 2 * spec.pad < 3 {
                continue;
            }
            let input = random_sparse(&mut rng, &[c_in, h, w], 0.5);
            let weight = random_sparse(&mut rng, &[k_out, c_in, 3, 3], 0.4);
            assert_eq!(
                fine_grained_work(&input, &weight, spec),
                brute_work(&input, &weight, spec),
                "stride={} pad={}",
                spec.stride,
                spec.pad
            );
        }
    }

    #[test]
    fn dense_tensors_give_density_one() {
        let input = Tensor::from_vec(&[2, 6, 6], vec![1.0; 72]);
        let weight = Tensor::from_vec(&[3, 2, 3, 3], vec![1.0; 54]);
        let rep = layer_report(&input, &weight, ConvSpec::default(), 3);
        assert_eq!(rep.input_elem, 1.0);
        assert_eq!(rep.weight_elem, 1.0);
        assert_eq!(rep.input_vec, 1.0);
        assert_eq!(rep.weight_vec, 1.0);
        assert_eq!(rep.work_vec, 1.0);
        assert_eq!(rep.pairs_total, rep.pairs_nonzero);
        // Element work < 1 only from padding boundary; interior all survives.
        assert!(rep.work_elem > 0.7 && rep.work_elem <= 1.0);
        assert_eq!(rep.macs_total, 3 * 2 * 9 * 36);
    }

    #[test]
    fn all_zero_weight_means_no_work() {
        let input = Tensor::from_vec(&[1, 4, 4], vec![1.0; 16]);
        let weight = Tensor::zeros(&[2, 1, 3, 3]);
        let rep = layer_report(&input, &weight, ConvSpec::default(), 2);
        assert_eq!(rep.macs_nonzero, 0);
        assert_eq!(rep.pairs_nonzero, 0);
        assert_eq!(rep.work_vec, 0.0);
    }

    #[test]
    fn vector_work_upper_bounds_element_work() {
        // Skipping at coarser granularity can never skip more than
        // fine-grained skipping: work_vec >= work_elem (modulo the boundary
        // pairs which only exist at vector granularity — they only raise
        // work_vec further).
        let mut rng = Pcg32::seeded(808);
        for _ in 0..10 {
            let input = random_sparse(&mut rng, &[2, 8, 8], 0.4);
            let weight = random_sparse(&mut rng, &[3, 2, 3, 3], 0.3);
            let rep = layer_report(&input, &weight, ConvSpec::default(), 4);
            assert!(
                rep.work_vec >= rep.work_elem - 1e-9,
                "vec {} < elem {}",
                rep.work_vec,
                rep.work_elem
            );
        }
    }

    #[test]
    fn vector_pairs_match_manual_count() {
        // 1 channel, 4x2 input, r=2 → 2 strips; one nonzero col per strip.
        let mut input = Tensor::zeros(&[1, 4, 2]);
        *input.at3_mut(0, 0, 0) = 1.0; // strip 0, col 0
        *input.at3_mut(0, 2, 1) = 1.0; // strip 1, col 1
        // 1 filter with 2 nonzero kernel columns.
        let mut weight = Tensor::zeros(&[1, 1, 3, 3]);
        *weight.at4_mut(0, 0, 0, 0) = 1.0;
        *weight.at4_mut(0, 0, 1, 2) = 1.0;
        let va = VectorActivations::from_tensor(&input, 2);
        let vw = VectorWeights::from_tensor(&weight);
        let (total, nz) = vector_pairs(&va, &vw);
        // total = C(1)*strips(2)*W(2)*K(1)*KW(3) = 12
        assert_eq!(total, 12);
        // nz = Σ_strips |nzI| * |nzW| = (1*2) + (1*2) = 4
        assert_eq!(nz, 4);
    }

    #[test]
    fn cached_layer_report_is_bit_identical() {
        // The compile/execute split caches the weight-side stats; the
        // cached report must equal the from-scratch one exactly (same
        // integer counts, same f64s), for both CVF encode flavours.
        let mut rng = Pcg32::seeded(909);
        for case in 0..8 {
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 5);
            let h = rng.range(4, 12);
            let w = rng.range(4, 12);
            let k = if case % 2 == 0 { 3 } else { 5 };
            let spec = ConvSpec {
                stride: rng.range(1, 3),
                pad: rng.range(0, 2),
            };
            if h + 2 * spec.pad < k || w + 2 * spec.pad < k {
                continue;
            }
            let input = random_sparse(&mut rng, &[c_in, h, w], 0.5);
            let weight = random_sparse(&mut rng, &[k_out, c_in, k, k], 0.4);
            let r = rng.range(1, 6);
            let full = layer_report(&input, &weight, spec, r);
            for vw in [
                VectorWeights::index_only(&weight),
                VectorWeights::from_tensor(&weight),
            ] {
                let ws = weight_side_stats(&weight, &vw);
                let cached = layer_report_cached(&input, &ws, spec, r);
                assert_eq!(full, cached, "case {case}");
            }
        }
    }

    #[test]
    fn conv_consistency_smoke() {
        // The report's macs_nonzero of a dense input must equal the exact
        // count of in-bounds (weight_nz × input_nz) products that conv2d
        // actually performs — spot-check via an all-ones case where
        // output values count contributing taps.
        let input = Tensor::from_vec(&[1, 5, 5], vec![1.0; 25]);
        let weight = Tensor::from_vec(&[1, 1, 3, 3], vec![1.0; 9]);
        let spec = ConvSpec::default();
        let out = conv2d(&input, &weight, None, spec);
        let taps_sum: f32 = out.data().iter().sum();
        assert_eq!(fine_grained_work(&input, &weight, spec), taps_sum as u64);
    }
}
