//! Compressed vector format (CVF) — the data structure behind the paper's
//! index system.
//!
//! Only nonzero vectors are kept (matching "zero input data and weight data
//! ... will not be in SRAM"); each surviving vector carries its original
//! index so the shared accumulator flow can place partial sums correctly.

use crate::sparse::bitset::Bitset;
use crate::tensor::Tensor;

/// Vector-sparse view of an activation tensor `[C, H, W]`.
///
/// The vector granularity is an `R`-element column strip: vector
/// `(c, s, col)` covers `input[c, s*R .. min((s+1)*R, H), col]`. A vector is
/// *occupied* iff any element in it is nonzero.
#[derive(Debug, Clone)]
pub struct VectorActivations {
    /// Channels.
    pub c: usize,
    /// Row strips: `ceil(H / r)`.
    pub strips: usize,
    /// Spatial columns.
    pub w: usize,
    /// Vector length = PE-array rows (14 or 7 in the paper).
    pub r: usize,
    /// Original height (last strip may be ragged).
    pub h: usize,
    occ: Bitset,
    /// Flattened per-`(c, strip)` sorted nonzero column indices — exactly
    /// the contents of the input SRAM index list (CSR layout: one heap
    /// allocation instead of one per group; EXPERIMENTS.md §Perf).
    nz_flat: Vec<u16>,
    /// `c * strips + 1` offsets into `nz_flat`.
    nz_offsets: Vec<u32>,
    /// Packed vector payloads: `r` values per nonzero vector, in `nz_flat`
    /// order, zero-padded for ragged last strips — the compressed data the
    /// SRAM actually holds. Value `p` of vector `nz_flat[i]` sits at
    /// `vals_flat[i * r + p]`, so the functional dataflow reads contiguous
    /// slices instead of re-gathering through `Tensor::at3`. Empty for
    /// [`Self::index_only`] encodes.
    vals_flat: Vec<f32>,
    /// Whether `vals_flat` was packed (guards [`Self::nz_vals`]).
    has_vals: bool,
}

impl VectorActivations {
    /// Encode a `[C,H,W]` tensor at vector length `r`, packing the value
    /// payloads next to the index lists (what the SRAM holds — feeds the
    /// functional dataflow).
    pub fn from_tensor(t: &Tensor, r: usize) -> VectorActivations {
        Self::encode(t, r, true)
    }

    /// Index-only encode: occupancy + index lists without the value
    /// payloads. For timing, density and post-processing paths that never
    /// read [`Self::nz_vals`] — skips the payload allocation and copy.
    pub fn index_only(t: &Tensor, r: usize) -> VectorActivations {
        Self::encode(t, r, false)
    }

    fn encode(t: &Tensor, r: usize, pack_vals: bool) -> VectorActivations {
        assert_eq!(t.ndim(), 3, "activations must be [C,H,W]");
        assert!(r > 0, "vector length must be positive");
        let (c, h, w) = (t.shape()[0], t.shape()[1], t.shape()[2]);
        let strips = h.div_ceil(r);
        let mut occ = Bitset::new(c * strips * w);
        let mut nz_flat = Vec::new();
        let mut nz_offsets = Vec::with_capacity(c * strips + 1);
        let mut vals_flat = Vec::new();
        nz_offsets.push(0);
        let data = t.data();
        for ci in 0..c {
            // One contiguous channel plane: rows are `w` apart.
            let chan = &data[ci * h * w..(ci + 1) * h * w];
            for s in 0..strips {
                let row_lo = s * r;
                let row_hi = ((s + 1) * r).min(h);
                for col in 0..w {
                    let nz = (row_lo..row_hi).any(|row| chan[row * w + col] != 0.0);
                    if nz {
                        occ.set((ci * strips + s) * w + col, true);
                        nz_flat.push(col as u16);
                        if pack_vals {
                            let start = vals_flat.len();
                            vals_flat.resize(start + r, 0.0);
                            for (p, row) in (row_lo..row_hi).enumerate() {
                                vals_flat[start + p] = chan[row * w + col];
                            }
                        }
                    }
                }
                nz_offsets.push(nz_flat.len() as u32);
            }
        }
        VectorActivations {
            c,
            strips,
            w,
            r,
            h,
            occ,
            nz_flat,
            nz_offsets,
            vals_flat,
            has_vals: pack_vals,
        }
    }

    /// Total candidate vectors.
    pub fn total_vectors(&self) -> usize {
        self.c * self.strips * self.w
    }

    /// Occupied (nonzero) vectors.
    pub fn nonzero_vectors(&self) -> usize {
        self.occ.count_ones()
    }

    /// Vector-granularity density (the paper's Fig 10/11 "input" series).
    pub fn density(&self) -> f64 {
        self.occ.density()
    }

    /// Is vector `(c, strip, col)` occupied?
    pub fn occupied(&self, c: usize, strip: usize, col: usize) -> bool {
        self.occ.get((c * self.strips + strip) * self.w + col)
    }

    /// Sorted nonzero column indices for one `(c, strip)` — the index list
    /// the scheduler walks when issuing input vectors.
    #[inline]
    pub fn nz_cols(&self, c: usize, strip: usize) -> &[u16] {
        let g = c * self.strips + strip;
        &self.nz_flat[self.nz_offsets[g] as usize..self.nz_offsets[g + 1] as usize]
    }

    /// Packed payloads of the nonzero vectors of one `(c, strip)`:
    /// `nz_cols(c, strip).len() * r` values; position `pos` of the index
    /// list owns the sub-slice `[pos * r, (pos + 1) * r)` (zero-padded for
    /// ragged last strips). Panics on an [`Self::index_only`] encode.
    #[inline]
    pub fn nz_vals(&self, c: usize, strip: usize) -> &[f32] {
        assert!(self.has_vals, "nz_vals on an index-only encode");
        let g = c * self.strips + strip;
        &self.vals_flat
            [self.nz_offsets[g] as usize * self.r..self.nz_offsets[g + 1] as usize * self.r]
    }

    /// Elements resident in the input SRAM (nonzero vectors × R).
    pub fn sram_elems(&self) -> usize {
        self.nonzero_vectors() * self.r
    }

    /// Index-list entries resident in SRAM (one per nonzero vector).
    pub fn index_entries(&self) -> usize {
        self.nonzero_vectors()
    }
}

/// Vector-sparse view of a weight tensor `[K, C, KH, KW]`.
///
/// The weight vector granularity is one kernel *column*: vector
/// `(k, c, j)` covers `weight[k, c, :, j]` (KH elements, 3 for VGG).
#[derive(Debug, Clone)]
pub struct VectorWeights {
    pub k: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    occ: Bitset,
    /// Flattened per-`(k, c)` sorted nonzero kernel-column indices (CSR
    /// layout — see `VectorActivations::nz_flat`).
    nz_flat: Vec<u8>,
    /// `k * c + 1` offsets into `nz_flat`.
    nz_offsets: Vec<u32>,
    /// Packed kernel-column payloads: `kh` values (top to bottom) per
    /// nonzero vector, in `nz_flat` order — see
    /// [`VectorActivations::nz_vals`]. Empty for [`Self::index_only`].
    vals_flat: Vec<f32>,
    /// Whether `vals_flat` was packed (guards [`Self::nz_vals`]).
    has_vals: bool,
}

impl VectorWeights {
    /// Encode a `[K,C,KH,KW]` weight tensor, packing kernel-column value
    /// payloads next to the index lists.
    pub fn from_tensor(t: &Tensor) -> VectorWeights {
        Self::encode(t, true)
    }

    /// Index-only encode — see [`VectorActivations::index_only`].
    pub fn index_only(t: &Tensor) -> VectorWeights {
        Self::encode(t, false)
    }

    fn encode(t: &Tensor, pack_vals: bool) -> VectorWeights {
        assert_eq!(t.ndim(), 4, "weights must be [K,C,KH,KW]");
        let (k, c, kh, kw) = (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
        let mut occ = Bitset::new(k * c * kw);
        let mut nz_flat = Vec::new();
        let mut nz_offsets = Vec::with_capacity(k * c + 1);
        let mut vals_flat = Vec::new();
        nz_offsets.push(0);
        // Linear pass over contiguous (k,c) blocks of kh*kw elements
        // (perf: strided at4 indexing here dominated encoding —
        // EXPERIMENTS.md §Perf).
        for (kc, block) in t.data().chunks_exact(kh * kw).enumerate() {
            for j in 0..kw {
                let nz = (0..kh).any(|i| block[i * kw + j] != 0.0);
                if nz {
                    occ.set(kc * kw + j, true);
                    nz_flat.push(j as u8);
                    if pack_vals {
                        for i in 0..kh {
                            vals_flat.push(block[i * kw + j]);
                        }
                    }
                }
            }
            nz_offsets.push(nz_flat.len() as u32);
        }
        VectorWeights {
            k,
            c,
            kh,
            kw,
            occ,
            nz_flat,
            nz_offsets,
            vals_flat,
            has_vals: pack_vals,
        }
    }

    /// Total candidate weight vectors.
    pub fn total_vectors(&self) -> usize {
        self.k * self.c * self.kw
    }

    /// Occupied weight vectors.
    pub fn nonzero_vectors(&self) -> usize {
        self.occ.count_ones()
    }

    /// Vector-granularity weight density (Fig 10/11 "weight" series).
    pub fn density(&self) -> f64 {
        self.occ.density()
    }

    /// Is weight vector `(k, c, j)` occupied?
    pub fn occupied(&self, k: usize, c: usize, j: usize) -> bool {
        self.occ.get((k * self.c + c) * self.kw + j)
    }

    /// Sorted nonzero kernel-column indices for filter `(k, c)`.
    #[inline]
    pub fn nz_cols(&self, k: usize, c: usize) -> &[u8] {
        let g = k * self.c + c;
        &self.nz_flat[self.nz_offsets[g] as usize..self.nz_offsets[g + 1] as usize]
    }

    /// Packed payloads of the nonzero kernel columns of filter `(k, c)`:
    /// position `pos` of [`Self::nz_cols`] owns `[pos * kh, (pos+1) * kh)`.
    /// Panics on an [`Self::index_only`] encode.
    #[inline]
    pub fn nz_vals(&self, k: usize, c: usize) -> &[f32] {
        assert!(self.has_vals, "nz_vals on an index-only encode");
        let g = k * self.c + c;
        &self.vals_flat
            [self.nz_offsets[g] as usize * self.kh..self.nz_offsets[g + 1] as usize * self.kh]
    }

    /// Elements resident in the weight SRAM (nonzero vectors × KH).
    pub fn sram_elems(&self) -> usize {
        self.nonzero_vectors() * self.kh
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activation_encoding_basic() {
        // 1 channel, 4x3, r=2 → 2 strips × 3 cols = 6 vectors.
        let mut t = Tensor::zeros(&[1, 4, 3]);
        *t.at3_mut(0, 0, 1) = 5.0; // strip 0, col 1
        *t.at3_mut(0, 3, 2) = -1.0; // strip 1, col 2
        let va = VectorActivations::from_tensor(&t, 2);
        assert_eq!(va.total_vectors(), 6);
        assert_eq!(va.nonzero_vectors(), 2);
        assert!(va.occupied(0, 0, 1));
        assert!(va.occupied(0, 1, 2));
        assert!(!va.occupied(0, 0, 0));
        assert_eq!(va.nz_cols(0, 0), &[1]);
        assert_eq!(va.nz_cols(0, 1), &[2]);
        assert!((va.density() - 2.0 / 6.0).abs() < 1e-12);
        assert_eq!(va.sram_elems(), 4);
    }

    #[test]
    fn ragged_last_strip() {
        // H=5, r=2 → 3 strips, last strip has 1 row.
        let mut t = Tensor::zeros(&[1, 5, 2]);
        *t.at3_mut(0, 4, 0) = 1.0;
        let va = VectorActivations::from_tensor(&t, 2);
        assert_eq!(va.strips, 3);
        assert!(va.occupied(0, 2, 0));
        assert!(!va.occupied(0, 2, 1));
    }

    #[test]
    fn any_nonzero_element_occupies_whole_vector() {
        let mut t = Tensor::zeros(&[1, 4, 1]);
        *t.at3_mut(0, 1, 0) = 0.001; // single element in strip 0
        let va = VectorActivations::from_tensor(&t, 4);
        assert_eq!(va.nonzero_vectors(), 1);
        assert_eq!(va.sram_elems(), 4); // whole vector stored
    }

    #[test]
    fn weight_encoding_kernel_columns() {
        // [2,1,3,3]: filter 0 has nonzero col 0 only; filter 1 all-zero.
        let mut t = Tensor::zeros(&[2, 1, 3, 3]);
        *t.at4_mut(0, 0, 2, 0) = 1.0;
        let vw = VectorWeights::from_tensor(&t);
        assert_eq!(vw.total_vectors(), 6);
        assert_eq!(vw.nonzero_vectors(), 1);
        assert!(vw.occupied(0, 0, 0));
        assert!(!vw.occupied(0, 0, 1));
        assert_eq!(vw.nz_cols(0, 0), &[0]);
        assert!(vw.nz_cols(1, 0).is_empty());
        assert_eq!(vw.sram_elems(), 3);
    }

    #[test]
    fn dense_tensor_fully_occupied() {
        let t = Tensor::from_vec(&[2, 4, 4], vec![1.0; 32]);
        let va = VectorActivations::from_tensor(&t, 2);
        assert_eq!(va.density(), 1.0);
        let w = Tensor::from_vec(&[2, 2, 3, 3], vec![1.0; 36]);
        let vw = VectorWeights::from_tensor(&w);
        assert_eq!(vw.density(), 1.0);
    }

    #[test]
    fn activation_values_packed_in_index_order() {
        // Values must sit next to their indices: vals[pos*r..] is exactly
        // the column strip of nz_cols[pos], zero-padded when ragged.
        let mut t = Tensor::zeros(&[1, 5, 3]);
        *t.at3_mut(0, 0, 1) = 2.0; // strip 0 col 1: [2, 3]
        *t.at3_mut(0, 1, 1) = 3.0;
        *t.at3_mut(0, 1, 2) = 4.0; // strip 0 col 2: [0, 4]
        *t.at3_mut(0, 4, 0) = 5.0; // strip 2 (ragged, 1 row) col 0: [5, 0]
        let va = VectorActivations::from_tensor(&t, 2);
        assert_eq!(va.nz_cols(0, 0), &[1, 2]);
        assert_eq!(va.nz_vals(0, 0), &[2.0, 3.0, 0.0, 4.0]);
        assert!(va.nz_vals(0, 1).is_empty());
        assert_eq!(va.nz_cols(0, 2), &[0]);
        assert_eq!(va.nz_vals(0, 2), &[5.0, 0.0]);
    }

    #[test]
    fn weight_values_packed_in_index_order() {
        let mut t = Tensor::zeros(&[1, 2, 3, 3]);
        // (k=0, c=1): column 0 = [1, 0, 2], column 2 = [0, 3, 0].
        *t.at4_mut(0, 1, 0, 0) = 1.0;
        *t.at4_mut(0, 1, 2, 0) = 2.0;
        *t.at4_mut(0, 1, 1, 2) = 3.0;
        let vw = VectorWeights::from_tensor(&t);
        assert_eq!(vw.nz_cols(0, 1), &[0, 2]);
        assert_eq!(vw.nz_vals(0, 1), &[1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        assert!(vw.nz_vals(0, 0).is_empty());
    }

    #[test]
    fn packed_values_roundtrip_randomized() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(321);
        for _ in 0..10 {
            let c = rng.range(1, 4);
            let h = rng.range(2, 16);
            let w = rng.range(1, 10);
            let r = rng.range(1, 6);
            let data: Vec<f32> = (0..c * h * w)
                .map(|_| if rng.bernoulli(0.4) { rng.normal() } else { 0.0 })
                .collect();
            let t = Tensor::from_vec(&[c, h, w], data);
            let va = VectorActivations::from_tensor(&t, r);
            for ci in 0..c {
                for s in 0..va.strips {
                    let cols = va.nz_cols(ci, s);
                    let vals = va.nz_vals(ci, s);
                    assert_eq!(vals.len(), cols.len() * r);
                    for (pos, &col) in cols.iter().enumerate() {
                        for p in 0..r {
                            let row = s * r + p;
                            let want = if row < h { t.at3(ci, row, col as usize) } else { 0.0 };
                            assert_eq!(vals[pos * r + p], want);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn index_only_matches_indices_and_guards_vals() {
        let mut t = Tensor::zeros(&[2, 6, 4]);
        *t.at3_mut(0, 1, 2) = 1.0;
        *t.at3_mut(1, 5, 0) = -3.0;
        let full = VectorActivations::from_tensor(&t, 3);
        let idx = VectorActivations::index_only(&t, 3);
        assert_eq!(idx.nonzero_vectors(), full.nonzero_vectors());
        for c in 0..2 {
            for s in 0..full.strips {
                assert_eq!(idx.nz_cols(c, s), full.nz_cols(c, s));
            }
        }
        let w = Tensor::from_vec(&[1, 2, 3, 3], vec![1.0; 18]);
        let vw_idx = VectorWeights::index_only(&w);
        assert_eq!(vw_idx.nonzero_vectors(), 6);
        assert_eq!(vw_idx.nz_cols(0, 1), &[0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "index-only")]
    fn index_only_activation_vals_panics() {
        let t = Tensor::from_vec(&[1, 2, 2], vec![1.0; 4]);
        let va = VectorActivations::index_only(&t, 2);
        let _ = va.nz_vals(0, 0);
    }

    #[test]
    fn vector_density_at_least_element_density() {
        // Vector granularity can only merge zeros, never create them.
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(123);
        for _ in 0..20 {
            let c = rng.range(1, 4);
            let h = rng.range(2, 20);
            let w = rng.range(1, 12);
            let r = rng.range(1, 8);
            let data = (0..c * h * w)
                .map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 })
                .collect();
            let t = Tensor::from_vec(&[c, h, w], data);
            let va = VectorActivations::from_tensor(&t, r);
            assert!(
                va.density() >= t.density() - 1e-9,
                "vector density {} < element density {}",
                va.density(),
                t.density()
            );
        }
    }
}
