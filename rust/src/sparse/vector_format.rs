//! Compressed vector format (CVF) — the data structure behind the paper's
//! index system.
//!
//! Only nonzero vectors are kept (matching "zero input data and weight data
//! ... will not be in SRAM"); each surviving vector carries its original
//! index so the shared accumulator flow can place partial sums correctly.
//!
//! ## Layout (ISSUE 5): structure-of-arrays
//!
//! The value-carrying activation encode is stored as separate contiguous
//! **index planes** (`nz_flat` CSR lists) and **payload planes**: within a
//! `(channel, strip)` group of `n` nonzero vectors, element `p` of every
//! vector sits contiguously in plane `p` (`vals[p * n + v]`), instead of
//! the old array-of-vectors order (`vals[v * r + p]`). The encoder fills
//! each plane with one contiguous row sweep and detects occupancy with a
//! branch-free bitwise-OR accumulator, so the per-image activation encode
//! autovectorizes; the old per-vector layout stays reachable through the
//! [`VectorActivations::nz_vals_aos`] conversion and is pinned equivalent
//! by the round-trip tests below. Weight payloads keep the per-vector
//! order ([`VectorWeights::nz_vals`]): a weight vector is the `KH`-element
//! operand the MAC kernel consumes whole, so per-vector *is* its plane.

use crate::sparse::bitset::Bitset;
use crate::tensor::Tensor;
use std::fmt;

/// Typed structural defect found in a CVF encoding (ISSUE 10): the
/// decode-side contract check for data that crossed an unreliable
/// SRAM/DRAM boundary. Every variant names the first offending site so
/// detection counters and blast-radius reasoning stay precise; malformed
/// data becomes an `Err`, never a panic or an out-of-bounds read.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CvfError {
    /// The CSR offset table is the wrong length, decreasing, or points
    /// past the index list — decoding would read out of bounds.
    OffsetCorrupt { group: usize },
    /// An index word names a column at or past the group's width.
    IndexOutOfBounds { group: usize, pos: usize, col: usize, limit: usize },
    /// Index words within a group are not strictly increasing (the
    /// scheduler's merge walk requires sorted, duplicate-free lists).
    IndexNotMonotone { group: usize, pos: usize },
    /// Occupancy bitmap and index list disagree: a listed column's bit
    /// is clear, or the group's popcount exceeds its list length
    /// (`col == limit` marks the popcount case).
    OccupancyMismatch { group: usize, col: usize },
    /// Payload plane length disagrees with `index words * vector len`.
    PayloadSizeMismatch { expected: usize, got: usize },
    /// A payload word decodes to NaN/inf — an upset in the exponent
    /// bits of a stored value.
    PayloadNotFinite { word: usize },
}

impl fmt::Display for CvfError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            CvfError::OffsetCorrupt { group } => {
                write!(f, "CVF offset table corrupt at group {group}")
            }
            CvfError::IndexOutOfBounds { group, pos, col, limit } => write!(
                f,
                "CVF index out of bounds: group {group} pos {pos} col {col} >= {limit}"
            ),
            CvfError::IndexNotMonotone { group, pos } => {
                write!(f, "CVF index list not strictly increasing: group {group} pos {pos}")
            }
            CvfError::OccupancyMismatch { group, col } => {
                write!(f, "CVF occupancy/index mismatch at group {group} col {col}")
            }
            CvfError::PayloadSizeMismatch { expected, got } => {
                write!(f, "CVF payload size mismatch: expected {expected} words, got {got}")
            }
            CvfError::PayloadNotFinite { word } => {
                write!(f, "CVF payload word {word} is not finite")
            }
        }
    }
}

impl std::error::Error for CvfError {}

/// Shared CSR validation walk over one encode's raw planes: offsets
/// first (so the index slicing below can never go out of bounds), then
/// per-group index bounds + strict monotonicity + occupancy
/// cross-check, then payload shape and finiteness. `groups * width`
/// must equal `occ.len()`.
fn validate_csr(
    occ: &Bitset,
    nz_offsets: &[u32],
    index_cols: &dyn Fn(usize) -> usize,
    index_len: usize,
    groups: usize,
    width: usize,
    payload: &[f32],
    payload_per_index: usize,
    has_vals: bool,
) -> Result<(), CvfError> {
    if nz_offsets.len() != groups + 1 || nz_offsets[0] != 0 {
        return Err(CvfError::OffsetCorrupt { group: 0 });
    }
    if nz_offsets[groups] as usize != index_len {
        return Err(CvfError::OffsetCorrupt { group: groups });
    }
    for g in 0..groups {
        let (lo, hi) = (nz_offsets[g] as usize, nz_offsets[g + 1] as usize);
        if lo > hi || hi > index_len {
            return Err(CvfError::OffsetCorrupt { group: g });
        }
        let mut prev: Option<usize> = None;
        for pos in lo..hi {
            let col = index_cols(pos);
            if col >= width {
                return Err(CvfError::IndexOutOfBounds { group: g, pos: pos - lo, col, limit: width });
            }
            if prev.is_some_and(|p| col <= p) {
                return Err(CvfError::IndexNotMonotone { group: g, pos: pos - lo });
            }
            prev = Some(col);
            if !occ.get(g * width + col) {
                return Err(CvfError::OccupancyMismatch { group: g, col });
            }
        }
        // Every listed column's bit is set; equal counts rule out extra
        // bits with no matching index word.
        if occ.count_ones_in(g * width, (g + 1) * width) != hi - lo {
            return Err(CvfError::OccupancyMismatch { group: g, col: width });
        }
    }
    if has_vals {
        let expected = index_len * payload_per_index;
        if payload.len() != expected {
            return Err(CvfError::PayloadSizeMismatch { expected, got: payload.len() });
        }
        if let Some(word) = payload.iter().position(|v| !v.is_finite()) {
            return Err(CvfError::PayloadNotFinite { word });
        }
    }
    Ok(())
}

/// Vector-sparse view of an activation tensor `[C, H, W]`.
///
/// The vector granularity is an `R`-element column strip: vector
/// `(c, s, col)` covers `input[c, s*R .. min((s+1)*R, H), col]`. A vector is
/// *occupied* iff any element in it is nonzero.
#[derive(Debug, Clone)]
pub struct VectorActivations {
    /// Channels.
    pub c: usize,
    /// Row strips: `ceil(H / r)`.
    pub strips: usize,
    /// Spatial columns.
    pub w: usize,
    /// Vector length = PE-array rows (14 or 7 in the paper).
    pub r: usize,
    /// Original height (last strip may be ragged).
    pub h: usize,
    occ: Bitset,
    /// Flattened per-`(c, strip)` sorted nonzero column indices — exactly
    /// the contents of the input SRAM index list (CSR layout: one heap
    /// allocation instead of one per group; EXPERIMENTS.md §Perf).
    nz_flat: Vec<u16>,
    /// `c * strips + 1` offsets into `nz_flat`.
    nz_offsets: Vec<u32>,
    /// Packed vector payloads in **plane-major (SoA) order**: the group
    /// `(c, strip)` with `n = nz_cols(c, strip).len()` vectors occupies
    /// `vals_flat[off * r .. (off + n) * r]` (`off = nz_offsets[g]`), and
    /// within the group element `p` of every vector is contiguous —
    /// vector `v`'s element `p` sits at `group[p * n + v]`, zero-padded
    /// for ragged last strips. Empty for [`Self::index_only`] encodes.
    vals_flat: Vec<f32>,
    /// Whether `vals_flat` was packed (guards the payload accessors).
    has_vals: bool,
}

impl VectorActivations {
    /// Encode a `[C,H,W]` tensor at vector length `r`, packing the value
    /// payloads next to the index lists (what the SRAM holds — feeds the
    /// functional dataflow).
    pub fn from_tensor(t: &Tensor, r: usize) -> VectorActivations {
        Self::encode(t, r, true)
    }

    /// Index-only encode: occupancy + index lists without the value
    /// payloads. For timing, density and post-processing paths that never
    /// read [`Self::nz_vals`] — skips the payload allocation and copy.
    pub fn index_only(t: &Tensor, r: usize) -> VectorActivations {
        Self::encode(t, r, false)
    }

    fn encode(t: &Tensor, r: usize, pack_vals: bool) -> VectorActivations {
        assert_eq!(t.ndim(), 3, "activations must be [C,H,W]");
        assert!(r > 0, "vector length must be positive");
        let (c, h, w) = (t.shape()[0], t.shape()[1], t.shape()[2]);
        let strips = h.div_ceil(r);
        let mut occ = Bitset::new(c * strips * w);
        let mut nz_flat = Vec::new();
        let mut nz_offsets = Vec::with_capacity(c * strips + 1);
        let mut vals_flat = Vec::new();
        nz_offsets.push(0);
        let data = t.data();
        // Per-column occupancy as an OR of magnitude bits over the strip's
        // rows: `x != 0.0  ⟺  (x.to_bits() & 0x7FFF_FFFF) != 0` (treats
        // ±0.0 as zero and NaN/inf as nonzero, exactly like the float
        // compare) — branch-free over contiguous rows, so it vectorizes.
        let mut colbits = crate::util::scratch::take_u32(w, 0);
        for ci in 0..c {
            // One contiguous channel plane: rows are `w` apart.
            let chan = &data[ci * h * w..(ci + 1) * h * w];
            for s in 0..strips {
                let row_lo = s * r;
                let row_hi = ((s + 1) * r).min(h);
                colbits.fill(0);
                for row in row_lo..row_hi {
                    let row_vals = &chan[row * w..(row + 1) * w];
                    crate::util::simd::or_abs_bits(&mut colbits, row_vals);
                }
                let group_start = nz_flat.len();
                for (col, &b) in colbits.iter().enumerate() {
                    if b != 0 {
                        occ.set((ci * strips + s) * w + col, true);
                        nz_flat.push(col as u16);
                    }
                }
                let n = nz_flat.len() - group_start;
                if pack_vals && n > 0 {
                    // SoA payload: one contiguous plane per element row,
                    // gathered from one row sweep each; planes past the
                    // ragged end stay at the zero fill.
                    let base = vals_flat.len();
                    vals_flat.resize(base + n * r, 0.0);
                    let cols = &nz_flat[group_start..];
                    for (p, row) in (row_lo..row_hi).enumerate() {
                        let row_vals = &chan[row * w..(row + 1) * w];
                        let plane = &mut vals_flat[base + p * n..base + (p + 1) * n];
                        for (dst, &col) in plane.iter_mut().zip(cols) {
                            *dst = row_vals[col as usize];
                        }
                    }
                }
                nz_offsets.push(nz_flat.len() as u32);
            }
        }
        crate::util::scratch::recycle_u32(colbits);
        VectorActivations {
            c,
            strips,
            w,
            r,
            h,
            occ,
            nz_flat,
            nz_offsets,
            vals_flat,
            has_vals: pack_vals,
        }
    }

    /// Total candidate vectors.
    pub fn total_vectors(&self) -> usize {
        self.c * self.strips * self.w
    }

    /// Occupied (nonzero) vectors.
    pub fn nonzero_vectors(&self) -> usize {
        self.occ.count_ones()
    }

    /// Vector-granularity density (the paper's Fig 10/11 "input" series).
    pub fn density(&self) -> f64 {
        self.occ.density()
    }

    /// Is vector `(c, strip, col)` occupied?
    pub fn occupied(&self, c: usize, strip: usize, col: usize) -> bool {
        self.occ.get((c * self.strips + strip) * self.w + col)
    }

    /// Sorted nonzero column indices for one `(c, strip)` — the index list
    /// the scheduler walks when issuing input vectors.
    #[inline]
    pub fn nz_cols(&self, c: usize, strip: usize) -> &[u16] {
        let g = c * self.strips + strip;
        &self.nz_flat[self.nz_offsets[g] as usize..self.nz_offsets[g + 1] as usize]
    }

    /// SoA payload of one `(c, strip)` group: the full `n * r` plane-major
    /// slice plus `n` (the group's nonzero-vector count). Element `p` of
    /// the vector at index-list position `pos` sits at `slice[p * n + pos]`
    /// (zero-padded for ragged last strips). Panics on an
    /// [`Self::index_only`] encode.
    #[inline]
    pub fn nz_group_soa(&self, c: usize, strip: usize) -> (&[f32], usize) {
        assert!(self.has_vals, "nz_group_soa on an index-only encode");
        let g = c * self.strips + strip;
        let (lo, hi) = (self.nz_offsets[g] as usize, self.nz_offsets[g + 1] as usize);
        (&self.vals_flat[lo * self.r..hi * self.r], hi - lo)
    }

    /// One payload plane of `(c, strip)`: element `p` (row `strip * r + p`)
    /// of every nonzero vector, in index-list order.
    #[inline]
    pub fn nz_plane(&self, c: usize, strip: usize, p: usize) -> &[f32] {
        let (soa, n) = self.nz_group_soa(c, strip);
        &soa[p * n..(p + 1) * n]
    }

    /// The pre-SoA **array-of-vectors** payload of one `(c, strip)` — the
    /// conversion that keeps the old layout reachable: position `pos` of
    /// the index list owns `[pos * r, (pos + 1) * r)`, exactly the slice
    /// `nz_vals` used to return. Allocates; for tests and format
    /// interop, not the hot path.
    pub fn nz_vals_aos(&self, c: usize, strip: usize) -> Vec<f32> {
        let (soa, n) = self.nz_group_soa(c, strip);
        let mut out = vec![0.0f32; n * self.r];
        for pos in 0..n {
            for p in 0..self.r {
                out[pos * self.r + p] = soa[p * n + pos];
            }
        }
        out
    }

    /// Elements resident in the input SRAM (nonzero vectors × R).
    pub fn sram_elems(&self) -> usize {
        self.nonzero_vectors() * self.r
    }

    /// Index-list entries resident in SRAM (one per nonzero vector).
    pub fn index_entries(&self) -> usize {
        self.nonzero_vectors()
    }

    /// Structural decode validation (ISSUE 10): offset-table sanity,
    /// per-group index bounds + strict monotonicity, occupancy
    /// cross-check, payload shape and finiteness. `Ok` guarantees every
    /// accessor above stays in bounds; run this before walking an
    /// encode that crossed an unreliable transfer.
    pub fn validate(&self) -> Result<(), CvfError> {
        validate_csr(
            &self.occ,
            &self.nz_offsets,
            &|pos| self.nz_flat[pos] as usize,
            self.nz_flat.len(),
            self.c * self.strips,
            self.w,
            &self.vals_flat,
            self.r,
            self.has_vals,
        )
    }

    /// Fault-injection site counts: 16-bit index words and 32-bit
    /// payload words resident in SRAM (what a bit flip can hit).
    pub fn index_words(&self) -> usize {
        self.nz_flat.len()
    }

    /// See [`Self::index_words`].
    pub fn payload_words(&self) -> usize {
        self.vals_flat.len()
    }

    /// Flip one bit of an index word — the injection hook for SDC
    /// experiments and the fuzz property tests. `bit` wraps at the
    /// 16-bit word width.
    pub fn flip_index_bit(&mut self, word: usize, bit: u32) {
        self.nz_flat[word] ^= 1u16 << (bit % 16);
    }

    /// Flip one bit of an IEEE-754 payload word (see
    /// [`Self::flip_index_bit`]). `bit` wraps at 32.
    pub fn flip_payload_bit(&mut self, word: usize, bit: u32) {
        let bits = self.vals_flat[word].to_bits() ^ (1u32 << (bit % 32));
        self.vals_flat[word] = f32::from_bits(bits);
    }

    /// Flip one bit of a CSR offset word — models corruption of the
    /// transfer stream's header, the nastiest site because it redirects
    /// whole group slices. `bit` wraps at 32.
    pub fn flip_offset_bit(&mut self, word: usize, bit: u32) {
        self.nz_offsets[word] ^= 1u32 << (bit % 32);
    }

    /// Stream checksum over the packed payload words, f64-accumulated:
    /// `(sum, abs_sum)`. The integrity scrubber recomputes this against
    /// the stored value to catch payload flips that structural
    /// validation cannot see; `abs_sum` scales the comparison's rounding
    /// floor. `(0, 0)` for index-only encodes.
    pub fn payload_checksum(&self) -> (f64, f64) {
        payload_checksum(&self.vals_flat)
    }
}

/// Shared payload-checksum kernel (see
/// [`VectorActivations::payload_checksum`]).
fn payload_checksum(vals: &[f32]) -> (f64, f64) {
    let mut sum = 0.0f64;
    let mut abs = 0.0f64;
    for &v in vals {
        sum += v as f64;
        abs += v.abs() as f64;
    }
    (sum, abs)
}

/// Vector-sparse view of a weight tensor `[K, C, KH, KW]`.
///
/// The weight vector granularity is one kernel *column*: vector
/// `(k, c, j)` covers `weight[k, c, :, j]` (KH elements, 3 for VGG).
#[derive(Debug, Clone)]
pub struct VectorWeights {
    pub k: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    occ: Bitset,
    /// Flattened per-`(k, c)` sorted nonzero kernel-column indices (CSR
    /// layout — see `VectorActivations::nz_flat`).
    nz_flat: Vec<u8>,
    /// `k * c + 1` offsets into `nz_flat`.
    nz_offsets: Vec<u32>,
    /// Packed kernel-column payloads: `kh` values (top to bottom) per
    /// nonzero vector, in `nz_flat` order — see
    /// [`VectorActivations::nz_vals`]. Empty for [`Self::index_only`].
    vals_flat: Vec<f32>,
    /// Whether `vals_flat` was packed (guards [`Self::nz_vals`]).
    has_vals: bool,
}

impl VectorWeights {
    /// Encode a `[K,C,KH,KW]` weight tensor, packing kernel-column value
    /// payloads next to the index lists.
    pub fn from_tensor(t: &Tensor) -> VectorWeights {
        Self::encode(t, true)
    }

    /// Index-only encode — see [`VectorActivations::index_only`].
    pub fn index_only(t: &Tensor) -> VectorWeights {
        Self::encode(t, false)
    }

    fn encode(t: &Tensor, pack_vals: bool) -> VectorWeights {
        assert_eq!(t.ndim(), 4, "weights must be [K,C,KH,KW]");
        let (k, c, kh, kw) = (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
        let mut occ = Bitset::new(k * c * kw);
        let mut nz_flat = Vec::new();
        let mut nz_offsets = Vec::with_capacity(k * c + 1);
        let mut vals_flat = Vec::new();
        nz_offsets.push(0);
        // Linear pass over contiguous (k,c) blocks of kh*kw elements
        // (perf: strided at4 indexing here dominated encoding —
        // EXPERIMENTS.md §Perf).
        for (kc, block) in t.data().chunks_exact(kh * kw).enumerate() {
            for j in 0..kw {
                let nz = (0..kh).any(|i| block[i * kw + j] != 0.0);
                if nz {
                    occ.set(kc * kw + j, true);
                    nz_flat.push(j as u8);
                    if pack_vals {
                        for i in 0..kh {
                            vals_flat.push(block[i * kw + j]);
                        }
                    }
                }
            }
            nz_offsets.push(nz_flat.len() as u32);
        }
        VectorWeights {
            k,
            c,
            kh,
            kw,
            occ,
            nz_flat,
            nz_offsets,
            vals_flat,
            has_vals: pack_vals,
        }
    }

    /// Total candidate weight vectors.
    pub fn total_vectors(&self) -> usize {
        self.k * self.c * self.kw
    }

    /// Occupied weight vectors.
    pub fn nonzero_vectors(&self) -> usize {
        self.occ.count_ones()
    }

    /// Vector-granularity weight density (Fig 10/11 "weight" series).
    pub fn density(&self) -> f64 {
        self.occ.density()
    }

    /// Is weight vector `(k, c, j)` occupied?
    pub fn occupied(&self, k: usize, c: usize, j: usize) -> bool {
        self.occ.get((k * self.c + c) * self.kw + j)
    }

    /// Sorted nonzero kernel-column indices for filter `(k, c)`.
    #[inline]
    pub fn nz_cols(&self, k: usize, c: usize) -> &[u8] {
        let g = k * self.c + c;
        &self.nz_flat[self.nz_offsets[g] as usize..self.nz_offsets[g + 1] as usize]
    }

    /// Packed payloads of the nonzero kernel columns of filter `(k, c)`:
    /// position `pos` of [`Self::nz_cols`] owns `[pos * kh, (pos+1) * kh)`.
    /// Panics on an [`Self::index_only`] encode.
    #[inline]
    pub fn nz_vals(&self, k: usize, c: usize) -> &[f32] {
        assert!(self.has_vals, "nz_vals on an index-only encode");
        let g = k * self.c + c;
        &self.vals_flat
            [self.nz_offsets[g] as usize * self.kh..self.nz_offsets[g + 1] as usize * self.kh]
    }

    /// Elements resident in the weight SRAM (nonzero vectors × KH).
    pub fn sram_elems(&self) -> usize {
        self.nonzero_vectors() * self.kh
    }

    /// Structural decode validation — see
    /// [`VectorActivations::validate`]. Weight groups are `(k, c)`
    /// filter slices of width `kw`.
    pub fn validate(&self) -> Result<(), CvfError> {
        validate_csr(
            &self.occ,
            &self.nz_offsets,
            &|pos| self.nz_flat[pos] as usize,
            self.nz_flat.len(),
            self.k * self.c,
            self.kw,
            &self.vals_flat,
            self.kh,
            self.has_vals,
        )
    }

    /// Fault-injection site counts — see
    /// [`VectorActivations::index_words`]. Weight index words are 8-bit.
    pub fn index_words(&self) -> usize {
        self.nz_flat.len()
    }

    /// See [`Self::index_words`].
    pub fn payload_words(&self) -> usize {
        self.vals_flat.len()
    }

    /// Flip one bit of an 8-bit weight index word (`bit` wraps at 8) —
    /// see [`VectorActivations::flip_index_bit`].
    pub fn flip_index_bit(&mut self, word: usize, bit: u32) {
        self.nz_flat[word] ^= 1u8 << (bit % 8);
    }

    /// Flip one bit of a payload word — see
    /// [`VectorActivations::flip_payload_bit`].
    pub fn flip_payload_bit(&mut self, word: usize, bit: u32) {
        let bits = self.vals_flat[word].to_bits() ^ (1u32 << (bit % 32));
        self.vals_flat[word] = f32::from_bits(bits);
    }

    /// Flip one bit of a CSR offset word — see
    /// [`VectorActivations::flip_offset_bit`].
    pub fn flip_offset_bit(&mut self, word: usize, bit: u32) {
        self.nz_offsets[word] ^= 1u32 << (bit % 32);
    }

    /// Stream checksum over the packed payload words — see
    /// [`VectorActivations::payload_checksum`].
    pub fn payload_checksum(&self) -> (f64, f64) {
        payload_checksum(&self.vals_flat)
    }
}

// --- fixed-point payloads (ISSUE 8 precision axis) ----------------------
//
// The CVF payload words can be stored as 16- or 8-bit fixed point
// (`sim::config::Precision`): a per-layer *calibrated scale* maps the
// layer's observed magnitude range onto the signed integer grid, and the
// functional path runs **fake-quantized** — every payload is rounded to
// a representable grid point and dequantized back to f32, so the rest of
// the dataflow is unchanged while the numerics match what the narrow
// datapath would compute. Quantized zero is exactly zero, so occupancy
// (and therefore the index system and the timing model) is never
// *densified* by quantization; small values may round to zero, which is
// the real hardware's behavior too.

/// Per-tensor calibrated quantization scale: `max|x| / qmax` (the
/// symmetric-range calibration used by inference accelerators), with a
/// positive fallback for all-zero tensors so division is always safe.
pub fn calibrated_scale(data: &[f32], qmax: f32) -> f32 {
    assert!(qmax > 0.0, "qmax must be positive");
    let max_abs = data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if max_abs > 0.0 {
        max_abs / qmax
    } else {
        1.0 / qmax
    }
}

/// Fake-quantize in place against a calibrated scale: round each value
/// to the nearest grid point `q * scale` with `q` clamped to
/// `[-qmax, qmax]`, then dequantize back to f32. Exact zeros stay
/// exactly zero.
pub fn fake_quantize(data: &mut [f32], scale: f32, qmax: f32) {
    assert!(scale > 0.0 && qmax > 0.0, "scale and qmax must be positive");
    for x in data.iter_mut() {
        let q = (*x / scale).round().clamp(-qmax, qmax);
        *x = q * scale;
    }
}

/// Calibrate-and-quantize against a [`crate::sim::config::Precision`]:
/// no-op at `F32` (returns `None`), otherwise fake-quantizes in place
/// and returns the per-tensor scale used (reported per layer).
pub fn fake_quantize_precision(
    data: &mut [f32],
    precision: crate::sim::config::Precision,
) -> Option<f32> {
    let qmax = precision.qmax()?;
    let scale = calibrated_scale(data, qmax);
    fake_quantize(data, scale, qmax);
    Some(scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activation_encoding_basic() {
        // 1 channel, 4x3, r=2 → 2 strips × 3 cols = 6 vectors.
        let mut t = Tensor::zeros(&[1, 4, 3]);
        *t.at3_mut(0, 0, 1) = 5.0; // strip 0, col 1
        *t.at3_mut(0, 3, 2) = -1.0; // strip 1, col 2
        let va = VectorActivations::from_tensor(&t, 2);
        assert_eq!(va.total_vectors(), 6);
        assert_eq!(va.nonzero_vectors(), 2);
        assert!(va.occupied(0, 0, 1));
        assert!(va.occupied(0, 1, 2));
        assert!(!va.occupied(0, 0, 0));
        assert_eq!(va.nz_cols(0, 0), &[1]);
        assert_eq!(va.nz_cols(0, 1), &[2]);
        assert!((va.density() - 2.0 / 6.0).abs() < 1e-12);
        assert_eq!(va.sram_elems(), 4);
    }

    #[test]
    fn ragged_last_strip() {
        // H=5, r=2 → 3 strips, last strip has 1 row.
        let mut t = Tensor::zeros(&[1, 5, 2]);
        *t.at3_mut(0, 4, 0) = 1.0;
        let va = VectorActivations::from_tensor(&t, 2);
        assert_eq!(va.strips, 3);
        assert!(va.occupied(0, 2, 0));
        assert!(!va.occupied(0, 2, 1));
    }

    #[test]
    fn any_nonzero_element_occupies_whole_vector() {
        let mut t = Tensor::zeros(&[1, 4, 1]);
        *t.at3_mut(0, 1, 0) = 0.001; // single element in strip 0
        let va = VectorActivations::from_tensor(&t, 4);
        assert_eq!(va.nonzero_vectors(), 1);
        assert_eq!(va.sram_elems(), 4); // whole vector stored
    }

    #[test]
    fn weight_encoding_kernel_columns() {
        // [2,1,3,3]: filter 0 has nonzero col 0 only; filter 1 all-zero.
        let mut t = Tensor::zeros(&[2, 1, 3, 3]);
        *t.at4_mut(0, 0, 2, 0) = 1.0;
        let vw = VectorWeights::from_tensor(&t);
        assert_eq!(vw.total_vectors(), 6);
        assert_eq!(vw.nonzero_vectors(), 1);
        assert!(vw.occupied(0, 0, 0));
        assert!(!vw.occupied(0, 0, 1));
        assert_eq!(vw.nz_cols(0, 0), &[0]);
        assert!(vw.nz_cols(1, 0).is_empty());
        assert_eq!(vw.sram_elems(), 3);
    }

    #[test]
    fn dense_tensor_fully_occupied() {
        let t = Tensor::from_vec(&[2, 4, 4], vec![1.0; 32]);
        let va = VectorActivations::from_tensor(&t, 2);
        assert_eq!(va.density(), 1.0);
        let w = Tensor::from_vec(&[2, 2, 3, 3], vec![1.0; 36]);
        let vw = VectorWeights::from_tensor(&w);
        assert_eq!(vw.density(), 1.0);
    }

    #[test]
    fn activation_values_packed_plane_major() {
        // SoA: within a group, plane p holds element p of every vector;
        // the AoS conversion reproduces the old per-vector layout.
        let mut t = Tensor::zeros(&[1, 5, 3]);
        *t.at3_mut(0, 0, 1) = 2.0; // strip 0 col 1: [2, 3]
        *t.at3_mut(0, 1, 1) = 3.0;
        *t.at3_mut(0, 1, 2) = 4.0; // strip 0 col 2: [0, 4]
        *t.at3_mut(0, 4, 0) = 5.0; // strip 2 (ragged, 1 row) col 0: [5, 0]
        let va = VectorActivations::from_tensor(&t, 2);
        assert_eq!(va.nz_cols(0, 0), &[1, 2]);
        let (soa, n) = va.nz_group_soa(0, 0);
        assert_eq!(n, 2);
        assert_eq!(soa, &[2.0, 0.0, 3.0, 4.0]); // plane 0 | plane 1
        assert_eq!(va.nz_plane(0, 0, 0), &[2.0, 0.0]);
        assert_eq!(va.nz_plane(0, 0, 1), &[3.0, 4.0]);
        // AoS conversion = the pre-SoA `nz_vals` layout.
        assert_eq!(va.nz_vals_aos(0, 0), vec![2.0, 3.0, 0.0, 4.0]);
        assert!(va.nz_group_soa(0, 1).0.is_empty());
        assert_eq!(va.nz_cols(0, 2), &[0]);
        assert_eq!(va.nz_vals_aos(0, 2), vec![5.0, 0.0]);
        assert_eq!(va.nz_group_soa(0, 2).0, &[5.0, 0.0]); // n = 1: SoA == AoS
    }

    #[test]
    fn weight_values_packed_in_index_order() {
        let mut t = Tensor::zeros(&[1, 2, 3, 3]);
        // (k=0, c=1): column 0 = [1, 0, 2], column 2 = [0, 3, 0].
        *t.at4_mut(0, 1, 0, 0) = 1.0;
        *t.at4_mut(0, 1, 2, 0) = 2.0;
        *t.at4_mut(0, 1, 1, 2) = 3.0;
        let vw = VectorWeights::from_tensor(&t);
        assert_eq!(vw.nz_cols(0, 1), &[0, 2]);
        assert_eq!(vw.nz_vals(0, 1), &[1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        assert!(vw.nz_vals(0, 0).is_empty());
    }

    #[test]
    fn packed_values_roundtrip_randomized() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(321);
        for _ in 0..10 {
            let c = rng.range(1, 4);
            let h = rng.range(2, 16);
            let w = rng.range(1, 10);
            let r = rng.range(1, 6);
            let data: Vec<f32> = (0..c * h * w)
                .map(|_| if rng.bernoulli(0.4) { rng.normal() } else { 0.0 })
                .collect();
            let t = Tensor::from_vec(&[c, h, w], data);
            let va = VectorActivations::from_tensor(&t, r);
            for ci in 0..c {
                for s in 0..va.strips {
                    let cols = va.nz_cols(ci, s);
                    let (soa, n) = va.nz_group_soa(ci, s);
                    assert_eq!(n, cols.len());
                    assert_eq!(soa.len(), n * r);
                    let aos = va.nz_vals_aos(ci, s);
                    for (pos, &col) in cols.iter().enumerate() {
                        for p in 0..r {
                            let row = s * r + p;
                            let want = if row < h { t.at3(ci, row, col as usize) } else { 0.0 };
                            // Plane-major storage and the AoS conversion
                            // agree with the tensor element for element.
                            assert_eq!(soa[p * n + pos], want);
                            assert_eq!(aos[pos * r + p], want);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn index_only_matches_indices_and_guards_vals() {
        let mut t = Tensor::zeros(&[2, 6, 4]);
        *t.at3_mut(0, 1, 2) = 1.0;
        *t.at3_mut(1, 5, 0) = -3.0;
        let full = VectorActivations::from_tensor(&t, 3);
        let idx = VectorActivations::index_only(&t, 3);
        assert_eq!(idx.nonzero_vectors(), full.nonzero_vectors());
        for c in 0..2 {
            for s in 0..full.strips {
                assert_eq!(idx.nz_cols(c, s), full.nz_cols(c, s));
            }
        }
        let w = Tensor::from_vec(&[1, 2, 3, 3], vec![1.0; 18]);
        let vw_idx = VectorWeights::index_only(&w);
        assert_eq!(vw_idx.nonzero_vectors(), 6);
        assert_eq!(vw_idx.nz_cols(0, 1), &[0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "index-only")]
    fn index_only_activation_vals_panics() {
        let t = Tensor::from_vec(&[1, 2, 2], vec![1.0; 4]);
        let va = VectorActivations::index_only(&t, 2);
        let _ = va.nz_group_soa(0, 0);
    }

    #[test]
    fn quantize_round_trip_error_bounded_by_half_step() {
        use crate::sim::config::Precision;
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(88);
        for precision in [Precision::Int16, Precision::Int8] {
            let qmax = precision.qmax().unwrap();
            for _ in 0..10 {
                let n = rng.range(1, 200);
                let amp = rng.f32_range(0.01, 8.0);
                let mut data: Vec<f32> = (0..n)
                    .map(|_| {
                        if rng.bernoulli(0.3) {
                            0.0
                        } else {
                            rng.f32_range(-amp, amp)
                        }
                    })
                    .collect();
                let original = data.clone();
                let scale = fake_quantize_precision(&mut data, precision).unwrap();
                let expect_scale = calibrated_scale(&original, qmax);
                assert_eq!(scale, expect_scale);
                for (&q, &x) in data.iter().zip(&original) {
                    // In-range values round to the nearest grid point:
                    // error at most half a quantization step. Calibration
                    // covers max|x|, so nothing is out of range.
                    assert!(
                        (q - x).abs() <= scale * 0.5 + 1e-12,
                        "{precision:?}: |{q} - {x}| > {}/2",
                        scale
                    );
                    // Exact zeros survive exactly (sparsity is never
                    // densified by quantization).
                    if x == 0.0 {
                        assert_eq!(q, 0.0);
                    }
                    // Every output sits on the grid.
                    let steps = q / scale;
                    assert!((steps - steps.round()).abs() < 1e-3);
                    assert!(steps.abs() <= qmax + 0.5);
                }
            }
        }
    }

    #[test]
    fn quantize_f32_is_identity_and_zero_tensor_safe() {
        use crate::sim::config::Precision;
        let mut data = vec![0.1f32, -2.5, 0.0];
        let orig = data.clone();
        assert_eq!(fake_quantize_precision(&mut data, Precision::F32), None);
        assert_eq!(data, orig);
        // All-zero tensor: positive fallback scale, values unchanged.
        let mut zeros = vec![0.0f32; 5];
        let s = fake_quantize_precision(&mut zeros, Precision::Int8).unwrap();
        assert!(s > 0.0);
        assert!(zeros.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn int8_coarser_than_int16() {
        use crate::sim::config::Precision;
        // Same payload, both precisions: the int8 grid is coarser, so its
        // worst-case error is at least the int16 one.
        let data: Vec<f32> = (0..64).map(|i| ((i * 37 % 101) as f32 - 50.0) / 13.0).collect();
        let err = |p| {
            let mut q = data.clone();
            fake_quantize_precision(&mut q, p).unwrap();
            q.iter()
                .zip(&data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max)
        };
        let e8 = err(Precision::Int8);
        let e16 = err(Precision::Int16);
        assert!(e8 >= e16);
        assert!(e8 > 0.0); // int8 genuinely rounds at this amplitude
        assert!(e16 < 1e-3); // int16 is near-exact at this amplitude
    }

    #[test]
    fn vector_density_at_least_element_density() {
        // Vector granularity can only merge zeros, never create them.
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(123);
        for _ in 0..20 {
            let c = rng.range(1, 4);
            let h = rng.range(2, 20);
            let w = rng.range(1, 12);
            let r = rng.range(1, 8);
            let data = (0..c * h * w)
                .map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 })
                .collect();
            let t = Tensor::from_vec(&[c, h, w], data);
            let va = VectorActivations::from_tensor(&t, r);
            assert!(
                va.density() >= t.density() - 1e-9,
                "vector density {} < element density {}",
                va.density(),
                t.density()
            );
        }
    }

    #[test]
    fn clean_encodes_validate_ok() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(777);
        for _ in 0..10 {
            let c = rng.range(1, 4);
            let h = rng.range(2, 16);
            let w = rng.range(1, 10);
            let r = rng.range(1, 6);
            let data: Vec<f32> = (0..c * h * w)
                .map(|_| if rng.bernoulli(0.4) { rng.normal() } else { 0.0 })
                .collect();
            let t = Tensor::from_vec(&[c, h, w], data);
            assert_eq!(VectorActivations::from_tensor(&t, r).validate(), Ok(()));
            assert_eq!(VectorActivations::index_only(&t, r).validate(), Ok(()));
        }
        let w = Tensor::from_vec(&[2, 2, 3, 3], vec![1.0; 36]);
        assert_eq!(VectorWeights::from_tensor(&w).validate(), Ok(()));
    }

    #[test]
    fn index_bit_flips_are_always_structurally_detected() {
        // Any single index-word flip lands in one of the validate arms:
        // out of bounds (high bits), occupancy mismatch (bit for the new
        // column is clear), or monotonicity (collision with a listed
        // column). None escape.
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(778);
        let data: Vec<f32> =
            (0..3 * 12 * 9).map(|_| if rng.bernoulli(0.5) { rng.normal() } else { 0.0 }).collect();
        let t = Tensor::from_vec(&[3, 12, 9], data);
        let clean = VectorActivations::from_tensor(&t, 4);
        assert!(clean.index_words() > 0);
        for _ in 0..30 {
            let mut va = clean.clone();
            let word = rng.below(va.index_words() as u32) as usize;
            va.flip_index_bit(word, rng.below(16));
            assert!(va.validate().is_err(), "index flip at word {word} escaped validation");
        }
    }

    #[test]
    fn payload_flip_blast_radius_is_one_word() {
        let mut t = Tensor::zeros(&[1, 4, 3]);
        *t.at3_mut(0, 0, 1) = 2.0;
        *t.at3_mut(0, 1, 1) = 3.0;
        let clean = VectorActivations::from_tensor(&t, 2);
        let mut va = clean.clone();
        va.flip_payload_bit(0, 21); // a mantissa bit: stays finite
        assert_eq!(va.validate(), Ok(()));
        let (dirty, n) = va.nz_group_soa(0, 0);
        let (orig, _) = clean.nz_group_soa(0, 0);
        assert_eq!(n, 1);
        let diffs = dirty.iter().zip(orig).filter(|(a, b)| a != b).count();
        assert_eq!(diffs, 1, "payload flip must corrupt exactly one word");
        // Drive word 0 (the 2.0) to an all-ones exponent: +inf, caught.
        let mut bad = clean.clone();
        for bit in 23..30 {
            bad.flip_payload_bit(0, bit);
        }
        assert!(!bad.nz_group_soa(0, 0).0[0].is_finite());
        assert!(matches!(bad.validate(), Err(CvfError::PayloadNotFinite { .. })));
    }

    #[test]
    fn offset_corruption_is_detected_before_any_decode() {
        let t = Tensor::from_vec(&[2, 6, 4], vec![1.0; 48]);
        let clean = VectorActivations::from_tensor(&t, 3);
        for (word, bit) in [(1usize, 0u32), (2, 5), (3, 31), (4, 16)] {
            let mut va = clean.clone();
            va.flip_offset_bit(word, bit);
            assert!(va.validate().is_err(), "offset flip ({word},{bit}) escaped");
        }
    }

    #[test]
    fn weight_flips_detected_like_activations() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(779);
        let data: Vec<f32> =
            (0..4 * 3 * 3 * 3).map(|_| if rng.bernoulli(0.5) { rng.normal() } else { 0.0 }).collect();
        let t = Tensor::from_vec(&[4, 3, 3, 3], data);
        let clean = VectorWeights::from_tensor(&t);
        assert!(clean.index_words() > 0 && clean.payload_words() > 0);
        for _ in 0..20 {
            let mut vw = clean.clone();
            vw.flip_index_bit(rng.below(vw.index_words() as u32) as usize, rng.below(8));
            assert!(vw.validate().is_err());
        }
        let mut vw = clean.clone();
        vw.flip_offset_bit(1, 3);
        assert!(vw.validate().is_err());
    }
}
