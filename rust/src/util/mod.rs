//! Substrate utilities built from scratch for the offline environment:
//! deterministic PRNG, minimal JSON, a leveled logger and stat helpers.
//!
//! The build environment has no network access to crates.io, so the usual
//! `rand`/`serde_json`/`log` stack is unavailable; these are small,
//! well-tested replacements that the rest of the crate depends on.

pub mod bench;
pub mod json;
pub mod logging;
pub mod metrics;
pub mod parallel;
pub mod pool;
pub mod rng;
pub mod scratch;
pub mod simd;
pub mod stats;
pub mod trace_span;

pub use json::Json;
pub use parallel::{par_chunk_map, par_chunks_mut};
pub use rng::Pcg32;

/// The one host-thread default shared by every layer of the stack (the
/// CLI, the engine, the experiment driver and the simulator all used to
/// carry their own): one worker per available core, `1` when the core
/// count cannot be determined. A `--threads 0` / `SimConfig::threads == 0`
/// resolves through this ("auto").
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Resolve a user-facing thread count: `0` means auto
/// ([`default_threads`]), anything else is taken literally. The one place
/// the `--threads 0` / `SimConfig::threads == 0` convention is
/// implemented.
pub fn resolve_threads(n: usize) -> usize {
    if n == 0 {
        default_threads()
    } else {
        n
    }
}

/// Integer ceiling division: smallest `q` with `q * d >= n`.
#[inline]
pub fn ceil_div(n: usize, d: usize) -> usize {
    assert!(d > 0, "ceil_div by zero");
    n.div_ceil(d)
}

/// Round `n` up to the next multiple of `m`.
#[inline]
pub fn round_up(n: usize, m: usize) -> usize {
    ceil_div(n, m) * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(4, 3), 2);
        assert_eq!(ceil_div(9, 3), 3);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 4), 0);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(5, 4), 8);
    }

    #[test]
    #[should_panic(expected = "ceil_div by zero")]
    fn ceil_div_zero_divisor_panics() {
        let _ = ceil_div(1, 0);
    }
}
