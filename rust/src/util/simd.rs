//! 8-lane f32 kernels for the compute hot paths (EXPERIMENTS.md §Perf):
//! the scheduler's clipped diagonal accumulate, the blocked im2col panel
//! kernel's inner axpy, and the CVF encoder's occupancy bit-OR.
//!
//! Every kernel here is strictly elementwise — lane `i` only ever reads
//! and writes element `i` — so there is no cross-lane float reduction to
//! reassociate and the scalar, blocked and explicit-SIMD paths are
//! bit-identical by construction. That is what lets the f32 exact path
//! stay pinned bit-for-bit (tests/pool_determinism.rs,
//! `blocked_matmul_bit_identical_to_naive`) while still vectorizing.
//!
//! Dispatch: the `simd` cargo feature (nightly, `portable_simd`) selects
//! explicit `std::simd` vectors; the default stable build runs the same
//! loop over fixed 8-element blocks, which the autovectorizer handles
//! reliably because the trip count is a compile-time constant. The
//! `*_scalar` reference variants are always available so the paired
//! benches (`bench_sim_perf` kernel series) and the parity tests can
//! compare the dispatched kernel against plain scalar code in the same
//! binary, whichever feature set is active.

/// Vector width of the blocked/SIMD paths (f32 lanes in 256 bits).
pub const LANES: usize = 8;

/// `dst[i] += src[i]` — the clipped diagonal accumulate in
/// `sim/scheduler.rs::functional_forward` / `diag_clip`.
#[inline]
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    let n = dst.len().min(src.len());
    let main = n - n % LANES;
    #[cfg(feature = "simd")]
    {
        use std::simd::f32x8;
        for (d, s) in dst[..main]
            .chunks_exact_mut(LANES)
            .zip(src[..main].chunks_exact(LANES))
        {
            (f32x8::from_slice(d) + f32x8::from_slice(s)).copy_to_slice(d);
        }
    }
    #[cfg(not(feature = "simd"))]
    for (d, s) in dst[..main]
        .chunks_exact_mut(LANES)
        .zip(src[..main].chunks_exact(LANES))
    {
        for (x, &y) in d.iter_mut().zip(s) {
            *x += y;
        }
    }
    for (x, &y) in dst[main..n].iter_mut().zip(&src[main..n]) {
        *x += y;
    }
}

/// Scalar reference for [`add_assign`] (paired-bench baseline).
#[inline]
pub fn add_assign_scalar(dst: &mut [f32], src: &[f32]) {
    for (x, &y) in dst.iter_mut().zip(src) {
        *x += y;
    }
}

/// `dst[i] += a * src[i]` — the inner loop of the blocked matmul panel
/// kernel (`tensor/ops.rs::matmul_acc_into`). Multiply-then-add, never
/// fused, to match the scalar semantics exactly.
#[inline]
pub fn axpy(dst: &mut [f32], a: f32, src: &[f32]) {
    let n = dst.len().min(src.len());
    let main = n - n % LANES;
    #[cfg(feature = "simd")]
    {
        use std::simd::f32x8;
        let av = f32x8::splat(a);
        for (d, s) in dst[..main]
            .chunks_exact_mut(LANES)
            .zip(src[..main].chunks_exact(LANES))
        {
            (f32x8::from_slice(d) + av * f32x8::from_slice(s)).copy_to_slice(d);
        }
    }
    #[cfg(not(feature = "simd"))]
    for (d, s) in dst[..main]
        .chunks_exact_mut(LANES)
        .zip(src[..main].chunks_exact(LANES))
    {
        for (x, &y) in d.iter_mut().zip(s) {
            *x += a * y;
        }
    }
    for (x, &y) in dst[main..n].iter_mut().zip(&src[main..n]) {
        *x += a * y;
    }
}

/// Scalar reference for [`axpy`] (paired-bench baseline).
#[inline]
pub fn axpy_scalar(dst: &mut [f32], a: f32, src: &[f32]) {
    for (x, &y) in dst.iter_mut().zip(src) {
        *x += a * y;
    }
}

/// `dst[i] |= src[i].to_bits() & 0x7FFF_FFFF` — the CVF encoder's
/// branch-free occupancy reduction (`sparse/vector_format.rs`): OR the
/// sign-stripped bit patterns of a kernel-height row into the per-vector
/// accumulator, so a vector is occupied iff any accumulated word is
/// nonzero (`-0.0` counts as zero, matching `x != 0.0`).
#[inline]
pub fn or_abs_bits(dst: &mut [u32], src: &[f32]) {
    let n = dst.len().min(src.len());
    let main = n - n % LANES;
    #[cfg(feature = "simd")]
    {
        use std::simd::{f32x8, num::SimdFloat, u32x8};
        let mask = u32x8::splat(0x7FFF_FFFF);
        for (d, s) in dst[..main]
            .chunks_exact_mut(LANES)
            .zip(src[..main].chunks_exact(LANES))
        {
            (u32x8::from_slice(d) | (f32x8::from_slice(s).to_bits() & mask)).copy_to_slice(d);
        }
    }
    #[cfg(not(feature = "simd"))]
    for (d, s) in dst[..main]
        .chunks_exact_mut(LANES)
        .zip(src[..main].chunks_exact(LANES))
    {
        for (x, &y) in d.iter_mut().zip(s) {
            *x |= y.to_bits() & 0x7FFF_FFFF;
        }
    }
    for (x, &y) in dst[main..n].iter_mut().zip(&src[main..n]) {
        *x |= y.to_bits() & 0x7FFF_FFFF;
    }
}

/// Scalar reference for [`or_abs_bits`] (paired-bench baseline).
#[inline]
pub fn or_abs_bits_scalar(dst: &mut [u32], src: &[f32]) {
    for (x, &y) in dst.iter_mut().zip(src) {
        *x |= y.to_bits() & 0x7FFF_FFFF;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let x = rng.f32_range(-2.0, 2.0);
                // Mix in exact zeros and a negative zero so the occupancy
                // kernel's sign handling is exercised.
                match rng.next_u32() % 8 {
                    0 => 0.0,
                    1 => -0.0,
                    _ => x,
                }
            })
            .collect()
    }

    /// The dispatched kernels match the scalar references bit-for-bit on
    /// every length (covering all remainder cases around the lane width).
    #[test]
    fn kernels_bit_identical_to_scalar_references() {
        let mut rng = Pcg32::new(0x51_3D, 7);
        for n in [0, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100, 257] {
            let src = random_vec(&mut rng, n);
            let base = random_vec(&mut rng, n);
            let a = rng.f32_range(-1.0, 1.0);

            let mut d0 = base.clone();
            let mut d1 = base.clone();
            add_assign(&mut d0, &src);
            add_assign_scalar(&mut d1, &src);
            assert_eq!(bits(&d0), bits(&d1), "add_assign n={n}");

            let mut d0 = base.clone();
            let mut d1 = base.clone();
            axpy(&mut d0, a, &src);
            axpy_scalar(&mut d1, a, &src);
            assert_eq!(bits(&d0), bits(&d1), "axpy n={n}");

            let seed: Vec<u32> = base.iter().map(|x| x.to_bits() >> 3).collect();
            let mut b0 = seed.clone();
            let mut b1 = seed;
            or_abs_bits(&mut b0, &src);
            or_abs_bits_scalar(&mut b1, &src);
            assert_eq!(b0, b1, "or_abs_bits n={n}");
        }
    }

    /// Occupancy semantics: the OR accumulator is nonzero iff some input
    /// element is nonzero as a float (`-0.0` does not count).
    #[test]
    fn or_abs_bits_matches_nonzero_test() {
        let vals = [0.0f32, -0.0, 1.5, 0.0, -3.0, 0.0];
        for w in 1..=vals.len() {
            for start in 0..=(vals.len() - w) {
                let window = &vals[start..start + w];
                let mut acc = vec![0u32; w];
                or_abs_bits(&mut acc, window);
                let occupied = acc.iter().any(|&b| b != 0);
                assert_eq!(occupied, window.iter().any(|&x| x != 0.0));
            }
        }
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }
}
