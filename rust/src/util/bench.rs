//! Hand-rolled micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §9): warmup + median-of-N wall times with basic spread, plus
//! machine-readable JSON emission so the perf trajectory is tracked across
//! PRs (EXPERIMENTS.md §Perf).

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl BenchResult {
    /// `name: median 12.3ms (min 11.8ms, max 13.1ms, n=9)`
    pub fn line(&self) -> String {
        format!(
            "{:40} median {:>12?} (min {:?}, max {:?}, n={})",
            self.name, self.median, self.min, self.max, self.iters
        )
    }

    /// Throughput line given a per-iteration work amount.
    pub fn throughput(&self, units: f64, unit_name: &str) -> String {
        let per_sec = units / self.median.as_secs_f64();
        format!("{:40} {:>14.3e} {unit_name}/s", self.name, per_sec)
    }

    /// Serialize as `{name, median_ns, min_ns, max_ns, iters}`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("median_ns", self.median.as_nanos() as u64)
            .set("min_ns", self.min.as_nanos() as u64)
            .set("max_ns", self.max.as_nanos() as u64)
            .set("iters", self.iters);
        o
    }
}

/// Bundle bench results (plus free-form derived metrics) into one report
/// document: `{"results": [...], "derived": {...}}`.
pub fn results_json(results: &[BenchResult], derived: Json) -> Json {
    let mut o = Json::obj();
    o.set(
        "results",
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    )
    .set("derived", derived);
    o
}

/// Write a bench report (see [`results_json`]) as pretty JSON.
pub fn write_results(
    path: &str,
    results: &[BenchResult],
    derived: Json,
) -> std::io::Result<()> {
    std::fs::write(path, results_json(results, derived).pretty())
}

/// Run `f` `iters` times after `warmup` runs; report the median.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    BenchResult {
        name: name.to_string(),
        median: times[times.len() / 2],
        min: times[0],
        max: times[times.len() - 1],
        iters,
    }
}

/// Prevent the optimizer from discarding a value (ptr read + fence — stable
/// Rust's `black_box` equivalent good enough for coarse benches).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0usize;
        let r = bench("noop", 2, 5, || {
            count += 1;
            black_box(count);
        });
        assert_eq!(count, 7); // 2 warmup + 5 timed
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.line().contains("noop"));
        assert!(r.throughput(1e6, "ops").contains("ops/s"));
    }

    #[test]
    fn json_report_shape_and_roundtrip() {
        let r = bench("case", 0, 3, || {
            black_box(1 + 1);
        });
        let mut derived = Json::obj();
        derived.set("speedup", 4.2);
        let doc = results_json(&[r], derived);
        let arr = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("case"));
        assert_eq!(arr[0].get("iters").unwrap().as_usize(), Some(3));
        assert!(arr[0].get("median_ns").unwrap().as_f64().is_some());
        assert!(doc.get("derived").unwrap().get("speedup").is_some());
        // Round-trips through the parser.
        assert_eq!(Json::parse(&doc.pretty()).unwrap(), doc);
    }
}
