//! Minimal JSON value type, parser and writer.
//!
//! Used for the artifact manifest produced by `python/compile/aot.py`, for
//! experiment reports under `reports/`, and for simulator configuration
//! files. Supports the full JSON grammar except `\u` surrogate pairs are
//! passed through unvalidated (sufficient for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (stable report diffs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors --------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Array element lookup.
    pub fn at(&self, i: usize) -> Option<&Json> {
        self.as_arr().and_then(|a| a.get(i))
    }

    // ---- parse ------------------------------------------------------------

    /// Parse a JSON document. Errors carry the byte offset.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- write ------------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// JSON parse error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.bytes.len());
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8")),
                        }
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().at(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn deterministic_object_order() {
        let mut o = Json::obj();
        o.set("zebra", 1usize).set("apple", 2usize);
        assert_eq!(o.to_string(), r#"{"apple":2,"zebra":1}"#);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_numbers_with_exponents() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn unicode_escape_and_utf8() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
        let v = Json::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str(), Some("café"));
    }

    #[test]
    fn pretty_is_reparseable() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse("[]").unwrap().to_string(), "[]");
        assert_eq!(Json::parse("{}").unwrap().to_string(), "{}");
    }

    #[test]
    fn fuzz_roundtrip_random_structures() {
        // Hand-rolled property test: random JSON trees survive a
        // serialize→parse round trip.
        use crate::util::rng::Pcg32;
        fn gen(rng: &mut Pcg32, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bernoulli(0.5)),
                2 => Json::Num((rng.next_u32() % 10_000) as f64 / 8.0),
                3 => Json::Str(format!("s{}", rng.next_u32() % 1000)),
                4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => {
                    let mut o = std::collections::BTreeMap::new();
                    for i in 0..rng.below(4) {
                        o.insert(format!("k{i}"), gen(rng, depth - 1));
                    }
                    Json::Obj(o)
                }
            }
        }
        let mut rng = Pcg32::seeded(2024);
        for _ in 0..200 {
            let v = gen(&mut rng, 3);
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
            assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
        }
    }
}
