//! Per-worker reusable scratch buffers.
//!
//! Each thread — the persistent pool workers above all — owns one
//! [`ScratchArena`]: a free-list of previously used buffers, checked out
//! with `take_*` and returned with `recycle_*`. Because pool workers
//! live for the whole process, a hot loop that takes and recycles its
//! buffers allocates only on its first visit to a given thread; every
//! later image, batch or serve-profiling run reuses the same memory.
//!
//! Buffers are re-initialized on every `take_*` (`resize` after `clear`,
//! filled with the caller's value), so no state can leak between users —
//! pinned by `tests/pool_determinism.rs`, which runs repeated engine
//! images on one pool and asserts bit-identical reports.

use std::cell::RefCell;

/// Free-lists of reusable buffers, one arena per thread.
#[derive(Default)]
pub struct ScratchArena {
    f32s: Vec<Vec<f32>>,
    u32s: Vec<Vec<u32>>,
}

/// Pull the **best-fitting** buffer from a free-list: the smallest one
/// whose capacity already covers `len`, else the largest available (one
/// grow beats many). Size-aware so small takes (an 8-float MAC column)
/// don't walk off with the multi-MB im2col buffer and force it to be
/// re-grown — the lists stay role-stable and per-thread heap stays near
/// one copy of each distinct working size.
fn best_fit<T>(list: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    let mut best: Option<usize> = None;
    for (i, v) in list.iter().enumerate() {
        let cap = v.capacity();
        best = match best {
            None => Some(i),
            Some(b) => {
                let bcap = list[b].capacity();
                let better = if cap >= len {
                    bcap < len || cap < bcap
                } else {
                    bcap < len && cap > bcap
                };
                if better {
                    Some(i)
                } else {
                    Some(b)
                }
            }
        };
    }
    match best {
        Some(i) => list.swap_remove(i),
        None => Vec::new(),
    }
}

impl ScratchArena {
    fn take_f32(&mut self, len: usize, fill: f32) -> Vec<f32> {
        let mut v = best_fit(&mut self.f32s, len);
        v.clear();
        v.resize(len, fill);
        v
    }

    fn take_u32(&mut self, len: usize, fill: u32) -> Vec<u32> {
        let mut v = best_fit(&mut self.u32s, len);
        v.clear();
        v.resize(len, fill);
        v
    }
}

thread_local! {
    static ARENA: RefCell<ScratchArena> = RefCell::new(ScratchArena::default());
}

/// Check out an `f32` buffer of `len` elements, all set to `fill`.
pub fn take_f32(len: usize, fill: f32) -> Vec<f32> {
    ARENA.with(|a| a.borrow_mut().take_f32(len, fill))
}

/// Return an `f32` buffer to this thread's arena for reuse.
pub fn recycle_f32(v: Vec<f32>) {
    ARENA.with(|a| a.borrow_mut().f32s.push(v));
}

/// Check out a `u32` buffer of `len` elements, all set to `fill`.
pub fn take_u32(len: usize, fill: u32) -> Vec<u32> {
    ARENA.with(|a| a.borrow_mut().take_u32(len, fill))
}

/// Return a `u32` buffer to this thread's arena for reuse.
pub fn recycle_u32(v: Vec<u32>) {
    ARENA.with(|a| a.borrow_mut().u32s.push(v));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reinitializes_recycled_buffers() {
        let mut a = take_f32(4, 1.5);
        assert_eq!(a, vec![1.5; 4]);
        a[0] = 99.0;
        recycle_f32(a);
        // The recycled buffer must come back fully re-initialized.
        let b = take_f32(6, 0.0);
        assert_eq!(b, vec![0.0; 6]);
        recycle_f32(b);
    }

    #[test]
    fn best_fit_keeps_buffer_roles_stable() {
        // A small take must not walk off with the big recycled buffer.
        let big = take_f32(1000, 0.0);
        let small = take_f32(4, 0.0);
        recycle_f32(big);
        recycle_f32(small);
        let s = take_f32(3, 1.0);
        assert!(s.capacity() < 1000, "small take claimed the big buffer");
        let b = take_f32(900, 0.0);
        assert!(b.capacity() >= 1000, "big take missed the big buffer");
        recycle_f32(s);
        recycle_f32(b);
    }

    #[test]
    fn u32_roundtrip() {
        let w = take_u32(2, 1);
        assert_eq!(w, vec![1, 1]);
        recycle_u32(w);
    }
}
