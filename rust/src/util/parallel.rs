//! Minimal scoped-thread fan-out helper (rayon is unavailable offline —
//! DESIGN.md §9). One implementation of the "split an index range into
//! contiguous chunks, evaluate each on a worker, merge in order" pattern
//! shared by the simulation engine, the batch runner and the multi-config
//! experiment driver.

/// Evaluate `f` over `0..n` split into at most `workers` contiguous
/// chunks, each on its own scoped thread, and return the per-chunk results
/// in chunk order.
///
/// Deterministic by construction: the chunk boundaries depend only on
/// `(n, workers)` and results are merged in index order, so any
/// order-sensitive fold inside `f` sees the same elements as a sequential
/// loop over its range. With `workers <= 1` (or a single chunk) `f` runs
/// inline on the caller's thread — no spawn overhead on small inputs.
pub fn par_chunk_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let chunk = n.div_ceil(workers);
    let n_chunks = n.div_ceil(chunk);
    if n_chunks == 1 {
        return vec![f(0..n)];
    }
    let mut slots: Vec<Option<T>> = Vec::new();
    slots.resize_with(n_chunks, || None);
    std::thread::scope(|s| {
        for (ci, slot) in slots.iter_mut().enumerate() {
            let f = &f;
            s.spawn(move || {
                let lo = ci * chunk;
                *slot = Some(f(lo..((ci + 1) * chunk).min(n)));
            });
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("every chunk evaluated by its worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_range_exactly_once_in_order() {
        for n in [0usize, 1, 2, 7, 16, 33] {
            for workers in [1usize, 2, 3, 8, 64] {
                let chunks = par_chunk_map(n, workers, |r| r.collect::<Vec<usize>>());
                let flat: Vec<usize> = chunks.into_iter().flatten().collect();
                assert_eq!(flat, (0..n).collect::<Vec<usize>>(), "n={n} w={workers}");
            }
        }
    }

    #[test]
    fn partial_sums_merge_to_sequential_total() {
        let chunks = par_chunk_map(1000, 7, |r| r.map(|i| i as u64).sum::<u64>());
        assert_eq!(chunks.into_iter().sum::<u64>(), 499_500);
    }

    #[test]
    fn single_worker_runs_inline() {
        // With one worker the closure must still see the full range.
        let chunks = par_chunk_map(5, 1, |r| (r.start, r.end));
        assert_eq!(chunks, vec![(0, 5)]);
    }
}
