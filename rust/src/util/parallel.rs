//! Parallel fan-out helpers (rayon is unavailable offline — DESIGN.md §9).
//!
//! Since ISSUE 5 both entry points run on the persistent
//! [`super::pool::WorkerPool`] instead of spawning scoped threads per
//! call: a parallel region costs a queue push and a wake-up, not N thread
//! spawns. The old per-call `std::thread::scope` implementation is kept
//! behind [`force_scoped`] as the measured baseline
//! (`benches/bench_sim_perf.rs`) and as the reference the pool is pinned
//! bit-identical against (`tests/pool_determinism.rs`).

use super::pool::WorkerPool;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// When set, every fan-out below spawns scoped threads per call (the
/// pre-pool behavior). Results are bit-identical either way — this is a
/// benchmarking/verification knob, not a semantic one.
static FORCE_SCOPED: AtomicBool = AtomicBool::new(false);

/// Toggle the scoped-thread fallback (see [`FORCE_SCOPED`]). Used by the
/// perf benches to measure the spawn-per-call baseline and by the
/// determinism tests; process-global. Tests that depend on which mode
/// actually runs must hold [`scoped_test_lock`] around the toggle —
/// otherwise a concurrently running test can flip the flag mid-measure
/// (results stay bit-identical either way, but the pinned mode would
/// silently not be the mode exercised).
pub fn force_scoped(on: bool) {
    FORCE_SCOPED.store(on, Ordering::SeqCst);
}

/// Whether the scoped-thread fallback is active.
pub fn scoped_mode() -> bool {
    FORCE_SCOPED.load(Ordering::SeqCst)
}

/// Holds the process-wide mode lock; restores pooled mode when dropped
/// (panic-safe), so a failing test can't leave the process scoped.
pub struct ScopedModeLock {
    _guard: std::sync::MutexGuard<'static, ()>,
}

impl Drop for ScopedModeLock {
    fn drop(&mut self) {
        FORCE_SCOPED.store(false, Ordering::SeqCst);
    }
}

/// Serialize tests that toggle — or rely on — the execution mode: hold
/// the returned lock for the whole comparison region. Recovers from
/// poisoning (a panicked holder already restored nothing worse than the
/// default mode, which `Drop` re-asserts).
pub fn scoped_test_lock() -> ScopedModeLock {
    static LOCK: Mutex<()> = Mutex::new(());
    ScopedModeLock {
        _guard: LOCK.lock().unwrap_or_else(|e| e.into_inner()),
    }
}

/// Raw-pointer wrapper that lets pool tasks write disjoint regions of a
/// caller-owned buffer. Callers must guarantee disjointness.
struct SendPtr<T>(*mut T);
// SAFETY: only used to address disjoint elements/chunks from parallel
// tasks, all of which complete before the owning frame returns.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Evaluate `f` over `0..n` split into at most `workers` contiguous
/// chunks and return the per-chunk results in chunk order.
///
/// Deterministic by construction: the chunk boundaries depend only on
/// `(n, workers)` and results are merged in index order, so any
/// order-sensitive fold inside `f` sees the same elements as a sequential
/// loop over its range. With `workers <= 1` (or a single chunk) `f` runs
/// inline on the caller's thread — no pool round-trip on small inputs.
pub fn par_chunk_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let chunk = n.div_ceil(workers);
    let n_chunks = n.div_ceil(chunk);
    if n_chunks == 1 {
        return vec![f(0..n)];
    }
    let mut slots: Vec<Option<T>> = Vec::new();
    slots.resize_with(n_chunks, || None);
    if scoped_mode() {
        std::thread::scope(|s| {
            for (ci, slot) in slots.iter_mut().enumerate() {
                let f = &f;
                s.spawn(move || {
                    let lo = ci * chunk;
                    *slot = Some(f(lo..((ci + 1) * chunk).min(n)));
                });
            }
        });
    } else {
        let base = SendPtr(slots.as_mut_ptr());
        WorkerPool::global().run(n_chunks, &|ci| {
            let lo = ci * chunk;
            let v = f(lo..((ci + 1) * chunk).min(n));
            // SAFETY: each task index writes only its own slot, and all
            // tasks finish before `run` returns (then `slots` is read).
            unsafe {
                *base.0.add(ci) = Some(v);
            }
        });
    }
    slots
        .into_iter()
        .map(|r| r.expect("every chunk evaluated by its worker"))
        .collect()
}

/// Run `f(chunk_index, chunk)` over `data` split into `chunk_len`-sized
/// mutable chunks (the last may be shorter), one pool task per chunk.
///
/// The disjoint-output twin of [`par_chunk_map`]: the functional dataflow
/// and the im2col forward write per-filter planes into one output buffer.
/// Chunk boundaries depend only on `(data.len(), chunk_len)`, so outputs
/// are bit-identical for every worker count and to the scoped fallback.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let len = data.len();
    let n_chunks = len.div_ceil(chunk_len);
    if n_chunks <= 1 {
        if len > 0 {
            f(0, data);
        }
        return;
    }
    if scoped_mode() {
        std::thread::scope(|s| {
            for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
                let f = &f;
                s.spawn(move || f(ci, chunk));
            }
        });
        return;
    }
    let base = SendPtr(data.as_mut_ptr());
    WorkerPool::global().run(n_chunks, &|ci| {
        let lo = ci * chunk_len;
        let hi = ((ci + 1) * chunk_len).min(len);
        // SAFETY: chunk `ci` covers `[lo, hi)` exclusively — the ranges
        // are disjoint by construction and every task finishes before
        // `run` returns, when the caller regains `&mut data`.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
        f(ci, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_range_exactly_once_in_order() {
        for n in [0usize, 1, 2, 7, 16, 33] {
            for workers in [1usize, 2, 3, 8, 64] {
                let chunks = par_chunk_map(n, workers, |r| r.collect::<Vec<usize>>());
                let flat: Vec<usize> = chunks.into_iter().flatten().collect();
                assert_eq!(flat, (0..n).collect::<Vec<usize>>(), "n={n} w={workers}");
            }
        }
    }

    #[test]
    fn partial_sums_merge_to_sequential_total() {
        let chunks = par_chunk_map(1000, 7, |r| r.map(|i| i as u64).sum::<u64>());
        assert_eq!(chunks.into_iter().sum::<u64>(), 499_500);
    }

    #[test]
    fn single_worker_runs_inline() {
        // With one worker the closure must still see the full range.
        let chunks = par_chunk_map(5, 1, |r| (r.start, r.end));
        assert_eq!(chunks, vec![(0, 5)]);
    }

    #[test]
    fn chunks_mut_writes_every_element_once() {
        for len in [0usize, 1, 5, 16, 33] {
            for chunk_len in [1usize, 2, 7, 40] {
                let mut data = vec![0u32; len];
                par_chunks_mut(&mut data, chunk_len, |ci, chunk| {
                    for (off, x) in chunk.iter_mut().enumerate() {
                        *x += (ci * chunk_len + off) as u32 + 1;
                    }
                });
                let want: Vec<u32> = (0..len as u32).map(|i| i + 1).collect();
                assert_eq!(data, want, "len={len} chunk={chunk_len}");
            }
        }
    }

    #[test]
    fn scoped_fallback_matches_pool() {
        let _mode = scoped_test_lock();
        force_scoped(false);
        let pooled = par_chunk_map(100, 5, |r| r.sum::<usize>());
        force_scoped(true);
        let scoped = par_chunk_map(100, 5, |r| r.sum::<usize>());
        assert_eq!(pooled, scoped);
    }
}
