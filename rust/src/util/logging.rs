//! Tiny leveled logger (the `log` crate is unavailable offline).
//!
//! Level is process-global, settable from the CLI (`-v`, `-q`) or the
//! `VSCNN_LOG` environment variable (`error|warn|info|debug|trace`).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity levels, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from `VSCNN_LOG` if set. An unparseable value leaves the
/// level unchanged but warns once to stderr instead of being silently
/// ignored.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("VSCNN_LOG") {
        apply_env_value(&v);
    }
}

static WARNED_BAD_ENV: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Apply a `VSCNN_LOG` value; returns the parsed level, warning (once per
/// process) on garbage. Split from [`init_from_env`] so tests can drive
/// it without mutating the process environment.
pub fn apply_env_value(v: &str) -> Option<Level> {
    match parse_level(v) {
        Some(l) => {
            set_level(l);
            Some(l)
        }
        None => {
            if !WARNED_BAD_ENV.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "[WARN ] VSCNN_LOG={v:?} is not a log level \
                     (error|warn|info|debug|trace); keeping the current level"
                );
            }
            None
        }
    }
}

/// Parse a level name (case-insensitive).
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Whether a message at `level` would be emitted.
#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a message (used by the macros; prefer those).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    // The level is process-global and tests run in parallel: tests that
    // mutate it serialize on this gate and restore Info before releasing.
    fn gate() -> std::sync::MutexGuard<'static, ()> {
        static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn level_parse_and_order() {
        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("Warning"), Some(Level::Warn));
        assert_eq!(parse_level("TRACE"), Some(Level::Trace));
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("Info"), Some(Level::Info));
        assert_eq!(parse_level("DeBuG"), Some(Level::Debug));
        assert_eq!(parse_level("nope"), None);
        assert_eq!(parse_level(""), None);
        assert_eq!(parse_level(" info"), None, "no trimming");
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn env_init_applies_good_values_and_keeps_level_on_garbage() {
        // Exercises the split-out value path directly — no process-env
        // mutation, which would race with parallel tests.
        let _g = gate();
        set_level(Level::Info);
        assert_eq!(apply_env_value("debug"), Some(Level::Debug));
        assert!(enabled(Level::Debug));
        // Garbage: warns (once, to stderr) and leaves the level alone.
        assert_eq!(apply_env_value("chatty"), None);
        assert!(enabled(Level::Debug));
        assert!(!enabled(Level::Trace));
        assert!(WARNED_BAD_ENV.load(Ordering::Relaxed));
        // A second bad value stays silent but still reports None.
        assert_eq!(apply_env_value("louder"), None);
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn enabled_respects_level() {
        let _g = gate();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
