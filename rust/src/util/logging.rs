//! Tiny leveled logger (the `log` crate is unavailable offline).
//!
//! Level is process-global, settable from the CLI (`-v`, `-q`) or the
//! `VSCNN_LOG` environment variable (`error|warn|info|debug|trace`).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity levels, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from `VSCNN_LOG` if set.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("VSCNN_LOG") {
        if let Some(l) = parse_level(&v) {
            set_level(l);
        }
    }
}

/// Parse a level name (case-insensitive).
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Whether a message at `level` would be emitted.
#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a message (used by the macros; prefer those).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("TRACE"), Some(Level::Trace));
        assert_eq!(parse_level("nope"), None);
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn enabled_respects_level() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
