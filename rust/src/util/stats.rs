//! Small statistics helpers shared by the simulator, benches and reports.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean; 0.0 for empty input. Panics on non-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive values");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (averages the middle pair for even lengths); 0.0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len();
    if n % 2 == 1 {
        s[n / 2]
    } else {
        0.5 * (s[n / 2 - 1] + s[n / 2])
    }
}

/// `q`-quantile for `q` in [0, 1] via **nearest rank**: the value at
/// sorted index `round((n - 1) * q)`, no interpolation between
/// neighbours — always an element of `xs`, never a blend. 0.0 for empty
/// input; `q` outside [0, 1] is clamped.
///
/// The single quantile implementation in the crate: [`percentile`] (the
/// serving latency reports), `model::calibrate` (the bias quantile) and
/// `serve::report` all resolve here, so every consumer agrees on the
/// interpolation rule.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((s.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
    s[rank.min(s.len() - 1)]
}

/// Percentile (`p` in [0, 100]) — [`quantile`] at `p / 100`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    quantile(xs, p / 100.0)
}

/// Running accumulator for counts expressed as ratios (e.g. densities).
#[derive(Debug, Default, Clone, Copy)]
pub struct Ratio {
    pub num: u64,
    pub den: u64,
}

impl Ratio {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, num: u64, den: u64) {
        self.num += num;
        self.den += den;
    }

    /// num/den as f64; 0.0 when empty.
    pub fn value(&self) -> f64 {
        if self.den == 0 {
            0.0
        } else {
            self.num as f64 / self.den as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn quantile_nearest_rank_never_interpolates() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        // round(3 * 0.5) = 2 → sorted[2] = 3 (nearest rank, not 2.5).
        assert_eq!(quantile(&xs, 0.5), 3.0);
        // Out-of-range q clamps.
        assert_eq!(quantile(&xs, -1.0), 1.0);
        assert_eq!(quantile(&xs, 2.0), 4.0);
        // Percentile is exactly quantile(p / 100).
        for p in [0.0, 10.0, 37.0, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&xs, p), quantile(&xs, p / 100.0));
        }
    }

    #[test]
    fn ratio_accumulates() {
        let mut r = Ratio::new();
        assert_eq!(r.value(), 0.0);
        r.add(1, 4);
        r.add(1, 4);
        assert!((r.value() - 0.25).abs() < 1e-12);
    }
}
