//! Deterministic PRNG (PCG32) for synthetic weights/activations and the
//! hand-rolled property-test harness.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014). Deterministic across platforms, seedable,
//! and with independent streams — every experiment in this repo is exactly
//! reproducible from a `(seed, stream)` pair recorded in EXPERIMENTS.md.

/// A PCG32 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different stream ids
    /// yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        let _ = rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        let _ = rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0)");
        // Rejection threshold: multiples of bound fitting in 2^32.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi)` (half-open). Panics if `lo >= hi`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "range: empty interval [{lo}, {hi})");
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of precision.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (mean 0, std 1).
    pub fn normal(&mut self) -> f32 {
        // Avoid log(0) by shifting u1 away from zero.
        let u1 = (self.f32() + f32::EPSILON).min(1.0 - f32::EPSILON);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p` of `true`.
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm order not
    /// needed; simple shuffle-prefix for clarity, k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should diverge, {same} collisions");
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Pcg32::seeded(1);
        for bound in [1u32, 2, 3, 7, 100, 1 << 20] {
            for _ in 0..200 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_small_range_uniformly() {
        let mut rng = Pcg32::seeded(9);
        let mut hist = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            hist[rng.below(5) as usize] += 1;
        }
        for &h in &hist {
            let frac = h as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.02, "bucket frac {frac}");
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(5);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(11);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::seeded(13);
        let idx = rng.sample_indices(20, 10);
        assert_eq!(idx.len(), 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(idx.iter().all(|&i| i < 20));
    }

    #[test]
    fn range_bounds() {
        let mut rng = Pcg32::seeded(17);
        for _ in 0..1000 {
            let v = rng.range(5, 9);
            assert!((5..9).contains(&v));
        }
    }
}
