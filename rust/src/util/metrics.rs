//! Process-global metrics registry: counters, gauges, and log-bucketed
//! histograms with cheap atomic recording.
//!
//! Metric names are hierarchical dotted paths (`engine.compile.prune_us`,
//! `pool.tasks_stolen`, `serve.batch_size`). Recording is disabled by
//! default: every convenience recorder (`add`, `gauge_set`, `observe`)
//! starts with one relaxed atomic load and returns immediately when the
//! registry is off, so default runs pay a branch per call site and stay
//! bit-identical — no metric ever feeds back into simulation results.
//! `--metrics-out` (or `enable()` in tests/benches) turns recording on;
//! `snapshot()` serializes everything to deterministic sorted JSON.
//!
//! The `no-obs` cargo feature compiles the enable flag down to a constant
//! `false`, letting the optimizer delete every recording path outright for
//! overhead-audit builds; the default build keeps the runtime flag.
//!
//! Histograms use an octave layout (8 sub-buckets per power of two):
//! values below 16 land in exact buckets, larger values see at most
//! ~12.5% quantization. `p50/p95/p99` are nearest-rank over bucket lower
//! bounds — exact for small-integer distributions such as batch sizes,
//! and deterministic for a given multiset of recorded values.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::Json;

#[cfg(not(feature = "no-obs"))]
static ENABLED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Is recording on? Constant `false` under the `no-obs` feature.
#[inline]
pub fn enabled() -> bool {
    #[cfg(feature = "no-obs")]
    {
        false
    }
    #[cfg(not(feature = "no-obs"))]
    {
        ENABLED.load(Ordering::Relaxed)
    }
}

/// Turn recording on or off (CLI `--metrics-out`, benches, tests).
pub fn set_enabled(on: bool) {
    #[cfg(feature = "no-obs")]
    let _ = on;
    #[cfg(not(feature = "no-obs"))]
    ENABLED.store(on, Ordering::SeqCst);
}

// ---------------------------------------------------------------- handles

/// Monotonic counter.
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins signed gauge.
#[derive(Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS; // 8 sub-buckets per octave
const BUCKETS: usize = SUB + (64 - SUB_BITS as usize) * SUB; // 496 < 512

/// Log-bucketed histogram of `u64` samples.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: Vec<AtomicU64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// Bucket index: exact for `v < 2*SUB`, octave+sub-bucket above.
fn bucket_index(v: u64) -> usize {
    if v == 0 {
        return 0;
    }
    let msb = 63 - v.leading_zeros();
    if msb < SUB_BITS {
        return v as usize;
    }
    let shift = msb - SUB_BITS;
    let sub = (v >> shift) as usize - SUB;
    SUB + shift as usize * SUB + sub
}

/// Lower bound of bucket `i` — the representative used for quantiles.
fn bucket_floor(i: usize) -> u64 {
    if i < 2 * SUB {
        return i as u64;
    }
    let k = (i - SUB) / SUB;
    let sub = (i - SUB) % SUB;
    ((SUB + sub) as u64) << k
}

impl Histogram {
    #[inline]
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Nearest-rank quantile over bucket lower bounds, clamped to the
    /// exact observed [min, max].
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                let min = self.min.load(Ordering::Relaxed);
                let max = self.max.load(Ordering::Relaxed);
                return bucket_floor(i).clamp(min, max);
            }
        }
        self.max.load(Ordering::Relaxed)
    }

    fn to_json(&self) -> Json {
        let count = self.count();
        let mut j = Json::obj();
        j.set("count", count as f64);
        if count == 0 {
            return j;
        }
        let sum = self.sum.load(Ordering::Relaxed);
        j.set("sum", sum as f64);
        j.set("min", self.min.load(Ordering::Relaxed) as f64);
        j.set("max", self.max.load(Ordering::Relaxed) as f64);
        j.set("mean", sum as f64 / count as f64);
        j.set("p50", self.quantile(0.50) as f64);
        j.set("p95", self.quantile(0.95) as f64);
        j.set("p99", self.quantile(0.99) as f64);
        j
    }
}

// --------------------------------------------------------------- registry

enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

fn registry() -> &'static Mutex<BTreeMap<String, Metric>> {
    static REG: OnceLock<Mutex<BTreeMap<String, Metric>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Look up (or register) the named counter. Handles are `&'static` and
/// leaked on first registration; cache the handle in genuinely hot loops.
pub fn counter(name: &str) -> &'static Counter {
    let mut reg = registry().lock().unwrap();
    let got = match reg
        .entry(name.to_string())
        .or_insert_with(|| Metric::Counter(Box::leak(Box::default())))
    {
        Metric::Counter(c) => Some(*c),
        _ => None,
    };
    // Release the lock before panicking on a type clash so a buggy call
    // site can't poison the whole registry.
    drop(reg);
    got.unwrap_or_else(|| panic!("metric `{name}` already registered with another type"))
}

/// Look up (or register) the named gauge.
pub fn gauge(name: &str) -> &'static Gauge {
    let mut reg = registry().lock().unwrap();
    let got = match reg
        .entry(name.to_string())
        .or_insert_with(|| Metric::Gauge(Box::leak(Box::default())))
    {
        Metric::Gauge(g) => Some(*g),
        _ => None,
    };
    drop(reg);
    got.unwrap_or_else(|| panic!("metric `{name}` already registered with another type"))
}

/// Look up (or register) the named histogram.
pub fn histogram(name: &str) -> &'static Histogram {
    let mut reg = registry().lock().unwrap();
    let got = match reg
        .entry(name.to_string())
        .or_insert_with(|| Metric::Histogram(Box::leak(Box::default())))
    {
        Metric::Histogram(h) => Some(*h),
        _ => None,
    };
    drop(reg);
    got.unwrap_or_else(|| panic!("metric `{name}` already registered with another type"))
}

// ------------------------------------------------- convenience recorders
//
// Instrumentation call sites use these: when the registry is disabled the
// cost is a single relaxed load + branch, with no name lookup.

/// Bump a counter by `n` (no-op while disabled).
#[inline]
pub fn add(name: &str, n: u64) {
    if enabled() {
        counter(name).add(n);
    }
}

/// Set a gauge (no-op while disabled).
#[inline]
pub fn gauge_set(name: &str, v: i64) {
    if enabled() {
        gauge(name).set(v);
    }
}

/// Record a histogram sample (no-op while disabled).
#[inline]
pub fn observe(name: &str, v: u64) {
    if enabled() {
        histogram(name).record(v);
    }
}

/// Serialize every registered metric to deterministic sorted JSON:
/// `{"counters": {..}, "gauges": {..}, "histograms": {name: {count, sum,
/// min, max, mean, p50, p95, p99}}}`.
pub fn snapshot() -> Json {
    let reg = registry().lock().unwrap();
    let mut counters = Json::obj();
    let mut gauges = Json::obj();
    let mut histograms = Json::obj();
    for (name, m) in reg.iter() {
        match m {
            Metric::Counter(c) => {
                counters.set(name, c.get() as f64);
            }
            Metric::Gauge(g) => {
                gauges.set(name, g.get() as f64);
            }
            Metric::Histogram(h) => {
                histograms.set(name, h.to_json());
            }
        }
    }
    let mut j = Json::obj();
    j.set("counters", counters);
    j.set("gauges", gauges);
    j.set("histograms", histograms);
    j
}

#[cfg(all(test, not(feature = "no-obs")))]
mod tests {
    use super::*;

    // The enable flag is process-global and lib tests run in parallel:
    // every test that flips it serializes on this gate and restores the
    // disabled state before releasing it.
    fn gate() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn bucket_layout_is_exact_below_sixteen_and_monotone() {
        for v in 0..(2 * SUB as u64) {
            assert_eq!(bucket_index(v), v as usize, "exact bucket for {v}");
            assert_eq!(bucket_floor(v as usize), v);
        }
        let mut prev = 0;
        for v in [
            1u64,
            7,
            8,
            16,
            17,
            100,
            1000,
            1 << 20,
            u64::MAX / 2,
            u64::MAX,
        ] {
            let i = bucket_index(v);
            assert!(i >= prev, "monotone index for {v}");
            assert!(i < BUCKETS);
            let floor = bucket_floor(i);
            assert!(floor <= v, "floor {floor} > value {v}");
            // Floor within 12.5% of the value (one sub-bucket of slack).
            assert!(
                (v - floor) as f64 <= v as f64 / SUB as f64,
                "floor {floor} too far below {v}"
            );
            prev = i;
        }
    }

    #[test]
    fn disabled_recorders_do_not_touch_registered_metrics() {
        let _g = gate();
        set_enabled(false);
        add("test.disabled_counter", 5);
        observe("test.disabled_hist", 42);
        gauge_set("test.disabled_gauge", 7);
        // The convenience recorders short-circuit before registration, so
        // the names never appear in the snapshot.
        let snap = snapshot().to_string();
        assert!(!snap.contains("test.disabled_counter"));
        assert!(!snap.contains("test.disabled_hist"));
        assert!(!snap.contains("test.disabled_gauge"));
    }

    #[test]
    fn counters_gauges_histograms_record_and_snapshot() {
        let _g = gate();
        set_enabled(true);
        add("test.snap_counter", 3);
        add("test.snap_counter", 4);
        gauge_set("test.snap_gauge", -12);
        for v in [2u64, 2, 3, 9, 1000] {
            observe("test.snap_hist", v);
        }
        set_enabled(false);

        assert_eq!(counter("test.snap_counter").get(), 7);
        assert_eq!(gauge("test.snap_gauge").get(), -12);
        let h = histogram("test.snap_hist");
        assert_eq!(h.count(), 5);
        assert_eq!(h.quantile(0.50), 3); // exact: small values hit exact buckets

        let snap = snapshot();
        fn num(j: &Json, path: &[&str]) -> f64 {
            let mut cur = j;
            for k in path {
                cur = cur.get(k).unwrap_or_else(|| panic!("missing key {k}"));
            }
            cur.as_f64().unwrap()
        }
        assert_eq!(num(&snap, &["counters", "test.snap_counter"]), 7.0);
        assert_eq!(num(&snap, &["gauges", "test.snap_gauge"]), -12.0);
        assert_eq!(num(&snap, &["histograms", "test.snap_hist", "count"]), 5.0);
        assert_eq!(num(&snap, &["histograms", "test.snap_hist", "min"]), 2.0);
        assert_eq!(num(&snap, &["histograms", "test.snap_hist", "p50"]), 3.0);
        // 1000 lands in an approximate bucket: p99 within 12.5% below max.
        let p99 = num(&snap, &["histograms", "test.snap_hist", "p99"]);
        assert!((875.0..=1000.0).contains(&p99), "p99 = {p99}");
    }

    #[test]
    fn quantiles_are_exact_for_small_integer_samples() {
        // Direct handle recording bypasses the enable flag, so no gate.
        let h = histogram("test.exact_quantiles");
        for v in 1..=10u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.50), 5);
        assert_eq!(h.quantile(0.95), 10);
        assert_eq!(h.quantile(0.99), 10);
        assert_eq!(h.quantile(0.10), 1);
    }

    #[test]
    #[should_panic(expected = "already registered with another type")]
    fn type_confusion_panics() {
        counter("test.type_confused");
        histogram("test.type_confused");
    }
}
