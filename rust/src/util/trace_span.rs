//! Lightweight span tracing with Chrome/Perfetto `trace_event` export.
//!
//! Two clock domains share one bounded global sink:
//!
//! * **Wall clock** (`pid 1`): scoped RAII [`span`] guards record "X"
//!   complete events in microseconds since process start, one lane (tid)
//!   per OS thread — compile phases, per-layer simulate calls, worker-pool
//!   chunk execution. Wall lanes are real time and therefore not
//!   replay-deterministic; they exist for profiling.
//! * **Virtual cycles** (`pid 2`, plus `pid 3` for per-PE issue events):
//!   explicit emitters stamp events with simulator cycle counts — serve
//!   fleet timelines, per-layer compute/transfer/fill attribution. These
//!   are derived purely from simulation state, so two same-seed runs
//!   export byte-identical traces. One trace tick equals one sim cycle.
//!
//! Everything is disabled by default; emitters short-circuit on a relaxed
//! atomic load so instrumented code costs a branch per site until
//! `--trace-out` turns a domain on. The event buffer is bounded by
//! `--trace-limit`; overflow increments a `dropped` counter that the
//! export records under `otherData.dropped_events`. The `no-obs` cargo
//! feature compiles both domain checks to constant `false`.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Wall-clock process lane.
pub const WALL_PID: u32 = 1;
/// Virtual-cycle process lane (engine layers, serve fleet).
pub const CYCLES_PID: u32 = 2;
/// Per-PE issue events promoted from `sim::trace` (Table-I style).
pub const PE_PID: u32 = 3;

/// Argument value attached to an event (`args` in trace_event JSON).
pub enum Arg {
    U(u64),
    F(f64),
    S(String),
}

struct Event {
    ph: char,
    pid: u32,
    tid: u64,
    ts: u64,
    dur: u64,
    cat: &'static str,
    name: String,
    args: Vec<(&'static str, Arg)>,
}

struct Sink {
    wall: AtomicBool,
    cycles: AtomicBool,
    limit: AtomicUsize,
    dropped: AtomicU64,
    events: Mutex<Vec<Event>>,
    epoch: Instant,
    next_wall_tid: AtomicU64,
    next_cycle_track: AtomicU64,
    pe_budget: AtomicU64,
}

fn sink() -> &'static Sink {
    static SINK: OnceLock<Sink> = OnceLock::new();
    SINK.get_or_init(|| Sink {
        wall: AtomicBool::new(false),
        cycles: AtomicBool::new(false),
        limit: AtomicUsize::new(0),
        dropped: AtomicU64::new(0),
        events: Mutex::new(Vec::new()),
        epoch: Instant::now(),
        next_wall_tid: AtomicU64::new(0),
        next_cycle_track: AtomicU64::new(0),
        pe_budget: AtomicU64::new(0),
    })
}

/// Enable tracing with an event cap. `wall` turns on the RAII wall-clock
/// spans; `cycles` turns on the virtual-cycle emitters. Serve traces
/// enable only `cycles` so replay is bit-deterministic.
pub fn enable(limit: usize, wall: bool, cycles: bool) {
    #[cfg(feature = "no-obs")]
    {
        let _ = (limit, wall, cycles);
    }
    #[cfg(not(feature = "no-obs"))]
    {
        let s = sink();
        s.limit.store(limit, Ordering::SeqCst);
        s.wall.store(wall, Ordering::SeqCst);
        s.cycles.store(cycles, Ordering::SeqCst);
    }
}

/// Turn both domains off (the buffer is kept until [`clear`]).
pub fn disable() {
    let s = sink();
    s.wall.store(false, Ordering::SeqCst);
    s.cycles.store(false, Ordering::SeqCst);
}

/// Drop all buffered events and reset the drop counter and PE budget.
pub fn clear() {
    let s = sink();
    s.events.lock().unwrap().clear();
    s.dropped.store(0, Ordering::SeqCst);
    s.pe_budget.store(0, Ordering::SeqCst);
}

#[inline]
pub fn wall_enabled() -> bool {
    #[cfg(feature = "no-obs")]
    {
        false
    }
    #[cfg(not(feature = "no-obs"))]
    {
        sink().wall.load(Ordering::Relaxed)
    }
}

#[inline]
pub fn cycles_enabled() -> bool {
    #[cfg(feature = "no-obs")]
    {
        false
    }
    #[cfg(not(feature = "no-obs"))]
    {
        sink().cycles.load(Ordering::Relaxed)
    }
}

fn push(ev: Event) {
    let s = sink();
    let mut events = s.events.lock().unwrap();
    if events.len() < s.limit.load(Ordering::Relaxed) {
        events.push(ev);
    } else {
        s.dropped.fetch_add(1, Ordering::Relaxed);
    }
}

/// Number of events rejected by the `--trace-limit` cap so far.
pub fn dropped() -> u64 {
    sink().dropped.load(Ordering::Relaxed)
}

// ------------------------------------------------------ wall-clock spans

thread_local! {
    static WALL_TID: std::cell::OnceCell<u64> = const { std::cell::OnceCell::new() };
}

/// Stable per-thread wall lane id; registers a `thread_name` metadata
/// event on first use so Perfetto labels the lane.
fn wall_tid() -> u64 {
    WALL_TID.with(|c| {
        *c.get_or_init(|| {
            let id = sink().next_wall_tid.fetch_add(1, Ordering::Relaxed);
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{id}"));
            push(Event {
                ph: 'M',
                pid: WALL_PID,
                tid: id,
                ts: 0,
                dur: 0,
                cat: "__metadata",
                name: "thread_name".to_string(),
                args: vec![("name", Arg::S(name))],
            });
            id
        })
    })
}

/// RAII wall-clock span: records an "X" event on drop.
pub struct Span {
    cat: &'static str,
    name: String,
    start_us: u64,
}

impl Drop for Span {
    fn drop(&mut self) {
        let end_us = sink().epoch.elapsed().as_micros() as u64;
        push(Event {
            ph: 'X',
            pid: WALL_PID,
            tid: wall_tid(),
            ts: self.start_us,
            dur: end_us.saturating_sub(self.start_us),
            cat: self.cat,
            name: std::mem::take(&mut self.name),
            args: Vec::new(),
        });
    }
}

/// Open a wall-clock span; `None` (zero-cost to hold) while disabled.
/// Guard callers that build dynamic names with [`wall_enabled`] to avoid
/// paying the `format!` when tracing is off.
#[inline]
pub fn span(cat: &'static str, name: impl Into<String>) -> Option<Span> {
    if !wall_enabled() {
        return None;
    }
    Some(Span {
        cat,
        name: name.into(),
        start_us: sink().epoch.elapsed().as_micros() as u64,
    })
}

// --------------------------------------------------- virtual-cycle events

/// Reserve `n` consecutive cycle-domain track ids (tids under
/// [`CYCLES_PID`]). Sequential callers get deterministic ids.
pub fn alloc_cycle_tracks(n: u64) -> u64 {
    sink().next_cycle_track.fetch_add(n, Ordering::Relaxed)
}

/// Claim cycle tracks `[base, base+n)` explicitly (serve uses instance
/// indices as track ids) so later [`alloc_cycle_tracks`] calls don't
/// collide with them.
pub fn reserve_cycle_tracks(base: u64, n: u64) {
    sink().next_cycle_track.fetch_max(base + n, Ordering::Relaxed);
}

/// Name a cycle-domain track (Perfetto lane label).
pub fn name_track(pid: u32, track: u64, name: impl Into<String>) {
    if !cycles_enabled() {
        return;
    }
    push(Event {
        ph: 'M',
        pid,
        tid: track,
        ts: 0,
        dur: 0,
        cat: "__metadata",
        name: "thread_name".to_string(),
        args: vec![("name", Arg::S(name.into()))],
    });
}

/// Emit a complete ("X") event stamped in sim cycles.
pub fn complete_cycles(
    pid: u32,
    track: u64,
    cat: &'static str,
    name: impl Into<String>,
    ts: u64,
    dur: u64,
    args: Vec<(&'static str, Arg)>,
) {
    if !cycles_enabled() {
        return;
    }
    push(Event {
        ph: 'X',
        pid,
        tid: track,
        ts,
        dur,
        cat,
        name: name.into(),
        args,
    });
}

/// Emit an instant ("i") marker stamped in sim cycles.
pub fn instant_cycles(pid: u32, track: u64, cat: &'static str, name: impl Into<String>, ts: u64) {
    if !cycles_enabled() {
        return;
    }
    push(Event {
        ph: 'i',
        pid,
        tid: track,
        ts,
        dur: 0,
        cat,
        name: name.into(),
        args: Vec::new(),
    });
}

/// Emit a counter ("C") sample stamped in sim cycles. Counters are keyed
/// by (pid, name) in Perfetto, so per-instance counters need distinct
/// names (e.g. `inst03.queue`).
pub fn counter_cycles(pid: u32, name: impl Into<String>, ts: u64, key: &'static str, value: u64) {
    if !cycles_enabled() {
        return;
    }
    push(Event {
        ph: 'C',
        pid,
        tid: 0,
        ts,
        dur: 0,
        cat: "counter",
        name: name.into(),
        args: vec![(key, Arg::U(value))],
    });
}

// -------------------------------------------------------- PE issue budget
//
// `vscnn simulate --trace-out` promotes the per-cycle PE trace
// (`sim::trace::Trace`) into the export. The sequential functional walk
// that produces those events is slow, so a run-wide budget bounds how
// many issue events the engine asks for; once exhausted, later layers
// fall back to the index-only timing path.

/// Set the run-wide PE issue-event budget (simulate CLI only).
pub fn set_pe_budget(n: u64) {
    sink().pe_budget.store(n, Ordering::SeqCst);
}

/// Remaining PE issue-event budget; 0 when PE tracing is off.
pub fn pe_budget() -> u64 {
    if !cycles_enabled() {
        return 0;
    }
    sink().pe_budget.load(Ordering::Relaxed)
}

/// Consume `n` events from the PE budget after a traced layer.
pub fn pe_consume(n: u64) {
    let s = sink();
    let cur = s.pe_budget.load(Ordering::Relaxed);
    s.pe_budget.store(cur.saturating_sub(n), Ordering::Relaxed);
}

// ----------------------------------------------------------------- export

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

fn write_event(out: &mut String, ev: &Event) {
    out.push_str("{\"name\":\"");
    escape_into(out, &ev.name);
    out.push_str("\",\"cat\":\"");
    escape_into(out, ev.cat);
    out.push_str("\",\"ph\":\"");
    out.push(ev.ph);
    out.push_str("\",\"pid\":");
    out.push_str(&ev.pid.to_string());
    out.push_str(",\"tid\":");
    out.push_str(&ev.tid.to_string());
    out.push_str(",\"ts\":");
    out.push_str(&ev.ts.to_string());
    if ev.ph == 'X' {
        out.push_str(",\"dur\":");
        out.push_str(&ev.dur.to_string());
    }
    if ev.ph == 'i' {
        out.push_str(",\"s\":\"t\"");
    }
    if !ev.args.is_empty() {
        out.push_str(",\"args\":{");
        for (i, (k, v)) in ev.args.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            escape_into(out, k);
            out.push_str("\":");
            match v {
                Arg::U(u) => out.push_str(&u.to_string()),
                Arg::F(f) => out.push_str(&format!("{f}")),
                Arg::S(s) => {
                    out.push('"');
                    escape_into(out, s);
                    out.push('"');
                }
            }
        }
        out.push('}');
    }
    out.push('}');
}

/// Serialize the buffer to Chrome `trace_event` JSON. Deterministic for
/// a deterministic event sequence: fixed key order, process-name
/// metadata derived from the pids present, no wall-clock stamps unless
/// wall spans were recorded.
pub fn export_string() -> String {
    let s = sink();
    let events = s.events.lock().unwrap();
    let mut out = String::with_capacity(events.len() * 96 + 256);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for pid in [WALL_PID, CYCLES_PID, PE_PID] {
        if events.iter().any(|e| e.pid == pid) {
            let label = match pid {
                WALL_PID => "vscnn wall clock (us)",
                CYCLES_PID => "vscnn sim (cycles)",
                _ => "vscnn pe issue (cycles)",
            };
            if !first {
                out.push(',');
            }
            first = false;
            write_event(
                &mut out,
                &Event {
                    ph: 'M',
                    pid,
                    tid: 0,
                    ts: 0,
                    dur: 0,
                    cat: "__metadata",
                    name: "process_name".to_string(),
                    args: vec![("name", Arg::S(label.to_string()))],
                },
            );
        }
    }
    for ev in events.iter() {
        if !first {
            out.push(',');
        }
        first = false;
        write_event(&mut out, ev);
    }
    out.push_str("],\"displayTimeUnit\":\"ms\",\"otherData\":{");
    out.push_str("\"cycle_domain\":\"pids 2,3: 1 tick = 1 sim cycle\",\"dropped_events\":");
    out.push_str(&s.dropped.load(Ordering::Relaxed).to_string());
    out.push_str("}}\n");
    out
}

/// Write the trace to `path` (see [`export_string`]).
pub fn write_chrome_trace(path: &str) -> std::io::Result<()> {
    std::fs::write(path, export_string())
}

// Behavioral tests live in tests/observability.rs: the sink is
// process-global, and the engine/serve/pool unit tests in this lib run
// concurrently with instrumented code, so exact-count assertions need a
// dedicated test binary where every tracer-flipping test serializes on
// one gate.
