//! Persistent worker pool behind [`crate::util::par_chunk_map`] and
//! [`crate::util::parallel::par_chunks_mut`].
//!
//! Every parallel site used to pay a `std::thread::scope` spawn per call —
//! hundreds of spawns per simulated image once the engine, the batch
//! runner and the serving profiler stack up. The pool spawns its workers
//! **once** (lazily, on first parallel call) and keeps them parked on a
//! condvar between jobs, so a parallel region costs a queue push and a
//! wake-up instead of N thread spawns.
//!
//! ## Scheduling
//!
//! A job is a type-erased chunk runner plus an atomic next-chunk cursor:
//! workers (and the submitting thread, which always participates) *steal*
//! chunks from the shared cursor with `fetch_add`, so a slow chunk never
//! idles the other workers — the classic self-scheduling form of work
//! stealing. Multiple jobs can be in flight at once (nested parallel
//! regions submit freely); the queue holds every job with unclaimed
//! chunks and workers drain it in submission order.
//!
//! ## Determinism
//!
//! The pool schedules *execution*, never *meaning*: chunk boundaries are a
//! pure function of the caller's `(n, workers)` and results are merged by
//! chunk index, so any thread interleaving produces bit-identical output
//! (pinned by `tests/pool_determinism.rs`).
//!
//! ## Deadlock freedom
//!
//! A submitter first runs chunks itself until the cursor is exhausted and
//! only then blocks on the job's completion — it can only be waiting on
//! chunks *claimed by running threads*. Nested jobs form a tree whose
//! deepest chunks spawn no further work, so some claimed chunk always
//! makes progress.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::util::{metrics, trace_span};

/// Type-erased pointer to a job's chunk runner. May dangle once the
/// submitting frame returns; the completion protocol guarantees it is
/// never dereferenced after that (see [`WorkerPool::run`]).
struct TaskRef(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared-call safe) and the pointer is only
// dereferenced under the job's claim/completion protocol.
unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

/// Erase the closure's lifetime so it can sit in the job queue. The raw
/// pointer is only dereferenced for claimed chunks, all of which complete
/// before [`WorkerPool::run`] returns — the pointee outlives every use.
#[allow(clippy::useless_transmute)]
fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> TaskRef {
    let p: *const (dyn Fn(usize) + Sync + 'a) = f;
    TaskRef(unsafe {
        std::mem::transmute::<
            *const (dyn Fn(usize) + Sync + 'a),
            *const (dyn Fn(usize) + Sync + 'static),
        >(p)
    })
}

struct Job {
    task: TaskRef,
    n_chunks: usize,
    /// Next chunk index to claim (claimed past `n_chunks` = exhausted).
    next: AtomicUsize,
    /// Chunks claimed but not yet finished + chunks never claimed.
    left: Mutex<usize>,
    done_cv: Condvar,
    /// First panic payload caught in a chunk; re-raised by the submitter
    /// (same payload the `std::thread::scope` baseline would deliver).
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Job {
    /// Claim and run chunks until the cursor is exhausted. Returns after
    /// the *claim* fails; other claimed chunks may still be running.
    /// `worker` marks pool-thread executions (vs the submitting thread)
    /// for the `pool.tasks_stolen` metric and the per-worker trace lanes.
    fn run_chunks(&self, worker: bool) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_chunks {
                return;
            }
            metrics::add("pool.chunks_run", 1);
            if worker {
                metrics::add("pool.tasks_stolen", 1);
            }
            // One wall span per chunk: each OS thread is its own trace
            // lane, so these render as per-worker occupancy bars.
            let _sp = trace_span::span("pool", if worker { "chunk(stolen)" } else { "chunk" });
            // SAFETY: `i < n_chunks` was claimed, so the submitter is still
            // blocked in `run` and the pointee is alive.
            let task = unsafe { &*self.task.0 };
            if let Err(payload) = std::panic::catch_unwind(AssertUnwindSafe(|| task(i))) {
                let mut p = self.panic.lock().unwrap();
                if p.is_none() {
                    *p = Some(payload);
                }
            }
            let mut left = self.left.lock().unwrap();
            *left -= 1;
            if *left == 0 {
                self.done_cv.notify_all();
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.n_chunks
    }
}

struct Shared {
    /// Jobs that may still have unclaimed chunks, in submission order.
    queue: Mutex<Vec<Arc<Job>>>,
    work_cv: Condvar,
}

/// The persistent pool. Use [`WorkerPool::global`]; constructing private
/// pools is deliberately unsupported (one pool per process keeps the
/// worker count bounded by the machine, not by call sites).
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Worker threads spawned (reporting/tests only).
    workers: usize,
}

impl WorkerPool {
    /// The process-wide pool, spawned on first use with
    /// [`super::default_threads`] workers.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let shared = Arc::new(Shared {
                queue: Mutex::new(Vec::new()),
                work_cv: Condvar::new(),
            });
            let workers = super::default_threads();
            for i in 0..workers {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("vscnn-pool-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker");
            }
            WorkerPool { shared, workers }
        })
    }

    /// Number of persistent worker threads (excludes submitters, which
    /// also execute chunks).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(0..n_chunks)` across the pool, returning when every chunk
    /// has finished. The submitting thread participates, so `n_chunks == 1`
    /// runs entirely inline. Panics in `f` are re-raised here after all
    /// chunks complete (matching `std::thread::scope` semantics).
    pub fn run(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        if n_chunks == 1 {
            f(0);
            return;
        }
        let job = Arc::new(Job {
            task: erase(f),
            n_chunks,
            next: AtomicUsize::new(0),
            left: Mutex::new(n_chunks),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(job.clone());
        }
        self.shared.work_cv.notify_all();
        metrics::add("pool.jobs", 1);
        job.run_chunks(false);
        let mut left = job.left.lock().unwrap();
        while *left > 0 {
            left = job.done_cv.wait(left).unwrap();
        }
        drop(left);
        // Lazily GC'd by workers too; remove eagerly to keep the queue
        // short.
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.retain(|j| !Arc::ptr_eq(j, &job));
        }
        let payload = job.panic.lock().unwrap().take();
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                q.retain(|j| !j.exhausted());
                if let Some(j) = q.first() {
                    break j.clone();
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        job.run_chunks(true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let hits = AtomicU64::new(0);
        let mask = Mutex::new(vec![false; 37]);
        WorkerPool::global().run(37, &|i| {
            hits.fetch_add(1, Ordering::SeqCst);
            let mut m = mask.lock().unwrap();
            assert!(!m[i], "chunk {i} ran twice");
            m[i] = true;
        });
        assert_eq!(hits.load(Ordering::SeqCst), 37);
        assert!(mask.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn nested_jobs_complete() {
        let total = AtomicU64::new(0);
        WorkerPool::global().run(4, &|_| {
            WorkerPool::global().run(8, &|j| {
                total.fetch_add(j as u64 + 1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * (1..=8).sum::<u64>());
    }

    #[test]
    fn zero_and_one_chunk_run_inline() {
        let hits = AtomicU64::new(0);
        WorkerPool::global().run(0, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 0);
        WorkerPool::global().run(1, &|i| {
            assert_eq!(i, 0);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn task_panic_propagates_to_submitter() {
        let res = std::panic::catch_unwind(|| {
            WorkerPool::global().run(3, &|i| {
                if i == 1 {
                    panic!("boom");
                }
            });
        });
        // The original payload is re-raised, scope-style.
        let payload = res.expect_err("panic must propagate");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // The pool survives a panicking job.
        let ok = AtomicU64::new(0);
        WorkerPool::global().run(3, &|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 3);
    }
}
