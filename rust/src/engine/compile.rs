//! The compile phase: turn `(network, params)` into a [`PreparedNetwork`]
//! of input-independent per-layer artifacts (see the module doc of
//! [`crate::engine`]).

use crate::model::init::Params;
use crate::model::{LayerKind, Network};
use crate::pruning;
use crate::sim::config::{Precision, SimConfig};
use crate::sim::mapping::{compile_conv, CompiledConv};
use crate::sim::sram::TilePlan;
use crate::sparse::encode::{weight_side_stats, WeightSideStats};
use crate::sparse::VectorWeights;
use crate::tensor::conv::ConvSpec;
use crate::tensor::Tensor;
use crate::util::{metrics, trace_span};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// PE-column count of both paper configurations (`[4,14,3]` / `[8,7,3]`):
/// the kernel height the array natively serves, and the default mapping
/// target for compiled plans.
pub const PAPER_COLS: usize = 3;

/// Optional activation calibration performed at compile time (substitutes
/// the missing training — see [`crate::model::calibrate`]).
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Held-out calibration image (keep it out of the measurement batch).
    pub image: Tensor,
    /// Multiplier on the per-layer post-ReLU density profile (1.0 = paper).
    pub density_scale: f64,
    /// Host threads for the calibration forward pass.
    pub threads: usize,
}

/// What [`compile`] does to the raw parameters before encoding.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    /// PE-array column count the kernel mapping targets.
    pub cols: usize,
    /// Vector-prune the weights to this per-layer density schedule first.
    pub prune: Option<BTreeMap<String, f64>>,
    /// Then calibrate activations against a held-out image.
    pub calibration: Option<Calibration>,
    /// CVF payload precision: the fixed-point modes fake-quantize each
    /// layer's (pruned, calibrated) weights against a per-layer
    /// calibrated scale before encoding, so the compiled CVF payloads
    /// are exactly what the narrow datapath holds. Biases stay f32
    /// (accumulators are wide in fixed-point accelerators).
    /// [`Precision::F32`] is the pinned exact path.
    pub precision: Precision,
}

impl CompileOptions {
    /// Encode-only compile (no pruning, no calibration) for `cols` columns.
    pub fn new(cols: usize) -> CompileOptions {
        CompileOptions {
            cols,
            prune: None,
            calibration: None,
            precision: Precision::F32,
        }
    }
}

/// Everything input-independent about one conv layer, computed once.
#[derive(Debug)]
pub struct CompiledLayer {
    pub name: String,
    pub spec: ConvSpec,
    /// The (pruned, calibrated) weight tensor `[K, C, KH, KW]`.
    pub weight: Arc<Tensor>,
    pub bias: Arc<Vec<f32>>,
    /// Value-carrying CVF encode of `weight` — the compressed form the
    /// weight SRAM holds.
    pub vw: Arc<VectorWeights>,
    /// Weight-side density statistics (the cached half of
    /// [`crate::sparse::encode::layer_report_cached`]).
    pub wstats: WeightSideStats,
    /// The §II-B mapping plan: pre-encoded sub-kernels / polyphase phases.
    pub conv: CompiledConv,
    /// Activation shape `[C, H, W]` entering this layer.
    pub in_shape: [usize; 3],
}

impl CompiledLayer {
    /// Closed-form dense-flow cycle baseline under `cfg` (no simulation
    /// needed; equals the scheduler's reported `dense_cycles`).
    pub fn dense_cycles(&self, cfg: &SimConfig) -> u64 {
        self.conv.dense_cycles(cfg)
    }

    /// The SRAM tiling of this layer's *primary* (unmapped) geometry
    /// under `cfg` — derived entirely at compile time for reporting and
    /// provisioning (input side sized for worst-case dense strips, weight
    /// side from the layer's compressed encode with the raw-format escape
    /// the execute-time model applies). Row-mapped and polyphase layers
    /// execute as several sub-convs, each tiled separately by the
    /// scheduler over its own sub-plane; this plan describes the
    /// original-shape working set those tilings share.
    pub fn tile_plan(&self, cfg: &SimConfig) -> TilePlan {
        let [c_in, h, w] = self.in_shape;
        let bpe = cfg.sram.bytes_per_elem;
        let b = cfg.pe.arrays.max(1);
        let groups = self.vw.k.div_ceil(b).max(1);
        let dense_kc_bytes = self.vw.kh * self.vw.kw * bpe;
        let max_group_bytes = (0..groups)
            .map(|g| {
                let mut bytes = 0usize;
                for k in g * b..((g + 1) * b).min(self.vw.k) {
                    for c in 0..self.vw.c {
                        let cvf = self.vw.nz_cols(k, c).len() * (self.vw.kh * bpe + 2);
                        bytes += cvf.min(dense_kc_bytes);
                    }
                }
                bytes
            })
            .max()
            .unwrap_or(0);
        let w_out = crate::tensor::conv::out_dim(w, self.conv.kw, self.spec);
        TilePlan::new(&cfg.sram, &cfg.pe, c_in, h, w, w_out, self.vw.k, max_group_bytes)
    }
}

/// A network compiled for execution: shared, immutable, cheap to hand to
/// any number of executing workers.
#[derive(Debug)]
pub struct PreparedNetwork {
    pub net: Network,
    /// PE-column count the plans target.
    pub cols: usize,
    /// Compiled conv layers by layer name.
    pub layers: BTreeMap<String, Arc<CompiledLayer>>,
    /// Overall conv weight density after pruning/calibration (and, under
    /// a fixed-point precision, after weight quantization — small values
    /// rounding to zero count as zeros, like the hardware sees them).
    pub weight_density: f64,
    /// CVF payload precision the weights were compiled at.
    pub precision: Precision,
}

impl PreparedNetwork {
    /// Rebuild the mapping plans for a different PE-column count, sharing
    /// the weight tensors, CVF encodes and density stats (those are
    /// cols-independent). Cheap relative to a full [`compile`].
    pub fn recompiled(&self, cols: usize) -> PreparedNetwork {
        let layers = self
            .layers
            .iter()
            .map(|(name, cl)| {
                let conv = compile_conv(
                    cl.in_shape,
                    cl.weight.clone(),
                    Some(cl.vw.clone()),
                    cols,
                    cl.spec,
                    true,
                );
                (
                    name.clone(),
                    Arc::new(CompiledLayer {
                        name: cl.name.clone(),
                        spec: cl.spec,
                        weight: cl.weight.clone(),
                        bias: cl.bias.clone(),
                        vw: cl.vw.clone(),
                        wstats: cl.wstats.clone(),
                        conv,
                        in_shape: cl.in_shape,
                    }),
                )
            })
            .collect();
        PreparedNetwork {
            net: self.net.clone(),
            cols,
            layers,
            weight_density: self.weight_density,
            precision: self.precision,
        }
    }
}

/// Compile a network: optional vector pruning, optional activation
/// calibration, then — per conv layer — kernel mapping and CVF weight
/// encoding, all exactly once. `params` is consumed; its tensors move into
/// the prepared layers without copying.
///
/// Panics on geometry mismatches (missing layer params, wrong weight or
/// bias shapes), like the per-job checks the monolithic pipeline performed.
pub fn compile(net: &Network, mut params: Params, opts: &CompileOptions) -> PreparedNetwork {
    let _sp = trace_span::span("engine", "compile");
    if let Some(schedule) = &opts.prune {
        let _sp = trace_span::span("engine", "compile.prune");
        let t0 = Instant::now();
        pruning::prune_network_vectors(&mut params, schedule);
        metrics::observe("engine.compile.prune_us", t0.elapsed().as_micros() as u64);
    }
    if let Some(cal) = &opts.calibration {
        let _sp = trace_span::span("engine", "compile.calibrate");
        let t0 = Instant::now();
        crate::model::calibrate::calibrate_activations(
            net,
            &mut params,
            &cal.image,
            cal.density_scale,
            cal.threads,
        );
        metrics::observe("engine.compile.calibrate_us", t0.elapsed().as_micros() as u64);
    }

    // Fixed-point payloads: fake-quantize each conv layer's (pruned,
    // calibrated) weights against its calibrated scale *before* density
    // stats and CVF encoding — the compiled payloads, the zero pattern
    // and therefore the timing model all reflect what the narrow
    // datapath holds. No-op at F32 (the pinned exact path).
    if opts.precision != Precision::F32 {
        let _sp = trace_span::span("engine", "compile.quantize");
        let t0 = Instant::now();
        for lp in params.values_mut() {
            if lp.weight.ndim() == 4 {
                crate::sparse::vector_format::fake_quantize_precision(
                    lp.weight.data_mut(),
                    opts.precision,
                );
            }
        }
        metrics::observe("engine.compile.quantize_us", t0.elapsed().as_micros() as u64);
    }

    // Overall conv weight density of the artifact that will be executed
    // (calibration rescales weights but never changes the zero pattern).
    let mut kept = 0u64;
    let mut total = 0u64;
    for lp in params.values() {
        if lp.weight.ndim() == 4 {
            kept += lp.weight.count_nonzero() as u64;
            total += lp.weight.len() as u64;
        }
    }
    let weight_density = if total == 0 {
        0.0
    } else {
        kept as f64 / total as f64
    };

    let _sp_enc = trace_span::span("engine", "compile.encode");
    let t_enc = Instant::now();
    let shapes = net.activation_shapes();
    let mut layers = BTreeMap::new();
    for (li, layer) in net.layers.iter().enumerate() {
        let LayerKind::Conv { c_in, c_out, k, spec } = &layer.kind else {
            continue;
        };
        let lp = params
            .remove(&layer.name)
            .unwrap_or_else(|| panic!("missing params for {}", layer.name));
        assert_eq!(
            lp.weight.shape(),
            &[*c_out, *c_in, *k, *k],
            "{}: weight shape",
            layer.name
        );
        assert_eq!(lp.bias.len(), *c_out, "{}: bias length", layer.name);
        let in_shape = shapes[li];
        assert_eq!(in_shape[0], *c_in, "{}: input channels", layer.name);

        let weight = Arc::new(lp.weight);
        let vw = Arc::new(VectorWeights::from_tensor(&weight));
        let wstats = weight_side_stats(&weight, &vw);
        let conv = compile_conv(
            in_shape,
            weight.clone(),
            Some(vw.clone()),
            opts.cols,
            *spec,
            true,
        );
        layers.insert(
            layer.name.clone(),
            Arc::new(CompiledLayer {
                name: layer.name.clone(),
                spec: *spec,
                weight,
                bias: Arc::new(lp.bias),
                vw,
                wstats,
                conv,
                in_shape,
            }),
        );
    }
    metrics::observe("engine.compile.encode_us", t_enc.elapsed().as_micros() as u64);
    metrics::add("engine.compile.networks", 1);
    PreparedNetwork {
        net: net.clone(),
        cols: opts.cols,
        layers,
        weight_density,
        precision: opts.precision,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::synthetic_params;
    use crate::model::vgg16::tiny_vgg;
    use crate::pruning::sensitivity::flat_schedule;

    #[test]
    fn compile_encodes_every_conv_layer_once() {
        let net = tiny_vgg(8);
        let params = synthetic_params(&net, 3, 0.0);
        let mut opts = CompileOptions::new(PAPER_COLS);
        opts.prune = Some(flat_schedule(&net, 0.5));
        let prepared = compile(&net, params, &opts);
        assert_eq!(prepared.layers.len(), 4);
        assert_eq!(prepared.cols, 3);
        assert!(prepared.weight_density > 0.2 && prepared.weight_density <= 0.51);
        for name in net.conv_layer_names() {
            let cl = &prepared.layers[name];
            // Value-carrying encode: functional execution reads payloads.
            assert!(cl.vw.nonzero_vectors() > 0);
            assert_eq!(cl.wstats.k, cl.weight.shape()[0]);
            // 3x3 at cols=3 compiles to the native direct plan: one
            // sub-conv, dense baseline > 0.
            assert_eq!(cl.conv.sub_dims.len(), 1);
            assert!(cl.dense_cycles(&SimConfig::paper_8_7_3()) > 0);
        }
    }

    #[test]
    fn recompiled_shares_weights_and_changes_cols() {
        let net = tiny_vgg(8);
        let params = synthetic_params(&net, 4, 0.0);
        let prepared = compile(&net, params, &CompileOptions::new(3));
        let re = prepared.recompiled(4);
        assert_eq!(re.cols, 4);
        for name in net.conv_layer_names() {
            // Weight storage and encodes are shared, not copied.
            assert!(Arc::ptr_eq(
                &prepared.layers[name].weight,
                &re.layers[name].weight
            ));
            assert!(Arc::ptr_eq(&prepared.layers[name].vw, &re.layers[name].vw));
            // 3-tall kernels on a 4-column array need the row mapping.
            assert_eq!(re.layers[name].conv.cols, 4);
        }
    }

    #[test]
    fn tile_plan_is_compile_time_derivable() {
        let net = tiny_vgg(8);
        let params = synthetic_params(&net, 6, 0.0);
        let prepared = compile(&net, params, &CompileOptions::new(PAPER_COLS));
        let cfg = SimConfig::paper_8_7_3();
        for name in net.conv_layer_names() {
            let cl = &prepared.layers[name];
            let plan = cl.tile_plan(&cfg);
            // Tiny planes on R=7 arrays: every layer's strips fit half of
            // the 64 KiB input buffer in a single tile.
            let strips = cl.in_shape[1].div_ceil(cfg.pe.rows);
            assert_eq!(plan.strips, strips, "{name}");
            assert_eq!(plan.strips_per_tile, strips, "{name}");
            assert_eq!(plan.tiles_per_group, 1, "{name}");
            assert_eq!(plan.groups, cl.vw.k.div_ceil(cfg.pe.arrays), "{name}");
            assert!(plan.total_tiles() >= 1, "{name}");
        }
        // Starving the input buffer forces more, smaller tiles.
        let mut tiny = cfg;
        tiny.sram.input_bytes = 64;
        let cl = &prepared.layers[net.conv_layer_names()[0]];
        let plan = cl.tile_plan(&tiny);
        assert_eq!(plan.strips_per_tile, 1);
        assert_eq!(plan.tiles_per_group, 2);
    }

    #[test]
    fn quantized_compile_puts_payloads_on_the_grid() {
        let net = tiny_vgg(8);
        for precision in [Precision::Int16, Precision::Int8] {
            let params = synthetic_params(&net, 3, 0.0);
            let mut opts = CompileOptions::new(PAPER_COLS);
            opts.prune = Some(flat_schedule(&net, 0.5));
            opts.precision = precision;
            let prepared = compile(&net, params, &opts);
            assert_eq!(prepared.precision, precision);
            let exact = compile(&net, synthetic_params(&net, 3, 0.0), &{
                let mut o = CompileOptions::new(PAPER_COLS);
                o.prune = Some(flat_schedule(&net, 0.5));
                o
            });
            // Rounding can only zero values, never create new nonzeros.
            assert!(prepared.weight_density <= exact.weight_density + 1e-12);
            for name in net.conv_layer_names() {
                let cl = &prepared.layers[name];
                let qmax = precision.qmax().unwrap();
                let max_abs = cl
                    .weight
                    .data()
                    .iter()
                    .fold(0.0f32, |m, &x| m.max(x.abs()));
                assert!(max_abs > 0.0, "{name}: all-zero after quantization");
                // Every compiled weight sits on some uniform grid whose
                // step divides the observed magnitude range into at most
                // qmax levels (per-layer calibrated scale).
                let step = max_abs / qmax;
                for &x in cl.weight.data() {
                    let q = x / step;
                    assert!(
                        (q - q.round()).abs() < 1e-2,
                        "{name}: {x} off the {step} grid"
                    );
                }
            }
            // The recompile keeps the precision tag.
            assert_eq!(prepared.recompiled(4).precision, precision);
        }
    }

    #[test]
    #[should_panic(expected = "missing params")]
    fn compile_rejects_missing_params() {
        let net = tiny_vgg(8);
        let mut params = synthetic_params(&net, 5, 0.0);
        params.remove("c2_1");
        let _ = compile(&net, params, &CompileOptions::new(3));
    }
}
