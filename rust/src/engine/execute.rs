//! The execute phase: run images against a [`PreparedNetwork`], with
//! per-image work only on the activation side (see the module doc of
//! [`crate::engine`]).

use super::compile::{CompiledLayer, PreparedNetwork};
use crate::baselines::{ideal_speedups, ideal_speedups_mem, SpeedupSeries};
use crate::model::LayerKind;
use crate::runtime::Runtime;
use crate::sim::config::{MemModel, Precision, SimConfig};
use crate::sim::mapping::simulate_compiled;
use crate::sim::postproc;
use crate::sim::scheduler::Mode;
use crate::sim::sdc::{abft_unit_round, EngineSdc, IntegrityCounters, SDC_ENGINE_STREAM_BASE};
use crate::sim::stats::{MemBound, SimStats};
use crate::sim::trace::Trace;
use crate::sparse::encode::{layer_report_cached, DensityReport};
use crate::sparse::vector_format::VectorActivations;
use crate::tensor::conv::maxpool2x2;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::{metrics, trace_span};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Version of the [`NetworkReport::to_json`] document layout, bumped
/// whenever a key is added, removed or renamed (pinned by a golden-key
/// test so observability additions can't silently break parsers).
pub const NETWORK_REPORT_SCHEMA_VERSION: usize = 1;

/// Everything measured for one conv layer in one run.
#[derive(Debug, Clone)]
pub struct LayerRecord {
    pub name: String,
    /// Input/weight/work densities at both granularities.
    pub density: DensityReport,
    /// Vector-sparse flow stats (the design under test).
    pub sparse: SimStats,
    /// Dense-flow cycle count (speedup denominator).
    pub dense_cycles: u64,
    /// Speedups: ours vs the ideal machines.
    pub speedups: SpeedupSeries,
    /// Post-ReLU output density (what the next layer sees).
    pub output_density_elem: f64,
    /// Roofline classification under the run's memory model (always
    /// `Compute` under [`MemModel::Ideal`]).
    pub bound: MemBound,
    /// Fraction of the layer's cycles the DRAM bus was busy.
    pub bw_util: f64,
}

impl LayerRecord {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("input_density_elem", self.density.input_elem)
            .set("weight_density_elem", self.density.weight_elem)
            .set("work_density_elem", self.density.work_elem)
            .set("input_density_vec", self.density.input_vec)
            .set("weight_density_vec", self.density.weight_vec)
            .set("work_density_vec", self.density.work_vec)
            .set("cycles", self.sparse.cycles)
            .set("dense_cycles", self.dense_cycles)
            .set("speedup", self.speedups.ours)
            .set("speedup_ideal_vector", self.speedups.ideal_vector)
            .set("speedup_ideal_fine", self.speedups.ideal_fine)
            .set("utilization", self.sparse.utilization())
            .set("output_density_elem", self.output_density_elem)
            .set("bound", self.bound.label())
            .set("bw_utilization", self.bw_util)
            .set("stats", self.sparse.to_json());
        o
    }
}

/// Which engine computes the functional forward pass.
#[derive(Clone)]
pub enum FunctionalBackend {
    /// Scalar golden conv — slow, for tiny runs and tests.
    Golden,
    /// Multithreaded im2col conv (the default fast path).
    Im2colMt(usize),
    /// PJRT executing the AOT artifacts of the given kind
    /// (`"ref"` = lax.conv, `"vscnn"` = Pallas column kernel).
    Pjrt(Arc<Runtime>, String),
}

impl std::fmt::Debug for FunctionalBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FunctionalBackend::Golden => write!(f, "Golden"),
            FunctionalBackend::Im2colMt(t) => write!(f, "Im2colMt({t})"),
            FunctionalBackend::Pjrt(_, k) => write!(f, "Pjrt({k})"),
        }
    }
}

/// Options for one network run.
#[derive(Debug, Clone)]
pub struct RunOptions {
    pub sim: SimConfig,
    pub backend: FunctionalBackend,
    /// Also run the simulator's own functional dataflow per layer and
    /// assert it matches the backend (expensive; tests/small runs only).
    pub verify_dataflow: bool,
    /// Fused strip execution (CLI `--fuse`): when a conv layer's input is
    /// the immediately preceding conv's output (no pooling in between)
    /// and the whole activation fits the input SRAM, the strip stays
    /// resident across the layer boundary and the consumer is timed with
    /// zero input DRAM traffic ([`SimConfig::fused_input_resident`]).
    /// Functional outputs are unchanged — fusion only eliminates modeled
    /// transfers — and it only applies under [`MemModel::Tiled`] (the
    /// ideal model has no transfers to eliminate).
    pub fuse: bool,
    /// Silent-data-corruption injection (ISSUE 10): real seeded bit
    /// flips into each conv layer's weight/activation/accumulator state,
    /// detected by structural CVF validation + ABFT column checksums and
    /// healed by bounded per-layer re-execution. `None` (the default)
    /// keeps the engine byte-identical to the pre-SDC path.
    pub sdc: Option<EngineSdc>,
}

impl RunOptions {
    pub fn new(sim: SimConfig) -> RunOptions {
        RunOptions {
            sim,
            backend: FunctionalBackend::Im2colMt(crate::util::default_threads()),
            verify_dataflow: false,
            fuse: false,
            sdc: None,
        }
    }
}

/// Engine-path integrity ledger, present on a [`NetworkReport`] iff
/// [`RunOptions::sdc`] was set (the report JSON stays key-identical to
/// the pre-SDC schema otherwise). Counters cover all conv layers of one
/// image run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineIntegrity {
    /// The injected / masked / detected / corrected / silent ledger.
    pub counters: IntegrityCounters,
    /// Cycles charged for bounded per-layer re-execution (already folded
    /// into the layer records and totals).
    pub reexec_cycles: u64,
    /// Detections past the per-layer re-execution budget: the corruption
    /// persisted and the batch-level retry path must absorb it.
    pub escalated: u64,
}

impl EngineIntegrity {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("injected", self.counters.injected)
            .set("masked", self.counters.masked)
            .set("detected", self.counters.detected)
            .set("corrected", self.counters.corrected)
            .set("silent", self.counters.silent)
            .set("reexec_cycles", self.reexec_cycles)
            .set("escalated", self.escalated);
        o
    }
}

/// Result of running one image through the network on one configuration.
#[derive(Debug, Clone)]
pub struct NetworkReport {
    pub network: String,
    pub config_label: String,
    /// Memory model the run was timed under.
    pub mem_model: MemModel,
    pub layers: Vec<LayerRecord>,
    pub totals: SimStats,
    pub total_dense_cycles: u64,
    /// CVF payload precision the run was timed and executed at.
    pub precision: Precision,
    /// Conv layers that executed fused (input strip resident across the
    /// layer boundary — zero input DRAM traffic); `0` unless
    /// [`RunOptions::fuse`] was set under the tiled model.
    pub fused_layers: usize,
    /// Cycles needed to move the run's *total* DRAM traffic
    /// (`totals.dram.transfer_cycles(bandwidth)`) — the roofline memory
    /// axis. Counted from the raw byte totals (no raw-format escape) and
    /// including output write-back, which the tiled model overlaps with
    /// the next layer's prologue — so this can legitimately exceed
    /// `totals.cycles`; it is a traffic measure, not a bound on them.
    pub dram_floor_cycles: u64,
    /// Integrity ledger of the run's SDC injection; `None` whenever
    /// [`RunOptions::sdc`] was `None` (so the JSON schema is untouched).
    pub integrity: Option<EngineIntegrity>,
}

impl NetworkReport {
    /// Whole-network speedup over the dense flow (the paper's headline
    /// 1.871x / 1.93x metric).
    pub fn overall_speedup(&self) -> f64 {
        self.total_dense_cycles as f64 / self.totals.cycles.max(1) as f64
    }

    /// Whole-network ideal-machine speedups (cycle-weighted, same
    /// aggregation as the per-layer ones). Under the tiled memory model
    /// the ideal machines carry the same per-layer memory floor as the
    /// per-layer series, aggregated by summing their floored cycle counts
    /// — so the network-level efficiency numbers respect the bandwidth
    /// bound too.
    pub fn overall_series(&self) -> SpeedupSeries {
        if self.mem_model == MemModel::Tiled && !self.layers.is_empty() {
            // Tiled: recover each layer's floored ideal cycle count from
            // its (dense-normalized) speedup and sum.
            let mut iv_cycles = 0.0f64;
            let mut fine_cycles = 0.0f64;
            for l in &self.layers {
                iv_cycles += l.dense_cycles as f64 / l.speedups.ideal_vector.max(1e-12);
                fine_cycles += l.dense_cycles as f64 / l.speedups.ideal_fine.max(1e-12);
            }
            let dense = self.total_dense_cycles as f64;
            return SpeedupSeries {
                ours: self.overall_speedup(),
                ideal_vector: dense / iv_cycles.max(1e-12),
                ideal_fine: dense / fine_cycles.max(1e-12),
            };
        }
        let (mut pairs_t, mut pairs_nz) = (0u64, 0u64);
        let (mut macs_t, mut macs_nz) = (0u64, 0u64);
        for l in &self.layers {
            pairs_t += l.density.pairs_total;
            pairs_nz += l.density.pairs_nonzero;
            macs_t += l.density.macs_total;
            macs_nz += l.density.macs_nonzero;
        }
        SpeedupSeries {
            ours: self.overall_speedup(),
            ideal_vector: pairs_t as f64 / pairs_nz.max(1) as f64,
            ideal_fine: macs_t as f64 / macs_nz.max(1) as f64,
        }
    }

    /// Cycles the DRAM bus spent streaming the compressed CVF weight
    /// payloads of this run at `bytes_per_cycle` (index traffic, shared
    /// with the input side, is left out — a conservative lower bound).
    /// This is the portion of a run's memory traffic that does not depend
    /// on the image — the part a serving batch amortizes by keeping
    /// weights resident across same-network requests
    /// ([`crate::serve::fleet::ServiceProfile`]), and the reload cost a
    /// fleet instance pays when it switches networks.
    pub fn weight_stream_cycles(&self, bytes_per_cycle: f64) -> u64 {
        crate::sim::dram::cycles_for_bytes(self.totals.dram.weight_read, bytes_per_cycle)
    }

    /// Fraction of conv layers classified memory-bound (0 under the ideal
    /// memory model).
    pub fn memory_bound_layer_frac(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        let mem = self.layers.iter().filter(|l| l.bound == MemBound::Memory).count();
        mem as f64 / self.layers.len() as f64
    }

    /// Network-level DRAM bus busy fraction: transfer cycles over total
    /// cycles (0 under the ideal memory model).
    pub fn effective_bw_util(&self) -> f64 {
        if self.totals.cycles == 0 {
            0.0
        } else {
            self.totals.transfer_cycles.min(self.totals.cycles) as f64
                / self.totals.cycles as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let series = self.overall_series();
        let mut roofline = Json::obj();
        roofline
            .set("compute_cycles", self.totals.compute_cycles)
            .set("transfer_cycles", self.totals.transfer_cycles)
            .set("dram_floor_cycles", self.dram_floor_cycles)
            .set("bound", self.totals.bound().label());
        let mut o = Json::obj();
        o.set("schema_version", NETWORK_REPORT_SCHEMA_VERSION)
            .set("network", self.network.as_str())
            .set("config", self.config_label.as_str())
            .set("mem_model", self.mem_model.label())
            .set("precision", self.precision.label())
            .set("fused_layers", self.fused_layers)
            .set("overall_speedup", series.ours)
            .set("overall_ideal_vector", series.ideal_vector)
            .set("overall_ideal_fine", series.ideal_fine)
            .set("vector_skip_efficiency", series.vector_skip_efficiency())
            .set("fine_skip_efficiency", series.fine_skip_efficiency())
            .set("total_cycles", self.totals.cycles)
            .set("total_dense_cycles", self.total_dense_cycles)
            .set("memory_bound_layer_frac", self.memory_bound_layer_frac())
            .set("effective_bw_util", self.effective_bw_util())
            .set("roofline", roofline)
            .set(
                "layers",
                Json::Arr(self.layers.iter().map(|l| l.to_json()).collect()),
            );
        // Gated, not versioned: the key only exists when injection ran,
        // so SDC-off reports stay byte-identical (schema_version holds).
        if let Some(integ) = &self.integrity {
            o.set("integrity", integ.to_json());
        }
        o
    }
}

/// Executes images against a shared [`PreparedNetwork`]. Construction is
/// free — all the heavy lifting happened in [`super::compile`]; clones of
/// the engine (or of the prepared `Arc`) share every compiled artifact.
#[derive(Debug, Clone)]
pub struct Engine {
    prepared: Arc<PreparedNetwork>,
}

impl Engine {
    pub fn new(prepared: Arc<PreparedNetwork>) -> Engine {
        Engine { prepared }
    }

    /// The shared compiled state this engine executes against.
    pub fn prepared(&self) -> &Arc<PreparedNetwork> {
        &self.prepared
    }

    /// Run one image through the network; returns per-layer records with
    /// the activation sparsity produced by this very input. Identical
    /// numbers to the pre-split monolithic pipeline.
    pub fn run_image(&self, input: &Tensor, opts: &RunOptions) -> Result<NetworkReport> {
        let net = &self.prepared.net;
        assert_eq!(
            opts.sim.pe.cols, self.prepared.cols,
            "network compiled for {} PE columns, run asked for {} \
             (use PreparedNetwork::recompiled)",
            self.prepared.cols, opts.sim.pe.cols
        );
        assert_eq!(input.shape(), &net.input_shape, "input shape mismatch");
        let mut act = input.clone();
        // Fixed-point payloads: activations are fake-quantized at layer
        // boundaries (here: the network input; below: every conv output)
        // against per-tensor calibrated scales, mirroring the weight
        // quantization the compile phase applied. No-op at F32.
        let precision = opts.sim.precision;
        if precision != Precision::F32 {
            crate::sparse::vector_format::fake_quantize_precision(act.data_mut(), precision);
        }
        let _sp = trace_span::span("engine", format!("run_image({})", net.name));
        // Two virtual-cycle lanes per image: conv layers laid end to end
        // at accumulated cycle offsets, DRAM transfer on a sibling lane.
        let cycle_lanes = if trace_span::cycles_enabled() {
            let base = trace_span::alloc_cycle_tracks(2);
            let img = base / 2;
            trace_span::name_track(trace_span::CYCLES_PID, base, format!("img{img:02} layers"));
            trace_span::name_track(trace_span::CYCLES_PID, base + 1, format!("img{img:02} dram"));
            if trace_span::pe_budget() > 0 {
                for a in 0..opts.sim.pe.arrays {
                    trace_span::name_track(trace_span::PE_PID, a as u64, format!("pe array {a}"));
                }
            }
            Some(base)
        } else {
            None
        };
        let mut cycle_cursor = 0u64;
        let mut layers = Vec::new();
        let mut totals = SimStats::default();
        let mut total_dense = 0u64;
        let mut fused_layers = 0usize;
        // SDC injection state (ISSUE 10): the ledger exists iff injection
        // is configured; `conv_idx` keys the per-layer PCG32 streams.
        let mut integrity: Option<EngineIntegrity> = opts.sdc.map(|_| EngineIntegrity::default());
        let mut conv_idx = 0u64;
        // Fusion eligibility tracker: true when `act` is the immediately
        // preceding conv's output, still strip-shaped (pooling re-stages
        // the activation through the output path, breaking residency).
        let mut prev_was_conv = false;

        for layer in &net.layers {
            match &layer.kind {
                LayerKind::Conv { .. } => {
                    let cl = self
                        .prepared
                        .layers
                        .get(&layer.name)
                        .with_context(|| format!("missing compiled layer {}", layer.name))?;

                    // --- fused strip execution (ISSUE 8) ----------------
                    // The producing conv's output strip stays resident in
                    // input SRAM iff the whole (dense) activation fits;
                    // the consumer is then timed with zero input DRAM
                    // traffic, on ours *and* on every baseline.
                    let act_bytes =
                        act.shape().iter().product::<usize>() * opts.sim.sram.bytes_per_elem;
                    let fused = opts.fuse
                        && opts.sim.mem_model == MemModel::Tiled
                        && prev_was_conv
                        && act_bytes <= opts.sim.sram.input_bytes;
                    let mut lsim = opts.sim;
                    lsim.fused_input_resident = fused;
                    fused_layers += usize::from(fused);

                    // --- timing (vector-sparse flow) --------------------
                    // With a PE issue budget set (`simulate --trace-out`),
                    // capture the per-cycle issue trace for the export.
                    // This forces the scheduler's sequential functional
                    // walk, so the budget bounds it to small runs.
                    let pe_budget = trace_span::pe_budget();
                    let mut trace = if pe_budget > 0 {
                        Trace::new(pe_budget as usize)
                    } else {
                        Trace::disabled()
                    };
                    let res = simulate_compiled(
                        &act,
                        &cl.conv,
                        Some(cl.bias.as_slice()),
                        &lsim,
                        Mode::VectorSparse,
                        false,
                        &mut trace,
                    );

                    // --- densities / ideal baselines (weight side cached)
                    let density =
                        layer_report_cached(&act, &cl.wstats, cl.spec, opts.sim.pe.rows);
                    // Under the tiled model every baseline shares the
                    // layer's transfer-cycle floor (ISSUE 3 satellite:
                    // skip efficiency cannot exceed the bandwidth bound).
                    let (ideal_vector, ideal_fine) = match opts.sim.mem_model {
                        MemModel::Ideal => ideal_speedups(&density),
                        MemModel::Tiled => ideal_speedups_mem(
                            &density,
                            &lsim,
                            res.dense_cycles,
                            res.stats.transfer_cycles,
                        ),
                    };

                    // --- functional forward ------------------------------
                    let out = forward_conv(cl, &act, opts)?;
                    if opts.verify_dataflow {
                        let mut tr = Trace::disabled();
                        let fres = simulate_compiled(
                            &act,
                            &cl.conv,
                            Some(cl.bias.as_slice()),
                            &lsim,
                            Mode::VectorSparse,
                            true,
                            &mut tr,
                        );
                        let sim_out = fres.output.expect("functional mode");
                        anyhow::ensure!(
                            sim_out.allclose(&out, 1e-2, 1e-2),
                            "{}: dataflow output diverges from backend by {}",
                            layer.name,
                            sim_out.max_abs_diff(&out)
                        );
                    }

                    // --- silent-data-corruption injection (ISSUE 10) ----
                    // After the dataflow verification (which pins the
                    // *clean* forward), before quantization: flips land
                    // on raw MAC outputs and in-flight CVF streams.
                    // Detected flips are healed by re-execution while the
                    // budget lasts (charged below); silent accumulator
                    // flips stay in `out` and propagate downstream.
                    let mut out = out;
                    let mut sdc_extra = 0u64;
                    if let (Some(sdc), Some(integ)) = (&opts.sdc, integrity.as_mut()) {
                        let reexecs =
                            inject_layer_sdc(sdc, conv_idx, cl, &act, &mut out, &opts.sim, integ);
                        sdc_extra = reexecs as u64 * res.stats.cycles;
                        integ.reexec_cycles += sdc_extra;
                    }
                    conv_idx += 1;

                    // --- post-processing (ReLU + zero detection) --------
                    // Quantize the layer's output at the boundary first
                    // (fixed-point modes), so the zero detection, the
                    // compressed write-back and the next layer all see
                    // the narrow activations. ReLU and maxpool preserve
                    // the grid (they only select or zero values).
                    if precision != Precision::F32 {
                        crate::sparse::vector_format::fake_quantize_precision(
                            out.data_mut(),
                            precision,
                        );
                    }
                    let post = postproc::postprocess(out, opts.sim.pe.rows);
                    let mut stats = res.stats;
                    // Re-execution repairs replay the whole layer.
                    stats.cycles += sdc_extra;
                    if let Some(va) = &post.compressed {
                        stats.dram.output_write =
                            postproc::output_dram_bytes(va, opts.sim.sram.bytes_per_elem, 2);
                    }

                    metrics::observe("engine.layer.cycles", stats.cycles);
                    if let Some(base) = cycle_lanes {
                        emit_layer_cycle_spans(base, &layer.name, cycle_cursor, &stats);
                        if !trace.events.is_empty() {
                            emit_pe_issue_events(&layer.name, cycle_cursor, &trace);
                        }
                    }
                    if trace.enabled() {
                        trace_span::pe_consume(trace.events.len() as u64 + trace.dropped());
                    }
                    cycle_cursor += stats.cycles;

                    let record = LayerRecord {
                        name: layer.name.clone(),
                        density,
                        sparse: stats,
                        dense_cycles: res.dense_cycles,
                        speedups: SpeedupSeries {
                            ours: res.dense_cycles as f64 / stats.cycles.max(1) as f64,
                            ideal_vector,
                            ideal_fine,
                        },
                        output_density_elem: post.output.density(),
                        bound: stats.bound(),
                        bw_util: stats.bw_utilization(),
                    };
                    totals.merge(&record.sparse);
                    total_dense += record.dense_cycles;
                    layers.push(record);
                    act = post.output;
                    prev_was_conv = true;
                }
                LayerKind::Relu => {
                    // ReLU already applied by the conv post-processing;
                    // applying again is a no-op (idempotent).
                }
                LayerKind::MaxPool2 => {
                    act = maxpool2x2(&act);
                    // Pooling re-stages the activation; the conv→conv
                    // strip residency is broken.
                    prev_was_conv = false;
                }
                LayerKind::Linear { .. } => {
                    // FC head is out of the accelerator evaluation scope.
                }
            }
        }

        metrics::add("engine.images", 1);
        let dram_floor_cycles = totals.dram.transfer_cycles(opts.sim.dram_bytes_per_cycle);
        Ok(NetworkReport {
            network: net.name.clone(),
            config_label: opts.sim.pe.label(),
            mem_model: opts.sim.mem_model,
            layers,
            totals,
            total_dense_cycles: total_dense,
            precision,
            fused_layers,
            dram_floor_cycles,
            integrity,
        })
    }

    /// Run a batch of images, returning one report each.
    ///
    /// Images are independent, so the batch fans out across scoped worker
    /// threads sharing the prepared state. The run's thread budget is
    /// *split* across the batch workers (each per-image run gets
    /// `budget / workers` simulator and backend threads), so nested
    /// parallelism stays within the configured budget instead of
    /// multiplying it — `--threads 1` really is single-threaded. Each
    /// image's report is identical to a sequential `run_image`; the
    /// returned order matches the input order, and an error
    /// short-circuits the rest of its worker's chunk.
    pub fn run_batch(&self, inputs: &[Tensor], opts: &RunOptions) -> Result<Vec<NetworkReport>> {
        let budget = opts.sim.effective_threads();
        let workers = budget.min(inputs.len().max(1));
        let mut inner = opts.clone();
        inner.sim.threads = (budget / workers).max(1);
        if let FunctionalBackend::Im2colMt(t) = &mut inner.backend {
            *t = (*t / workers).max(1);
        }
        let inner = &inner;
        let chunks: Result<Vec<Vec<NetworkReport>>> =
            crate::util::par_chunk_map(inputs.len(), workers, |range| {
                inputs[range]
                    .iter()
                    .map(|x| self.run_image(x, inner))
                    .collect()
            })
            .into_iter()
            .collect();
        Ok(chunks?.into_iter().flatten().collect())
    }
}

/// Lay one conv layer's interval onto the image's virtual-cycle lanes:
/// the layer span with fill/compute children on the layer lane, DRAM
/// transfer on the sibling lane, every child clamped into the layer
/// interval so the spans nest cleanly in Perfetto.
fn emit_layer_cycle_spans(base: u64, name: &str, t0: u64, stats: &SimStats) {
    use crate::util::trace_span::{complete_cycles, Arg, CYCLES_PID};
    let cyc = stats.cycles;
    complete_cycles(
        CYCLES_PID,
        base,
        "layer",
        name.to_string(),
        t0,
        cyc,
        vec![
            ("compute_cycles", Arg::U(stats.compute_cycles)),
            ("transfer_cycles", Arg::U(stats.transfer_cycles)),
            ("fill_cycles", Arg::U(stats.fill_cycles)),
            ("tiles", Arg::U(stats.tiles)),
        ],
    );
    let fill = stats.fill_cycles.min(cyc);
    if fill > 0 {
        let nm = format!("{name}.fill");
        complete_cycles(CYCLES_PID, base, "fill", nm, t0, fill, Vec::new());
    }
    let compute = stats.compute_cycles.min(cyc - fill);
    if compute > 0 {
        complete_cycles(
            CYCLES_PID,
            base,
            "compute",
            format!("{name}.compute"),
            t0 + fill,
            compute,
            Vec::new(),
        );
    }
    let transfer = stats.transfer_cycles.min(cyc);
    if transfer > 0 {
        complete_cycles(
            CYCLES_PID,
            base + 1,
            "dram",
            format!("{name}.transfer"),
            t0,
            transfer,
            Vec::new(),
        );
    }
}

/// Promote the per-cycle PE issue trace (the Table-I walk) into the
/// export: one lane per PE array, one 1-cycle slot per issued pair laid
/// sequentially from the layer's start cycle. `TraceEvent::cycle` is the
/// position within its strip block, not globally monotonic, so it rides
/// along as an arg while the slot index provides the timeline position.
fn emit_pe_issue_events(layer: &str, t0: u64, trace: &Trace) {
    use crate::util::trace_span::{complete_cycles, Arg, PE_PID};
    let mut next_slot: std::collections::BTreeMap<usize, u64> = std::collections::BTreeMap::new();
    for ev in &trace.events {
        let slot = next_slot.entry(ev.array).or_insert(0);
        let out = match ev.pair.output_col {
            Some(o) => o.to_string(),
            None => "X".to_string(),
        };
        complete_cycles(
            PE_PID,
            ev.array as u64,
            "pe-issue",
            format!("{layer} k{} c{} s{}", ev.filter, ev.channel, ev.strip),
            t0 + *slot,
            1,
            vec![
                ("input_col", Arg::U(ev.pair.input_col as u64)),
                ("weight_col", Arg::U(ev.pair.weight_col as u64)),
                ("output_col", Arg::S(out)),
                ("block_cycle", Arg::U(ev.cycle)),
            ],
        );
        *slot += 1;
    }
}

/// Inject `sdc.flips_per_layer` seeded bit flips into one conv layer's
/// live state and run the protection stack over each (ISSUE 10). The
/// taxonomy site is drawn uniformly per flip on stream
/// `SDC_ENGINE_STREAM_BASE + conv_idx`:
///
/// * **weight** — an index-word bit in a clone of the resident CVF
///   encode; the structural walk ([`crate::sparse::VectorWeights`]
///   `::validate`) must notice the bounds/monotonicity/occupancy break.
/// * **activation** — an index or payload bit of the layer's input CVF
///   stream; index damage is caught structurally, payload damage by the
///   stream checksum the scrubber recomputes, and low-mantissa payload
///   flips escape below the rounding floor (the modeled coverage gap).
/// * **accumulator** — a bit of one real output word; the ABFT column
///   checksum ([`crate::tensor::ops::abft_check`]) over the very im2col
///   operands the forward pass multiplied must flag the column.
///
/// Detected flips are healed (accumulator words restored) while the
/// per-layer re-execution budget lasts; past it the corruption persists
/// and is escalated. *Silent* accumulator flips stay in `out` and
/// propagate downstream — a real wrong answer, which is what the
/// unprotected arm measures. Returns the number of re-executions (the
/// caller charges the layer's cycles per replay).
fn inject_layer_sdc(
    sdc: &EngineSdc,
    conv_idx: u64,
    cl: &CompiledLayer,
    act: &Tensor,
    out: &mut Tensor,
    sim: &SimConfig,
    integ: &mut EngineIntegrity,
) -> u32 {
    let mut rng = Pcg32::new(sdc.seed, SDC_ENGINE_STREAM_BASE + conv_idx);
    let unit_round = abft_unit_round(sim.precision);
    // The ABFT operands: the same [K, C*KH*KW] weight panel and im2col
    // patch matrix the functional forward multiplied.
    let (kh, kw) = (cl.weight.shape()[2], cl.weight.shape()[3]);
    let patches = crate::tensor::ops::im2col(act, kh, kw, cl.spec.stride, cl.spec.pad);
    let (kdim, cols) = (patches.shape()[0], patches.shape()[1]);
    let m = cl.weight.shape()[0];
    // The layer's input stream and its clean checksum (what a scrubber
    // would hold), encoded once and cloned per activation-site flip.
    let clean_va = VectorActivations::from_tensor(act, sim.pe.rows);
    let (clean_sum, clean_abs) = clean_va.payload_checksum();
    let mut budget = sdc.reexec_budget;
    let mut reexecs = 0u32;
    for _ in 0..sdc.flips_per_layer {
        integ.counters.injected += 1;
        metrics::add("integrity.injected", 1);
        // Accumulator-site bookkeeping so a detected flip can be healed
        // *after* the budget decision (escalated corruption persists).
        let mut acc_restore: Option<(usize, f32)> = None;
        let caught = match rng.below(3) {
            0 => {
                let mut w = (*cl.vw).clone();
                if w.index_words() == 0 {
                    integ.counters.masked += 1;
                    metrics::add("integrity.masked", 1);
                    continue;
                }
                let word = rng.below(w.index_words() as u32) as usize;
                w.flip_index_bit(word, rng.below(8));
                sdc.protect && w.validate().is_err()
            }
            1 => {
                let payload = rng.bernoulli(0.5);
                let words = if payload {
                    clean_va.payload_words()
                } else {
                    clean_va.index_words()
                };
                if words == 0 {
                    integ.counters.masked += 1;
                    metrics::add("integrity.masked", 1);
                    continue;
                }
                let mut va = clean_va.clone();
                let word = rng.below(words as u32) as usize;
                if payload {
                    va.flip_payload_bit(word, rng.below(32));
                } else {
                    va.flip_index_bit(word, rng.below(16));
                }
                let (sum, _) = va.payload_checksum();
                let floor = (va.payload_words() as f64 + 2.0) * unit_round * (clean_abs + 1.0);
                let delta = (sum - clean_sum).abs();
                sdc.protect && (va.validate().is_err() || delta.is_nan() || delta > floor)
            }
            _ => {
                let od = out.data_mut();
                let word = rng.below(od.len() as u32) as usize;
                let clean = od[word];
                od[word] = f32::from_bits(clean.to_bits() ^ (1u32 << rng.below(32)));
                acc_restore = Some((word, clean));
                sdc.protect
                    && crate::tensor::ops::abft_check(
                        cl.weight.data(),
                        patches.data(),
                        out.data(),
                        m,
                        kdim,
                        cols,
                        Some(cl.bias.as_slice()),
                        unit_round,
                    )
                    .is_err()
            }
        };
        if caught {
            integ.counters.detected += 1;
            metrics::add("integrity.detected", 1);
            if budget > 0 {
                budget -= 1;
                reexecs += 1;
                integ.counters.corrected += 1;
                metrics::add("integrity.corrected", 1);
                if let Some((word, clean)) = acc_restore {
                    out.data_mut()[word] = clean;
                }
            } else {
                integ.escalated += 1;
                metrics::add("integrity.escalated", 1);
            }
        } else {
            integ.counters.silent += 1;
            metrics::add("integrity.silent", 1);
        }
    }
    reexecs
}

fn forward_conv(cl: &CompiledLayer, input: &Tensor, opts: &RunOptions) -> Result<Tensor> {
    Ok(match &opts.backend {
        FunctionalBackend::Golden => {
            crate::tensor::conv::conv2d(input, &cl.weight, Some(cl.bias.as_slice()), cl.spec)
        }
        FunctionalBackend::Im2colMt(threads) => crate::tensor::ops::conv2d_im2col_mt(
            input,
            &cl.weight,
            Some(cl.bias.as_slice()),
            cl.spec,
            *threads,
        ),
        FunctionalBackend::Pjrt(rt, kind) => rt
            .run_conv_by_shape(kind, input, &cl.weight, cl.bias.as_slice())
            .with_context(|| format!("PJRT conv for {}", cl.name))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::compile::{compile, CompileOptions};
    use crate::model::init::{synthetic_image, synthetic_params};
    use crate::model::vgg16::tiny_vgg;
    use crate::pruning;
    use crate::pruning::sensitivity::flat_schedule;

    fn prepared(seed: u64) -> (Arc<PreparedNetwork>, Tensor) {
        let net = tiny_vgg(8);
        let mut params = synthetic_params(&net, seed, 0.0);
        pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.4));
        let img = synthetic_image(net.input_shape, seed);
        (Arc::new(compile(&net, params, &CompileOptions::new(3))), img)
    }

    fn small_opts() -> RunOptions {
        let mut cfg = SimConfig::paper_4_14_3();
        cfg.pe.arrays = 2;
        cfg.pe.rows = 4;
        RunOptions {
            sim: cfg,
            backend: FunctionalBackend::Golden,
            verify_dataflow: true,
            fuse: false,
            sdc: None,
        }
    }

    #[test]
    fn engine_runs_and_verifies_dataflow() {
        let (p, img) = prepared(21);
        let engine = Engine::new(p);
        let report = engine.run_image(&img, &small_opts()).unwrap();
        assert_eq!(report.layers.len(), 4);
        assert!(report.overall_speedup() >= 1.0);
    }

    #[test]
    fn compiled_dense_baseline_matches_execution() {
        // The closed-form dense cycles stored at compile time must equal
        // what executing the plan reports, for both paper geometries.
        let (p, img) = prepared(22);
        for sim in [SimConfig::paper_4_14_3(), SimConfig::paper_8_7_3()] {
            let mut opts = small_opts();
            opts.sim = sim;
            opts.verify_dataflow = false;
            let report = Engine::new(p.clone()).run_image(&img, &opts).unwrap();
            for l in &report.layers {
                assert_eq!(
                    p.layers[&l.name].dense_cycles(&sim),
                    l.dense_cycles,
                    "{} {}",
                    l.name,
                    sim.pe.label()
                );
            }
        }
    }

    #[test]
    fn tiled_model_reports_memory_fields_and_dominates_ideal() {
        let (p, img) = prepared(24);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        opts.sim.mem_model = MemModel::Ideal;
        let ideal = Engine::new(p.clone()).run_image(&img, &opts).unwrap();
        assert_eq!(ideal.totals.transfer_cycles, 0);
        assert_eq!(ideal.memory_bound_layer_frac(), 0.0);
        assert_eq!(ideal.effective_bw_util(), 0.0);

        opts.sim.mem_model = MemModel::Tiled;
        let tiled = Engine::new(p).run_image(&img, &opts).unwrap();
        // The memory floor can only add cycles, on ours and on dense.
        assert!(tiled.totals.cycles >= ideal.totals.cycles);
        assert!(tiled.totals.cycles >= tiled.totals.transfer_cycles);
        assert!(tiled.total_dense_cycles >= ideal.total_dense_cycles);
        assert!(tiled.totals.tiles > 0);
        for l in &tiled.layers {
            assert!((0.0..=1.0).contains(&l.bw_util), "{}", l.name);
            assert!(
                l.speedups.ours <= l.speedups.ideal_vector + 1e-9,
                "{}",
                l.name
            );
        }
        let j = tiled.to_json();
        assert_eq!(j.get("mem_model").unwrap().as_str(), Some("tiled"));
        assert!(j.get("roofline").unwrap().get("transfer_cycles").is_some());
        assert!(j.get("memory_bound_layer_frac").is_some());
        assert!(j.get("effective_bw_util").is_some());
    }

    #[test]
    fn weight_stream_cycles_is_a_positive_fraction_of_traffic() {
        let (p, img) = prepared(25);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let report = Engine::new(p).run_image(&img, &opts).unwrap();
        let bw = opts.sim.dram_bytes_per_cycle;
        let ws = report.weight_stream_cycles(bw);
        assert!(ws > 0);
        // Weight payloads are a strict subset of the total DRAM traffic.
        assert!(ws <= report.totals.dram.transfer_cycles(bw));
    }

    /// Fused-vs-unfused equivalence pin: fusion eliminates modeled input
    /// transfers only — every functional field (densities, outputs,
    /// compute work) is exactly equal, input DRAM traffic drops, and
    /// cycles never increase.
    #[test]
    fn fused_run_pins_functional_outputs_and_drops_input_traffic() {
        let (p, img) = prepared(26);
        let engine = Engine::new(p);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let plain = engine.run_image(&img, &opts).unwrap();
        opts.fuse = true;
        let fused = engine.run_image(&img, &opts).unwrap();

        // tiny_vgg: conv pairs inside each block share residency, so at
        // least one layer must fuse at these tiny shapes.
        assert!(fused.fused_layers > 0, "no layer fused");
        assert_eq!(plain.fused_layers, 0);
        assert_eq!(fused.layers.len(), plain.layers.len());
        for (f, u) in fused.layers.iter().zip(&plain.layers) {
            // Functional pin: exact equality on everything the dataflow
            // computes.
            assert_eq!(f.name, u.name);
            assert_eq!(f.density.input_elem, u.density.input_elem);
            assert_eq!(f.density.work_vec, u.density.work_vec);
            assert_eq!(f.output_density_elem, u.output_density_elem);
            assert_eq!(f.dense_cycles, u.dense_cycles);
            assert_eq!(f.sparse.compute_cycles, u.sparse.compute_cycles);
            // Timing: eliminating transfers can only help.
            assert!(f.sparse.cycles <= u.sparse.cycles, "{}", f.name);
            assert!(f.sparse.dram.input_read <= u.sparse.dram.input_read);
        }
        assert!(fused.totals.dram.input_read < plain.totals.dram.input_read);
        assert!(fused.totals.cycles <= plain.totals.cycles);
        // The first conv can never fuse (its input comes from DRAM).
        assert!(fused.layers[0].sparse.dram.input_read > 0);
    }

    #[test]
    fn fuse_is_inert_under_ideal_memory_model() {
        let (p, img) = prepared(27);
        let engine = Engine::new(p);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        opts.sim.mem_model = MemModel::Ideal;
        let plain = engine.run_image(&img, &opts).unwrap();
        opts.fuse = true;
        let fused = engine.run_image(&img, &opts).unwrap();
        assert_eq!(fused.fused_layers, 0);
        assert_eq!(fused.totals.cycles, plain.totals.cycles);
    }

    /// An int8 run executes end to end: the narrower payloads shrink the
    /// modeled traffic, and the dataflow verification passes against the
    /// quantized backend (both sides see the same narrow values).
    #[test]
    fn int8_run_shrinks_traffic_and_verifies_dataflow() {
        let net = tiny_vgg(8);
        let img = synthetic_image(net.input_shape, 31);
        let build = |precision| {
            let mut params = synthetic_params(&net, 31, 0.0);
            pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.4));
            let mut copts = CompileOptions::new(3);
            copts.precision = precision;
            Arc::new(compile(&net, params, &copts))
        };
        let mut opts = small_opts(); // verify_dataflow = true
        let f32_report = Engine::new(build(Precision::F32))
            .run_image(&img, &opts)
            .unwrap();
        opts.sim = opts.sim.with_precision(Precision::Int8);
        let int8_report = Engine::new(build(Precision::Int8))
            .run_image(&img, &opts)
            .unwrap();
        assert_eq!(int8_report.precision, Precision::Int8);
        assert_eq!(f32_report.precision, Precision::F32);
        // Half-width payloads: strictly less DRAM traffic than the f32
        // (16-bit-modeled) run, on the input and weight streams alike.
        assert!(
            int8_report.totals.dram.input_read < f32_report.totals.dram.input_read,
            "int8 {} !< f32 {}",
            int8_report.totals.dram.input_read,
            f32_report.totals.dram.input_read
        );
        assert!(int8_report.totals.dram.weight_read < f32_report.totals.dram.weight_read);
        let j = int8_report.to_json();
        assert_eq!(j.get("precision").unwrap().as_str(), Some("int8"));
    }

    #[test]
    #[should_panic(expected = "PE columns")]
    fn engine_rejects_mismatched_cols() {
        let (p, img) = prepared(23);
        let mut opts = small_opts();
        opts.sim.pe.cols = 4;
        let _ = Engine::new(p).run_image(&img, &opts);
    }

    #[test]
    fn recompiled_network_runs_on_other_geometry() {
        let (p, img) = prepared(23);
        let re = Arc::new(p.recompiled(4));
        let mut opts = small_opts();
        opts.sim.pe.cols = 4;
        opts.verify_dataflow = false;
        let report = Engine::new(re).run_image(&img, &opts).unwrap();
        assert_eq!(report.layers.len(), 4);
        assert!(report.overall_speedup() >= 1.0);
    }

    /// Golden-key pin: the full `NetworkReport` JSON key set, including
    /// the layer records and their stats. Adding, removing or renaming a
    /// key must come with a `NETWORK_REPORT_SCHEMA_VERSION` bump and an
    /// update here — downstream parsers key off this contract.
    #[test]
    fn network_report_json_golden_keys() {
        let (p, img) = prepared(28);
        let mut opts = small_opts();
        opts.verify_dataflow = false;
        let j = Engine::new(p).run_image(&img, &opts).unwrap().to_json();
        assert_eq!(j.get("schema_version").and_then(Json::as_f64), Some(1.0));
        let keys = |o: &Json| -> Vec<String> {
            o.as_obj().expect("object").keys().cloned().collect()
        };
        assert_eq!(
            keys(&j),
            [
                "config",
                "effective_bw_util",
                "fine_skip_efficiency",
                "fused_layers",
                "layers",
                "mem_model",
                "memory_bound_layer_frac",
                "network",
                "overall_ideal_fine",
                "overall_ideal_vector",
                "overall_speedup",
                "precision",
                "roofline",
                "schema_version",
                "total_cycles",
                "total_dense_cycles",
                "vector_skip_efficiency",
            ]
        );
        assert_eq!(
            keys(j.get("roofline").unwrap()),
            ["bound", "compute_cycles", "dram_floor_cycles", "transfer_cycles"]
        );
        let layer = j.get("layers").unwrap().at(0).unwrap();
        assert_eq!(
            keys(layer),
            [
                "bound",
                "bw_utilization",
                "cycles",
                "dense_cycles",
                "input_density_elem",
                "input_density_vec",
                "name",
                "output_density_elem",
                "speedup",
                "speedup_ideal_fine",
                "speedup_ideal_vector",
                "stats",
                "utilization",
                "weight_density_elem",
                "weight_density_vec",
                "work_density_elem",
                "work_density_vec",
            ]
        );
        assert_eq!(
            keys(layer.get("stats").unwrap()),
            [
                "bound",
                "boundary_pairs",
                "bw_utilization",
                "compute_cycles",
                "cycles",
                "dram_total_bytes",
                "fill_cycles",
                "issued_pairs",
                "macs",
                "mem_stall_cycles",
                "overhead_cycles",
                "skipped_input",
                "skipped_weight",
                "sram_input_peak",
                "sram_overflows",
                "sram_psum_peak",
                "sram_weight_peak",
                "sync_stall_slots",
                "tiles",
                "transfer_cycles",
                "utilization",
            ]
        );
    }

    /// SDC injection end to end (ISSUE 10): the protected arm detects
    /// and heals flips inside the budget (charged as re-executed
    /// cycles), the unprotected arm serves silent wrong answers, the
    /// same seed replays bit-identically, and the SDC-off report
    /// carries no `integrity` section at all.
    #[test]
    fn sdc_injection_detects_heals_and_stays_gated_off() {
        use crate::sim::sdc::EngineSdc;
        let (p, img) = prepared(29);
        let engine = Engine::new(p);
        let mut opts = small_opts();
        opts.verify_dataflow = false;

        let clean = engine.run_image(&img, &opts).unwrap();
        assert!(clean.integrity.is_none());
        assert!(clean.to_json().get("integrity").is_none());

        opts.sdc = Some(EngineSdc {
            flips_per_layer: 6,
            seed: 11,
            protect: true,
            reexec_budget: 8,
        });
        let prot = engine.run_image(&img, &opts).unwrap();
        let pi = prot.integrity.expect("protected run carries the ledger");
        assert_eq!(pi.counters.injected, 6 * clean.layers.len() as u64);
        assert!(pi.counters.consistent(), "{pi:?}");
        assert!(pi.counters.detected > 0, "nothing detected: {pi:?}");
        assert!(pi.counters.corrected <= pi.counters.detected);
        // Repairs replay layers, so corrections and their cycle charge
        // come together — and a whole-layer replay dwarfs the few-column
        // density drift a propagated flip can cause downstream.
        assert_eq!(
            pi.reexec_cycles > 0,
            pi.counters.corrected > 0,
            "repairs and their cycle charge must agree: {pi:?}"
        );
        if pi.counters.corrected > 0 {
            assert!(prot.totals.cycles > clean.totals.cycles);
        }

        // Unprotected arm: same flips, no detector — every consequential
        // upset is a silent wrong answer.
        opts.sdc = Some(EngineSdc {
            flips_per_layer: 6,
            seed: 11,
            protect: false,
            reexec_budget: 8,
        });
        let unprot = engine.run_image(&img, &opts).unwrap();
        let ui = unprot.integrity.unwrap();
        assert_eq!(ui.counters.detected, 0);
        assert_eq!(ui.counters.corrected, 0);
        assert_eq!(ui.reexec_cycles, 0);
        assert_eq!(
            ui.counters.injected,
            ui.counters.masked + ui.counters.silent
        );
        assert!(ui.counters.silent > 0);

        // Seeded determinism: the whole report replays bit-identically.
        let replay = engine.run_image(&img, &opts).unwrap();
        assert_eq!(replay.integrity.unwrap(), ui);
        assert_eq!(replay.to_json().pretty(), unprot.to_json().pretty());

        // The gated JSON section and its pinned keys.
        let j = prot.to_json();
        let keys: Vec<String> = j
            .get("integrity")
            .unwrap()
            .as_obj()
            .unwrap()
            .keys()
            .cloned()
            .collect();
        assert_eq!(
            keys,
            [
                "corrected",
                "detected",
                "escalated",
                "injected",
                "masked",
                "reexec_cycles",
                "silent",
            ]
        );
    }
}
