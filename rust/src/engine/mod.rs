//! Compile/execute engine — the vector-sparse pipeline split at its
//! natural seam.
//!
//! The paper treats vector-sparse weights as a *static* artifact: pruned,
//! CVF-encoded once, and streamed to the array — only activations change
//! per image. This module makes the software follow the same contract:
//!
//! * **Compile** ([`compile`]): prune → calibrate → per-layer kernel
//!   mapping ([`crate::sim::mapping::compile_conv`]: row-mapping /
//!   polyphase) → CVF weight encoding, **once per network**. The result is
//!   a [`PreparedNetwork`] of [`Arc<CompiledLayer>`]s holding everything
//!   input-independent: the encoded [`crate::sparse::VectorWeights`], the
//!   mapped sub-kernel plan, the weight-side density statistics
//!   ([`crate::sparse::encode::WeightSideStats`]), and the closed-form
//!   dense-cycle baseline.
//! * **Execute** ([`Engine::run_image`] / [`Engine::run_batch`]): run
//!   images against the shared prepared state. Per image, only the
//!   activation-side work remains — the functional forward, the
//!   activation CVF encodes, and the input-side density stats. Nothing on
//!   the weight side is recomputed, regardless of image or config count.
//!
//! The plans are compiled for one PE-column count (`cols`, 3 in both paper
//! configurations); everything else in a [`crate::sim::config::SimConfig`]
//! — arrays, rows, SRAM, context-switch cost — varies freely at execute
//! time, so the two paper configs share a single compile.
//! [`PreparedNetwork::recompiled`] rebuilds the (cheap) mapping plans for a
//! different column count while sharing the weight tensors and encodes.
//!
//! Reports are identical to what the pre-split monolithic coordinator
//! produced — [`crate::coordinator::Coordinator`] survives as a
//! compatibility shim over this engine.

pub mod compile;
pub mod execute;

pub use compile::{
    compile, Calibration, CompileOptions, CompiledLayer, PreparedNetwork, PAPER_COLS,
};
pub use execute::{Engine, EngineIntegrity, FunctionalBackend, LayerRecord, NetworkReport, RunOptions};
