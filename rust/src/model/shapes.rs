//! Shape arithmetic for the layer kinds in [`super::LayerKind`].

use super::LayerKind;
use crate::tensor::conv::out_dim;

/// Output `[C,H,W]` of a layer applied to input `[C,H,W]`.
pub fn layer_output_shape(input: [usize; 3], kind: &LayerKind) -> [usize; 3] {
    let [c, h, w] = input;
    match kind {
        LayerKind::Conv { c_in, c_out, k, spec } => {
            assert_eq!(c, *c_in, "conv expects {c_in} channels, got {c}");
            [*c_out, out_dim(h, *k, *spec), out_dim(w, *k, *spec)]
        }
        LayerKind::Relu => input,
        LayerKind::MaxPool2 => [c, h / 2, w / 2],
        LayerKind::Linear { d_in, d_out } => {
            assert_eq!(c * h * w, *d_in, "linear expects {d_in} inputs, got {}", c * h * w);
            [*d_out, 1, 1]
        }
    }
}

/// Weight tensor shape for a layer, if it has one.
pub fn weight_shape(kind: &LayerKind) -> Option<Vec<usize>> {
    match kind {
        LayerKind::Conv { c_in, c_out, k, .. } => Some(vec![*c_out, *c_in, *k, *k]),
        LayerKind::Linear { d_in, d_out } => Some(vec![*d_out, *d_in]),
        _ => None,
    }
}

/// Parameter count for a layer (weights + bias).
pub fn param_count(kind: &LayerKind) -> usize {
    match kind {
        LayerKind::Conv { c_in, c_out, k, .. } => c_out * c_in * k * k + c_out,
        LayerKind::Linear { d_in, d_out } => d_out * d_in + d_out,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::conv::ConvSpec;

    #[test]
    fn conv_same_padding_keeps_hw() {
        let kind = LayerKind::Conv {
            c_in: 3,
            c_out: 64,
            k: 3,
            spec: ConvSpec { stride: 1, pad: 1 },
        };
        assert_eq!(layer_output_shape([3, 224, 224], &kind), [64, 224, 224]);
        assert_eq!(weight_shape(&kind), Some(vec![64, 3, 3, 3]));
        assert_eq!(param_count(&kind), 64 * 3 * 9 + 64);
    }

    #[test]
    fn pool_halves() {
        assert_eq!(layer_output_shape([64, 224, 224], &LayerKind::MaxPool2), [64, 112, 112]);
    }

    #[test]
    fn linear_flattens() {
        let kind = LayerKind::Linear { d_in: 25088, d_out: 4096 };
        assert_eq!(layer_output_shape([512, 7, 7], &kind), [4096, 1, 1]);
        assert_eq!(param_count(&kind), 4096 * 25088 + 4096);
    }

    #[test]
    #[should_panic(expected = "conv expects")]
    fn conv_channel_mismatch_panics() {
        let kind = LayerKind::Conv {
            c_in: 3,
            c_out: 8,
            k: 3,
            spec: ConvSpec::default(),
        };
        let _ = layer_output_shape([4, 8, 8], &kind);
    }
}
