//! Synthetic parameter and input generation.
//!
//! The paper evaluates on a VGG-16 checkpoint pretrained on ImageNet —
//! unavailable here (DESIGN.md §2). The speedup/density results depend only
//! on the *sparsity statistics*, so we substitute weights drawn from
//! per-layer Gaussians (He-style fan-in scaling, like the real training
//! would produce) and inputs that mimic natural-image statistics; pruning
//! (see [`crate::pruning`]) then imposes the paper's density profile.

use super::Network;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;

/// Learned parameters of one conv/linear layer.
#[derive(Debug, Clone)]
pub struct LayerParams {
    /// `[K, C, KH, KW]` for conv, `[D_out, D_in]` for linear.
    pub weight: Tensor,
    /// `[K]` / `[D_out]`.
    pub bias: Vec<f32>,
}

/// All parameters of a network, keyed by layer name (BTreeMap: stable
/// iteration order for deterministic reports).
pub type Params = BTreeMap<String, LayerParams>;

/// Generate He-initialized synthetic parameters for every parametric layer.
///
/// `bias_shift` moves every bias by a constant; negative values make the
/// post-ReLU activations sparser (the calibration knob of DESIGN.md §6).
pub fn synthetic_params(net: &Network, seed: u64, bias_shift: f32) -> Params {
    let mut params = Params::new();
    for (li, layer) in net.layers.iter().enumerate() {
        let Some(wshape) = super::shapes::weight_shape(&layer.kind) else {
            continue;
        };
        // Stream = layer index so adding layers never reshuffles others.
        let mut rng = Pcg32::new(seed, li as u64 + 1);
        let fan_in: usize = wshape[1..].iter().product();
        let std = (2.0 / fan_in as f32).sqrt();
        let n: usize = wshape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() * std).collect();
        let k_out = wshape[0];
        let bias = (0..k_out)
            .map(|_| rng.normal() * 0.01 + bias_shift)
            .collect();
        params.insert(
            layer.name.clone(),
            LayerParams {
                weight: Tensor::from_vec(&wshape, data),
                bias,
            },
        );
    }
    params
}

/// Synthetic "natural image": a mixture of smooth 2-D gradients and
/// band-limited noise, normalized to ImageNet-like statistics. Produces the
/// spatially-correlated structure that makes post-ReLU activation sparsity
/// spatially clustered (which is what vector sparsity exploits).
pub fn synthetic_image(shape: [usize; 3], seed: u64) -> Tensor {
    let [c, h, w] = shape;
    let mut rng = Pcg32::new(seed, 99);
    let mut t = Tensor::zeros(&[c, h, w]);
    for ci in 0..c {
        // Low- and mid-frequency components: random sinusoids across a
        // spread of spatial frequencies. The mid-frequency band matters:
        // all-smooth images make post-ReLU feature maps zero out in large
        // blobs, which over-states vector sparsity relative to real
        // ImageNet activations (EXPERIMENTS.md §Calibration).
        let n_waves = 8;
        let waves: Vec<(f32, f32, f32, f32)> = (0..n_waves)
            .map(|k| {
                let fmax = if k < 4 { 3.0 } else { 12.0 };
                (
                    rng.f32_range(0.5, fmax),             // fx (cycles over image)
                    rng.f32_range(0.5, fmax),             // fy
                    rng.f32_range(0.0, std::f32::consts::TAU), // phase
                    rng.f32_range(0.2, if k < 4 { 1.0 } else { 0.5 }), // amplitude
                )
            })
            .collect();
        for i in 0..h {
            for j in 0..w {
                let (x, y) = (j as f32 / w as f32, i as f32 / h as f32);
                let mut v = 0.0;
                for &(fx, fy, ph, amp) in &waves {
                    v += amp * (std::f32::consts::TAU * (fx * x + fy * y) + ph).sin();
                }
                // High-frequency texture.
                v += 0.6 * rng.normal();
                *t.at3_mut(ci, i, j) = v;
            }
        }
    }
    // Normalize to zero mean, unit std per image (ImageNet preprocessing).
    let n = t.len() as f32;
    let mean = t.data().iter().sum::<f32>() / n;
    let var = t.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    for x in t.data_mut() {
        *x = (*x - mean) / std;
    }
    t
}

/// A batch of distinct synthetic images.
pub fn synthetic_batch(shape: [usize; 3], count: usize, seed: u64) -> Vec<Tensor> {
    (0..count)
        .map(|i| synthetic_image(shape, seed.wrapping_add(i as u64 * 7919)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg16::tiny_vgg;

    #[test]
    fn params_cover_all_conv_layers() {
        let net = tiny_vgg(8);
        let params = synthetic_params(&net, 1, 0.0);
        assert_eq!(params.len(), 4);
        let p = &params["c1_1"];
        assert_eq!(p.weight.shape(), &[8, 3, 3, 3]);
        assert_eq!(p.bias.len(), 8);
    }

    #[test]
    fn params_deterministic_and_seed_sensitive() {
        let net = tiny_vgg(8);
        let a = synthetic_params(&net, 5, 0.0);
        let b = synthetic_params(&net, 5, 0.0);
        let c = synthetic_params(&net, 6, 0.0);
        assert_eq!(a["c1_1"].weight.data(), b["c1_1"].weight.data());
        assert_ne!(a["c1_1"].weight.data(), c["c1_1"].weight.data());
    }

    #[test]
    fn he_scaling_shrinks_with_fan_in() {
        let net = tiny_vgg(8);
        let params = synthetic_params(&net, 2, 0.0);
        let std = |t: &Tensor| {
            let m = t.data().iter().sum::<f32>() / t.len() as f32;
            (t.data().iter().map(|x| (x - m) * (x - m)).sum::<f32>() / t.len() as f32).sqrt()
        };
        // fan_in c1_1 = 27, c2_2 = 144 → bigger fan-in, smaller std.
        assert!(std(&params["c1_1"].weight) > std(&params["c2_2"].weight));
    }

    #[test]
    fn bias_shift_moves_biases() {
        let net = tiny_vgg(8);
        let p = synthetic_params(&net, 3, -0.5);
        let mean_bias: f32 =
            p["c1_1"].bias.iter().sum::<f32>() / p["c1_1"].bias.len() as f32;
        assert!((mean_bias + 0.5).abs() < 0.05, "mean bias {mean_bias}");
    }

    #[test]
    fn synthetic_image_normalized() {
        let img = synthetic_image([3, 16, 16], 42);
        let n = img.len() as f32;
        let mean = img.data().iter().sum::<f32>() / n;
        let var = img.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
        assert!(mean.abs() < 1e-3);
        assert!((var - 1.0).abs() < 1e-2);
        // Natural images are dense.
        assert!(img.density() > 0.99);
    }

    #[test]
    fn batch_images_differ() {
        let batch = synthetic_batch([1, 8, 8], 3, 7);
        assert_eq!(batch.len(), 3);
        assert_ne!(batch[0].data(), batch[1].data());
        assert_ne!(batch[1].data(), batch[2].data());
    }
}
