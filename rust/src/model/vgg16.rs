//! VGG-16 network builder — the workload of the paper's evaluation
//! (Simonyan & Zisserman 2014, configuration D): 13 conv layers in 5 blocks
//! with 2x2 max-pool between blocks, all 3x3 kernels with unit stride and
//! pad 1 — exactly the geometry the VSCNN array is optimized for.

use super::{Layer, LayerKind, Network};
use crate::tensor::conv::ConvSpec;

/// The 13 conv layers of VGG-16: `(name, c_in, c_out)`, grouped in blocks.
pub const VGG16_CONVS: [(&str, usize, usize); 13] = [
    ("conv1_1", 3, 64),
    ("conv1_2", 64, 64),
    ("conv2_1", 64, 128),
    ("conv2_2", 128, 128),
    ("conv3_1", 128, 256),
    ("conv3_2", 256, 256),
    ("conv3_3", 256, 256),
    ("conv4_1", 256, 512),
    ("conv4_2", 512, 512),
    ("conv4_3", 512, 512),
    ("conv5_1", 512, 512),
    ("conv5_2", 512, 512),
    ("conv5_3", 512, 512),
];

/// Indices after which a 2x2 max-pool follows (end of each block).
const POOL_AFTER: [&str; 5] = ["conv1_2", "conv2_2", "conv3_3", "conv4_3", "conv5_3"];

/// Build VGG-16's convolutional trunk at full 224x224 resolution.
///
/// The FC head is omitted: the paper's accelerator evaluation (Figs 9–13)
/// covers the 13 conv layers only, which hold >99% of VGG-16's MACs.
pub fn vgg16() -> Network {
    vgg16_at(224)
}

/// VGG-16 trunk at a reduced input resolution (for fast tests/benches).
/// `res` must be divisible by 32 so all five pools stay even.
pub fn vgg16_at(res: usize) -> Network {
    assert!(res >= 32 && res % 32 == 0, "resolution must be a multiple of 32");
    let mut layers = Vec::new();
    for (name, c_in, c_out) in VGG16_CONVS {
        layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Conv {
                c_in,
                c_out,
                k: 3,
                spec: ConvSpec { stride: 1, pad: 1 },
            },
        });
        layers.push(Layer {
            name: format!("{name}_relu"),
            kind: LayerKind::Relu,
        });
        if POOL_AFTER.contains(&name) {
            layers.push(Layer {
                name: format!("pool_{}", &name[4..5]),
                kind: LayerKind::MaxPool2,
            });
        }
    }
    Network {
        name: format!("vgg16-{res}"),
        input_shape: [3, res, res],
        layers,
    }
}

/// A small VGG-style network for unit tests: 4 conv layers, 2 blocks.
pub fn tiny_vgg(res: usize) -> Network {
    assert!(res % 4 == 0, "resolution must be a multiple of 4");
    let convs = [("c1_1", 3, 8), ("c1_2", 8, 8), ("c2_1", 8, 16), ("c2_2", 16, 16)];
    let mut layers = Vec::new();
    for (i, (name, c_in, c_out)) in convs.into_iter().enumerate() {
        layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Conv {
                c_in,
                c_out,
                k: 3,
                spec: ConvSpec { stride: 1, pad: 1 },
            },
        });
        layers.push(Layer {
            name: format!("{name}_relu"),
            kind: LayerKind::Relu,
        });
        if i == 1 || i == 3 {
            layers.push(Layer {
                name: format!("pool{}", i / 2 + 1),
                kind: LayerKind::MaxPool2,
            });
        }
    }
    Network {
        name: format!("tiny-vgg-{res}"),
        input_shape: [3, res, res],
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_has_13_convs_and_5_pools() {
        let net = vgg16();
        assert_eq!(net.conv_layer_names().len(), 13);
        let pools = net
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::MaxPool2))
            .count();
        assert_eq!(pools, 5);
    }

    #[test]
    fn vgg16_mac_count_matches_literature() {
        // VGG-16 conv trunk ≈ 15.35 GMACs at 224x224.
        let macs = vgg16().total_conv_macs();
        assert!(
            (15.0e9..15.7e9).contains(&(macs as f64)),
            "got {macs} MACs"
        );
    }

    #[test]
    fn vgg16_final_shape_is_512x7x7() {
        let net = vgg16();
        let last = *net.activation_shapes().last().unwrap();
        assert_eq!(last, [512, 7, 7]);
    }

    #[test]
    fn vgg16_heights_divisible_by_paper_vector_sizes() {
        // The paper chose R=14 and R=7 because every VGG activation height
        // (224,112,56,28,14) divides evenly — verify that invariant.
        let net = vgg16();
        let shapes = net.activation_shapes();
        for (layer, shape) in net.layers.iter().zip(&shapes) {
            if matches!(layer.kind, LayerKind::Conv { .. }) {
                assert_eq!(shape[1] % 14, 0, "{}: H={} not /14", layer.name, shape[1]);
                assert_eq!(shape[1] % 7, 0, "{}: H={} not /7", layer.name, shape[1]);
            }
        }
    }

    #[test]
    fn reduced_resolution_scales() {
        let net = vgg16_at(64);
        assert_eq!(net.input_shape, [3, 64, 64]);
        let last = *net.activation_shapes().last().unwrap();
        assert_eq!(last, [512, 2, 2]);
    }

    #[test]
    fn tiny_vgg_shapes() {
        let net = tiny_vgg(8);
        let last = *net.activation_shapes().last().unwrap();
        assert_eq!(last, [16, 2, 2]);
    }

    #[test]
    #[should_panic(expected = "multiple of 32")]
    fn vgg16_bad_resolution_panics() {
        let _ = vgg16_at(100);
    }
}
