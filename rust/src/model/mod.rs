//! Network model substrate: layer descriptors, the VGG-16 graph the paper
//! evaluates, shape arithmetic, and synthetic parameter generation
//! (substituting the unavailable ImageNet-pretrained checkpoint — see
//! DESIGN.md §2).

pub mod calibrate;
pub mod init;
pub mod shapes;
pub mod vgg16;
pub mod zoo;

use crate::tensor::conv::ConvSpec;

/// One layer of a feed-forward CNN. Only the layer kinds VGG-16 uses are
/// modelled; the simulator accelerates [`LayerKind::Conv`] layers and the
/// post-processing unit handles ReLU/pooling.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// 2-D convolution with square `k x k` kernels.
    Conv {
        c_in: usize,
        c_out: usize,
        k: usize,
        spec: ConvSpec,
    },
    /// In-place ReLU (fused into the conv's post-processing on hardware).
    Relu,
    /// 2x2 stride-2 max pooling.
    MaxPool2,
    /// Fully connected (`in -> out`); runs as a 1x1 conv on the array.
    Linear { d_in: usize, d_out: usize },
}

/// A named layer with its position in the network.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
}

/// A sequential network plus its input geometry.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    /// Input shape `[C, H, W]`.
    pub input_shape: [usize; 3],
    pub layers: Vec<Layer>,
}

impl Network {
    /// Names of all conv layers in order (the layers the figures index).
    pub fn conv_layer_names(&self) -> Vec<&str> {
        self.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv { .. }))
            .map(|l| l.name.as_str())
            .collect()
    }

    /// Activation shape `[C, H, W]` entering each layer, by index.
    pub fn activation_shapes(&self) -> Vec<[usize; 3]> {
        let mut shapes = Vec::with_capacity(self.layers.len() + 1);
        let mut cur = self.input_shape;
        shapes.push(cur);
        for layer in &self.layers {
            cur = shapes::layer_output_shape(cur, &layer.kind);
            shapes.push(cur);
        }
        shapes
    }

    /// Total dense MACs over all conv layers (for roofline numbers).
    pub fn total_conv_macs(&self) -> u64 {
        let shapes = self.activation_shapes();
        self.layers
            .iter()
            .enumerate()
            .map(|(i, l)| match l.kind {
                LayerKind::Conv { c_in, c_out, k, spec } => {
                    let [_, h, w] = shapes[i];
                    let ho = crate::tensor::conv::out_dim(h, k, spec) as u64;
                    let wo = crate::tensor::conv::out_dim(w, k, spec) as u64;
                    c_in as u64 * c_out as u64 * (k * k) as u64 * ho * wo
                }
                _ => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_propagate_through_stack() {
        let net = Network {
            name: "tiny".into(),
            input_shape: [3, 8, 8],
            layers: vec![
                Layer {
                    name: "conv1".into(),
                    kind: LayerKind::Conv {
                        c_in: 3,
                        c_out: 4,
                        k: 3,
                        spec: ConvSpec::default(),
                    },
                },
                Layer {
                    name: "relu1".into(),
                    kind: LayerKind::Relu,
                },
                Layer {
                    name: "pool1".into(),
                    kind: LayerKind::MaxPool2,
                },
            ],
        };
        let shapes = net.activation_shapes();
        assert_eq!(shapes, vec![[3, 8, 8], [4, 8, 8], [4, 8, 8], [4, 4, 4]]);
        assert_eq!(net.conv_layer_names(), vec!["conv1"]);
        assert_eq!(net.total_conv_macs(), 3 * 4 * 9 * 64);
    }
}
