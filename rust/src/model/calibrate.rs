//! Activation calibration for the synthetic checkpoint.
//!
//! A *trained* pruned VGG-16 keeps every layer's pre-activation
//! distribution in a healthy range (training/fine-tuning does this
//! implicitly), producing the 15–55% post-ReLU densities the paper's
//! Figs 9–11 show. Raw He-initialized weights do not: pruning shrinks each
//! layer's output variance, activations decay geometrically with depth and
//! the fixed bias then drives post-ReLU density to 0 — nothing like the
//! published workload.
//!
//! This module substitutes the missing training (DESIGN.md §2): it walks
//! the network once with a calibration image and, per conv layer,
//! (1) rescales weights to unit pre-activation variance (scale-invariant
//! for the zero pattern; the paper's post-processing unit performs
//! normalization on hardware), and (2) sets the layer bias to the quantile
//! that makes the post-ReLU element density hit a target profile taken
//! from published VGG-16 activation measurements.

use super::init::Params;
use super::{LayerKind, Network};
use crate::tensor::conv::maxpool2x2;
use crate::tensor::ops::conv2d_im2col_mt;
use crate::tensor::Tensor;

/// Post-ReLU element-density targets per VGG-16 conv layer — the declining
/// profile reported for ImageNet inference (cf. the activation-sparsity
/// measurements in Cnvlutin/Eyeriss and the paper's own Fig 9 input bars).
pub const VGG16_ACT_PROFILE: [(&str, f64); 13] = [
    ("conv1_1", 0.55), // feeds conv1_2
    ("conv1_2", 0.50),
    ("conv2_1", 0.45),
    ("conv2_2", 0.40),
    ("conv3_1", 0.45),
    ("conv3_2", 0.35),
    ("conv3_3", 0.32),
    ("conv4_1", 0.30),
    ("conv4_2", 0.25),
    ("conv4_3", 0.22),
    ("conv5_1", 0.20),
    ("conv5_2", 0.18),
    ("conv5_3", 0.18),
];

/// Calibrate `params` in place against one forward pass of `image`.
///
/// `density_scale` multiplies every profile target (ablation knob; 1.0 =
/// paper-like). Returns the per-layer post-ReLU densities achieved on the
/// calibration image.
pub fn calibrate_activations(
    net: &Network,
    params: &mut Params,
    image: &Tensor,
    density_scale: f64,
    threads: usize,
) -> Vec<(String, f64)> {
    let profile: std::collections::BTreeMap<&str, f64> =
        VGG16_ACT_PROFILE.iter().copied().collect();
    let mut act = image.clone();
    let mut achieved = Vec::new();

    for layer in &net.layers {
        match &layer.kind {
            LayerKind::Conv { .. } => {
                let lp = params.get_mut(&layer.name).expect("params for conv layer");
                // Pre-activation response without bias.
                let mut out = conv2d_im2col_mt(&act, &lp.weight, None, conv_spec(&layer.kind), threads);

                // (1) normalize: rescale weights so pre-activation std = 1.
                let n = out.len() as f64;
                let mean: f64 = out.data().iter().map(|&x| x as f64).sum::<f64>() / n;
                let var: f64 = out
                    .data()
                    .iter()
                    .map(|&x| (x as f64 - mean) * (x as f64 - mean))
                    .sum::<f64>()
                    / n;
                let scale = if var > 1e-20 { 1.0 / var.sqrt() } else { 1.0 };
                for wv in lp.weight.data_mut() {
                    *wv *= scale as f32;
                }
                for ov in out.data_mut() {
                    *ov *= scale as f32;
                }

                // (2) bias = the quantile hitting the target density.
                let target = profile
                    .get(layer.name.as_str())
                    .copied()
                    .unwrap_or(0.35)
                    * density_scale;
                let target = target.clamp(0.01, 0.99);
                let bias = -quantile(out.data(), 1.0 - target);
                for bv in lp.bias.iter_mut() {
                    *bv = bias;
                }

                // Apply bias + ReLU to continue the walk.
                let mut zeroed = 0usize;
                for ov in out.data_mut() {
                    *ov += bias;
                    if *ov < 0.0 {
                        *ov = 0.0;
                        zeroed += 1;
                    }
                }
                achieved.push((layer.name.clone(), 1.0 - zeroed as f64 / n));
                act = out;
            }
            LayerKind::Relu => {}
            LayerKind::MaxPool2 => act = maxpool2x2(&act),
            LayerKind::Linear { .. } => {}
        }
    }
    achieved
}

fn conv_spec(kind: &LayerKind) -> crate::tensor::conv::ConvSpec {
    match kind {
        LayerKind::Conv { spec, .. } => *spec,
        _ => unreachable!(),
    }
}

/// `q`-quantile (0..1) of an `f32` slice — the shared nearest-rank
/// [`crate::util::stats::quantile`] (one interpolation rule for the
/// whole crate; `f32 → f64` is exact and the result is always an element
/// of `xs`, so the round-trip loses nothing).
fn quantile(xs: &[f32], q: f64) -> f32 {
    debug_assert!(!xs.is_empty());
    let wide: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
    crate::util::stats::quantile(&wide, q) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{synthetic_image, synthetic_params};
    use crate::model::vgg16::vgg16_at;
    use crate::pruning;
    use crate::pruning::sensitivity::paper_schedule;

    #[test]
    fn quantile_basics() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 3.0); // nearest-rank on 0..3
    }

    #[test]
    fn calibration_hits_profile_on_calibration_image() {
        let net = vgg16_at(32);
        let mut params = synthetic_params(&net, 7, 0.0);
        let sched = paper_schedule(&net);
        pruning::prune_network_vectors(&mut params, &sched);
        let img = synthetic_image(net.input_shape, 7);
        let achieved = calibrate_activations(&net, &mut params, &img, 1.0, 2);
        assert_eq!(achieved.len(), 13);
        let profile: std::collections::BTreeMap<&str, f64> =
            VGG16_ACT_PROFILE.iter().copied().collect();
        for (name, d) in &achieved {
            let want = profile[name.as_str()];
            assert!(
                (d - want).abs() < 0.05,
                "{name}: achieved {d:.3} vs target {want}"
            );
        }
    }

    #[test]
    fn calibrated_network_keeps_deep_layers_alive_on_fresh_images() {
        // The real test: a *different* image must still produce live
        // activations at conv5 (the bug this module fixes).
        let net = vgg16_at(32);
        let mut params = synthetic_params(&net, 8, 0.0);
        let sched = paper_schedule(&net);
        pruning::prune_network_vectors(&mut params, &sched);
        let cal = synthetic_image(net.input_shape, 8);
        calibrate_activations(&net, &mut params, &cal, 1.0, 2);

        // Forward a different image through the calibrated weights.
        let fresh = synthetic_image(net.input_shape, 99);
        let mut act = fresh;
        for layer in &net.layers {
            match &layer.kind {
                crate::model::LayerKind::Conv { spec, .. } => {
                    let lp = &params[&layer.name];
                    let mut out = crate::tensor::ops::conv2d_im2col_mt(
                        &act,
                        &lp.weight,
                        Some(&lp.bias),
                        *spec,
                        2,
                    );
                    crate::tensor::conv::relu_inplace(&mut out);
                    act = out;
                }
                crate::model::LayerKind::MaxPool2 => {
                    act = crate::tensor::conv::maxpool2x2(&act)
                }
                _ => {}
            }
        }
        let d = act.density();
        assert!(d > 0.05, "conv5_3 output density {d} — activations died");
    }

    #[test]
    fn density_scale_moves_densities() {
        let net = vgg16_at(32);
        let img = synthetic_image(net.input_shape, 3);
        let mut lo = synthetic_params(&net, 3, 0.0);
        let mut hi = synthetic_params(&net, 3, 0.0);
        let a = calibrate_activations(&net, &mut lo, &img, 0.6, 2);
        let b = calibrate_activations(&net, &mut hi, &img, 1.4, 2);
        for ((_, da), (_, db)) in a.iter().zip(&b) {
            assert!(da < db, "scale 0.6 {da} !< scale 1.4 {db}");
        }
    }
}
