//! The model zoo: workloads beyond VGG-16, exercising the §II-B mapping
//! layer end-to-end — non-3×3 kernels (1×1, 5×5, 7×7, 11×11) and strided
//! convs (stride 2 and the AlexNet stem's stride 4) — the geometries the
//! paper defers to "a suitable mapping method [13]". Every network here
//! runs on the VSCNN array through `sim::mapping` and is selectable on the
//! CLI via `--net`.

use super::{Layer, LayerKind, Network};
use crate::tensor::conv::ConvSpec;
use anyhow::{bail, Result};

/// Conv rows used by the zoo builders: `(name, c_in, c_out, k, stride, pad)`.
type ConvRow = (&'static str, usize, usize, usize, usize, usize);

/// Build a sequential conv/ReLU stack, inserting a 2×2 max-pool after the
/// named layers only while the spatial plane stays poolable (≥ 2) — so the
/// same topology scales from full resolution down to tiny smoke-test
/// inputs.
fn stack(name: String, res: usize, convs: &[ConvRow], pool_after: &[&str]) -> Network {
    let mut layers = Vec::new();
    let mut cur = [3usize, res, res];
    for &(lname, c_in, c_out, k, stride, pad) in convs {
        let kind = LayerKind::Conv {
            c_in,
            c_out,
            k,
            spec: ConvSpec { stride, pad },
        };
        cur = super::shapes::layer_output_shape(cur, &kind);
        layers.push(Layer {
            name: lname.to_string(),
            kind,
        });
        layers.push(Layer {
            name: format!("{lname}_relu"),
            kind: LayerKind::Relu,
        });
        if pool_after.contains(&lname) && cur[1] >= 2 && cur[2] >= 2 {
            layers.push(Layer {
                name: format!("{lname}_pool"),
                kind: LayerKind::MaxPool2,
            });
            cur = [cur[0], cur[1] / 2, cur[2] / 2];
        }
    }
    Network {
        name,
        input_shape: [3, res, res],
        layers,
    }
}

/// AlexNet's five conv layers (Krizhevsky et al. 2012, conv trunk only):
/// the 11×11 stride-4 stem, the 5×5 mid layer and three 3×3 layers —
/// every §II-B mapping path (row split, polyphase stride 4, native) in one
/// classic network. `res` must be a multiple of 32 (224 = the real input,
/// modulo AlexNet's historical 227 off-by-one).
pub fn alexnet(res: usize) -> Network {
    assert!(res >= 32 && res % 32 == 0, "resolution must be a multiple of 32");
    let convs: &[ConvRow] = &[
        ("conv1", 3, 64, 11, 4, 2),
        ("conv2", 64, 192, 5, 1, 2),
        ("conv3", 192, 384, 3, 1, 1),
        ("conv4", 384, 256, 3, 1, 1),
        ("conv5", 256, 256, 3, 1, 1),
    ];
    stack(
        format!("alexnet-{res}"),
        res,
        convs,
        &["conv1", "conv2", "conv5"],
    )
}

/// A compact ResNet-style trunk (sequential approximation, no skip adds —
/// the accelerator evaluation cares about conv geometry, not accuracy):
/// 7×7 stride-2 stem, three stages separated by 3×3 stride-2 downsampling
/// convs, 1×1 projections. Exercises polyphase stride 2 *with padding* and
/// the 1×1 row mapping at network scale. `res` must be a multiple of 16.
pub fn resnet10(res: usize) -> Network {
    assert!(res >= 16 && res % 16 == 0, "resolution must be a multiple of 16");
    let convs: &[ConvRow] = &[
        ("stem7x7", 3, 32, 7, 2, 3),
        ("s1_conv1", 32, 32, 3, 1, 1),
        ("s1_conv2", 32, 32, 3, 1, 1),
        ("down1", 32, 64, 3, 2, 1),
        ("s2_conv1", 64, 64, 3, 1, 1),
        ("s2_proj", 64, 64, 1, 1, 0),
        ("down2", 64, 128, 3, 2, 1),
        ("s3_conv1", 128, 128, 3, 1, 1),
        ("head1x1", 128, 128, 1, 1, 0),
    ];
    stack(format!("resnet10-{res}"), res, convs, &[])
}

/// Every zoo network name accepted by [`by_name`] and the CLI `--net`
/// flag (`vscnn list` and `--help` enumerate these).
pub fn names() -> &'static [&'static str] {
    &["vgg16", "alexnet", "resnet10", "mixed"]
}

/// Look up a zoo network by CLI name. Resolution constraints are surfaced
/// as clean errors here (the builders themselves assert, as library API).
pub fn by_name(name: &str, res: usize) -> Result<Network> {
    let multiple = match name {
        "vgg16" | "alexnet" => 32,
        "resnet10" | "mixed" => 16,
        other => bail!("unknown network '{other}' (known: {})", names().join(", ")),
    };
    if res < multiple || res % multiple != 0 {
        bail!("--net {name} needs --res to be a multiple of {multiple} (got {res})");
    }
    Ok(match name {
        "vgg16" => super::vgg16::vgg16_at(res),
        "alexnet" => alexnet(res),
        "resnet10" => resnet10(res),
        "mixed" => mixed_kernel_net(res),
        _ => unreachable!(),
    })
}

/// A compact mixed-geometry backbone (AlexNet/ResNet-flavoured):
/// 7×7 stem, stride-2 downsampling convs instead of pools, 1×1
/// bottlenecks and a 5×5 mid block. Every layer runs on the VSCNN array
/// through `sim::mapping`.
pub fn mixed_kernel_net(res: usize) -> Network {
    assert!(res >= 16 && res % 16 == 0, "resolution must be a multiple of 16");
    let convs: Vec<(&str, usize, usize, usize, usize, usize)> = vec![
        // (name, c_in, c_out, k, stride, pad)
        ("stem7x7", 3, 16, 7, 1, 3),
        ("down1", 16, 32, 3, 2, 0),
        ("mid5x5", 32, 32, 5, 1, 2),
        ("bottleneck1x1", 32, 16, 1, 1, 0),
        ("expand3x3", 16, 32, 3, 1, 1),
        ("down2", 32, 64, 3, 2, 0),
        ("head1x1", 64, 64, 1, 1, 0),
    ];
    let mut layers = Vec::new();
    for (name, c_in, c_out, k, stride, pad) in convs {
        layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Conv {
                c_in,
                c_out,
                k,
                spec: ConvSpec { stride, pad },
            },
        });
        layers.push(Layer {
            name: format!("{name}_relu"),
            kind: LayerKind::Relu,
        });
    }
    Network {
        name: format!("mixed-kernel-{res}"),
        input_shape: [3, res, res],
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_propagate_through_mixed_geometry() {
        let net = mixed_kernel_net(32);
        let shapes = net.activation_shapes();
        // stem 7x7 pad 3 keeps 32; down1 stride2 k3 pad0: (32-3)/2+1 = 15.
        assert_eq!(shapes[1], [16, 32, 32]);
        assert_eq!(shapes[3], [32, 15, 15]);
        // 1x1 keeps spatial dims.
        assert_eq!(shapes[7][1], shapes[5][1]);
        assert_eq!(net.conv_layer_names().len(), 7);
    }

    #[test]
    fn alexnet_shapes_match_the_classic_trunk() {
        let net = alexnet(224);
        assert_eq!(net.conv_layer_names().len(), 5);
        let shapes = net.activation_shapes();
        // conv1 11x11 s4 p2: (224+4-11)/4+1 = 55; pool -> 27; conv2 keeps
        // 27; pool -> 13; conv3..5 keep 13; final pool -> 6.
        assert_eq!(shapes[1], [64, 55, 55]);
        assert_eq!(shapes[3], [64, 27, 27]);
        assert_eq!(*shapes.last().unwrap(), [256, 6, 6]);

        // At smoke resolution the plane shrinks to 1x1 and the final pool
        // drops out; every layer still has a valid geometry.
        let tiny = alexnet(32);
        let tshapes = tiny.activation_shapes();
        assert_eq!(tshapes[1], [64, 7, 7]);
        assert_eq!(*tshapes.last().unwrap(), [256, 1, 1]);
    }

    #[test]
    fn resnet10_shapes_downsample_by_stride() {
        let net = resnet10(32);
        assert_eq!(net.conv_layer_names().len(), 9);
        let shapes = net.activation_shapes();
        assert_eq!(shapes[1], [32, 16, 16]); // 7x7 s2 p3 stem halves
        let last = *shapes.last().unwrap();
        assert_eq!(last, [128, 4, 4]); // two more stride-2 halvings
    }

    #[test]
    fn resnet10_shape_chain_halves_exactly_at_stride2() {
        // The stride-2 *padded* convs (7x7 p3 stem, 3x3 p1 downsamplers)
        // must halve the plane exactly at any supported resolution — the
        // polyphase mapping depends on these geometries being clean.
        for res in [32usize, 64, 224] {
            let net = resnet10(res);
            let shapes = net.activation_shapes();
            // conv j sits at layer index 2j (conv/relu pairs, no pools).
            let out_of = |j: usize| shapes[2 * j + 1];
            assert_eq!(out_of(0), [32, res / 2, res / 2], "stem @{res}");
            assert_eq!(out_of(1), [32, res / 2, res / 2], "3x3 s1 p1 keeps @{res}");
            assert_eq!(out_of(3), [64, res / 4, res / 4], "down1 @{res}");
            assert_eq!(out_of(5), [64, res / 4, res / 4], "1x1 proj keeps @{res}");
            assert_eq!(out_of(6), [128, res / 8, res / 8], "down2 @{res}");
            assert_eq!(*shapes.last().unwrap(), [128, res / 8, res / 8], "@{res}");
        }
    }

    #[test]
    fn alexnet_shape_chain_and_pool_placement_across_resolutions() {
        for res in [32usize, 64, 224] {
            let net = alexnet(res);
            let shapes = net.activation_shapes();
            // 11x11 stride-4 pad-2 stem: (res + 4 - 11)/4 + 1.
            let stem = (res + 4 - 11) / 4 + 1;
            assert_eq!(shapes[1], [64, stem, stem], "stem @{res}");
            // Pools sit after conv1/conv2/conv5 only, in that order, and
            // drop out (never panic) when the plane shrinks below 2.
            let pools: Vec<&str> = net
                .layers
                .iter()
                .filter(|l| matches!(l.kind, LayerKind::MaxPool2))
                .map(|l| l.name.as_str())
                .collect();
            assert!(!pools.is_empty(), "@{res}");
            assert_eq!(pools[0], "conv1_pool", "@{res}");
            for p in &pools {
                assert!(
                    ["conv1_pool", "conv2_pool", "conv5_pool"].contains(p),
                    "unexpected pool {p} @{res}"
                );
            }
            if res == 224 {
                assert_eq!(pools.len(), 3);
                assert_eq!(*shapes.last().unwrap(), [256, 6, 6]);
            }
            // No conv layer ever sees an empty plane.
            for (i, l) in net.layers.iter().enumerate() {
                if matches!(l.kind, LayerKind::Conv { .. }) {
                    assert!(
                        shapes[i][1] >= 1 && shapes[i][2] >= 1,
                        "{} sees {:?} @{res}",
                        l.name,
                        shapes[i]
                    );
                }
            }
        }
    }

    #[test]
    fn zoo_builders_reject_unsupported_resolutions() {
        // Library builders assert; the CLI path returns clean errors.
        assert!(by_name("alexnet", 48).is_err()); // not a multiple of 32
        assert!(by_name("resnet10", 24).is_err()); // not a multiple of 16
        assert!(by_name("vgg16", 16).is_err()); // below the minimum
        let err = by_name("lenet", 32).unwrap_err().to_string();
        for n in names() {
            assert!(err.contains(n), "error should list '{n}': {err}");
        }
    }

    #[test]
    fn by_name_covers_the_zoo_and_rejects_unknown() {
        assert_eq!(by_name("vgg16", 32).unwrap().conv_layer_names().len(), 13);
        assert_eq!(by_name("alexnet", 32).unwrap().conv_layer_names().len(), 5);
        assert_eq!(by_name("resnet10", 32).unwrap().conv_layer_names().len(), 9);
        assert_eq!(by_name("mixed", 32).unwrap().conv_layer_names().len(), 7);
        assert!(by_name("lenet", 32).is_err());
    }

    #[test]
    fn mixed_net_runs_end_to_end_on_the_array() {
        use crate::coordinator::{Coordinator, FunctionalBackend, RunOptions};
        use crate::model::init::{synthetic_image, synthetic_params};
        use crate::pruning::{self, sensitivity::flat_schedule};
        use crate::sim::config::SimConfig;

        let net = mixed_kernel_net(32);
        let mut params = synthetic_params(&net, 17, 0.0);
        pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.4));
        let img = synthetic_image(net.input_shape, 17);
        let mut cfg = SimConfig::paper_8_7_3();
        cfg.pe.arrays = 2;
        let coord = Coordinator::new(net, params);
        let opts = RunOptions {
            sim: cfg,
            backend: FunctionalBackend::Golden,
            // The crucial bit: the mapped dataflow must match the golden
            // conv on every geometry (1x1, 5x5, 7x7, stride-2).
            verify_dataflow: true,
            fuse: false,
            sdc: None,
        };
        let report = coord.run(&img, &opts).unwrap();
        assert_eq!(report.layers.len(), 7);
        assert!(report.overall_speedup() >= 1.0);
    }
}
