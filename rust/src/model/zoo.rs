//! Additional workloads beyond VGG-16, exercising the §II-B mapping layer:
//! non-3×3 kernels (1×1, 5×5, 7×7) and stride-2 downsampling convs — the
//! geometries the paper defers to "a suitable mapping method [13]".

use super::{Layer, LayerKind, Network};
use crate::tensor::conv::ConvSpec;

/// A compact mixed-geometry backbone (AlexNet/ResNet-flavoured):
/// 7×7 stem, stride-2 downsampling convs instead of pools, 1×1
/// bottlenecks and a 5×5 mid block. Every layer runs on the VSCNN array
/// through `sim::mapping`.
pub fn mixed_kernel_net(res: usize) -> Network {
    assert!(res >= 16 && res % 16 == 0, "resolution must be a multiple of 16");
    let convs: Vec<(&str, usize, usize, usize, usize, usize)> = vec![
        // (name, c_in, c_out, k, stride, pad)
        ("stem7x7", 3, 16, 7, 1, 3),
        ("down1", 16, 32, 3, 2, 0),
        ("mid5x5", 32, 32, 5, 1, 2),
        ("bottleneck1x1", 32, 16, 1, 1, 0),
        ("expand3x3", 16, 32, 3, 1, 1),
        ("down2", 32, 64, 3, 2, 0),
        ("head1x1", 64, 64, 1, 1, 0),
    ];
    let mut layers = Vec::new();
    for (name, c_in, c_out, k, stride, pad) in convs {
        layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Conv {
                c_in,
                c_out,
                k,
                spec: ConvSpec { stride, pad },
            },
        });
        layers.push(Layer {
            name: format!("{name}_relu"),
            kind: LayerKind::Relu,
        });
    }
    Network {
        name: format!("mixed-kernel-{res}"),
        input_shape: [3, res, res],
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_propagate_through_mixed_geometry() {
        let net = mixed_kernel_net(32);
        let shapes = net.activation_shapes();
        // stem 7x7 pad 3 keeps 32; down1 stride2 k3 pad0: (32-3)/2+1 = 15.
        assert_eq!(shapes[1], [16, 32, 32]);
        assert_eq!(shapes[3], [32, 15, 15]);
        // 1x1 keeps spatial dims.
        assert_eq!(shapes[7][1], shapes[5][1]);
        assert_eq!(net.conv_layer_names().len(), 7);
    }

    #[test]
    fn mixed_net_runs_end_to_end_on_the_array() {
        use crate::coordinator::{Coordinator, FunctionalBackend, RunOptions};
        use crate::model::init::{synthetic_image, synthetic_params};
        use crate::pruning::{self, sensitivity::flat_schedule};
        use crate::sim::config::SimConfig;

        let net = mixed_kernel_net(32);
        let mut params = synthetic_params(&net, 17, 0.0);
        pruning::prune_network_vectors(&mut params, &flat_schedule(&net, 0.4));
        let img = synthetic_image(net.input_shape, 17);
        let mut cfg = SimConfig::paper_8_7_3();
        cfg.pe.arrays = 2;
        let coord = Coordinator::new(net, params);
        let opts = RunOptions {
            sim: cfg,
            backend: FunctionalBackend::Golden,
            // The crucial bit: the mapped dataflow must match the golden
            // conv on every geometry (1x1, 5x5, 7x7, stride-2).
            verify_dataflow: true,
        };
        let report = coord.run(&img, &opts).unwrap();
        assert_eq!(report.layers.len(), 7);
        assert!(report.overall_speedup() >= 1.0);
    }
}
