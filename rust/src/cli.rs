//! Hand-rolled CLI argument parsing (clap is unavailable offline —
//! DESIGN.md §9). Subcommand + `--key value` flags, with typed accessors.

use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Parsed command line: `vscnn <command> [args...] [--flag value]...`.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub command: String,
    /// Positional arguments after the command.
    pub positional: Vec<String>,
    /// `--key value` and boolean `--key` flags.
    flags: BTreeMap<String, String>,
    /// Flags given with no value (trailing `--flag` or `--flag --other`).
    /// Valid as booleans; asking for their *value* is a clean error
    /// instead of a confusing `cannot parse 'true'`.
    bare: BTreeSet<String>,
}

impl Cli {
    /// Parse from an argument iterator (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    bail!("empty flag '--'");
                }
                if let Some((k, v)) = name.split_once('=') {
                    cli.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    cli.flags.insert(name.to_string(), v);
                } else {
                    // Boolean flag (also reached when a value-taking flag
                    // is the last argument — remembered so the typed
                    // accessors can report it properly).
                    cli.bare.insert(name.to_string());
                    cli.flags.insert(name.to_string(), "true".to_string());
                }
            } else if cli.command.is_empty() {
                cli.command = arg;
            } else {
                cli.positional.push(arg);
            }
        }
        Ok(cli)
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag that *requires* a value: `Ok(None)` when absent, an
    /// error when the flag was given bare (`vscnn serve --out`) — so a
    /// trailing value flag can't be mistaken for the literal string
    /// `"true"`.
    pub fn get_value(&self, key: &str) -> Result<Option<&str>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(_) if self.bare.contains(key) => {
                Err(anyhow!("flag --{key} expects a value but none was given"))
            }
            Some(v) => Ok(Some(v.as_str())),
        }
    }

    /// Boolean flag (present, or `--key true/false`).
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed numeric flag with default.
    pub fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(_) if self.bare.contains(key) => {
                Err(anyhow!("flag --{key} expects a value but none was given"))
            }
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("flag --{key}: cannot parse '{v}'")),
        }
    }

    /// Error on unknown flags (catches typos).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k}; known flags: {known:?}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Cli {
        Cli::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_positional_and_flags() {
        let cli = parse(&["exp", "fig12", "--res", "64", "--trace"]);
        assert_eq!(cli.command, "exp");
        assert_eq!(cli.positional, vec!["fig12"]);
        assert_eq!(cli.get("res"), Some("64"));
        assert!(cli.get_bool("trace"));
        assert!(!cli.get_bool("missing"));
    }

    #[test]
    fn equals_syntax() {
        let cli = parse(&["run", "--seed=42"]);
        assert_eq!(cli.get_num::<u64>("seed", 0).unwrap(), 42);
    }

    #[test]
    fn numeric_defaults_and_errors() {
        let cli = parse(&["run", "--res", "abc"]);
        assert_eq!(cli.get_num::<usize>("images", 5).unwrap(), 5);
        assert!(cli.get_num::<usize>("res", 1).is_err());
    }

    #[test]
    fn unknown_flags_caught() {
        let cli = parse(&["run", "--tyop", "1"]);
        assert!(cli.check_known(&["res"]).is_err());
        assert!(cli.check_known(&["tyop"]).is_ok());
    }

    #[test]
    fn boolean_flag_at_end() {
        let cli = parse(&["run", "--verbose"]);
        assert!(cli.get_bool("verbose"));
    }

    #[test]
    fn value_flag_at_end_errors_cleanly() {
        // `vscnn simulate --res` — a value-taking flag as the last
        // argument must produce a proper Err from the typed accessor
        // (not a panic, and not "cannot parse 'true'").
        let cli = parse(&["simulate", "--res"]);
        assert!(cli.get_bool("res")); // still usable as a boolean
        let err = cli.get_num::<usize>("res", 1).unwrap_err();
        assert!(
            err.to_string().contains("expects a value"),
            "unexpected message: {err}"
        );
    }

    #[test]
    fn string_flag_at_end_errors_through_get_value() {
        // `vscnn serve --out` must not write a file literally named
        // "true": the value-requiring accessor reports it.
        let cli = parse(&["serve", "--out"]);
        let err = cli.get_value("out").unwrap_err();
        assert!(err.to_string().contains("expects a value"));
        assert_eq!(cli.get_value("missing").unwrap(), None);
        let ok = parse(&["serve", "--out", "r.json"]);
        assert_eq!(ok.get_value("out").unwrap(), Some("r.json"));
    }

    #[test]
    fn serve_fault_flags_parse_in_every_shape() {
        // The resilience flags mix value flags (--faults, --timeout-us,
        // --retries, --hedge-us) with one bare boolean (--shed); exercise
        // the exact shapes `vscnn serve` uses.
        let cli = parse(&[
            "serve",
            "--faults",
            "crash:0.01,mttr:2",
            "--timeout-us",
            "5000",
            "--retries",
            "2",
            "--hedge-us=800",
            "--shed",
        ]);
        assert_eq!(cli.get_value("faults").unwrap(), Some("crash:0.01,mttr:2"));
        assert_eq!(cli.get_num::<f64>("timeout-us", 0.0).unwrap(), 5000.0);
        assert_eq!(cli.get_num::<u32>("retries", 0).unwrap(), 2);
        assert_eq!(cli.get_num::<f64>("hedge-us", 0.0).unwrap(), 800.0);
        assert!(cli.get_bool("shed"));
        // All absent -> robustness stays off.
        let off = parse(&["serve"]);
        assert_eq!(off.get_value("faults").unwrap(), None);
        assert!(!off.get_bool("shed"));
        assert_eq!(off.get_num::<u32>("retries", 0).unwrap(), 0);
    }

    #[test]
    fn serve_sdc_flag_parses_in_every_shape() {
        // `--sdc` is a value flag holding the whole injection grammar
        // (including the bare `protect` token inside the value); the CLI
        // layer hands the string through untouched and SdcSpec::parse is
        // the gate.
        use crate::sim::sdc::SdcSpec;
        let cli = parse(&["serve", "--sdc", "flip:100,protect,scrub:2"]);
        let s = cli.get_value("sdc").unwrap().unwrap();
        assert_eq!(s, "flip:100,protect,scrub:2");
        assert!(SdcSpec::parse(s).unwrap().protect);
        let eq = parse(&["serve", "--sdc=flip:50"]);
        assert_eq!(eq.get_value("sdc").unwrap(), Some("flip:50"));
        // Absent -> injection stays off; trailing bare flag is a clean
        // error, not the string "true".
        let off = parse(&["serve"]);
        assert_eq!(off.get_value("sdc").unwrap(), None);
        let bare = parse(&["serve", "--sdc"]);
        assert!(bare.get_value("sdc").unwrap_err().to_string().contains("expects a value"));
    }

    #[test]
    fn serve_fault_flags_error_cleanly_when_malformed() {
        // `--faults --shed`: the value flag swallowed nothing, so asking
        // for its value must be a clean error (not the string "true").
        let cli = parse(&["serve", "--faults", "--shed"]);
        assert!(cli.get_bool("shed"));
        let err = cli.get_value("faults").unwrap_err();
        assert!(err.to_string().contains("expects a value"));
        // Non-numeric retry/timeout values are typed-accessor errors.
        let bad = parse(&["serve", "--retries", "two", "--timeout-us", "5ms"]);
        assert!(bad.get_num::<u32>("retries", 0).is_err());
        assert!(bad.get_num::<f64>("timeout-us", 0.0).is_err());
    }

    #[test]
    fn precision_and_fuse_flags_parse_in_every_shape() {
        // `--precision` is a value flag, `--fuse` a bare boolean; exercise
        // the exact shapes `vscnn simulate`/`exp` use.
        let cli = parse(&["simulate", "--precision", "int8", "--fuse"]);
        assert_eq!(cli.get_value("precision").unwrap(), Some("int8"));
        assert!(cli.get_bool("fuse"));
        let eq = parse(&["exp", "headline", "--precision=int16"]);
        assert_eq!(eq.get_value("precision").unwrap(), Some("int16"));
        assert!(!eq.get_bool("fuse"));
        // Both absent -> f32 exact path, fusion off.
        let off = parse(&["simulate"]);
        assert_eq!(off.get_value("precision").unwrap(), None);
        assert!(!off.get_bool("fuse"));
        // Trailing `--precision` with no value is a clean error.
        let bare = parse(&["simulate", "--precision"]);
        let err = bare.get_value("precision").unwrap_err();
        assert!(err.to_string().contains("expects a value"));
    }

    #[test]
    fn unknown_precision_names_rejected_helpfully() {
        // The CLI layer hands the string through; Precision::parse is the
        // gate — unknown spellings yield None so main can name the valid
        // set in its error instead of silently defaulting.
        use crate::sim::config::Precision;
        let cli = parse(&["simulate", "--precision", "bf16"]);
        let s = cli.get_value("precision").unwrap().unwrap();
        assert!(Precision::parse(s).is_none());
        for (ok, p) in [
            ("f32", Precision::F32),
            ("fp32", Precision::F32),
            ("int16", Precision::Int16),
            ("i16", Precision::Int16),
            ("int8", Precision::Int8),
            ("i8", Precision::Int8),
        ] {
            assert_eq!(Precision::parse(ok), Some(p), "{ok}");
        }
    }

    #[test]
    fn observability_flags_parse_in_every_shape() {
        // `--metrics-out`/`--trace-out` are value flags; `--trace-limit`
        // and `--pe-trace` numeric with defaults — the exact shapes
        // `simulate`/`exp`/`serve` use.
        let cli = parse(&[
            "simulate",
            "--metrics-out",
            "m.json",
            "--trace-out=t.json",
            "--trace-limit",
            "5000",
        ]);
        assert_eq!(cli.get_value("metrics-out").unwrap(), Some("m.json"));
        assert_eq!(cli.get_value("trace-out").unwrap(), Some("t.json"));
        assert_eq!(cli.get_num::<usize>("trace-limit", 200_000).unwrap(), 5000);
        assert_eq!(cli.get_num::<u64>("pe-trace", 20_000).unwrap(), 20_000);
        // All absent -> observability stays off (no files, defaults).
        let off = parse(&["simulate"]);
        assert_eq!(off.get_value("metrics-out").unwrap(), None);
        assert_eq!(off.get_value("trace-out").unwrap(), None);
        assert_eq!(off.get_num::<usize>("trace-limit", 200_000).unwrap(), 200_000);
        // Trailing value flag is a clean error, not a file named "true".
        let bare = parse(&["serve", "--trace-out"]);
        let err = bare.get_value("trace-out").unwrap_err();
        assert!(err.to_string().contains("expects a value"));
    }

    #[test]
    fn value_flag_before_another_flag_errors_cleanly() {
        let cli = parse(&["simulate", "--res", "--trace"]);
        assert!(cli.get_bool("trace"));
        let err = cli.get_num::<usize>("res", 1).unwrap_err();
        assert!(err.to_string().contains("expects a value"));
        // An explicit value is still parsed normally.
        let ok = parse(&["simulate", "--res", "64", "--trace"]);
        assert_eq!(ok.get_num::<usize>("res", 1).unwrap(), 64);
    }
}
