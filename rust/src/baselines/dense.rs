//! The dense-CNN baseline: the same `[B, R, C]` array running the dense
//! flow (every vector issued). This is the denominator of every speedup in
//! Figs 12/13. Closed-form — no per-element work.
//!
//! Under [`crate::sim::config::MemModel::Tiled`] the baseline carries the
//! same memory floor as the sparse flow ([`dense_mem_cycles`]): the dense
//! machine streams *uncompressed* activations and weights through the
//! identical double-buffered SRAM hierarchy, so speedups stay
//! apples-to-apples.

use crate::sim::config::SimConfig;
use crate::sim::sram::{stream_tiles, TileDemand, TilePlan};
use crate::tensor::conv::ConvSpec;

/// Dense cycle count for a conv layer on `cfg`:
/// `ceil(K/B) · C · strips · W · KW` plus context-switch overhead per
/// `(group, channel, strip)` block.
pub fn dense_cycles(
    cfg: &SimConfig,
    c_in: usize,
    k_out: usize,
    h: usize,
    w: usize,
    kw: usize,
    _spec: ConvSpec,
) -> u64 {
    let strips = h.div_ceil(cfg.pe.rows) as u64;
    let groups = k_out.div_ceil(cfg.pe.arrays) as u64;
    let blocks = groups * c_in as u64 * strips;
    blocks * (w as u64) * (kw as u64) + blocks * cfg.context_switch_cycles
}

/// Per-tile demands of the dense flow on a sub-conv issued at the array
/// height (`KH = cfg.pe.cols`): every `(channel, strip)` block costs
/// `W * KW` pairs plus one context switch, inputs stream uncompressed
/// (re-fetched per filter group unless the whole plane fits the input
/// buffer), and each group's dense weights load once per group when they
/// fit half the weight buffer — every tile otherwise. The scheduler's
/// `Mode::Dense` tiled run streams exactly these demands, so the closed
/// form and the simulator agree bit-for-bit.
pub fn dense_tile_demands(
    cfg: &SimConfig,
    c_in: usize,
    k_out: usize,
    h: usize,
    w: usize,
    kw: usize,
) -> Vec<TileDemand> {
    let bpe = cfg.sram.bytes_per_elem;
    let r = cfg.pe.rows;
    let kh = cfg.pe.cols;
    let b = cfg.pe.arrays.max(1);
    let max_group_w_bytes = b.min(k_out) * c_in * kh * kw * bpe;
    let plan = TilePlan::new(&cfg.sram, &cfg.pe, c_in, h, w, w, k_out, max_group_w_bytes);
    let input_resident = cfg.sram.input_bytes >= c_in * h * w * bpe;
    let mut demands = Vec::with_capacity(plan.total_tiles());
    for g in 0..plan.groups {
        let filters = (((g + 1) * b).min(k_out)) - g * b;
        let w_bytes_g = (filters * c_in * kh * kw * bpe) as u64;
        for t in 0..plan.tiles_per_group {
            let strips = plan.tile_strips(t);
            let blocks = (c_in * strips.len()) as u64;
            let compute = blocks * (w as u64) * (kw as u64) + blocks * cfg.context_switch_cycles;
            let mut input_bytes = 0u64;
            // Fused strip execution leaves the producing layer's output
            // resident, so the dense machine is granted the same zero
            // input traffic as the sparse flow (floors stay comparable).
            if !cfg.fused_input_resident && (g == 0 || !input_resident) {
                for s in strips {
                    let rows = ((s + 1) * r).min(h).saturating_sub(s * r);
                    input_bytes += (c_in * rows * w * bpe) as u64;
                }
            }
            let weight_bytes = if t == 0 || !plan.weight_group_fits {
                w_bytes_g
            } else {
                0
            };
            demands.push(TileDemand {
                compute,
                input_bytes,
                weight_bytes,
            });
        }
    }
    demands
}

/// Memory-aware dense cycle count: [`dense_tile_demands`] streamed through
/// the double-buffered SRAM model. Always `>= dense_cycles` (the pure
/// compute count) and `>=` the traffic's transfer-cycle floor.
pub fn dense_mem_cycles(
    cfg: &SimConfig,
    c_in: usize,
    k_out: usize,
    h: usize,
    w: usize,
    kw: usize,
) -> u64 {
    let demands = dense_tile_demands(cfg, c_in, k_out, h, w, kw);
    stream_tiles(&cfg.sram, cfg.dram_bytes_per_cycle, &demands).cycles
}

/// Dense MAC issue slots (pairs × per-array PEs) — the utilization
/// denominator for the reports.
pub fn dense_mac_slots(cfg: &SimConfig, c_in: usize, k_out: usize, h: usize, w: usize, kw: usize) -> u64 {
    let strips = h.div_ceil(cfg.pe.rows) as u64;
    k_out as u64
        * c_in as u64
        * strips
        * (w as u64)
        * (kw as u64)
        * (cfg.pe.rows as u64)
        * (cfg.pe.cols as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::SimConfig;
    use crate::sim::scheduler::{simulate_layer, Mode};
    use crate::sim::trace::Trace;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg32;

    /// The closed form must equal the simulator's dense run exactly —
    /// under both memory models.
    #[test]
    fn closed_form_matches_simulator() {
        let mut rng = Pcg32::seeded(3);
        for case in 0..8 {
            let mut cfg = SimConfig::paper_4_14_3();
            cfg.pe.arrays = rng.range(1, 5);
            cfg.pe.rows = rng.range(2, 8);
            cfg.context_switch_cycles = rng.range(0, 3) as u64;
            cfg.mem_model = if case % 2 == 0 {
                crate::sim::config::MemModel::Ideal
            } else {
                crate::sim::config::MemModel::Tiled
            };
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 9);
            let h = rng.range(3, 16);
            let w = rng.range(3, 16);
            let n: usize = c_in * h * w;
            let input = Tensor::from_vec(&[c_in, h, w], (0..n).map(|i| i as f32 + 1.0).collect());
            let wn = k_out * c_in * 9;
            let weight =
                Tensor::from_vec(&[k_out, c_in, 3, 3], (0..wn).map(|i| i as f32 + 1.0).collect());
            let spec = crate::tensor::conv::ConvSpec::default();
            let mut tr = Trace::disabled();
            let res = simulate_layer(&input, &weight, None, &cfg, spec, Mode::Dense, false, &mut tr);
            let expect = match cfg.mem_model {
                crate::sim::config::MemModel::Ideal => {
                    dense_cycles(&cfg, c_in, k_out, h, w, 3, spec)
                }
                crate::sim::config::MemModel::Tiled => dense_mem_cycles(&cfg, c_in, k_out, h, w, 3),
            };
            assert_eq!(res.stats.cycles, expect, "cfg {:?}", cfg.pe);
            assert_eq!(res.dense_cycles, expect, "cfg {:?}", cfg.pe);
        }
    }

    /// The memory-aware dense count dominates the pure compute count and
    /// the traffic's transfer floor, and collapses to compute-plus-fills
    /// when bandwidth is effectively infinite.
    #[test]
    fn dense_mem_cycles_bounds() {
        let mut cfg = SimConfig::paper_8_7_3();
        cfg.sram.input_bytes = 256;
        cfg.sram.weight_bytes = 256;
        cfg.dram_bytes_per_cycle = 1.0;
        let spec = crate::tensor::conv::ConvSpec::default();
        let (c_in, k_out, h, w, kw) = (3usize, 8usize, 20usize, 16usize, 3usize);
        let compute = dense_cycles(&cfg, c_in, k_out, h, w, kw, spec);
        let mem = dense_mem_cycles(&cfg, c_in, k_out, h, w, kw);
        assert!(mem >= compute, "{mem} < {compute}");
        let demands = dense_tile_demands(&cfg, c_in, k_out, h, w, kw);
        let transfer: u64 = demands
            .iter()
            .map(|d| {
                crate::sim::dram::cycles_for_bytes(
                    d.input_bytes + d.weight_bytes,
                    cfg.dram_bytes_per_cycle,
                )
            })
            .sum();
        assert!(mem >= transfer, "{mem} < {transfer}");
        // Plenty of bandwidth and SRAM: one tile, and only its 1-cycle
        // prologue fill separates the memory-aware count from compute.
        let mut fast = cfg;
        fast.dram_bytes_per_cycle = 1e9;
        fast.sram.input_bytes = 1 << 20;
        fast.sram.weight_bytes = 1 << 20;
        assert_eq!(dense_mem_cycles(&fast, c_in, k_out, h, w, kw), compute + 1);
    }

    #[test]
    fn paper_example_is_15_cycles() {
        let mut cfg = SimConfig::paper_4_14_3();
        cfg.pe.arrays = 1;
        cfg.pe.rows = 5;
        cfg.context_switch_cycles = 0;
        assert_eq!(
            dense_cycles(&cfg, 1, 1, 5, 5, 3, crate::tensor::conv::ConvSpec::default()),
            15
        );
    }

    #[test]
    fn mac_slots_scale_with_pes() {
        let cfg = SimConfig::paper_4_14_3();
        let slots = dense_mac_slots(&cfg, 2, 4, 14, 10, 3);
        assert_eq!(slots, 4 * 2 * 1 * 10 * 3 * 14 * 3);
    }
}
