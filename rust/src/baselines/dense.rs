//! The dense-CNN baseline: the same `[B, R, C]` array running the dense
//! flow (every vector issued). This is the denominator of every speedup in
//! Figs 12/13. Closed-form — no per-element work.

use crate::sim::config::SimConfig;
use crate::tensor::conv::ConvSpec;

/// Dense cycle count for a conv layer on `cfg`:
/// `ceil(K/B) · C · strips · W · KW` plus context-switch overhead per
/// `(group, channel, strip)` block.
pub fn dense_cycles(
    cfg: &SimConfig,
    c_in: usize,
    k_out: usize,
    h: usize,
    w: usize,
    kw: usize,
    _spec: ConvSpec,
) -> u64 {
    let strips = h.div_ceil(cfg.pe.rows) as u64;
    let groups = k_out.div_ceil(cfg.pe.arrays) as u64;
    let blocks = groups * c_in as u64 * strips;
    blocks * (w as u64) * (kw as u64) + blocks * cfg.context_switch_cycles
}

/// Dense MAC issue slots (pairs × per-array PEs) — the utilization
/// denominator for the reports.
pub fn dense_mac_slots(cfg: &SimConfig, c_in: usize, k_out: usize, h: usize, w: usize, kw: usize) -> u64 {
    let strips = h.div_ceil(cfg.pe.rows) as u64;
    k_out as u64
        * c_in as u64
        * strips
        * (w as u64)
        * (kw as u64)
        * (cfg.pe.rows as u64)
        * (cfg.pe.cols as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::SimConfig;
    use crate::sim::scheduler::{simulate_layer, Mode};
    use crate::sim::trace::Trace;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg32;

    /// The closed form must equal the simulator's dense run exactly.
    #[test]
    fn closed_form_matches_simulator() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..8 {
            let mut cfg = SimConfig::paper_4_14_3();
            cfg.pe.arrays = rng.range(1, 5);
            cfg.pe.rows = rng.range(2, 8);
            cfg.context_switch_cycles = rng.range(0, 3) as u64;
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 9);
            let h = rng.range(3, 16);
            let w = rng.range(3, 16);
            let n: usize = c_in * h * w;
            let input = Tensor::from_vec(&[c_in, h, w], (0..n).map(|i| i as f32 + 1.0).collect());
            let wn = k_out * c_in * 9;
            let weight =
                Tensor::from_vec(&[k_out, c_in, 3, 3], (0..wn).map(|i| i as f32 + 1.0).collect());
            let spec = crate::tensor::conv::ConvSpec::default();
            let mut tr = Trace::disabled();
            let res = simulate_layer(&input, &weight, None, &cfg, spec, Mode::Dense, false, &mut tr);
            assert_eq!(
                res.stats.cycles,
                dense_cycles(&cfg, c_in, k_out, h, w, 3, spec),
                "cfg {:?}",
                cfg.pe
            );
        }
    }

    #[test]
    fn paper_example_is_15_cycles() {
        let mut cfg = SimConfig::paper_4_14_3();
        cfg.pe.arrays = 1;
        cfg.pe.rows = 5;
        cfg.context_switch_cycles = 0;
        assert_eq!(
            dense_cycles(&cfg, 1, 1, 5, 5, 3, crate::tensor::conv::ConvSpec::default()),
            15
        );
    }

    #[test]
    fn mac_slots_scale_with_pes() {
        let cfg = SimConfig::paper_4_14_3();
        let slots = dense_mac_slots(&cfg, 2, 4, 14, 10, 3);
        assert_eq!(slots, 4 * 2 * 1 * 10 * 3 * 14 * 3);
    }
}
