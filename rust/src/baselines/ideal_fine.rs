//! Ideal fine-grained machine: skips every MAC whose input *or* weight
//! element is zero, with no indexing overhead — the theoretical ceiling of
//! designs like Cambricon-X [15] and SCNN [16] ("ideal fine grained" in
//! Figs 12/13).

use crate::sparse::encode::DensityReport;

/// Speedup over dense: total MACs / surviving MACs.
pub fn speedup(report: &DensityReport) -> f64 {
    if report.macs_nonzero == 0 {
        return report.macs_total.max(1) as f64;
    }
    report.macs_total as f64 / report.macs_nonzero as f64
}

/// Ideal cycle count on a machine with `pes` multipliers (perfect balance).
pub fn cycles(report: &DensityReport, pes: usize) -> u64 {
    report.macs_nonzero.div_ceil(pes as u64)
}

/// Ideal cycle count floored by the layer's DRAM transfer cycles — the
/// same memory floor as [`crate::baselines::ideal_vector::mem_cycles`]:
/// skipping MACs does not skip the bytes that feed them.
pub fn mem_cycles(report: &DensityReport, pes: usize, transfer_cycles: u64) -> u64 {
    cycles(report, pes).max(transfer_cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::encode::layer_report;
    use crate::tensor::conv::ConvSpec;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg32;

    #[test]
    fn fine_grained_beats_vector_granularity() {
        // Finer skipping can only help: ideal_fine >= ideal_vector on any
        // data (vector granularity merges zeros into nonzero vectors, and
        // additionally pays boundary pairs).
        let mut rng = Pcg32::seeded(23);
        for _ in 0..10 {
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 6);
            let h = rng.range(4, 12);
            let w = rng.range(4, 12);
            let n = c_in * h * w;
            let input = Tensor::from_vec(
                &[c_in, h, w],
                (0..n)
                    .map(|_| if rng.bernoulli(0.4) { rng.normal() } else { 0.0 })
                    .collect(),
            );
            let wn = k_out * c_in * 9;
            let weight = Tensor::from_vec(
                &[k_out, c_in, 3, 3],
                (0..wn)
                    .map(|_| if rng.bernoulli(0.35) { rng.normal() } else { 0.0 })
                    .collect(),
            );
            let rep = layer_report(&input, &weight, ConvSpec::default(), 4);
            assert!(
                speedup(&rep) >= crate::baselines::ideal_vector::speedup(&rep) - 1e-9,
                "fine {} < vector {}",
                speedup(&rep),
                crate::baselines::ideal_vector::speedup(&rep)
            );
        }
    }

    #[test]
    fn speedup_is_inverse_work_density() {
        let input = Tensor::from_vec(&[1, 6, 6], vec![1.0; 36]);
        let mut weight = Tensor::zeros(&[1, 1, 3, 3]);
        *weight.at4_mut(0, 0, 1, 1) = 1.0; // 1 of 9 taps
        let rep = layer_report(&input, &weight, ConvSpec::default(), 3);
        // Only the center tap works: work = 1/9 of interior (boundary makes
        // it slightly different); speedup ≈ 9 within boundary tolerance.
        let s = speedup(&rep);
        assert!(s > 8.0 && s < 10.5, "speedup {s}");
        assert_eq!(cycles(&rep, 1), rep.macs_nonzero);
        // The memory floor binds exactly when transfer dominates.
        assert_eq!(mem_cycles(&rep, 1, 0), rep.macs_nonzero);
        assert_eq!(
            mem_cycles(&rep, 1, rep.macs_nonzero + 7),
            rep.macs_nonzero + 7
        );
    }
}
