//! Simplified model of SCNN [16] — the fine-grained comparator of §IV.
//!
//! SCNN multiplies compressed nonzero input and weight elements in a 2-D
//! Cartesian-product multiplier array and scatters products to accumulator
//! banks through a crossbar; its losses come from accumulator-bank
//! contention, ragged tail fragmentation of the compressed streams, and
//! halo handling at tile edges. The paper summarizes the net effect:
//! *"The speedup over the dense CNN in [16] is about 3X, which roughly
//! exploits 66% of ideal fine grained zero computation."*
//!
//! We model SCNN at that published operating point: a fine-grained machine
//! capturing a configurable fraction (default 66%) of the ideal
//! fine-grained skip opportunity, plus an area-overhead proxy for the
//! §IV hardware-efficiency comparison.

use crate::sparse::encode::DensityReport;

/// SCNN-like model parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScnnModel {
    /// Fraction of the ideal fine-grained skipped computation the design
    /// realizes (0.66 per the paper's reading of [16]).
    pub skip_efficiency: f64,
    /// Index/accumulator/crossbar area overhead relative to the MAC array
    /// (dimensionless proxy; SCNN's indexing+crossbar dominate its area —
    /// reported ~30% of the PE in [16] vs ~5% for VSCNN's vector index).
    pub index_area_overhead: f64,
}

impl Default for ScnnModel {
    fn default() -> Self {
        ScnnModel {
            skip_efficiency: 0.66,
            index_area_overhead: 0.30,
        }
    }
}

/// VSCNN's corresponding overhead proxy (one index entry per whole vector;
/// §IV "our design overhead is very small").
pub const VSCNN_INDEX_AREA_OVERHEAD: f64 = 0.05;

impl ScnnModel {
    /// Speedup over dense at this layer: dense work shrunk by
    /// `skip_efficiency` of what ideal fine-grained would skip.
    pub fn speedup(&self, report: &DensityReport) -> f64 {
        let ideal = crate::baselines::ideal_fine::speedup(report);
        let ideal_skip = 1.0 - 1.0 / ideal; // fraction of cycles skipped
        let our_skip = self.skip_efficiency * ideal_skip;
        1.0 / (1.0 - our_skip)
    }

    /// Speedup per unit area relative to a dense design — the §IV
    /// "hardware efficient" comparison between VSCNN and SCNN.
    pub fn speedup_per_area(&self, report: &DensityReport) -> f64 {
        self.speedup(report) / (1.0 + self.index_area_overhead)
    }

    /// [`Self::speedup`] capped at the bandwidth bound
    /// `dense_cycles / transfer_cycles` — the tiled memory floor shared
    /// with the dense and ideal baselines: no machine that must move this
    /// traffic can beat dense by more than the bus allows.
    pub fn speedup_with_bw_floor(
        &self,
        report: &DensityReport,
        dense_cycles: u64,
        transfer_cycles: u64,
    ) -> f64 {
        let s = self.speedup(report);
        if transfer_cycles == 0 {
            return s;
        }
        s.min(dense_cycles as f64 / transfer_cycles as f64)
    }
}

/// VSCNN speedup per unit area for the same comparison.
pub fn vscnn_speedup_per_area(speedup: f64) -> f64 {
    speedup / (1.0 + VSCNN_INDEX_AREA_OVERHEAD)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::encode::DensityReport;

    fn report_with(macs_total: u64, macs_nonzero: u64) -> DensityReport {
        DensityReport {
            input_elem: 0.0,
            weight_elem: 0.0,
            work_elem: macs_nonzero as f64 / macs_total as f64,
            input_vec: 0.0,
            weight_vec: 0.0,
            work_vec: 0.0,
            macs_total,
            macs_nonzero,
            pairs_total: 0,
            pairs_nonzero: 0,
        }
    }

    #[test]
    fn paper_operating_point() {
        // The paper's two SCNN numbers are coupled: 3x speedup = skipping
        // 66.7% of dense cycles, i.e. "exploits 66% of ideal fine grained
        // zero computation" treats ideal skip as ≈ all of it. At SCNN's
        // very sparse workloads (work ≈ 5-10%) the model approaches its
        // 1/(1-0.66) ≈ 2.94x asymptote — "about 3X".
        let rep = report_with(1000, 60);
        let s = ScnnModel::default().speedup(&rep);
        assert!((2.6..3.1).contains(&s), "speedup {s}");
    }

    #[test]
    fn dense_data_no_speedup() {
        let rep = report_with(1000, 1000);
        assert!((ScnnModel::default().speedup(&rep) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn efficiency_one_recovers_ideal() {
        let rep = report_with(1000, 250);
        let m = ScnnModel {
            skip_efficiency: 1.0,
            index_area_overhead: 0.0,
        };
        assert!((m.speedup(&rep) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn bw_floor_caps_the_modelled_speedup() {
        let rep = report_with(1000, 60);
        let m = ScnnModel::default();
        let uncapped = m.speedup(&rep);
        // No transfer data: unchanged. Tight bus: capped at dense/transfer.
        assert_eq!(m.speedup_with_bw_floor(&rep, 1000, 0), uncapped);
        let capped = m.speedup_with_bw_floor(&rep, 1000, 800);
        assert!((capped - 1.25).abs() < 1e-12, "capped {capped}");
        assert!(capped < uncapped);
    }

    #[test]
    fn area_normalized_comparison_favors_vscnn_at_equal_speedup() {
        let rep = report_with(1000, 300);
        let scnn = ScnnModel::default();
        let s = scnn.speedup(&rep);
        // If VSCNN reached the same raw speedup, per-area it wins.
        assert!(vscnn_speedup_per_area(s) > scnn.speedup_per_area(&rep));
    }
}
