//! Ideal vector-sparse machine: skips **every** zero-vector pair with
//! perfect load balance across arrays, no sync stalls, no boundary (X)
//! slots and no context-switch overhead. Upper-bounds what any real
//! vector-granularity design can achieve — the "ideal vector sparse"
//! series of Figs 12/13.

use crate::sparse::encode::DensityReport;

/// Speedup over dense: total pairs / surviving pairs (granularity cancels
/// the array count).
pub fn speedup(report: &DensityReport) -> f64 {
    if report.pairs_nonzero == 0 {
        // Fully skippable layer: cap at the dense pair count (one cycle of
        // work minimum in any real machine).
        return report.pairs_total.max(1) as f64;
    }
    report.pairs_total as f64 / report.pairs_nonzero as f64
}

/// Ideal cycle count on a `B`-array machine (perfect balance).
pub fn cycles(report: &DensityReport, arrays: usize) -> u64 {
    report.pairs_nonzero.div_ceil(arrays as u64)
}

/// Ideal cycle count floored by the DRAM transfer the same compressed
/// layer must move — even a perfectly balanced machine cannot outrun the
/// bus. This is the tiled memory model's floor shared with every
/// baseline, so skip-efficiency numbers cannot exceed the bandwidth
/// bound.
pub fn mem_cycles(report: &DensityReport, arrays: usize, transfer_cycles: u64) -> u64 {
    cycles(report, arrays).max(transfer_cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::encode::layer_report;
    use crate::tensor::conv::ConvSpec;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg32;

    fn sparse_tensor(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_vec(
            shape,
            (0..n)
                .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
                .collect(),
        )
    }

    #[test]
    fn dense_data_gives_speedup_one() {
        let input = Tensor::from_vec(&[1, 6, 6], vec![1.0; 36]);
        let weight = Tensor::from_vec(&[2, 1, 3, 3], vec![1.0; 18]);
        let rep = layer_report(&input, &weight, ConvSpec::default(), 3);
        assert!((speedup(&rep) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ideal_upper_bounds_simulator() {
        // The simulator (with its sync/boundary losses) can never beat the
        // ideal machine.
        use crate::sim::config::SimConfig;
        use crate::sim::scheduler::{simulate_layer, Mode};
        use crate::sim::trace::Trace;
        let mut rng = Pcg32::seeded(19);
        for _ in 0..10 {
            let mut cfg = SimConfig::paper_4_14_3();
            cfg.pe.arrays = rng.range(1, 5);
            cfg.pe.rows = rng.range(2, 8);
            cfg.context_switch_cycles = 0;
            // Pure-compute comparison: the unfloored ideal machine only
            // upper-bounds the simulator's compute cycles.
            cfg.mem_model = crate::sim::config::MemModel::Ideal;
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 8);
            let h = rng.range(4, 14);
            let w = rng.range(4, 14);
            let input = sparse_tensor(&mut rng, &[c_in, h, w], 0.4);
            let weight = sparse_tensor(&mut rng, &[k_out, c_in, 3, 3], 0.35);
            let spec = ConvSpec::default();
            let rep = layer_report(&input, &weight, spec, cfg.pe.rows);
            let mut tr = Trace::disabled();
            let res = simulate_layer(
                &input,
                &weight,
                None,
                &cfg,
                spec,
                Mode::VectorSparse,
                false,
                &mut tr,
            );
            let ours = res.dense_cycles as f64 / res.stats.cycles.max(1) as f64;
            let ideal = speedup(&rep);
            assert!(
                ours <= ideal + 1e-9,
                "ours {ours} beats ideal {ideal} (arrays={} rows={})",
                cfg.pe.arrays,
                cfg.pe.rows
            );
        }
    }

    #[test]
    fn cycles_divide_across_arrays() {
        let input = Tensor::from_vec(&[1, 4, 4], vec![1.0; 16]);
        let weight = Tensor::from_vec(&[4, 1, 3, 3], vec![1.0; 36]);
        let rep = layer_report(&input, &weight, ConvSpec::default(), 4);
        assert_eq!(cycles(&rep, 1), rep.pairs_nonzero);
        assert_eq!(cycles(&rep, 4), rep.pairs_nonzero.div_ceil(4));
    }

    #[test]
    fn mem_cycles_apply_the_transfer_floor() {
        let input = Tensor::from_vec(&[1, 4, 4], vec![1.0; 16]);
        let weight = Tensor::from_vec(&[4, 1, 3, 3], vec![1.0; 36]);
        let rep = layer_report(&input, &weight, ConvSpec::default(), 4);
        let compute = cycles(&rep, 4);
        assert_eq!(mem_cycles(&rep, 4, 0), compute);
        assert_eq!(mem_cycles(&rep, 4, compute + 100), compute + 100);
    }
}
