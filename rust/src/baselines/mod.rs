//! Comparison baselines for the paper's Figs 12/13 and §IV discussion:
//! the dense flow, the two *ideal* (zero-overhead) sparse machines, and a
//! simplified model of the fine-grained SCNN comparator [16].

pub mod dense;
pub mod ideal_fine;
pub mod ideal_vector;
pub mod scnn_like;

use crate::sparse::encode::DensityReport;

/// The per-layer speedup series plotted in Figs 12/13 (dense = 1.0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeedupSeries {
    /// VSCNN (simulated, with sync/boundary/overhead losses).
    pub ours: f64,
    /// Ideal vector-sparse machine (skips every zero-vector pair, perfect
    /// load balance, no overhead).
    pub ideal_vector: f64,
    /// Ideal fine-grained machine (skips every zero-element MAC).
    pub ideal_fine: f64,
}

impl SpeedupSeries {
    /// Fraction of the ideal vector-sparse *skipped computation* that the
    /// real design captures — the paper's 92% / 85% metric:
    /// `(dense - ours) / (dense - ideal)` in cycle terms.
    pub fn vector_skip_efficiency(&self) -> f64 {
        skip_efficiency(self.ours, self.ideal_vector)
    }

    /// Same relative to the ideal fine-grained machine (46.6% / 47.1%).
    pub fn fine_skip_efficiency(&self) -> f64 {
        skip_efficiency(self.ours, self.ideal_fine)
    }
}

/// `(1 - 1/ours) / (1 - 1/ideal)`: share of ideal's skipped cycles that a
/// real design skips. 1.0 when the design matches ideal; 0 when it matches
/// dense; undefined (returns 1) when ideal itself has nothing to skip.
pub fn skip_efficiency(ours: f64, ideal: f64) -> f64 {
    let ideal_skip = 1.0 - 1.0 / ideal;
    if ideal_skip <= 0.0 {
        return 1.0;
    }
    (1.0 - 1.0 / ours) / ideal_skip
}

/// Build the ideal members of the series from a layer's density report
/// (`ours` must come from the simulator).
pub fn ideal_speedups(report: &DensityReport) -> (f64, f64) {
    (ideal_vector::speedup(report), ideal_fine::speedup(report))
}

/// Ideal-machine speedups under the tiled memory model: each ideal
/// machine's cycle count is floored by the layer's DRAM transfer cycles
/// (same compressed traffic, perfect overlap), and the speedup is taken
/// against the memory-aware dense baseline — so skip-efficiency numbers
/// cannot exceed the bandwidth bound.
pub fn ideal_speedups_mem(
    report: &DensityReport,
    cfg: &crate::sim::config::SimConfig,
    dense_cycles: u64,
    transfer_cycles: u64,
) -> (f64, f64) {
    let iv = ideal_vector::mem_cycles(report, cfg.pe.arrays, transfer_cycles);
    let fine = ideal_fine::mem_cycles(report, cfg.pe.total_pes(), transfer_cycles);
    (
        dense_cycles as f64 / iv.max(1) as f64,
        dense_cycles as f64 / fine.max(1) as f64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_efficiency_endpoints() {
        // Matching ideal → 1.0; no speedup at all → 0.0.
        assert!((skip_efficiency(2.0, 2.0) - 1.0).abs() < 1e-12);
        assert!((skip_efficiency(1.0, 2.0) - 0.0).abs() < 1e-12);
        // Half the skipped cycles: ideal 2x skips 50%, ours 4/3 skips 25%.
        assert!((skip_efficiency(4.0 / 3.0, 2.0) - 0.5).abs() < 1e-12);
        // Degenerate ideal (nothing to skip).
        assert_eq!(skip_efficiency(1.0, 1.0), 1.0);
    }

    #[test]
    fn series_methods_delegate() {
        let s = SpeedupSeries {
            ours: 1.8,
            ideal_vector: 2.0,
            ideal_fine: 4.0,
        };
        assert!(s.vector_skip_efficiency() > s.fine_skip_efficiency());
        assert!(s.vector_skip_efficiency() <= 1.0);
    }
}
