// The `simd` feature opts the hot-path kernels (util/simd.rs) into
// `std::simd` explicit vectors; it requires a nightly toolchain. The
// default stable build uses the blocked fallback paths, bit-identical by
// construction.
#![cfg_attr(feature = "simd", feature(portable_simd))]

//! # vscnn — VSCNN: CNN Accelerator With Vector Sparsity (cs.AR 2022)
//!
//! A full-system reproduction of "VSCNN: Convolution Neural Network
//! Accelerator with Vector Sparsity" (cs.AR 2022, arXiv:2205.02271)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system: a cycle-level simulator of the
//!   VSCNN PE array (1-D broadcast input/weight vectors, diagonal partial-sum
//!   accumulation, zero-vector skipping with an index system), SRAM/DRAM
//!   models, the dense/sparse schedulers, pruning, baselines, and the
//!   coordinator that runs whole networks and regenerates every table and
//!   figure of the paper's evaluation.
//! * **L2 (python/compile/model.py)** — the VGG-16 compute graph in JAX,
//!   AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! * **L1 (python/compile/kernels/)** — the VSCNN column dataflow as a Pallas
//!   kernel, validated against a pure-jnp oracle.
//!
//! Entry points: [`engine::compile`] + [`engine::Engine`] for the
//! compile-once/execute-many path, [`coordinator::Coordinator`] for the
//! one-shot construct-and-run shim, [`experiments`] for the paper's
//! tables/figures, [`serve`] for the multi-accelerator serving simulator
//! (traffic, batching, sharding, tail latency), the `vscnn` binary for
//! the CLI, and `examples/` for runnable scenarios.

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod model;
pub mod pruning;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sparse;
pub mod tensor;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
