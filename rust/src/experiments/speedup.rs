//! `fig12` / `fig13` / `headline` / `scnn`: the speedup figures and the
//! §IV summary numbers.

use super::workload::{avg_layer_metric, run_config, run_configs};
use super::{ExpContext, ExpOutput};
use crate::baselines::scnn_like::{vscnn_speedup_per_area, ScnnModel};
use crate::coordinator::report::ascii_table;
use crate::coordinator::NetworkReport;
use crate::sim::config::SimConfig;
use crate::util::json::Json;
use anyhow::Result;

fn speedup_rows(reports: &[NetworkReport]) -> Vec<(String, Vec<(String, f64)>)> {
    let ours = avg_layer_metric(reports, |l| l.speedups.ours);
    let iv = avg_layer_metric(reports, |l| l.speedups.ideal_vector);
    let ifg = avg_layer_metric(reports, |l| l.speedups.ideal_fine);
    let bw = avg_layer_metric(reports, |l| l.bw_util);
    ours.iter()
        .zip(&iv)
        .zip(&ifg)
        .zip(&bw)
        .map(|(((o, v), f), b)| {
            (
                o.0.clone(),
                vec![
                    ("ours".to_string(), o.1),
                    ("ideal_vector".to_string(), v.1),
                    ("ideal_fine".to_string(), f.1),
                    ("bw_util".to_string(), b.1),
                ],
            )
        })
        .collect()
}

/// Average memory-bound layer fraction and effective bandwidth
/// utilization across image reports (the roofline summary line).
fn mem_summary(reports: &[NetworkReport]) -> (f64, f64) {
    let n = reports.len().max(1) as f64;
    let frac: f64 = reports.iter().map(|r| r.memory_bound_layer_frac()).sum();
    let util: f64 = reports.iter().map(|r| r.effective_bw_util()).sum();
    (frac / n, util / n)
}

fn overall_avg(reports: &[NetworkReport]) -> (f64, f64, f64, f64, f64) {
    let n = reports.len().max(1) as f64;
    let mut s = (0.0, 0.0, 0.0, 0.0, 0.0);
    for r in reports {
        let series = r.overall_series();
        s.0 += series.ours / n;
        s.1 += series.ideal_vector / n;
        s.2 += series.ideal_fine / n;
        s.3 += series.vector_skip_efficiency() / n;
        s.4 += series.fine_skip_efficiency() / n;
    }
    s
}

/// Fig 12 (`cfg_4_14_3 = true`) or Fig 13: per-layer speedups of ours vs
/// the two ideal machines, plus the overall bar.
pub fn run_fig(ctx: &ExpContext, cfg_4_14_3: bool) -> Result<ExpOutput> {
    let (id, cfg, paper_overall) = if cfg_4_14_3 {
        ("fig12", SimConfig::paper_4_14_3(), 1.871)
    } else {
        ("fig13", SimConfig::paper_8_7_3(), 1.93)
    };
    let reports = run_config(ctx, cfg)?;
    let rows = speedup_rows(&reports);
    let (ours, iv, ifg, veff, feff) = overall_avg(&reports);
    let (mem_frac, bw_util) = mem_summary(&reports);

    let mut json = Json::obj();
    json.set("config", cfg.pe.label())
        .set("mem_model", ctx.mem_model.label())
        .set("overall_speedup", ours)
        .set("overall_ideal_vector", iv)
        .set("overall_ideal_fine", ifg)
        .set("vector_skip_efficiency", veff)
        .set("fine_skip_efficiency", feff)
        .set("memory_bound_layer_frac", mem_frac)
        .set("effective_bw_util", bw_util)
        .set("paper_overall_speedup", paper_overall)
        .set(
            "layers",
            Json::Arr(
                rows.iter()
                    .map(|(name, cols)| {
                        let mut o = Json::obj();
                        o.set("name", name.as_str());
                        for (k, v) in cols {
                            o.set(k, *v);
                        }
                        o
                    })
                    .collect(),
            ),
        );
    let text = format!(
        "Fig {} — speedup over dense, {} (mem model: {})\n{}\noverall: ours {:.3}x | ideal vector {:.3}x | ideal fine {:.3}x (paper: {:.3}x)\nmemory-bound layers: {:.0}% | effective DRAM bw utilization: {:.1}%\n",
        if cfg_4_14_3 { 12 } else { 13 },
        cfg.pe.label(),
        ctx.mem_model.label(),
        ascii_table(&rows),
        ours,
        iv,
        ifg,
        paper_overall,
        100.0 * mem_frac,
        100.0 * bw_util
    );
    Ok(ExpOutput {
        id: id.to_string(),
        json,
        text,
    })
}

/// `headline`: both configurations side by side with the paper's §IV
/// summary numbers.
pub fn run_headline(ctx: &ExpContext) -> Result<ExpOutput> {
    let mut json = Json::obj();
    let mut text = String::from("Headline summary (paper §IV)\n");
    let entries = [
        (SimConfig::paper_4_14_3(), 1.871, 0.92, 0.466),
        (SimConfig::paper_8_7_3(), 1.93, 0.85, 0.471),
    ];
    // Both configurations simulate concurrently (one worker each, backed
    // by the workload memoizer so repeat figures stay free).
    let all = run_configs(ctx, &[entries[0].0, entries[1].0])?;
    for ((cfg, paper_speedup, paper_veff, paper_feff), reports) in
        entries.into_iter().zip(&all)
    {
        let (ours, iv, ifg, veff, feff) = overall_avg(reports);
        let (mem_frac, bw_util) = mem_summary(reports);
        // Per-layer roofline classification (image 0; the classification
        // is shape-dominated, so one image is representative). Empty when
        // the run had no images (`--images 0`).
        let layers = Json::Arr(
            reports
                .first()
                .map(|r| r.layers.as_slice())
                .unwrap_or(&[])
                .iter()
                .map(|l| {
                    let mut lo = Json::obj();
                    lo.set("name", l.name.as_str())
                        .set("bound", l.bound.label())
                        .set("bw_utilization", l.bw_util)
                        .set("speedup", l.speedups.ours);
                    lo
                })
                .collect(),
        );
        // Per-image fusion counts are identical (eligibility is
        // shape-driven), so image 0 is representative.
        let fused_layers = reports.first().map(|r| r.fused_layers).unwrap_or(0);
        let mut o = Json::obj();
        o.set("speedup", ours)
            .set("ideal_vector", iv)
            .set("ideal_fine", ifg)
            .set("vector_skip_efficiency", veff)
            .set("fine_skip_efficiency", feff)
            .set("memory_bound_layer_frac", mem_frac)
            .set("effective_bw_util", bw_util)
            .set("mem_model", ctx.mem_model.label())
            .set("precision", ctx.precision.label())
            .set("fused_layers", fused_layers)
            .set("layers", layers)
            .set("paper_speedup", paper_speedup)
            .set("paper_vector_skip_efficiency", paper_veff)
            .set("paper_fine_skip_efficiency", paper_feff);
        json.set(&cfg.pe.label(), o);
        text.push_str(&format!(
            "{}: speedup {:.3}x (paper {:.3}x) | vector-skip eff {:.1}% (paper {:.0}%) | fine-skip eff {:.1}% (paper {:.1}%) | mem-bound layers {:.0}% | bw util {:.1}%\n",
            cfg.pe.label(),
            ours,
            paper_speedup,
            100.0 * veff,
            100.0 * paper_veff,
            100.0 * feff,
            100.0 * paper_feff,
            100.0 * mem_frac,
            100.0 * bw_util,
        ));
    }
    Ok(ExpOutput {
        id: "headline".into(),
        json,
        text,
    })
}

/// `scnn`: the §IV comparison — VSCNN's small-overhead vector skipping vs
/// an SCNN-like fine-grained design at its published operating point.
pub fn run_scnn(ctx: &ExpContext) -> Result<ExpOutput> {
    let cfg = SimConfig::paper_8_7_3();
    let reports = run_config(ctx, cfg)?;
    let (ours, _iv, ifg, _veff, feff) = overall_avg(&reports);

    // SCNN-like model on the same (whole-network) work profile.
    let model = ScnnModel::default();
    let mut macs_t = 0u64;
    let mut macs_nz = 0u64;
    for r in &reports {
        for l in &r.layers {
            macs_t += l.density.macs_total;
            macs_nz += l.density.macs_nonzero;
        }
    }
    let agg = crate::sparse::encode::DensityReport {
        input_elem: 0.0,
        weight_elem: 0.0,
        work_elem: macs_nz as f64 / macs_t.max(1) as f64,
        input_vec: 0.0,
        weight_vec: 0.0,
        work_vec: 0.0,
        macs_total: macs_t,
        macs_nonzero: macs_nz,
        pairs_total: 0,
        pairs_nonzero: 0,
    };
    // Under the tiled model the SCNN-like comparator shares the same
    // bandwidth floor as every other baseline: no machine moving this
    // traffic beats dense by more than the bus allows.
    let total_dense: u64 = reports.iter().map(|r| r.total_dense_cycles).sum();
    let total_transfer: u64 = reports.iter().map(|r| r.totals.transfer_cycles).sum();
    let scnn_speedup = model.speedup_with_bw_floor(&agg, total_dense, total_transfer);

    let mut json = Json::obj();
    json.set("vscnn_speedup", ours)
        .set("transfer_floor_cycles", total_transfer)
        .set("vscnn_fine_skip_efficiency", feff)
        .set("vscnn_speedup_per_area", vscnn_speedup_per_area(ours))
        .set("scnn_speedup", scnn_speedup)
        .set("scnn_skip_efficiency", model.skip_efficiency)
        .set("scnn_speedup_per_area", model.speedup_per_area(&agg))
        .set("ideal_fine_speedup", ifg)
        .set("paper_scnn_speedup", 3.0)
        .set("paper_scnn_skip_efficiency", 0.66);
    let text = format!(
        "SCNN comparison (§IV)\n\
         VSCNN : {ours:.3}x speedup, {:.1}% of ideal fine-grained, {:.3}x/area\n\
         SCNN  : {scnn_speedup:.3}x speedup (paper ~3x), 66% of ideal, {:.3}x/area\n\
         ideal fine-grained: {ifg:.3}x\n",
        100.0 * feff,
        vscnn_speedup_per_area(ours),
        model.speedup_per_area(&agg),
    );
    Ok(ExpOutput {
        id: "scnn".into(),
        json,
        text,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx() -> ExpContext {
        ExpContext {
            res: 32,
            ..Default::default()
        }
    }

    #[test]
    fn fig12_structure_and_bounds() {
        let out = run_fig(&tiny_ctx(), true).unwrap();
        let layers = out.json.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 13);
        for l in layers {
            let ours = l.get("ours").unwrap().as_f64().unwrap();
            let iv = l.get("ideal_vector").unwrap().as_f64().unwrap();
            assert!(ours >= 1.0 - 1e-9, "ours {ours}");
            assert!(ours <= iv + 1e-6, "ours {ours} > ideal vector {iv}");
        }
    }

    #[test]
    fn fig13_has_more_skippable_work_than_fig12() {
        // [8,7,3]'s smaller vectors expose at least as many zero vectors:
        // the *ideal* vector-sparse speedup is monotone in 1/R (each R=14
        // strip is the union of two aligned R=7 strips). The realized
        // speedups trade this gain against the wider group's sync loss —
        // the paper's two configs land within 3% of each other; at tiny
        // test resolutions the balance can tip either way, so the test
        // checks the monotone quantity plus sanity bounds on both.
        let ctx = tiny_ctx();
        let f12 = run_fig(&ctx, true).unwrap();
        let f13 = run_fig(&ctx, false).unwrap();
        // At the tiny test resolution VGG heights are ragged (not multiples
        // of 14), so the aligned-strip monotonicity of ideal-vector work is
        // checked in density.rs on aligned layers; here assert both configs
        // are in the sane band (the full-res ordering is checked by the
        // fig12/fig13 benches at 224).
        for f in [&f12, &f13] {
            let ours = f.json.get("overall_speedup").unwrap().as_f64().unwrap();
            let iv = f.json.get("overall_ideal_vector").unwrap().as_f64().unwrap();
            assert!(ours >= 1.0 && ours <= iv + 1e-6, "ours {ours} ideal {iv}");
        }
    }

    #[test]
    fn headline_and_scnn_render() {
        let ctx = tiny_ctx();
        let h = run_headline(&ctx).unwrap();
        assert!(h.json.get("[4,14,3]").is_some());
        assert!(h.json.get("[8,7,3]").is_some());
        let s = run_scnn(&ctx).unwrap();
        let v = s.json.get("vscnn_speedup").unwrap().as_f64().unwrap();
        assert!(v >= 1.0);
    }
}
