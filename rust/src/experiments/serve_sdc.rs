//! `serve-sdc`: the data-integrity curve — detection rate, escape rate
//! and goodput as the per-instance bit-flip rate rises (ISSUE 10).
//!
//! Two arms sweep the same flip-rate grid over the same profiled fleet:
//!
//! * **unprotected** — flips land with no checksums; nothing is detected,
//!   and corrupted batches ship as `silent_completions` (wrong answers
//!   delivered as successes).
//! * **protected** — ABFT checksums + CVF structural validation detect
//!   the covered fraction, batch re-execution and the periodic weight
//!   scrubber repair what they catch, and a fractional service-time
//!   overhead is charged for the protection.
//!
//! A clean (zero-flip) run anchors the goodput axis, so the protected
//! arm's overhead and the unprotected arm's corruption losses are both
//! measured against the same baseline. The emitted curve
//! (`reports/serve_sdc.json` + `BENCH_serve_sdc.json`) quantifies the
//! protection trade: how much goodput the checksums cost vs how many
//! wrong answers they keep off the wire — see EXPERIMENTS.md §Integrity
//! for a worked reading.

use super::{ExpContext, ExpOutput};
use crate::coordinator::report::ascii_table;
use crate::serve::{
    build_profiles, default_fleet, default_mix, simulate, BatchPolicy, DispatchPolicy, FaultSpec,
    IntegritySummary, RobustnessPolicy, ServeReport, ServeSpec, TrafficModel,
};
use crate::sim::sdc::SdcSpec;
use crate::util::json::Json;
use anyhow::Result;

/// Flip intensity swept, in *expected upsets per instance over the
/// horizon* (the per-second rate is derived from the horizon so the
/// curve shape is resolution-invariant). The top point lands hundreds of
/// flips across the fleet, enough for the detection-rate estimate to
/// concentrate near the analytic coverage.
const EXPECTED_FLIPS: [f64; 3] = [4.0, 16.0, 64.0];

/// Expected arrivals per sweep point (sets the horizon from the offered
/// rate, exactly like the `serve` capacity curve).
const ARRIVALS_PER_POINT: f64 = 400.0;

/// Offered load as a fraction of the estimated warm-batch capacity:
/// below the knee so the clean anchor is healthy, high enough that the
/// protection overhead and re-execution stalls show up in goodput.
const LOAD_FRAC: f64 = 0.85;

/// One sweep point: the same flip plan with and without protection.
struct SdcPoint {
    flip_per_sec: f64,
    unprot: ServeReport,
    prot: ServeReport,
}

fn goodput(r: &ServeReport) -> f64 {
    r.throughput_rps()
}

/// Integrity section of one report — every sweep arm runs with SDC
/// active, so the gated section is always present here.
fn integ(r: &ServeReport) -> &IntegritySummary {
    r.integrity.as_ref().expect("sdc arm has integrity section")
}

fn side_json(r: &ServeReport) -> Json {
    let ig = integ(r);
    let mut o = Json::obj();
    o.set("goodput_rps", goodput(r))
        .set("p99_ms", r.p99_ms())
        .set("completed", r.completed)
        .set("injected", ig.injected)
        .set("masked", ig.masked)
        .set("detected", ig.detected)
        .set("corrected", ig.corrected)
        .set("silent", ig.silent)
        .set("detection_rate", ig.detection_rate)
        .set("escape_rate", ig.escape_rate)
        .set("silent_completions", ig.silent_completions)
        .set("scrubs", ig.scrubs)
        .set("overhead_frac", ig.overhead_frac);
    o
}

fn point_json(p: &SdcPoint) -> Json {
    let mut o = Json::obj();
    o.set("flip_per_sec", p.flip_per_sec)
        .set("unprotected", side_json(&p.unprot))
        .set("protected", side_json(&p.prot));
    o
}

/// Run the `serve-sdc` experiment (see module docs).
pub fn run_serve_sdc(ctx: &ExpContext) -> Result<ExpOutput> {
    let tenants = default_mix(ctx.res);
    let instances = default_fleet(4);
    let base = ServeSpec {
        tenants: tenants.clone(),
        instances,
        traffic: TrafficModel::OpenLoop { rps: 1.0 },
        policy: DispatchPolicy::NetworkAffinity,
        batch: BatchPolicy::none(),
        queue_cap: 32,
        racks: 1,
        duration_cycles: 1,
        clock_mhz: 500.0,
        seed: ctx.seed,
        faults: FaultSpec::none(),
        robust: RobustnessPolicy::none(),
        sdc: SdcSpec::none(),
    };
    let profiles = build_profiles(&base, ctx.threads)?;

    // Mix-weighted service means: capacity estimate (same arithmetic as
    // the `serve` experiment) and the single-request mean that anchors
    // the retry timeout.
    let wsum: f64 = tenants.iter().map(|t| t.weight).sum();
    let mut capacity_rps = 0.0;
    for i in 0..base.instances.len() {
        let mean_marginal: f64 = tenants
            .iter()
            .enumerate()
            .map(|(t, ten)| ten.weight / wsum * profiles[t][i].marginal_cycles as f64)
            .sum();
        capacity_rps += base.clock_hz() / mean_marginal.max(1.0);
    }
    let mut mean_single = 0.0;
    for (t, ten) in tenants.iter().enumerate() {
        let avg: f64 = profiles[t]
            .iter()
            .map(|p| p.single_cycles as f64)
            .sum::<f64>()
            / profiles[t].len() as f64;
        mean_single += ten.weight / wsum * avg;
    }

    let rps = capacity_rps * LOAD_FRAC;
    let duration_cycles = (ARRIVALS_PER_POINT * base.clock_hz() / rps).ceil() as u64;
    let duration_secs = duration_cycles as f64 / base.clock_hz();

    // Retries catch the batches that detection fails into the retry path
    // once the re-execution budget runs dry; shedding keeps overload
    // degradation orderly. No crash/straggler faults: the curve isolates
    // the corruption axis.
    let robust = RobustnessPolicy {
        timeout_cycles: ((mean_single * 24.0) as u64).max(1),
        max_retries: 2,
        backoff_cycles: ((mean_single / 2.0) as u64).max(1),
        hedge_cycles: 0,
        shed: true,
    };

    let mut loaded = base.clone();
    loaded.traffic = TrafficModel::OpenLoop { rps };
    loaded.duration_cycles = duration_cycles;
    loaded.batch = BatchPolicy {
        max_batch: 8,
        max_wait_cycles: ((mean_single / 2.0) as u64).max(1),
    };
    loaded.robust = robust;

    // Zero-flip anchor: the goodput baseline both arms are judged
    // against (and the byte-identity reference for the SDC-off claim).
    let clean = ServeReport::new(&loaded, &simulate(&loaded, &profiles));

    let mut curve: Vec<SdcPoint> = Vec::new();
    for expected in EXPECTED_FLIPS {
        let flip_per_sec = expected / duration_secs;
        let mut unprot_spec = loaded.clone();
        unprot_spec.sdc = SdcSpec {
            flip_per_sec,
            ..SdcSpec::none()
        };
        let mut prot_spec = loaded.clone();
        prot_spec.sdc = SdcSpec {
            flip_per_sec,
            protect: true,
            ..SdcSpec::none()
        };
        let unprot = ServeReport::new(&unprot_spec, &simulate(&unprot_spec, &profiles));
        let prot = ServeReport::new(&prot_spec, &simulate(&prot_spec, &profiles));
        curve.push(SdcPoint {
            flip_per_sec,
            unprot,
            prot,
        });
    }

    // Aggregate rates across the whole sweep: the per-point estimates at
    // the low-rate end ride on a handful of flips, so acceptance metrics
    // pool every arm's ledger.
    let pool = |f: &dyn Fn(&IntegritySummary) -> u64, prot: bool| -> u64 {
        curve
            .iter()
            .map(|p| f(integ(if prot { &p.prot } else { &p.unprot })))
            .sum()
    };
    let prot_detected = pool(&|ig| ig.detected, true);
    let prot_consequential = pool(&|ig| ig.injected - ig.masked, true).max(1);
    let unprot_consequential = pool(&|ig| ig.injected - ig.masked, false).max(1);
    let detection_rate = prot_detected as f64 / prot_consequential as f64;
    let prot_escape = pool(&|ig| ig.silent, true) as f64 / prot_consequential as f64;
    let unprot_escape = pool(&|ig| ig.silent, false) as f64 / unprot_consequential as f64;
    let prot_silent_completions = pool(&|ig| ig.silent_completions, true);
    let unprot_silent_completions = pool(&|ig| ig.silent_completions, false);

    let worst = curve.last().expect("non-empty sweep");
    let first = curve.first().expect("non-empty sweep");
    let clean_goodput = goodput(&clean).max(1e-9);
    // Protection cost with corruption nearly out of the picture: goodput
    // lost at the *lowest* flip rate is almost entirely the checksum +
    // validation overhead charge, not re-execution stalls. The bench
    // checker warns (never gates) when this crosses 5%.
    let checksum_overhead_frac = 1.0 - goodput(&first.prot) / clean_goodput;
    // What protection costs (checksum overhead + re-execution stalls)
    // and what going without costs (corruption losses), both at the top
    // flip rate, both against the clean anchor.
    let prot_goodput_retention = goodput(&worst.prot) / clean_goodput;
    let unprot_goodput_retention = goodput(&worst.unprot) / clean_goodput;
    // Analytic coverage of the default taxonomy mixture — the pooled
    // detection estimate should concentrate near this.
    let expected_coverage = SdcSpec::none().expected_coverage();

    let mut json = Json::obj();
    json.set(
        "tenants",
        Json::Arr(tenants.iter().map(|t| Json::Str(t.name.clone())).collect()),
    )
    .set(
        "fleet",
        Json::Arr(
            base.instances
                .iter()
                .map(|i| Json::Str(i.label()))
                .collect(),
        ),
    )
    .set("capacity_rps_estimate", capacity_rps)
    .set("offered_rps", rps)
    .set("duration_secs", duration_secs)
    .set("seed", base.seed)
    .set("clean_goodput_rps", goodput(&clean))
    .set("clean_p99_ms", clean.p99_ms())
    .set("expected_coverage", expected_coverage)
    .set("detection_rate", detection_rate)
    .set("escape_rate_protected", prot_escape)
    .set("escape_rate_unprotected", unprot_escape)
    .set("silent_completions_protected", prot_silent_completions)
    .set("silent_completions_unprotected", unprot_silent_completions)
    .set("protected_goodput_retention", prot_goodput_retention)
    .set("unprotected_goodput_retention", unprot_goodput_retention)
    .set("checksum_overhead_frac", checksum_overhead_frac)
    .set("curve", Json::Arr(curve.iter().map(point_json).collect()));

    let rows: Vec<(String, Vec<(String, f64)>)> = curve
        .iter()
        .map(|p| {
            (
                format!("flip {:>6.0}/s", p.flip_per_sec),
                vec![
                    ("raw_rps".to_string(), goodput(&p.unprot)),
                    ("raw_escape".to_string(), integ(&p.unprot).escape_rate),
                    (
                        "raw_bad_answers".to_string(),
                        integ(&p.unprot).silent_completions as f64,
                    ),
                    ("abft_rps".to_string(), goodput(&p.prot)),
                    ("abft_detect".to_string(), integ(&p.prot).detection_rate),
                    (
                        "abft_bad_answers".to_string(),
                        integ(&p.prot).silent_completions as f64,
                    ),
                ],
            )
        })
        .collect();
    let text = format!(
        "Data-integrity curve — {} tenants on {} instances, offered {:.0} rps ({:.0}% of capacity)\n\
         clean anchor {:.0} rps; protection = ABFT checksums + CVF validation + weight scrub + {} re-exec/batch\n{}\n\
         pooled: detection {:.3} (coverage {:.3}), escape protected {:.4} vs raw {:.4}, goodput retention protected {:.3} vs raw {:.3}\n",
        tenants.len(),
        base.instances.len(),
        rps,
        LOAD_FRAC * 100.0,
        goodput(&clean),
        SdcSpec::none().reexec_budget,
        ascii_table(&rows),
        detection_rate,
        expected_coverage,
        prot_escape,
        unprot_escape,
        prot_goodput_retention,
        unprot_goodput_retention,
    );

    // Machine-readable trajectory next to the bench outputs.
    let mut derived = Json::obj();
    derived
        .set("offered_rps", rps)
        .set("clean_goodput_rps", goodput(&clean))
        .set("detection_rate", detection_rate)
        .set("escape_rate_protected", prot_escape)
        .set("escape_rate_unprotected", unprot_escape)
        .set(
            "silent_completions_unprotected",
            unprot_silent_completions,
        )
        .set("silent_completions_protected", prot_silent_completions)
        .set("protected_goodput_retention", prot_goodput_retention)
        .set("unprotected_goodput_retention", unprot_goodput_retention)
        .set("checksum_overhead_frac", checksum_overhead_frac);
    let bench_path = "BENCH_serve_sdc.json";
    if let Err(e) = crate::util::bench::write_results(bench_path, &[], derived) {
        crate::log_warn!("could not write {bench_path}: {e}");
    }

    Ok(ExpOutput {
        id: "serve_sdc".to_string(),
        json,
        text,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrity_curve_detects_ninety_percent_and_bounds_escapes() {
        let ctx = ExpContext {
            res: 32,
            ..Default::default()
        };
        let out = run_serve_sdc(&ctx).unwrap();
        assert_eq!(out.id, "serve_sdc");
        let curve = out.json.get("curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), EXPECTED_FLIPS.len());

        // Acceptance bar (ISSUE 10): the protected fleet detects >= 90%
        // of consequential injected flips, pooled across the sweep.
        let detection = out.json.get("detection_rate").unwrap().as_f64().unwrap();
        assert!(detection >= 0.9, "detection rate {detection} < 0.9");

        // Checksums narrow the escape channel and keep wrong answers off
        // the wire relative to the raw arm.
        let esc_p = out
            .json
            .get("escape_rate_protected")
            .unwrap()
            .as_f64()
            .unwrap();
        let esc_u = out
            .json
            .get("escape_rate_unprotected")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(esc_p < esc_u, "protected escape {esc_p} !< raw {esc_u}");
        let bad_p = out
            .json
            .get("silent_completions_protected")
            .unwrap()
            .as_f64()
            .unwrap();
        let bad_u = out
            .json
            .get("silent_completions_unprotected")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(bad_u > 0.0, "raw arm must ship wrong answers");
        assert!(bad_p < bad_u, "protected bad {bad_p} !< raw {bad_u}");

        // The fleet still serves under corruption: goodput never hits
        // zero, on either arm, at any flip rate.
        for p in curve {
            for arm in ["unprotected", "protected"] {
                let g = p
                    .get(arm)
                    .unwrap()
                    .get("goodput_rps")
                    .unwrap()
                    .as_f64()
                    .unwrap();
                assert!(g > 0.0, "{arm} goodput collapsed at {:?}", p.get("flip_per_sec"));
            }
        }
        // Text renders the table and the pooled summary line.
        assert!(out.text.contains("abft_detect"));
        assert!(out.text.contains("pooled: detection"));
    }

    #[test]
    fn curve_is_deterministic_for_the_same_seed() {
        let ctx = ExpContext {
            res: 32,
            ..Default::default()
        };
        let a = run_serve_sdc(&ctx).unwrap();
        let b = run_serve_sdc(&ctx).unwrap();
        assert_eq!(a.json.pretty(), b.json.pretty());
    }
}
