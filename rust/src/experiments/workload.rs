//! Shared workload preparation: the vector-pruned synthetic VGG-16 and its
//! synthetic input batch, plus the cached coordinator runs the figure
//! experiments slice in different ways.

use super::ExpContext;
use crate::coordinator::{Coordinator, FunctionalBackend, NetworkReport, RunOptions};
use crate::model::init::{synthetic_batch, synthetic_params};
use crate::model::vgg16::vgg16_at;
use crate::pruning;
use crate::pruning::sensitivity::paper_schedule;
use crate::runtime::Runtime;
use crate::sim::config::SimConfig;
use anyhow::Result;
use std::sync::Arc;

/// Build the paper's workload: VGG-16 at `ctx.res`, He-init weights vector-
/// pruned (Mao kernel-row granularity) to the 23.5% schedule, activations
/// calibrated to the published VGG density profile (DESIGN.md §6), and
/// `ctx.images` synthetic inputs.
pub fn prepare(ctx: &ExpContext) -> (Coordinator, Vec<crate::tensor::Tensor>, f64) {
    let net = vgg16_at(ctx.res);
    let mut params = synthetic_params(&net, ctx.seed, 0.0);
    let schedule = paper_schedule(&net);
    let achieved = pruning::prune_network_vectors(&mut params, &schedule);
    // Calibrate on a held-out image (not in the measurement batch):
    // density_scale 1.0 at the default bias_shift; the bias-shift knob
    // scales the whole activation-density profile for ablations.
    let cal_img = crate::model::init::synthetic_image(net.input_shape, ctx.seed ^ 0xCA11);
    let density_scale = (1.0 + ctx.bias_shift as f64).clamp(0.1, 2.0);
    crate::model::calibrate::calibrate_activations(
        &net,
        &mut params,
        &cal_img,
        density_scale,
        ctx.threads,
    );
    let images = synthetic_batch(net.input_shape, ctx.images, ctx.seed ^ 0xDEAD);
    (Coordinator::new(net, params), images, achieved)
}

/// Run options for a PE configuration under this context.
pub fn options(ctx: &ExpContext, sim: SimConfig) -> Result<RunOptions> {
    let backend = match &ctx.artifacts_dir {
        Some(dir) => {
            let rt = Arc::new(Runtime::new(dir)?);
            FunctionalBackend::Pjrt(rt, "ref".to_string())
        }
        None => FunctionalBackend::Im2colMt(ctx.threads),
    };
    // The context's thread budget also drives the simulation engine
    // (parallel functional dataflow + group-timing fan-out).
    let mut sim = sim;
    sim.threads = ctx.threads;
    Ok(RunOptions {
        sim,
        backend,
        verify_dataflow: false,
    })
}

/// Run the workload on one configuration, one report per image.
///
/// Results are memoized per (context, config) within the process —
/// `exp all` runs the same two configurations for several figures, and the
/// functional forward dominates the cost (EXPERIMENTS.md §Perf).
pub fn run_config(ctx: &ExpContext, sim: SimConfig) -> Result<Vec<NetworkReport>> {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<HashMap<String, Vec<NetworkReport>>>> = OnceLock::new();

    let key = format!(
        "res{} seed{} img{} shift{} {} pjrt:{}",
        ctx.res,
        ctx.seed,
        ctx.images,
        ctx.bias_shift,
        sim.pe.label(),
        ctx.artifacts_dir.as_deref().unwrap_or("-"),
    );
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(hit) = cache.lock().unwrap().get(&key) {
        return Ok(hit.clone());
    }
    let (coord, images, _) = prepare(ctx);
    let opts = options(ctx, sim)?;
    let reports = coord.run_batch(&images, &opts)?;
    cache.lock().unwrap().insert(key, reports.clone());
    Ok(reports)
}

/// Run the workload on several configurations concurrently, one scoped
/// worker per configuration (each lands in the memoization cache, so later
/// single-config calls are free). Results come back in `sims` order and are
/// identical to sequential [`run_config`] calls — the multi-config Table-I
/// runs and `exp all` fan out across cores through this.
pub fn run_configs(ctx: &ExpContext, sims: &[SimConfig]) -> Result<Vec<Vec<NetworkReport>>> {
    // Split the context's thread budget across the config workers so the
    // nested per-config parallelism (batch fan-out, simulator, backend)
    // stays within it — `--threads 1` runs the configs sequentially.
    // Thread counts never change results, so the memoized reports stay
    // valid for later full-budget callers.
    let workers = sims.len().min(ctx.threads.max(1));
    let mut inner = ctx.clone();
    inner.threads = (ctx.threads / workers.max(1)).max(1);
    let inner = &inner;
    let chunks: Result<Vec<Vec<Vec<NetworkReport>>>> =
        crate::util::par_chunk_map(sims.len(), workers, |range| {
            sims[range].iter().map(|s| run_config(inner, *s)).collect()
        })
        .into_iter()
        .collect();
    Ok(chunks?.into_iter().flatten().collect())
}

/// Average a per-layer metric across image reports.
pub fn avg_layer_metric(
    reports: &[NetworkReport],
    f: impl Fn(&crate::coordinator::LayerRecord) -> f64,
) -> Vec<(String, f64)> {
    let n = reports.len().max(1) as f64;
    let layers = reports[0].layers.len();
    (0..layers)
        .map(|i| {
            let name = reports[0].layers[i].name.clone();
            let sum: f64 = reports.iter().map(|r| f(&r.layers[i])).sum();
            (name, sum / n)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx() -> ExpContext {
        ExpContext {
            res: 32,
            images: 1,
            ..Default::default()
        }
    }

    #[test]
    fn prepare_prunes_to_paper_density() {
        let (coord, images, achieved) = prepare(&tiny_ctx());
        assert_eq!(coord.net.conv_layer_names().len(), 13);
        assert_eq!(images.len(), 1);
        // Vector pruning of dense-start weights lands on the schedule
        // (±2%: rounding per layer).
        assert!(
            (achieved - 0.235).abs() < 0.02,
            "achieved density {achieved}"
        );
    }

    #[test]
    fn run_config_produces_13_layer_reports() {
        let reports = run_config(&tiny_ctx(), SimConfig::paper_8_7_3()).unwrap();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].layers.len(), 13);
        let speedup = reports[0].overall_speedup();
        assert!(speedup > 1.0, "speedup {speedup}");
    }

    #[test]
    fn run_configs_matches_run_config_per_entry() {
        let ctx = tiny_ctx();
        let sims = [SimConfig::paper_4_14_3(), SimConfig::paper_8_7_3()];
        let both = run_configs(&ctx, &sims).unwrap();
        assert_eq!(both.len(), 2);
        for (sim, reports) in sims.iter().zip(&both) {
            let solo = run_config(&ctx, *sim).unwrap();
            assert_eq!(solo.len(), reports.len());
            for (a, b) in solo.iter().zip(reports) {
                assert_eq!(a.totals.cycles, b.totals.cycles);
                assert_eq!(a.config_label, b.config_label);
            }
        }
    }

    #[test]
    fn avg_layer_metric_averages() {
        let reports = run_config(&tiny_ctx(), SimConfig::paper_8_7_3()).unwrap();
        let rows = avg_layer_metric(&reports, |l| l.speedups.ours);
        assert_eq!(rows.len(), 13);
        assert_eq!(rows[0].0, "conv1_1");
    }
}
