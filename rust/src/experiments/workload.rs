//! Shared workload preparation: compile the (pruned, calibrated) synthetic
//! workload network exactly once per `(net, seed, res, shift)` and run the
//! figure experiments against the shared [`PreparedNetwork`].
//!
//! The compile cache is the primary memoizer — pruning, calibration and
//! CVF weight encoding never repeat, no matter how many images or PE
//! configurations a run sweeps (`exp all` runs both paper configs off one
//! compile). A small derived cache additionally keeps finished report
//! vectors per `(context, config)` so figures that replay the same
//! configuration don't re-execute the batch.

use super::ExpContext;
use crate::coordinator::{Coordinator, FunctionalBackend, NetworkReport, RunOptions};
use crate::engine::{compile, Calibration, CompileOptions, Engine, PreparedNetwork, PAPER_COLS};
use crate::model::init::{synthetic_batch, synthetic_params};
use crate::pruning::sensitivity::paper_schedule;
use crate::runtime::Runtime;
use crate::sim::config::SimConfig;
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Compile the paper's workload once per `(net, seed, res, shift)`: the zoo
/// network at `ctx.res`, He-init weights vector-pruned (Mao kernel-row
/// granularity) to the 23.5% schedule, activations calibrated to the
/// published VGG density profile (DESIGN.md §6) on a held-out image.
/// Returns the shared prepared network (weight encoding, kernel mapping
/// and weight-side stats all done).
pub fn prepared(ctx: &ExpContext) -> Result<Arc<PreparedNetwork>> {
    // Two-level cache: a short-lived map lock hands out one slot per key,
    // and the compile runs under the *slot's* lock only — concurrent
    // callers of the same key still share exactly one compile, while
    // different keys (e.g. the serve mix's three networks, profiled
    // tenant-parallel since ISSUE 5) compile concurrently instead of
    // serializing on the map.
    type Slot = Arc<Mutex<Option<Arc<PreparedNetwork>>>>;
    static CACHE: OnceLock<Mutex<HashMap<String, Slot>>> = OnceLock::new();
    let key = format!(
        "{} res{} seed{} shift{} prec:{}",
        ctx.net,
        ctx.res,
        ctx.seed,
        ctx.bias_shift,
        ctx.precision.label()
    );
    let slot: Slot = {
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = cache.lock().unwrap();
        map.entry(key).or_default().clone()
    };
    let mut slot = slot.lock().unwrap();
    if let Some(hit) = &*slot {
        return Ok(hit.clone());
    }

    let net = crate::model::zoo::by_name(&ctx.net, ctx.res)?;
    let params = synthetic_params(&net, ctx.seed, 0.0);
    // Calibrate on a held-out image (not in the measurement batch):
    // density_scale 1.0 at the default bias_shift; the bias-shift knob
    // scales the whole activation-density profile for ablations.
    let cal_img = crate::model::init::synthetic_image(net.input_shape, ctx.seed ^ 0xCA11);
    let density_scale = (1.0 + ctx.bias_shift as f64).clamp(0.1, 2.0);
    let opts = CompileOptions {
        cols: PAPER_COLS,
        prune: Some(paper_schedule(&net)),
        calibration: Some(Calibration {
            image: cal_img,
            density_scale,
            threads: ctx.threads,
        }),
        precision: ctx.precision,
    };
    let p = Arc::new(compile(&net, params, &opts));
    *slot = Some(p.clone());
    Ok(p)
}

/// The measurement batch for a context (the calibration image is held out).
pub fn images(ctx: &ExpContext, input_shape: [usize; 3]) -> Vec<Tensor> {
    synthetic_batch(input_shape, ctx.images, ctx.seed ^ 0xDEAD)
}

/// Compatibility wrapper for the pre-split API: `(coordinator, batch,
/// achieved weight density)`. The coordinator shares the memoized compile.
pub fn prepare(ctx: &ExpContext) -> Result<(Coordinator, Vec<Tensor>, f64)> {
    let p = prepared(ctx)?;
    let imgs = images(ctx, p.net.input_shape);
    let achieved = p.weight_density;
    Ok((Coordinator::from_prepared(p), imgs, achieved))
}

/// Run options for a PE configuration under this context.
pub fn options(ctx: &ExpContext, sim: SimConfig) -> Result<RunOptions> {
    let backend = match &ctx.artifacts_dir {
        Some(dir) => {
            let rt = Arc::new(Runtime::new(dir)?);
            FunctionalBackend::Pjrt(rt, "ref".to_string())
        }
        None => FunctionalBackend::Im2colMt(ctx.threads),
    };
    // The context's thread budget also drives the simulation engine
    // (parallel functional dataflow + group-timing fan-out), and the
    // context's memory model wins over whatever the config carried
    // (the CLI's `--mem-model` flag flows in through the context).
    // The precision axis rides the same channel: `--precision` retunes the
    // config's storage width (memory floors scale with the payload bytes)
    // and `--fuse` turns on conv→conv strip residency in the engine.
    let mut sim = sim;
    sim.threads = ctx.threads;
    sim.mem_model = ctx.mem_model;
    let sim = sim.with_precision(ctx.precision);
    Ok(RunOptions {
        sim,
        backend,
        verify_dataflow: false,
        fuse: ctx.fuse,
        sdc: None,
    })
}

/// Run the workload on one configuration, one report per image.
///
/// Compilation is shared through [`prepared`]; finished report vectors are
/// additionally memoized per (context, config) within the process — `exp
/// all` replays the same two configurations for several figures, and the
/// functional forward dominates the cost (EXPERIMENTS.md §Perf).
pub fn run_config(ctx: &ExpContext, sim: SimConfig) -> Result<Vec<NetworkReport>> {
    static CACHE: OnceLock<Mutex<HashMap<String, Vec<NetworkReport>>>> = OnceLock::new();

    let key = format!(
        "{} res{} seed{} img{} shift{} {} mem:{} prec:{} fuse:{} pjrt:{}",
        ctx.net,
        ctx.res,
        ctx.seed,
        ctx.images,
        ctx.bias_shift,
        sim.pe.label(),
        ctx.mem_model.label(),
        ctx.precision.label(),
        ctx.fuse,
        ctx.artifacts_dir.as_deref().unwrap_or("-"),
    );
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(hit) = cache.lock().unwrap().get(&key) {
        return Ok(hit.clone());
    }
    let p = prepared(ctx)?;
    // Non-paper column counts (custom `--config B,R,C` sweeps) rebuild the
    // cheap mapping plans; the weight encodes and stats stay shared.
    let p = if sim.pe.cols == p.cols {
        p
    } else {
        Arc::new(p.recompiled(sim.pe.cols))
    };
    let batch = images(ctx, p.net.input_shape);
    let opts = options(ctx, sim)?;
    let reports = Engine::new(p).run_batch(&batch, &opts)?;
    cache.lock().unwrap().insert(key, reports.clone());
    Ok(reports)
}

/// Run the workload on several configurations concurrently, one scoped
/// worker per configuration — all sharing one compiled network (the compile
/// happens up front, outside the fan-out). Results come back in `sims`
/// order and are identical to sequential [`run_config`] calls.
pub fn run_configs(ctx: &ExpContext, sims: &[SimConfig]) -> Result<Vec<Vec<NetworkReport>>> {
    // Compile once before fanning out so the workers race on execution
    // only, never on the (expensive) compile.
    let _ = prepared(ctx)?;
    // Split the context's thread budget across the config workers so the
    // nested per-config parallelism (batch fan-out, simulator, backend)
    // stays within it — `--threads 1` runs the configs sequentially.
    // Thread counts never change results, so the memoized reports stay
    // valid for later full-budget callers.
    let workers = sims.len().min(ctx.threads.max(1));
    let mut inner = ctx.clone();
    inner.threads = (ctx.threads / workers.max(1)).max(1);
    let inner = &inner;
    let chunks: Result<Vec<Vec<Vec<NetworkReport>>>> =
        crate::util::par_chunk_map(sims.len(), workers, |range| {
            sims[range].iter().map(|s| run_config(inner, *s)).collect()
        })
        .into_iter()
        .collect();
    Ok(chunks?.into_iter().flatten().collect())
}

/// Average a per-layer metric across image reports.
pub fn avg_layer_metric(
    reports: &[NetworkReport],
    f: impl Fn(&crate::coordinator::LayerRecord) -> f64,
) -> Vec<(String, f64)> {
    let n = reports.len().max(1) as f64;
    let layers = reports[0].layers.len();
    (0..layers)
        .map(|i| {
            let name = reports[0].layers[i].name.clone();
            let sum: f64 = reports.iter().map(|r| f(&r.layers[i])).sum();
            (name, sum / n)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx() -> ExpContext {
        ExpContext {
            res: 32,
            images: 1,
            ..Default::default()
        }
    }

    #[test]
    fn prepare_prunes_to_paper_density() {
        let (coord, imgs, achieved) = prepare(&tiny_ctx()).unwrap();
        assert_eq!(coord.net.conv_layer_names().len(), 13);
        assert_eq!(imgs.len(), 1);
        // Vector pruning of dense-start weights lands on the schedule
        // (±2%: rounding per layer).
        assert!(
            (achieved - 0.235).abs() < 0.02,
            "achieved density {achieved}"
        );
    }

    #[test]
    fn prepared_is_compiled_once_and_shared() {
        let ctx = tiny_ctx();
        let a = prepared(&ctx).unwrap();
        let b = prepared(&ctx).unwrap();
        // Same Arc: the compile ran once for this (net, seed, res, shift).
        assert!(Arc::ptr_eq(&a, &b));
        // A different image count shares the same compile...
        let more = ExpContext {
            images: 3,
            ..tiny_ctx()
        };
        assert!(Arc::ptr_eq(&a, &prepared(&more).unwrap()));
        // ...a different seed does not.
        let other = ExpContext {
            seed: 7,
            ..tiny_ctx()
        };
        assert!(!Arc::ptr_eq(&a, &prepared(&other).unwrap()));
    }

    #[test]
    fn run_config_produces_13_layer_reports() {
        let reports = run_config(&tiny_ctx(), SimConfig::paper_8_7_3()).unwrap();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].layers.len(), 13);
        let speedup = reports[0].overall_speedup();
        assert!(speedup > 1.0, "speedup {speedup}");
    }

    #[test]
    fn run_configs_matches_run_config_per_entry() {
        let ctx = tiny_ctx();
        let sims = [SimConfig::paper_4_14_3(), SimConfig::paper_8_7_3()];
        let both = run_configs(&ctx, &sims).unwrap();
        assert_eq!(both.len(), 2);
        for (sim, reports) in sims.iter().zip(&both) {
            let solo = run_config(&ctx, *sim).unwrap();
            assert_eq!(solo.len(), reports.len());
            for (a, b) in solo.iter().zip(reports) {
                assert_eq!(a.totals.cycles, b.totals.cycles);
                assert_eq!(a.config_label, b.config_label);
            }
        }
    }

    #[test]
    fn zoo_workloads_run_through_the_engine() {
        // The non-VGG zoo entries flow through the same prepare →
        // compile → execute path (mapped kernels and strided convs
        // included).
        for net in ["alexnet", "resnet10"] {
            let ctx = ExpContext {
                net: net.to_string(),
                ..tiny_ctx()
            };
            let reports = run_config(&ctx, SimConfig::paper_8_7_3()).unwrap();
            assert_eq!(reports.len(), 1, "{net}");
            let expect = if net == "alexnet" { 5 } else { 9 };
            assert_eq!(reports[0].layers.len(), expect, "{net}");
            assert!(reports[0].overall_speedup() >= 1.0, "{net}");
        }
    }

    #[test]
    fn mem_model_flows_from_context_and_caches_separately() {
        let ctx_t = tiny_ctx();
        let mut ctx_i = tiny_ctx();
        ctx_i.mem_model = crate::sim::config::MemModel::Ideal;
        let tiled = run_config(&ctx_t, SimConfig::paper_8_7_3()).unwrap();
        let ideal = run_config(&ctx_i, SimConfig::paper_8_7_3()).unwrap();
        assert_eq!(tiled[0].mem_model.label(), "tiled");
        assert_eq!(ideal[0].mem_model.label(), "ideal");
        // The memory floor only adds cycles, and only the tiled run
        // reports transfer time.
        assert!(tiled[0].totals.cycles >= ideal[0].totals.cycles);
        assert_eq!(ideal[0].totals.transfer_cycles, 0);
        assert!(tiled[0].totals.transfer_cycles > 0);
    }

    #[test]
    fn avg_layer_metric_averages() {
        let reports = run_config(&tiny_ctx(), SimConfig::paper_8_7_3()).unwrap();
        let rows = avg_layer_metric(&reports, |l| l.speedups.ours);
        assert_eq!(rows.len(), 13);
        assert_eq!(rows[0].0, "conv1_1");
    }
}
