//! `serve-scale`: simulator scalability sweep — the same bursty serving
//! scenario at fleet sizes 10 → 10k, measuring simulated tail latency
//! *and* simulator wall-clock rate (events processed per second).
//!
//! This is the acceptance experiment for the ISSUE 7 scaling work: the
//! calendar event queue and hierarchical dispatch exist so that a
//! 10k-instance fleet simulates at interactive speed. Offered load and
//! the horizon both scale with the fleet (fixed load fraction, fixed
//! arrivals per instance), so a scale-free simulator shows a flat
//! events-per-second curve; an O(n)-per-event one collapses at the top.
//!
//! Each point serves the default multi-tenant mix under MMPP flash-crowd
//! traffic on a racked topology (64 instances per rack) with
//! hierarchical dispatch. The emitted curve goes to
//! `reports/serve_scale.json` and the wall-clock rates to
//! `BENCH_serve_scale.json` for regression tracking.

use super::{ExpContext, ExpOutput};
use crate::coordinator::report::ascii_table;
use crate::serve::{
    build_profiles, default_fleet, default_mix, simulate, BatchPolicy, DispatchPolicy, FaultSpec,
    RobustnessPolicy, ServeReport, ServeSpec, TrafficModel,
};
use crate::util::json::Json;
use anyhow::Result;

/// Fleet sizes swept (clipped by `--max-fleet`).
const FLEET_SIZES: [usize; 4] = [10, 100, 1_000, 10_000];

/// Instances per rack; rack count grows with the fleet.
const RACK_SIZE: usize = 64;

/// Offered load as a fraction of the estimated warm-batch capacity —
/// busy but stable, so queues exercise dispatch without diverging.
const LOAD_FRAC: f64 = 0.6;

/// Expected arrivals per instance: fixes work-per-instance, so the
/// horizon (and ideal wall time) is the same at every fleet size.
const ARRIVALS_PER_INSTANCE: f64 = 30.0;

struct ScalePoint {
    fleet: usize,
    racks: usize,
    offered_rps: f64,
    report: ServeReport,
    offered: u64,
    events_processed: u64,
    events_per_sec: f64,
}

fn point_json(p: &ScalePoint) -> Json {
    let mut o = Json::obj();
    o.set("fleet", p.fleet)
        .set("racks", p.racks)
        .set("offered_rps", p.offered_rps)
        .set("offered", p.offered)
        .set("completed", p.report.completed)
        .set("rejected", p.report.rejected)
        .set("throughput_rps", p.report.throughput_rps())
        .set("p99_ms", p.report.p99_ms())
        .set("events_processed", p.events_processed)
        .set("events_per_sec", p.events_per_sec);
    o
}

/// Run the `serve-scale` experiment (see module docs).
pub fn run_serve_scale(ctx: &ExpContext) -> Result<ExpOutput> {
    let tenants = default_mix(ctx.res);
    // Profile the four cyclic fleet templates once; `default_fleet(n)`
    // repeats them, and `ServiceProfile` is `Copy`, so every sweep size
    // tiles the same profiles instead of re-touching the engine.
    let probe = ServeSpec {
        tenants: tenants.clone(),
        instances: default_fleet(4),
        traffic: TrafficModel::OpenLoop { rps: 1.0 },
        policy: DispatchPolicy::Hierarchical,
        batch: BatchPolicy::none(),
        queue_cap: 32,
        racks: 1,
        duration_cycles: 1,
        clock_mhz: 500.0,
        seed: ctx.seed,
        faults: FaultSpec::none(),
        robust: RobustnessPolicy::none(),
        sdc: crate::sim::sdc::SdcSpec::none(),
    };
    let base_profiles = build_profiles(&probe, ctx.threads)?;

    // Mix-weighted per-instance capacity, averaged over the cyclic
    // templates (same arithmetic as the `serve` experiment).
    let wsum: f64 = tenants.iter().map(|t| t.weight).sum();
    let mut capacity_per_instance = 0.0;
    for i in 0..probe.instances.len() {
        let mean_marginal: f64 = tenants
            .iter()
            .enumerate()
            .map(|(t, ten)| ten.weight / wsum * base_profiles[t][i].marginal_cycles as f64)
            .sum();
        capacity_per_instance += probe.clock_hz() / mean_marginal.max(1.0);
    }
    capacity_per_instance /= probe.instances.len() as f64;
    let mut mean_single = 0.0;
    for (t, ten) in tenants.iter().enumerate() {
        let avg: f64 = base_profiles[t]
            .iter()
            .map(|p| p.single_cycles as f64)
            .sum::<f64>()
            / base_profiles[t].len() as f64;
        mean_single += ten.weight / wsum * avg;
    }
    let max_wait_cycles = ((mean_single / 2.0) as u64).max(1);

    let mut sizes: Vec<usize> = FLEET_SIZES
        .iter()
        .copied()
        .filter(|&n| ctx.max_fleet.is_none_or(|m| n <= m))
        .collect();
    if sizes.is_empty() {
        // --max-fleet below the smallest grid point: sweep just that size
        // so the experiment still emits a (one-point) curve.
        sizes.push(ctx.max_fleet.unwrap_or(FLEET_SIZES[0]).max(1));
    }

    let mut curve: Vec<ScalePoint> = Vec::new();
    for &n in &sizes {
        let racks = n.div_ceil(RACK_SIZE).min(n).max(1);
        let rps = capacity_per_instance * n as f64 * LOAD_FRAC;
        let duration_cycles =
            ((ARRIVALS_PER_INSTANCE * n as f64 / rps * probe.clock_hz()).ceil() as u64).max(1);
        // Flash-crowd MMPP: 3x bursts, ~1 ms high dwell / ~10 ms low, so
        // every point sees several burst episodes inside its horizon.
        let clock_hz = probe.clock_hz();
        let spec = ServeSpec {
            tenants: tenants.clone(),
            instances: default_fleet(n),
            traffic: TrafficModel::Mmpp {
                rps,
                burst_x: 3.0,
                mean_high_cycles: (1e-3 * clock_hz) as u64,
                mean_low_cycles: (10e-3 * clock_hz) as u64,
            },
            policy: DispatchPolicy::Hierarchical,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait_cycles,
            },
            queue_cap: 32,
            racks,
            duration_cycles,
            clock_mhz: probe.clock_mhz,
            seed: ctx.seed,
            faults: FaultSpec::none(),
            robust: RobustnessPolicy::none(),
            sdc: crate::sim::sdc::SdcSpec::none(),
        };
        let profiles: Vec<Vec<_>> = (0..tenants.len())
            .map(|t| (0..n).map(|i| base_profiles[t][i % 4]).collect())
            .collect();
        let t0 = std::time::Instant::now();
        let out = simulate(&spec, &profiles);
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let events_per_sec = out.events_processed as f64 / wall;
        curve.push(ScalePoint {
            fleet: n,
            racks,
            offered_rps: rps,
            offered: out.offered,
            events_processed: out.events_processed,
            events_per_sec,
            report: ServeReport::new(&spec, &out),
        });
    }

    // Acceptance: the largest fleet must simulate within ~2x of the
    // smallest fleet's events-per-second rate — the curve is flat-ish,
    // i.e. per-event cost does not grow with the fleet.
    let eps_first = curve.first().map(|p| p.events_per_sec).unwrap_or(0.0);
    let eps_last = curve.last().map(|p| p.events_per_sec).unwrap_or(0.0);
    let within_2x = eps_last >= eps_first / 2.0;

    let mut json = Json::obj();
    json.set(
        "tenants",
        Json::Arr(
            tenants
                .iter()
                .map(|t| Json::Str(t.name.clone()))
                .collect(),
        ),
    )
    .set("rack_size", RACK_SIZE)
    .set("load_frac", LOAD_FRAC)
    .set("arrivals_per_instance", ARRIVALS_PER_INSTANCE)
    .set("capacity_rps_per_instance", capacity_per_instance)
    .set("seed", probe.seed)
    .set("events_per_sec_small", eps_first)
    .set("events_per_sec_large", eps_last)
    .set("within_2x", within_2x)
    .set(
        "curve",
        Json::Arr(curve.iter().map(point_json).collect()),
    );

    let rows: Vec<(String, Vec<(String, f64)>)> = curve
        .iter()
        .map(|p| {
            (
                format!("{} x{}", p.fleet, p.racks),
                vec![
                    ("offered_rps".to_string(), p.offered_rps),
                    ("throughput_rps".to_string(), p.report.throughput_rps()),
                    ("p99_ms".to_string(), p.report.p99_ms()),
                    ("events".to_string(), p.events_processed as f64),
                    ("events_per_sec".to_string(), p.events_per_sec),
                ],
            )
        })
        .collect();
    let text = format!(
        "Serving scalability sweep — fleet x racks, MMPP 3x bursts at {:.0}% of capacity\n\
         hierarchical dispatch, {} instances per rack, {} arrivals per instance\n{}\n\
         events/sec: {:.0} (smallest fleet) -> {:.0} (largest) — {}\n",
        LOAD_FRAC * 100.0,
        RACK_SIZE,
        ARRIVALS_PER_INSTANCE,
        ascii_table(&rows),
        eps_first,
        eps_last,
        if within_2x {
            "within 2x, scale-free"
        } else {
            "SLOWER THAN 2x of the small-fleet rate"
        },
    );

    // Wall-clock rates are machine-dependent, so they live in the bench
    // sidecar (compared with a tolerance by check_bench_regression.py),
    // not in the pinned report body.
    let mut derived = Json::obj();
    for p in &curve {
        derived.set(
            &format!("fleet{}_events_per_sec", p.fleet),
            p.events_per_sec,
        );
    }
    derived
        .set("events_per_sec_large", eps_last)
        .set("within_2x", within_2x);
    let bench_path = "BENCH_serve_scale.json";
    if let Err(e) = crate::util::bench::write_results(bench_path, &[], derived) {
        crate::log_warn!("could not write {bench_path}: {e}");
    }

    Ok(ExpOutput {
        id: "serve_scale".to_string(),
        json,
        text,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_sweep_respects_max_fleet_and_reports_rates() {
        let ctx = ExpContext {
            res: 32,
            max_fleet: Some(100),
            ..Default::default()
        };
        let out = run_serve_scale(&ctx).unwrap();
        assert_eq!(out.id, "serve_scale");
        let curve = out.json.get("curve").unwrap().as_arr().unwrap();
        // --max-fleet 100 clips the grid to {10, 100}.
        assert_eq!(curve.len(), 2);
        for p in curve {
            let fleet = p.get("fleet").unwrap().as_f64().unwrap() as usize;
            assert!(fleet == 10 || fleet == 100);
            assert!(p.get("events_per_sec").unwrap().as_f64().unwrap() > 0.0);
            let offered = p.get("offered").unwrap().as_f64().unwrap();
            let completed = p.get("completed").unwrap().as_f64().unwrap();
            assert!(offered > 0.0, "no arrivals at fleet {fleet}");
            assert!(
                completed > 0.6 * offered,
                "fleet {fleet}: {completed} of {offered} completed at 60% load"
            );
        }
        // Fleet sizes ascend and offered load scales with them.
        let rps: Vec<f64> = curve
            .iter()
            .map(|p| p.get("offered_rps").unwrap().as_f64().unwrap())
            .collect();
        assert!(rps[0] < rps[1]);
        assert!(out.text.contains("events_per_sec"));
    }

    #[test]
    fn tiny_max_fleet_still_produces_a_point() {
        let ctx = ExpContext {
            res: 32,
            max_fleet: Some(4),
            ..Default::default()
        };
        let out = run_serve_scale(&ctx).unwrap();
        let curve = out.json.get("curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), 1);
        assert_eq!(curve[0].get("fleet").unwrap().as_f64().unwrap(), 4.0);
    }
}
