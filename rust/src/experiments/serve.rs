//! `serve`: the serving capacity-curve experiment — offered load vs p99
//! latency for a heterogeneous VSCNN fleet, with and without the serving
//! optimizations.
//!
//! Two configurations sweep the same offered-load grid over the same
//! profiled fleet:
//!
//! * **naive** — round-robin dispatch, no batching: every batch is one
//!   request and most launches pay the network-switch weight reload.
//! * **tuned** — network-affinity sharding + dynamic batching: instances
//!   mostly re-serve their resident network, so the weight-side CVF
//!   stream is amortized across batches.
//!
//! The emitted curve (`reports/serve.json` + `BENCH_serve.json`) shows
//! where queueing sets in, where batching starts to win, and where the
//! memory-bound knee from the tiled timing model (PR 3) appears — see
//! EXPERIMENTS.md §Serving for a worked reading.

use super::{ExpContext, ExpOutput};
use crate::coordinator::report::ascii_table;
use crate::serve::{
    build_profiles, default_fleet, default_mix, simulate, BatchPolicy, DispatchPolicy, FaultSpec,
    RobustnessPolicy, ServeReport, ServeSpec, TrafficModel,
};
use crate::util::json::Json;
use anyhow::Result;

/// Offered load, as fractions of the estimated warm-batch capacity.
const LOAD_FRACS: [f64; 6] = [0.25, 0.5, 0.75, 1.0, 1.25, 1.5];

/// Expected arrivals per sweep point (sets the horizon per offered rate).
const ARRIVALS_PER_POINT: f64 = 300.0;

/// One sweep point: the same offered load under both configurations.
struct CurvePoint {
    offered_rps: f64,
    naive: ServeReport,
    tuned: ServeReport,
}

fn point_json(p: &CurvePoint) -> Json {
    let side = |r: &ServeReport| {
        let mut o = Json::obj();
        o.set("throughput_rps", r.throughput_rps())
            .set("p50_ms", r.latency.p50 / (r.clock_mhz * 1e3))
            .set("p99_ms", r.p99_ms())
            .set("completed", r.completed)
            .set("rejected", r.rejected)
            .set(
                "mean_utilization",
                if r.instances.is_empty() {
                    0.0
                } else {
                    r.instances.iter().map(|i| i.utilization).sum::<f64>()
                        / r.instances.len() as f64
                },
            );
        o
    };
    let mut o = Json::obj();
    o.set("offered_rps", p.offered_rps)
        .set("naive", side(&p.naive))
        .set("tuned", side(&p.tuned));
    o
}

/// Run the `serve` experiment (see module docs).
pub fn run_serve(ctx: &ExpContext) -> Result<ExpOutput> {
    let tenants = default_mix(ctx.res);
    let instances = default_fleet(4);
    let base = ServeSpec {
        tenants: tenants.clone(),
        instances,
        traffic: TrafficModel::OpenLoop { rps: 1.0 },
        policy: DispatchPolicy::NetworkAffinity,
        batch: BatchPolicy::none(),
        queue_cap: 32,
        racks: 1,
        duration_cycles: 1,
        clock_mhz: 500.0,
        seed: ctx.seed,
        faults: FaultSpec::none(),
        robust: RobustnessPolicy::none(),
        sdc: crate::sim::sdc::SdcSpec::none(),
    };
    let profiles = build_profiles(&base, ctx.threads)?;

    // Mix-weighted service means, for the capacity estimate and the batch
    // wait window.
    let wsum: f64 = tenants.iter().map(|t| t.weight).sum();
    let mut capacity_rps = 0.0;
    for i in 0..base.instances.len() {
        let mean_marginal: f64 = tenants
            .iter()
            .enumerate()
            .map(|(t, ten)| ten.weight / wsum * profiles[t][i].marginal_cycles as f64)
            .sum();
        capacity_rps += base.clock_hz() / mean_marginal.max(1.0);
    }
    let mut mean_single = 0.0;
    for (t, ten) in tenants.iter().enumerate() {
        let avg: f64 = profiles[t]
            .iter()
            .map(|p| p.single_cycles as f64)
            .sum::<f64>()
            / profiles[t].len() as f64;
        mean_single += ten.weight / wsum * avg;
    }
    // Half a service time of slack: enough to coalesce under load, small
    // against the queueing delays it is meant to beat.
    let max_wait_cycles = ((mean_single / 2.0) as u64).max(1);

    let mut curve: Vec<CurvePoint> = Vec::new();
    for frac in LOAD_FRACS {
        let rps = capacity_rps * frac;
        let duration_cycles = (ARRIVALS_PER_POINT * base.clock_hz() / rps).ceil() as u64;

        let mut naive = base.clone();
        naive.traffic = TrafficModel::OpenLoop { rps };
        naive.policy = DispatchPolicy::RoundRobin;
        naive.batch = BatchPolicy::none();
        naive.duration_cycles = duration_cycles;

        let mut tuned = naive.clone();
        tuned.policy = DispatchPolicy::NetworkAffinity;
        tuned.batch = BatchPolicy {
            max_batch: 8,
            max_wait_cycles,
        };

        let naive_report = ServeReport::new(&naive, &simulate(&naive, &profiles));
        let tuned_report = ServeReport::new(&tuned, &simulate(&tuned, &profiles));
        curve.push(CurvePoint {
            offered_rps: rps,
            naive: naive_report,
            tuned: tuned_report,
        });
    }

    // Acceptance metric: at the highest offered load the tuned fleet must
    // strictly beat the naive one on tail latency without losing
    // throughput.
    let high = curve.last().expect("non-empty sweep");
    let wins_at_high_load = high.tuned.throughput_rps() >= high.naive.throughput_rps()
        && high.tuned.p99_ms() < high.naive.p99_ms();

    // Knee: first sweep point where the tuned p99 leaves the flat region
    // (2x the lightest-load p99) — queueing has set in.
    let base_p99 = curve[0].tuned.p99_ms();
    let knee_rps = curve
        .iter()
        .find(|p| p.tuned.p99_ms() > 2.0 * base_p99)
        .map(|p| p.offered_rps);

    let mut json = Json::obj();
    json.set(
        "tenants",
        Json::Arr(
            tenants
                .iter()
                .map(|t| Json::Str(t.name.clone()))
                .collect(),
        ),
    )
    .set(
        "fleet",
        Json::Arr(
            base.instances
                .iter()
                .map(|i| Json::Str(i.label()))
                .collect(),
        ),
    )
    .set("capacity_rps_estimate", capacity_rps)
    .set("max_batch", 8usize)
    .set("max_wait_cycles", max_wait_cycles)
    .set("queue_cap", base.queue_cap)
    .set("seed", base.seed)
    .set("wins_at_high_load", wins_at_high_load)
    .set("knee_rps", knee_rps.map_or(Json::Null, Json::Num))
    .set(
        "curve",
        Json::Arr(curve.iter().map(point_json).collect()),
    );

    let rows: Vec<(String, Vec<(String, f64)>)> = curve
        .iter()
        .map(|p| {
            (
                format!("{:.0} rps", p.offered_rps),
                vec![
                    ("naive_p99_ms".to_string(), p.naive.p99_ms()),
                    ("tuned_p99_ms".to_string(), p.tuned.p99_ms()),
                    ("naive_rps".to_string(), p.naive.throughput_rps()),
                    ("tuned_rps".to_string(), p.tuned.throughput_rps()),
                    ("naive_rej".to_string(), p.naive.rejected as f64),
                    ("tuned_rej".to_string(), p.tuned.rejected as f64),
                ],
            )
        })
        .collect();
    let text = format!(
        "Serving capacity curve — {} tenants on {} instances (est. capacity {:.0} rps)\n\
         naive = round-robin, no batching | tuned = affinity + batch<=8 (wait {} cyc)\n{}\n\
         high load: tuned p99 {:.3} ms vs naive {:.3} ms — affinity+batching {}\n",
        tenants.len(),
        base.instances.len(),
        capacity_rps,
        max_wait_cycles,
        ascii_table(&rows),
        high.tuned.p99_ms(),
        high.naive.p99_ms(),
        if wins_at_high_load { "wins" } else { "DOES NOT WIN" },
    );

    // Machine-readable trajectory next to the bench outputs.
    let mut derived = Json::obj();
    derived
        .set("capacity_rps_estimate", capacity_rps)
        .set("high_load_offered_rps", high.offered_rps)
        .set("high_load_naive_p99_ms", high.naive.p99_ms())
        .set("high_load_tuned_p99_ms", high.tuned.p99_ms())
        .set("high_load_naive_rps", high.naive.throughput_rps())
        .set("high_load_tuned_rps", high.tuned.throughput_rps())
        .set("wins_at_high_load", wins_at_high_load)
        .set("knee_rps", knee_rps.map_or(Json::Null, Json::Num));
    let bench_path = "BENCH_serve.json";
    if let Err(e) = crate::util::bench::write_results(bench_path, &[], derived) {
        crate::log_warn!("could not write {bench_path}: {e}");
    }

    Ok(ExpOutput {
        id: "serve".to_string(),
        json,
        text,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_curve_shows_tuned_winning_at_high_load() {
        let ctx = ExpContext {
            res: 32,
            ..Default::default()
        };
        let out = run_serve(&ctx).unwrap();
        assert_eq!(out.id, "serve");
        let curve = out.json.get("curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), LOAD_FRACS.len());
        // The acceptance bit: affinity + batching strictly beats naive
        // round-robin/no-batching at the top of the curve.
        assert_eq!(
            out.json.get("wins_at_high_load").unwrap().as_bool(),
            Some(true)
        );
        let last = curve.last().unwrap();
        let naive_p99 = last.get("naive").unwrap().get("p99_ms").unwrap().as_f64().unwrap();
        let tuned_p99 = last.get("tuned").unwrap().get("p99_ms").unwrap().as_f64().unwrap();
        assert!(tuned_p99 < naive_p99, "tuned {tuned_p99} !< naive {naive_p99}");
        // Load points are increasing and positive.
        let rps: Vec<f64> = curve
            .iter()
            .map(|p| p.get("offered_rps").unwrap().as_f64().unwrap())
            .collect();
        assert!(rps.windows(2).all(|w| w[0] < w[1]));
        assert!(rps[0] > 0.0);
        // Text renders the table and the verdict.
        assert!(out.text.contains("tuned_p99_ms"));
        assert!(out.text.contains("wins"));
    }
}
