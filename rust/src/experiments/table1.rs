//! `table1`: the paper's worked example — Table I timing diagram and the
//! Fig 8 dataflow — on a 5x5 input with padding 1 and one 3x3 kernel,
//! where input column B and weight column WC are all-zero vectors.

use super::{ExpContext, ExpOutput};
use crate::sim::config::SimConfig;
use crate::sim::scheduler::{simulate_layer, Mode};
use crate::sim::trace::Trace;
use crate::tensor::conv::ConvSpec;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use anyhow::Result;

/// Build the Fig 6/7 example tensors.
pub fn example_tensors(seed: u64) -> (Tensor, Tensor) {
    let mut rng = Pcg32::seeded(seed);
    let mut input = Tensor::zeros(&[1, 5, 5]);
    for r in 0..5 {
        for c in [0usize, 2, 3, 4] {
            // column B (=1) stays zero
            *input.at3_mut(0, r, c) = rng.f32_range(0.5, 1.5);
        }
    }
    let mut weight = Tensor::zeros(&[1, 1, 3, 3]);
    for i in 0..3 {
        for j in 0..2 {
            // column WC (=2) stays zero
            *weight.at4_mut(0, 0, i, j) = rng.f32_range(0.5, 1.5);
        }
    }
    (input, weight)
}

pub fn run(ctx: &ExpContext) -> Result<ExpOutput> {
    let (input, weight) = example_tensors(ctx.seed);
    let mut cfg = SimConfig::paper_4_14_3();
    cfg.pe.arrays = 1;
    cfg.pe.rows = 5; // 15 PEs, as in §III
    cfg.context_switch_cycles = 0;
    // Table I is the paper's pure-compute timing diagram (15 vs 8
    // cycles); the memory hierarchy is out of its scope.
    cfg.mem_model = crate::sim::config::MemModel::Ideal;
    let spec = ConvSpec { stride: 1, pad: 1 };

    let mut text = String::new();
    let mut json = Json::obj();
    let mut cycles = [0u64; 2];
    for (i, mode) in [Mode::Dense, Mode::VectorSparse].into_iter().enumerate() {
        let mut trace = Trace::new(64);
        let res = simulate_layer(
            &input, &weight, None, &cfg, spec, mode, true, &mut trace,
        );
        cycles[i] = res.stats.cycles;
        let label = match mode {
            Mode::Dense => "Dense CNN Timing Diagram",
            Mode::VectorSparse => "Sparse CNN Timing Diagram",
        };
        text.push_str(&format!("{label} ({} cycles)\n", res.stats.cycles));
        text.push_str(&trace.render_timing_table());
        text.push_str("\n\n");

        // Functional check: the dataflow reproduces the golden conv.
        let golden = crate::tensor::conv::conv2d(&input, &weight, None, spec);
        let out = res.output.expect("functional");
        anyhow::ensure!(
            golden.allclose(&out, 1e-4, 1e-4),
            "dataflow output mismatch"
        );
    }
    let saving = 1.0 - cycles[1] as f64 / cycles[0] as f64;
    text.push_str(&format!(
        "dense = {} cycles, sparse = {} cycles, saving = {:.1}% (paper: 15, 8, 47%)\n",
        cycles[0],
        cycles[1],
        100.0 * saving
    ));
    json.set("dense_cycles", cycles[0])
        .set("sparse_cycles", cycles[1])
        .set("saving", saving)
        .set("paper_dense_cycles", 15usize)
        .set("paper_sparse_cycles", 8usize)
        .set("paper_saving", 0.47);

    Ok(ExpOutput {
        id: "table1".into(),
        json,
        text,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_exactly() {
        let out = run(&ExpContext::default()).unwrap();
        assert_eq!(out.json.get("dense_cycles").unwrap().as_usize(), Some(15));
        assert_eq!(out.json.get("sparse_cycles").unwrap().as_usize(), Some(8));
        let saving = out.json.get("saving").unwrap().as_f64().unwrap();
        assert!((saving - 0.4667).abs() < 0.01);
        // The rendered diagram carries the paper's column labels.
        assert!(out.text.contains("WA"));
        assert!(out.text.contains("X"));
    }
}
