//! `fig9` / `fig10` / `fig11`: per-layer nonzero density of input
//! activations, weights, and surviving work — at element granularity
//! (Fig 9) and vector granularity for R=14 (Fig 10) and R=7 (Fig 11).

use super::workload::run_config;
use super::{ExpContext, ExpOutput};
use crate::coordinator::report::ascii_table;
use crate::coordinator::LayerRecord;
use crate::sim::config::SimConfig;
use crate::util::json::Json;
use anyhow::Result;

fn density_output(
    id: &str,
    title: &str,
    ctx: &ExpContext,
    cfg: SimConfig,
    input_f: impl Fn(&LayerRecord) -> f64,
    weight_f: impl Fn(&LayerRecord) -> f64,
    work_f: impl Fn(&LayerRecord) -> f64,
) -> Result<ExpOutput> {
    let reports = run_config(ctx, cfg)?;
    // One pass over the per-image layer records for all three series
    // (instead of three `avg_layer_metric` traversals).
    let n = reports.len().max(1) as f64;
    let rows: Vec<(String, Vec<(String, f64)>)> = (0..reports[0].layers.len())
        .map(|i| {
            let (mut si, mut sw, mut sk) = (0.0, 0.0, 0.0);
            for r in &reports {
                let l = &r.layers[i];
                si += input_f(l);
                sw += weight_f(l);
                sk += work_f(l);
            }
            (
                reports[0].layers[i].name.clone(),
                vec![
                    ("input".to_string(), si / n),
                    ("weight".to_string(), sw / n),
                    ("work".to_string(), sk / n),
                ],
            )
        })
        .collect();

    let mut json = Json::obj();
    json.set("config", cfg.pe.label())
        .set("title", title)
        .set(
            "layers",
            Json::Arr(
                rows.iter()
                    .map(|(name, cols)| {
                        let mut o = Json::obj();
                        o.set("name", name.as_str());
                        for (k, v) in cols {
                            o.set(k, *v);
                        }
                        o
                    })
                    .collect(),
            ),
        );
    let text = format!("{title}\n{}", ascii_table(&rows));
    Ok(ExpOutput {
        id: id.to_string(),
        json,
        text,
    })
}

/// Fig 9: element-granularity densities (the "fine grained" view).
pub fn run_fig9(ctx: &ExpContext) -> Result<ExpOutput> {
    density_output(
        "fig9",
        "Fig 9 — density ratio, fine-grained granularity",
        ctx,
        SimConfig::paper_4_14_3(),
        |l| l.density.input_elem,
        |l| l.density.weight_elem,
        |l| l.density.work_elem,
    )
}

/// Fig 10: vector-granularity densities at R=14 (`[4,14,3]`).
pub fn run_fig10(ctx: &ExpContext) -> Result<ExpOutput> {
    density_output(
        "fig10",
        "Fig 10 — density ratio, vector granularity, [4,14,3] (R=14)",
        ctx,
        SimConfig::paper_4_14_3(),
        |l| l.density.input_vec,
        |l| l.density.weight_vec,
        |l| l.density.work_vec,
    )
}

/// Fig 11: vector-granularity densities at R=7 (`[8,7,3]`).
pub fn run_fig11(ctx: &ExpContext) -> Result<ExpOutput> {
    density_output(
        "fig11",
        "Fig 11 — density ratio, vector granularity, [8,7,3] (R=7)",
        ctx,
        SimConfig::paper_8_7_3(),
        |l| l.density.input_vec,
        |l| l.density.weight_vec,
        |l| l.density.work_vec,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx() -> ExpContext {
        ExpContext {
            res: 32,
            ..Default::default()
        }
    }

    #[test]
    fn fig9_vs_fig10_granularity_ordering() {
        // "As expected, the fine grained sparsity has lower density than
        // that in the vector sparsity case" (§IV): per layer,
        // elem densities <= vec densities.
        let ctx = tiny_ctx();
        let f9 = run_fig9(&ctx).unwrap();
        let f10 = run_fig10(&ctx).unwrap();
        let l9 = f9.json.get("layers").unwrap().as_arr().unwrap();
        let l10 = f10.json.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(l9.len(), 13);
        for (a, b) in l9.iter().zip(l10) {
            let (ia, ib) = (
                a.get("input").unwrap().as_f64().unwrap(),
                b.get("input").unwrap().as_f64().unwrap(),
            );
            let (wa, wb) = (
                a.get("weight").unwrap().as_f64().unwrap(),
                b.get("weight").unwrap().as_f64().unwrap(),
            );
            assert!(ia <= ib + 1e-9, "input {ia} > {ib}");
            assert!(wa <= wb + 1e-9, "weight {wa} > {wb}");
        }
    }

    #[test]
    fn smaller_vectors_never_increase_work_on_aligned_heights() {
        // R=7 fragments less than R=14 → more skippable zero vectors →
        // lower surviving *work* fraction ("Small zero vector enables more
        // zero skipping"). This monotonicity requires aligned strips (H a
        // multiple of 14 — true for every real VGG layer at 224, which is
        // exactly why the paper picked R ∈ {14, 7}); at the tiny test
        // resolution VGG heights are ragged, so we check the invariant on
        // aligned synthetic layers directly.
        use crate::sparse::encode::layer_report;
        use crate::tensor::conv::ConvSpec;
        use crate::tensor::Tensor;
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(42);
        for _ in 0..10 {
            let c = rng.range(1, 5);
            let k = rng.range(1, 5);
            let h = 28;
            let w = rng.range(4, 20);
            let n = c * h * w;
            let input = Tensor::from_vec(
                &[c, h, w],
                (0..n)
                    .map(|_| if rng.bernoulli(0.35) { rng.normal() } else { 0.0 })
                    .collect(),
            );
            let wn = k * c * 9;
            let weight = Tensor::from_vec(
                &[k, c, 3, 3],
                (0..wn)
                    .map(|_| if rng.bernoulli(0.3) { rng.normal() } else { 0.0 })
                    .collect(),
            );
            let r14 = layer_report(&input, &weight, ConvSpec::default(), 14);
            let r7 = layer_report(&input, &weight, ConvSpec::default(), 7);
            assert!(
                r7.work_vec <= r14.work_vec + 1e-12,
                "R=7 work {} > R=14 work {}",
                r7.work_vec,
                r14.work_vec
            );
        }
    }

    #[test]
    fn fig10_fig11_structure() {
        let ctx = tiny_ctx();
        for out in [run_fig10(&ctx).unwrap(), run_fig11(&ctx).unwrap()] {
            let layers = out.json.get("layers").unwrap().as_arr().unwrap();
            assert_eq!(layers.len(), 13);
            for l in layers {
                for key in ["input", "weight", "work"] {
                    let v = l.get(key).unwrap().as_f64().unwrap();
                    assert!((0.0..=1.0).contains(&v), "{key} = {v}");
                }
            }
        }
    }
}
