//! Experiment registry: one entry per table/figure of the paper's
//! evaluation (DESIGN.md §4 maps each to its modules).
//!
//! | id        | paper artifact                                     |
//! |-----------|----------------------------------------------------|
//! | `table1`  | Table I timing diagram + Fig 8 dataflow (5x5 example) |
//! | `fig9`    | per-layer density, element granularity             |
//! | `fig10`   | per-layer density, vector granularity, R=14        |
//! | `fig11`   | per-layer density, vector granularity, R=7         |
//! | `fig12`   | per-layer + overall speedup, `[4,14,3]`            |
//! | `fig13`   | per-layer + overall speedup, `[8,7,3]`             |
//! | `headline`| 1.871x/1.93x + 92%/85% + 46.6%/47.1% summary       |
//! | `scnn`    | §IV comparison against the SCNN-like model         |
//! | `serve`   | fleet serving capacity curve (beyond the paper)    |
//! | `serve-faults` | resilience degradation curve under injected faults |
//! | `serve-scale` | simulator events/sec + p99 at fleet sizes 10 → 10k |
//! | `serve-sdc` | detection/escape/goodput curve under injected bit flips |
//!
//! Every experiment returns a [`Json`] document and a human-readable text
//! block; the CLI writes both under `reports/`.

pub mod density;
pub mod serve;
pub mod serve_faults;
pub mod serve_scale;
pub mod serve_sdc;
pub mod speedup;
pub mod table1;
pub mod workload;

use crate::util::json::Json;
use anyhow::{bail, Result};

/// One rendered experiment.
#[derive(Debug)]
pub struct ExpOutput {
    pub id: String,
    pub json: Json,
    pub text: String,
}

/// Experiment-wide knobs (see CLI `--help`).
#[derive(Debug, Clone)]
pub struct ExpContext {
    /// Workload network from the model zoo (`vgg16` = the paper's
    /// evaluation; `alexnet`/`resnet10`/`mixed` exercise the §II-B
    /// mapping paths).
    pub net: String,
    /// Input resolution (224 = paper; smaller = faster smoke runs).
    pub res: usize,
    /// PRNG seed for synthetic weights/images.
    pub seed: u64,
    /// Number of synthetic images to average densities/speedups over.
    pub images: usize,
    /// Activation-density knob: the calibrated per-layer density profile
    /// is scaled by `1 + bias_shift` (0.0 = paper-like; DESIGN.md §6).
    pub bias_shift: f32,
    /// Threads for the functional forward pass.
    pub threads: usize,
    /// Artifacts directory for PJRT-backed runs (`None` = rust conv).
    pub artifacts_dir: Option<String>,
    /// Memory model for the cycle accounting (CLI `--mem-model`):
    /// `Tiled` (default) charges SRAM-sized tiles max(compute, transfer);
    /// `Ideal` reproduces the pure-compute counts.
    pub mem_model: crate::sim::config::MemModel,
    /// Cap on the `serve-scale` fleet-size grid (CLI `--max-fleet`;
    /// `None` = full sweep to 10k instances).
    pub max_fleet: Option<usize>,
    /// CVF payload precision (CLI `--precision`): `F32` (default, the
    /// pinned exact path), `Int16` or `Int8` fixed point with per-layer
    /// calibrated scales and precision-scaled memory floors.
    pub precision: crate::sim::config::Precision,
    /// Fused strip execution (CLI `--fuse`): keep conv→conv activation
    /// strips resident in SRAM where they fit, eliminating the
    /// consumer's input DRAM traffic under the tiled model.
    pub fuse: bool,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            net: "vgg16".to_string(),
            res: 224,
            // Historical seed, kept unchanged so every report stays
            // reproducible across PRs.
            seed: 20190526,
            images: 1,
            bias_shift: 0.0,
            threads: crate::util::default_threads(),
            artifacts_dir: None,
            mem_model: crate::sim::config::MemModel::Tiled,
            max_fleet: None,
            precision: crate::sim::config::Precision::F32,
            fuse: false,
        }
    }
}

/// All experiment ids, in paper order.
pub fn list() -> &'static [&'static str] {
    &[
        "table1",
        "fig9",
        "fig10",
        "fig11",
        "fig12",
        "fig13",
        "headline",
        "scnn",
        "serve",
        "serve-faults",
        "serve-scale",
        "serve-sdc",
    ]
}

/// Run one experiment by id.
pub fn run(id: &str, ctx: &ExpContext) -> Result<ExpOutput> {
    match id {
        "table1" => table1::run(ctx),
        "fig9" => density::run_fig9(ctx),
        "fig10" => density::run_fig10(ctx),
        "fig11" => density::run_fig11(ctx),
        "fig12" => speedup::run_fig(ctx, true),
        "fig13" => speedup::run_fig(ctx, false),
        "headline" => speedup::run_headline(ctx),
        "scnn" => speedup::run_scnn(ctx),
        "serve" => serve::run_serve(ctx),
        // Both spellings accepted; the report files use underscores.
        "serve-faults" | "serve_faults" => serve_faults::run_serve_faults(ctx),
        "serve-scale" | "serve_scale" => serve_scale::run_serve_scale(ctx),
        "serve-sdc" | "serve_sdc" => serve_sdc::run_serve_sdc(ctx),
        _ => bail!("unknown experiment '{id}'; known: {:?}", list()),
    }
}

/// Run every experiment, returning them in order.
pub fn run_all(ctx: &ExpContext) -> Result<Vec<ExpOutput>> {
    // Warm the workload memoizer for both paper configurations
    // concurrently (one core-pool worker per configuration); every figure
    // below then hits the cache instead of re-simulating.
    workload::run_configs(ctx, &crate::sim::config::SimConfig::paper_configs())?;
    list().iter().map(|id| run(id, ctx)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_is_error() {
        let err = run("fig99", &ExpContext::default()).unwrap_err();
        assert!(err.to_string().contains("unknown experiment"));
    }

    #[test]
    fn list_covers_every_paper_artifact() {
        // 1 table + 5 figures + 2 derived comparisons + the serving
        // capacity curve + the resilience degradation curve + the
        // fleet-scalability sweep + the data-integrity curve.
        assert_eq!(list().len(), 12);
        assert!(list().contains(&"serve"));
        assert!(list().contains(&"serve-faults"));
        assert!(list().contains(&"serve-scale"));
        assert!(list().contains(&"serve-sdc"));
    }
}
