//! `serve-faults`: the resilience degradation curve — goodput, tail
//! latency and availability as the per-instance crash rate rises, under a
//! constant background of stragglers.
//!
//! Two client configurations sweep the same crash-rate grid over the same
//! profiled fleet (ISSUE 6):
//!
//! * **plain** — per-attempt timeout + 2 retries with exponential backoff
//!   + load shedding; no hedging.
//! * **hedged** — the same, plus hedged requests: a second attempt is
//!   raced on another instance when the first exceeds the hedge window,
//!   first completion wins.
//!
//! Every point also injects transient stragglers (a few per
//! instance-second, 4x slowdown) so the hedge arm has something to win
//! against even before chips start dying. The emitted curve
//! (`reports/serve_faults.json` + `BENCH_serve_faults.json`) shows how
//! gracefully the fleet sheds capacity as availability drops — see
//! EXPERIMENTS.md §Resilience for a worked reading.

use super::{ExpContext, ExpOutput};
use crate::coordinator::report::ascii_table;
use crate::serve::{
    build_profiles, default_fleet, default_mix, simulate, BatchPolicy, DispatchPolicy, FaultSpec,
    RobustnessPolicy, ServeReport, ServeSpec, TrafficModel,
};
use crate::util::json::Json;
use anyhow::Result;

/// Crash intensity swept, in *expected crashes per instance over the
/// horizon* (the per-second rate is derived from the horizon so the curve
/// shape is resolution-invariant). Zero anchors the no-crash
/// (stragglers-only) baseline; the top point takes each instance down
/// three times in expectation.
const EXPECTED_CRASHES: [f64; 5] = [0.0, 0.25, 0.5, 1.0, 3.0];

/// Expected arrivals per sweep point (sets the horizon from the offered
/// rate, exactly like the `serve` capacity curve).
const ARRIVALS_PER_POINT: f64 = 400.0;

/// Offered load as a fraction of the estimated warm-batch capacity: high
/// enough that lost capacity shows up in goodput, below the knee so the
/// zero-crash anchor is healthy.
const LOAD_FRAC: f64 = 0.85;

/// One sweep point: the same fault plan under both client configurations.
struct FaultPoint {
    crash_per_sec: f64,
    plain: ServeReport,
    hedged: ServeReport,
}

/// Goodput (completed requests per second) of one report.
fn goodput(r: &ServeReport) -> f64 {
    r.throughput_rps()
}

/// Fleet availability of one report (1.0 when no resilience section —
/// cannot happen in this sweep, every point has stragglers on).
fn availability(r: &ServeReport) -> f64 {
    r.resilience.as_ref().map_or(1.0, |res| res.availability)
}

fn side_json(r: &ServeReport) -> Json {
    let mut o = Json::obj();
    o.set("goodput_rps", goodput(r))
        .set("p99_ms", r.p99_ms())
        .set("completed", r.completed)
        .set("rejected", r.rejected)
        .set("timed_out", r.timed_out)
        .set("shed", r.shed)
        .set("availability", availability(r));
    if let Some(res) = &r.resilience {
        o.set("retries", res.retries)
            .set("hedges", res.hedges)
            .set("hedge_wins", res.hedge_wins)
            .set("rehomed", res.rehomed)
            .set("crashes", res.crashes)
            .set("mttr_ms", res.mttr_ms);
    }
    o
}

fn point_json(p: &FaultPoint) -> Json {
    let mut o = Json::obj();
    o.set("crash_per_sec", p.crash_per_sec)
        .set("plain", side_json(&p.plain))
        .set("hedged", side_json(&p.hedged));
    o
}

/// Run the `serve-faults` experiment (see module docs).
pub fn run_serve_faults(ctx: &ExpContext) -> Result<ExpOutput> {
    let tenants = default_mix(ctx.res);
    let instances = default_fleet(4);
    let base = ServeSpec {
        tenants: tenants.clone(),
        instances,
        traffic: TrafficModel::OpenLoop { rps: 1.0 },
        policy: DispatchPolicy::NetworkAffinity,
        batch: BatchPolicy::none(),
        queue_cap: 32,
        racks: 1,
        duration_cycles: 1,
        clock_mhz: 500.0,
        seed: ctx.seed,
        faults: FaultSpec::none(),
        robust: RobustnessPolicy::none(),
        sdc: crate::sim::sdc::SdcSpec::none(),
    };
    let profiles = build_profiles(&base, ctx.threads)?;

    // Mix-weighted service means: capacity estimate (same arithmetic as
    // the `serve` experiment) and the single-request mean that anchors the
    // timeout/backoff/hedge windows.
    let wsum: f64 = tenants.iter().map(|t| t.weight).sum();
    let mut capacity_rps = 0.0;
    for i in 0..base.instances.len() {
        let mean_marginal: f64 = tenants
            .iter()
            .enumerate()
            .map(|(t, ten)| ten.weight / wsum * profiles[t][i].marginal_cycles as f64)
            .sum();
        capacity_rps += base.clock_hz() / mean_marginal.max(1.0);
    }
    let mut mean_single = 0.0;
    for (t, ten) in tenants.iter().enumerate() {
        let avg: f64 = profiles[t]
            .iter()
            .map(|p| p.single_cycles as f64)
            .sum::<f64>()
            / profiles[t].len() as f64;
        mean_single += ten.weight / wsum * avg;
    }

    let rps = capacity_rps * LOAD_FRAC;
    let duration_cycles = (ARRIVALS_PER_POINT * base.clock_hz() / rps).ceil() as u64;
    let duration_secs = duration_cycles as f64 / base.clock_hz();
    // Two straggler episodes per instance in expectation, whatever the
    // horizon, so the hedge arm always has slow chips to race against.
    let straggler_per_sec = 2.0 / duration_secs;

    // Timeout generous against queueing + 4x straggler stretch; retries
    // with half-a-service backoff; shedding on so overload degrades by
    // priority instead of by queue-full lottery.
    let robust_plain = RobustnessPolicy {
        timeout_cycles: ((mean_single * 24.0) as u64).max(1),
        max_retries: 2,
        backoff_cycles: ((mean_single / 2.0) as u64).max(1),
        hedge_cycles: 0,
        shed: true,
    };
    let robust_hedged = RobustnessPolicy {
        hedge_cycles: ((mean_single * 6.0) as u64).max(1),
        ..robust_plain
    };

    let mut curve: Vec<FaultPoint> = Vec::new();
    for expected in EXPECTED_CRASHES {
        let crash = expected / duration_secs;
        let faults = FaultSpec {
            crash_per_sec: crash,
            mttr_ms: 1.5,
            straggler_per_sec,
            slowdown: 4.0,
            straggler_ms: 1.0,
            req_fault_prob: 0.0,
        };
        let mut plain = base.clone();
        plain.traffic = TrafficModel::OpenLoop { rps };
        plain.duration_cycles = duration_cycles;
        plain.batch = BatchPolicy {
            max_batch: 8,
            max_wait_cycles: ((mean_single / 2.0) as u64).max(1),
        };
        plain.faults = faults;
        plain.robust = robust_plain;

        let mut hedged = plain.clone();
        hedged.robust = robust_hedged;

        let plain_report = ServeReport::new(&plain, &simulate(&plain, &profiles));
        let hedged_report = ServeReport::new(&hedged, &simulate(&hedged, &profiles));
        curve.push(FaultPoint {
            crash_per_sec: crash,
            plain: plain_report,
            hedged: hedged_report,
        });
    }

    let zero = curve.first().expect("non-empty sweep");
    let worst = curve.last().expect("non-empty sweep");
    // Acceptance metrics: availability must actually fall across the
    // sweep, and the goodput retention quantifies how gracefully.
    let availability_drop = availability(&zero.plain) - availability(&worst.plain);
    let goodput_retention = goodput(&worst.plain) / goodput(&zero.plain).max(1e-9);
    let hedge_p99_win = p_ratio(worst.hedged.p99_ms(), worst.plain.p99_ms());

    let mut json = Json::obj();
    json.set(
        "tenants",
        Json::Arr(tenants.iter().map(|t| Json::Str(t.name.clone())).collect()),
    )
    .set(
        "fleet",
        Json::Arr(
            base.instances
                .iter()
                .map(|i| Json::Str(i.label()))
                .collect(),
        ),
    )
    .set("capacity_rps_estimate", capacity_rps)
    .set("offered_rps", rps)
    .set("duration_secs", duration_secs)
    .set("mttr_ms", 1.5)
    .set("straggler_per_sec", straggler_per_sec)
    .set("timeout_cycles", robust_plain.timeout_cycles)
    .set("max_retries", robust_plain.max_retries as u64)
    .set("hedge_cycles", robust_hedged.hedge_cycles)
    .set("seed", base.seed)
    .set("availability_drop", availability_drop)
    .set("goodput_retention", goodput_retention)
    .set("hedge_p99_ratio", hedge_p99_win)
    .set("curve", Json::Arr(curve.iter().map(point_json).collect()));

    let rows: Vec<(String, Vec<(String, f64)>)> = curve
        .iter()
        .map(|p| {
            (
                format!("crash {:>5.0}/s", p.crash_per_sec),
                vec![
                    ("plain_rps".to_string(), goodput(&p.plain)),
                    ("plain_p99_ms".to_string(), p.plain.p99_ms()),
                    ("plain_avail".to_string(), availability(&p.plain)),
                    ("hedge_rps".to_string(), goodput(&p.hedged)),
                    ("hedge_p99_ms".to_string(), p.hedged.p99_ms()),
                    ("hedge_avail".to_string(), availability(&p.hedged)),
                ],
            )
        })
        .collect();
    let text = format!(
        "Resilience degradation curve — {} tenants on {} instances, offered {:.0} rps ({:.0}% of capacity)\n\
         constant stragglers {:.0}/inst-s (4x, 1 ms); crash mttr 1.5 ms; timeout+2 retries+shed, hedge arm adds {} cyc hedge\n{}\n\
         worst point: availability {:.3}, goodput retention {:.3}, hedged p99/plain p99 {:.3}\n",
        tenants.len(),
        base.instances.len(),
        rps,
        LOAD_FRAC * 100.0,
        straggler_per_sec,
        robust_hedged.hedge_cycles,
        ascii_table(&rows),
        availability(&worst.plain),
        goodput_retention,
        hedge_p99_win,
    );

    // Machine-readable trajectory next to the bench outputs.
    let mut derived = Json::obj();
    derived
        .set("offered_rps", rps)
        .set("zero_crash_goodput_rps", goodput(&zero.plain))
        .set("worst_crash_goodput_rps", goodput(&worst.plain))
        .set("goodput_retention", goodput_retention)
        .set("zero_crash_availability", availability(&zero.plain))
        .set("worst_crash_availability", availability(&worst.plain))
        .set("availability_drop", availability_drop)
        .set("worst_plain_p99_ms", worst.plain.p99_ms())
        .set("worst_hedged_p99_ms", worst.hedged.p99_ms())
        .set("hedge_p99_ratio", hedge_p99_win);
    let bench_path = "BENCH_serve_faults.json";
    if let Err(e) = crate::util::bench::write_results(bench_path, &[], derived) {
        crate::log_warn!("could not write {bench_path}: {e}");
    }

    Ok(ExpOutput {
        id: "serve_faults".to_string(),
        json,
        text,
    })
}

/// `a / b`, guarding the degenerate zero-latency denominator.
fn p_ratio(a: f64, b: f64) -> f64 {
    a / b.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degradation_curve_loses_availability_as_crashes_rise() {
        let ctx = ExpContext {
            res: 32,
            ..Default::default()
        };
        let out = run_serve_faults(&ctx).unwrap();
        assert_eq!(out.id, "serve_faults");
        let curve = out.json.get("curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), EXPECTED_CRASHES.len());

        let avail = |p: &Json| {
            p.get("plain")
                .unwrap()
                .get("availability")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // No crashes -> every cycle available; the heaviest crash rate
        // (expected >1 crash per instance over the horizon) takes real
        // downtime.
        assert_eq!(avail(&curve[0]), 1.0);
        let worst = avail(curve.last().unwrap());
        assert!(worst < 1.0, "availability stayed {worst} at crash:150");
        assert!(worst > 0.0);
        // Crashes showed up in the resilience ledger at the top rate.
        let crashes = curve
            .last()
            .unwrap()
            .get("plain")
            .unwrap()
            .get("crashes")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(crashes > 0.0);
        // The fleet still serves under fire: goodput never hits zero.
        for p in curve {
            let g = p
                .get("plain")
                .unwrap()
                .get("goodput_rps")
                .unwrap()
                .as_f64()
                .unwrap();
            assert!(g > 0.0, "goodput collapsed at {:?}", p.get("crash_per_sec"));
        }
        // Text renders the table and the summary line.
        assert!(out.text.contains("plain_p99_ms"));
        assert!(out.text.contains("goodput retention"));
    }

    #[test]
    fn curve_is_deterministic_for_the_same_seed() {
        let ctx = ExpContext {
            res: 32,
            ..Default::default()
        };
        let a = run_serve_faults(&ctx).unwrap();
        let b = run_serve_faults(&ctx).unwrap();
        assert_eq!(a.json.pretty(), b.json.pretty());
    }
}
