//! Per-layer pruning-density schedules.
//!
//! The paper reports a single number — 23.5% overall weight density on
//! VGG-16 after vector pruning — without per-layer targets. We reconstruct
//! a plausible schedule from the well-known per-layer sensitivity profile of
//! VGG-16 magnitude pruning (Han et al. [17]: early layers are sensitive
//! and stay denser; middle/late layers prune hard), then scale it so the
//! parameter-weighted overall density hits the paper's 23.5%.

use crate::model::{LayerKind, Network};
use std::collections::BTreeMap;

/// Relative per-layer density profile for VGG-16 (Han et al., Table 4 —
/// fraction of weights kept per conv layer).
pub const VGG16_PROFILE: [(&str, f64); 13] = [
    ("conv1_1", 0.58),
    ("conv1_2", 0.22),
    ("conv2_1", 0.34),
    ("conv2_2", 0.36),
    ("conv3_1", 0.53),
    ("conv3_2", 0.24),
    ("conv3_3", 0.42),
    ("conv4_1", 0.32),
    ("conv4_2", 0.27),
    ("conv4_3", 0.34),
    ("conv5_1", 0.35),
    ("conv5_2", 0.29),
    ("conv5_3", 0.36),
];

/// The paper's overall VGG-16 weight density after vector pruning (§IV).
pub const PAPER_OVERALL_DENSITY: f64 = 0.235;

/// Build a per-layer schedule for `net` by scaling `profile` so the
/// parameter-weighted overall density equals `overall`. Layers missing from
/// the profile get the overall target directly.
pub fn schedule_for(
    net: &Network,
    profile: &[(&str, f64)],
    overall: f64,
) -> BTreeMap<String, f64> {
    let prof: BTreeMap<&str, f64> = profile.iter().copied().collect();

    // Parameter counts per conv layer.
    let mut weights: Vec<(String, usize, f64)> = Vec::new(); // (name, params, profile density)
    for layer in &net.layers {
        if let LayerKind::Conv { c_in, c_out, k, .. } = layer.kind {
            let n = c_in * c_out * k * k;
            let d = prof.get(layer.name.as_str()).copied().unwrap_or(overall);
            weights.push((layer.name.clone(), n, d));
        }
    }
    let total: f64 = weights.iter().map(|(_, n, _)| *n as f64).sum();
    let achieved: f64 =
        weights.iter().map(|(_, n, d)| *n as f64 * d).sum::<f64>() / total.max(1.0);

    // Scale all layer targets by a common factor, clamped to [0.01, 1].
    let scale = if achieved > 0.0 { overall / achieved } else { 1.0 };
    weights
        .into_iter()
        .map(|(name, _, d)| (name, (d * scale).clamp(0.01, 1.0)))
        .collect()
}

/// The default schedule the experiments use: VGG-16 profile scaled to the
/// paper's 23.5%.
pub fn paper_schedule(net: &Network) -> BTreeMap<String, f64> {
    schedule_for(net, &VGG16_PROFILE, PAPER_OVERALL_DENSITY)
}

/// Validate a user-supplied density target: pruning to `d` only makes
/// sense for `d` in `(0.0, 1.0]` — anything else silently produces
/// nonsense schedules (all-zero weights or no-op pruning reported as if
/// it happened). The CLI `--density` flag goes through this.
pub fn checked_density(d: f64) -> anyhow::Result<f64> {
    anyhow::ensure!(
        d.is_finite() && d > 0.0 && d <= 1.0,
        "density must be in (0.0, 1.0], got {d}"
    );
    Ok(d)
}

/// A flat schedule (same density everywhere) for ablations.
pub fn flat_schedule(net: &Network, density: f64) -> BTreeMap<String, f64> {
    net.conv_layer_names()
        .into_iter()
        .map(|n| (n.to_string(), density))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg16::{tiny_vgg, vgg16};

    #[test]
    fn paper_schedule_weighted_density_matches() {
        let net = vgg16();
        let sched = paper_schedule(&net);
        assert_eq!(sched.len(), 13);
        // Recompute the parameter-weighted density of the schedule.
        let mut num = 0.0;
        let mut den = 0.0;
        for layer in &net.layers {
            if let LayerKind::Conv { c_in, c_out, k, .. } = layer.kind {
                let n = (c_in * c_out * k * k) as f64;
                num += n * sched[&layer.name];
                den += n;
            }
        }
        let overall = num / den;
        assert!(
            (overall - PAPER_OVERALL_DENSITY).abs() < 0.01,
            "overall {overall}"
        );
    }

    #[test]
    fn early_layers_stay_denser() {
        let net = vgg16();
        let sched = paper_schedule(&net);
        assert!(sched["conv1_1"] > sched["conv4_2"]);
        assert!(sched["conv3_1"] > sched["conv3_2"]);
    }

    #[test]
    fn unknown_layers_get_overall() {
        let net = tiny_vgg(8);
        let sched = schedule_for(&net, &VGG16_PROFILE, 0.4);
        // tiny_vgg layer names don't appear in the profile → all equal 0.4
        // after self-normalizing scaling.
        for (_, d) in &sched {
            assert!((d - 0.4).abs() < 1e-9);
        }
    }

    #[test]
    fn checked_density_accepts_the_half_open_unit_interval() {
        assert_eq!(checked_density(0.235).unwrap(), 0.235);
        assert_eq!(checked_density(1.0).unwrap(), 1.0);
        for bad in [0.0, -0.1, 1.0001, 17.0, f64::NAN, f64::INFINITY] {
            let err = checked_density(bad).unwrap_err();
            assert!(err.to_string().contains("density"), "{bad}: {err}");
        }
    }

    #[test]
    fn flat_schedule_is_flat() {
        let net = tiny_vgg(8);
        let sched = flat_schedule(&net, 0.3);
        assert_eq!(sched.len(), 4);
        assert!(sched.values().all(|&d| d == 0.3));
    }
}
