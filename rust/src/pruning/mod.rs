//! Weight pruning: the vector pruning of Mao et al. [18] that the paper's
//! evaluation uses (density 23.5% on VGG-16), plus element-granularity
//! magnitude pruning for the fine-grained comparison series.

pub mod fine_prune;
pub mod sensitivity;
pub mod vector_prune;

pub use fine_prune::prune_fine_grained;
pub use vector_prune::{prune_vectors, VectorGranularity};

use crate::model::init::Params;

/// Prune every conv layer of `params` in place to the per-layer density
/// `schedule` (name → target density), using vector-granularity pruning.
/// Returns the achieved overall (parameter-weighted) density.
///
/// Default granularity is [`VectorGranularity::KernelRow`] — Mao et al.'s
/// method, the one the paper's workload uses.
pub fn prune_network_vectors(
    params: &mut Params,
    schedule: &std::collections::BTreeMap<String, f64>,
) -> f64 {
    prune_network_vectors_with(params, schedule, VectorGranularity::KernelRow)
}

/// [`prune_network_vectors`] with explicit granularity (the hardware-
/// aligned `KernelCol` variant is the ablation of DESIGN.md §4).
pub fn prune_network_vectors_with(
    params: &mut Params,
    schedule: &std::collections::BTreeMap<String, f64>,
    gran: VectorGranularity,
) -> f64 {
    let mut kept = 0u64;
    let mut total = 0u64;
    for (name, lp) in params.iter_mut() {
        if lp.weight.ndim() != 4 {
            continue; // only conv layers take part in the evaluation
        }
        let target = schedule.get(name).copied().unwrap_or(1.0);
        prune_vectors(&mut lp.weight, target, gran);
        kept += lp.weight.count_nonzero() as u64;
        total += lp.weight.len() as u64;
    }
    if total == 0 {
        0.0
    } else {
        kept as f64 / total as f64
    }
}

/// Same, with fine-grained (element) pruning — the comparison workload
/// behind Fig 9.
pub fn prune_network_fine(
    params: &mut Params,
    schedule: &std::collections::BTreeMap<String, f64>,
) -> f64 {
    let mut kept = 0u64;
    let mut total = 0u64;
    for (name, lp) in params.iter_mut() {
        if lp.weight.ndim() != 4 {
            continue;
        }
        let target = schedule.get(name).copied().unwrap_or(1.0);
        prune_fine_grained(&mut lp.weight, target);
        kept += lp.weight.count_nonzero() as u64;
        total += lp.weight.len() as u64;
    }
    if total == 0 {
        0.0
    } else {
        kept as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::synthetic_params;
    use crate::model::vgg16::tiny_vgg;
    use std::collections::BTreeMap;

    #[test]
    fn network_pruning_hits_schedule() {
        let net = tiny_vgg(8);
        let mut params = synthetic_params(&net, 1, 0.0);
        let mut schedule = BTreeMap::new();
        for name in net.conv_layer_names() {
            schedule.insert(name.to_string(), 0.5);
        }
        let overall = prune_network_vectors(&mut params, &schedule);
        // Vector pruning prunes whole kernel columns; achieved density can
        // be below target but never above.
        assert!(overall <= 0.51, "overall {overall}");
        assert!(overall > 0.3, "overall {overall}");
    }

    #[test]
    fn fine_pruning_hits_schedule_exactly() {
        let net = tiny_vgg(8);
        let mut params = synthetic_params(&net, 1, 0.0);
        let mut schedule = BTreeMap::new();
        for name in net.conv_layer_names() {
            schedule.insert(name.to_string(), 0.25);
        }
        let overall = prune_network_fine(&mut params, &schedule);
        assert!((overall - 0.25).abs() < 0.02, "overall {overall}");
    }
}
