//! Fine-grained magnitude pruning (Han et al., "Deep Compression" — the
//! paper's [17]): individual elements below a magnitude threshold are
//! zeroed. Produces the irregular Fig 1 structure the fine-grained
//! comparison designs (Cambricon-X, SCNN) index.

use crate::tensor::Tensor;

/// Prune individual elements of `weight` in place to ≈`target_density`,
/// keeping the largest magnitudes. Returns the number of elements zeroed.
pub fn prune_fine_grained(weight: &mut Tensor, target_density: f64) -> usize {
    assert!(
        (0.0..=1.0).contains(&target_density),
        "density must be in [0,1]"
    );
    let n = weight.len();
    let keep = ((n as f64) * target_density).round() as usize;
    if keep >= n {
        return 0;
    }
    // Threshold = magnitude of the keep-th largest element.
    let mut mags: Vec<f32> = weight.data().iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let threshold = if keep == 0 { f32::INFINITY } else { mags[keep - 1] };

    // Zero strictly-below threshold, then resolve ties at the threshold so
    // exactly `keep` survive (deterministic: later elements pruned first).
    let mut surviving = weight.data().iter().filter(|x| x.abs() >= threshold && **x != 0.0).count();
    let mut zeroed = 0;
    for x in weight.data_mut().iter_mut() {
        if *x != 0.0 && x.abs() < threshold {
            *x = 0.0;
            zeroed += 1;
        }
    }
    if surviving > keep {
        for x in weight.data_mut().iter_mut().rev() {
            if surviving == keep {
                break;
            }
            if *x != 0.0 && x.abs() == threshold {
                *x = 0.0;
                zeroed += 1;
                surviving -= 1;
            }
        }
    }
    zeroed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn achieves_exact_density() {
        let mut rng = Pcg32::seeded(4);
        let data: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let mut w = Tensor::from_vec(&[10, 100], data);
        prune_fine_grained(&mut w, 0.3);
        assert_eq!(w.count_nonzero(), 300);
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let mut w = Tensor::from_vec(&[5], vec![0.1, -5.0, 0.2, 3.0, -0.05]);
        prune_fine_grained(&mut w, 0.4);
        assert_eq!(w.data(), &[0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn handles_ties_deterministically() {
        let mut w = Tensor::from_vec(&[4], vec![1.0, 1.0, 1.0, 1.0]);
        prune_fine_grained(&mut w, 0.5);
        assert_eq!(w.count_nonzero(), 2);
        // Later elements pruned first on ties.
        assert_eq!(w.data(), &[1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn extreme_densities() {
        let mut w = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let mut w2 = w.clone();
        assert_eq!(prune_fine_grained(&mut w, 1.0), 0);
        prune_fine_grained(&mut w2, 0.0);
        assert_eq!(w2.count_nonzero(), 0);
    }

    #[test]
    fn already_sparse_input_counts_existing_zeros() {
        // Tensor already 50% zero; target 0.5 should prune nothing more.
        let mut w = Tensor::from_vec(&[4], vec![0.0, 2.0, 0.0, 3.0]);
        prune_fine_grained(&mut w, 0.5);
        assert_eq!(w.count_nonzero(), 2);
    }
}
