//! Vector pruning (Mao et al., "Exploring the regularity of sparse
//! structure in convolutional neural networks", CVPR-W 2017 — the paper's
//! [18]): magnitude pruning at the granularity of whole 1-D sub-kernel
//! vectors.
//!
//! Two granularities matter here (and their *mismatch* is what shapes the
//! paper's numbers — see EXPERIMENTS.md §Calibration):
//!
//! * [`VectorGranularity::KernelRow`] — Mao et al.'s vectors run along the
//!   kernel's **rows** (`weight[k,c,i,:]`). This is what the paper's
//!   workload is pruned with ("pruned with the vector pruning method as
//!   [18]", density 23.5%).
//! * [`VectorGranularity::KernelCol`] — the VSCNN hardware skips kernel
//!   **columns** (`weight[k,c,:,j]`, the vertically-broadcast vectors).
//!   Row-pruned kernels leave a column nonzero whenever *any* of its taps
//!   survives (`1-(1-d)^KH ≈ 0.55` at d=0.235), which is exactly why the
//!   paper's ideal-vector speedup sits near 2x rather than 1/0.235. Pruning
//!   directly at column granularity is the hardware-aligned ablation.
//!
//! A vector's saliency is its L2 norm; the lowest-norm vectors are zeroed
//! until the requested element density is reached.

use crate::tensor::Tensor;

/// Which 1-D sub-kernel vectors pruning removes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VectorGranularity {
    /// Mao et al. [18]: vectors along kernel rows (the paper's workload).
    KernelRow,
    /// Hardware-aligned: vectors along kernel columns (ablation).
    KernelCol,
}

/// Prune `weight` (`[K, C, KH, KW]`) in place to ≈`target_density`
/// (fraction of elements kept), removing whole 1-D vectors of the given
/// granularity in ascending L2-norm order. Returns vectors zeroed.
pub fn prune_vectors(
    weight: &mut Tensor,
    target_density: f64,
    gran: VectorGranularity,
) -> usize {
    assert_eq!(weight.ndim(), 4, "weight must be [K,C,KH,KW]");
    assert!(
        (0.0..=1.0).contains(&target_density),
        "density must be in [0,1]"
    );
    let (k, c, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    // A vector is (k, c, fixed) × (sweep): rows fix i and sweep j; columns
    // fix j and sweep i.
    let (n_fixed, n_sweep) = match gran {
        VectorGranularity::KernelRow => (kh, kw),
        VectorGranularity::KernelCol => (kw, kh),
    };
    let n_vecs = k * c * n_fixed;

    let elem = |t: &Tensor, ki: usize, ci: usize, fixed: usize, sw: usize| match gran {
        VectorGranularity::KernelRow => t.at4(ki, ci, fixed, sw),
        VectorGranularity::KernelCol => t.at4(ki, ci, sw, fixed),
    };

    // Saliency of every vector.
    let mut saliency: Vec<(f32, usize)> = Vec::with_capacity(n_vecs);
    for ki in 0..k {
        for ci in 0..c {
            for f in 0..n_fixed {
                let mut norm2 = 0.0f32;
                for s in 0..n_sweep {
                    let v = elem(weight, ki, ci, f, s);
                    norm2 += v * v;
                }
                saliency.push((norm2, (ki * c + ci) * n_fixed + f));
            }
        }
    }

    // Keep the top `target_density` fraction of vectors.
    let keep = ((n_vecs as f64) * target_density).round() as usize;
    let prune = n_vecs - keep.min(n_vecs);
    saliency.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    for &(_, vid) in saliency.iter().take(prune) {
        let f = vid % n_fixed;
        let ci = (vid / n_fixed) % c;
        let ki = vid / (n_fixed * c);
        for s in 0..n_sweep {
            match gran {
                VectorGranularity::KernelRow => *weight.at4_mut(ki, ci, f, s) = 0.0,
                VectorGranularity::KernelCol => *weight.at4_mut(ki, ci, s, f) = 0.0,
            }
        }
    }
    prune
}

/// Vector-granularity density of a weight tensor (fraction of kernel
/// columns with any nonzero element).
pub fn vector_density(weight: &Tensor) -> f64 {
    let vw = crate::sparse::VectorWeights::index_only(weight);
    vw.density()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_weight(seed: u64, shape: &[usize]) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal()).collect())
    }

    #[test]
    fn achieves_target_density_both_granularities() {
        for gran in [VectorGranularity::KernelRow, VectorGranularity::KernelCol] {
            let mut w = random_weight(1, &[8, 4, 3, 3]);
            prune_vectors(&mut w, 0.25, gran);
            // Element density equals the pruned-granularity vector density
            // for dense-start weights.
            assert!(
                (w.density() - 0.25).abs() < 0.02,
                "{gran:?}: density {}",
                w.density()
            );
        }
    }

    #[test]
    fn column_pruning_aligns_with_hardware_vectors() {
        let mut w = random_weight(7, &[8, 4, 3, 3]);
        prune_vectors(&mut w, 0.25, VectorGranularity::KernelCol);
        assert!((vector_density(&w) - 0.25).abs() < 0.02);
    }

    #[test]
    fn row_pruning_leaves_columns_denser() {
        // The paper's granularity mismatch: row pruning to density d leaves
        // column-vector density ≈ 1-(1-d)^3 > d.
        let mut w = random_weight(8, &[16, 16, 3, 3]);
        prune_vectors(&mut w, 0.235, VectorGranularity::KernelRow);
        let col_density = vector_density(&w);
        let expect = 1.0 - (1.0f64 - 0.235).powi(3); // ≈ 0.552
        assert!(
            (col_density - expect).abs() < 0.05,
            "col density {col_density} vs expected {expect}"
        );
    }

    #[test]
    fn prunes_lowest_norm_vectors_first() {
        // Craft a weight where vector norms are known: filter 0 columns have
        // tiny values, filter 1 columns large.
        let mut w = Tensor::zeros(&[2, 1, 3, 3]);
        for j in 0..3 {
            for i in 0..3 {
                *w.at4_mut(0, 0, i, j) = 0.01;
                *w.at4_mut(1, 0, i, j) = 1.0;
            }
        }
        prune_vectors(&mut w, 0.5, VectorGranularity::KernelCol);
        // All of filter 0's columns pruned, filter 1 intact.
        assert_eq!(
            (0..3).map(|j| w.at4(0, 0, 0, j)).collect::<Vec<_>>(),
            vec![0.0; 3]
        );
        assert_eq!(
            (0..3).map(|j| w.at4(1, 0, 0, j)).collect::<Vec<_>>(),
            vec![1.0; 3]
        );
    }

    #[test]
    fn density_one_is_noop() {
        let mut w = random_weight(2, &[4, 2, 3, 3]);
        let before = w.clone();
        let pruned = prune_vectors(&mut w, 1.0, VectorGranularity::KernelRow);
        assert_eq!(pruned, 0);
        assert_eq!(w.data(), before.data());
    }

    #[test]
    fn density_zero_clears_everything() {
        let mut w = random_weight(3, &[4, 2, 3, 3]);
        prune_vectors(&mut w, 0.0, VectorGranularity::KernelRow);
        assert_eq!(w.count_nonzero(), 0);
    }

    #[test]
    fn monotone_in_target_density_randomized() {
        // Property: lower target density ⇒ subset of survivors.
        let mut rng = Pcg32::seeded(9);
        for gran in [VectorGranularity::KernelRow, VectorGranularity::KernelCol] {
            for _ in 0..10 {
                let shape = [rng.range(1, 6), rng.range(1, 6), 3, 3];
                let w0 = random_weight(rng.next_u64(), &shape);
                let mut w_half = w0.clone();
                let mut w_quarter = w0.clone();
                prune_vectors(&mut w_half, 0.5, gran);
                prune_vectors(&mut w_quarter, 0.25, gran);
                for (a, b) in w_quarter.data().iter().zip(w_half.data()) {
                    if *a != 0.0 {
                        assert_eq!(a, b, "survivor at 25% must survive at 50%");
                    }
                }
            }
        }
    }
}
