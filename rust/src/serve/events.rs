//! Deterministic discrete-event queue and event vocabulary for the
//! serving simulator.
//!
//! A binary min-heap keyed by `(cycle, seq)` where `seq` is a monotone
//! insertion counter: two events scheduled for the same cycle pop in the
//! order they were pushed, so the simulation is a pure function of the
//! spec and seed — no iteration-order or wall-clock nondeterminism can
//! leak in. Payloads need no ordering of their own.
//!
//! ## Same-cycle tie-break contract
//!
//! Ties at one cycle resolve strictly in **push order**, which the
//! simulation exploits to pin a *pessimistic* resolution order
//! (`tests/serve.rs` holds the property tests):
//!
//! 1. **Fault-plan events first.** The seeded crash/recover/straggler
//!    timeline ([`super::faults::generate_plan`]) is enqueued before the
//!    arrival processes are seeded, so a crash at cycle `c` carries a
//!    lower `seq` than *any* event scheduled during the run for `c` — a
//!    batch completing exactly when its instance crashes is killed and
//!    re-homed, not completed.
//! 2. **Timeouts beat completions.** A per-attempt [`ServeEvent::Timeout`]
//!    is pushed at dispatch time, before the batch containing the attempt
//!    is launched (and thus before its [`ServeEvent::Complete`] exists);
//!    an attempt whose timeout lands exactly on its completion cycle is
//!    timed out.
//! 3. Among run-scheduled events, causal push order wins — identical to
//!    one-at-a-time popping even under `drain_cycle` batching (pinned by
//!    `drain_matches_pop_order`).

use super::faults::FaultKind;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// The serving simulator's event vocabulary. Ordering between same-cycle
/// events is purely push order (see the module docs); the variants carry
/// no priority of their own.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// A request arrives. `client` marks closed-loop re-issue chains
    /// (unused under open-loop traffic); `reissue_of` links a closed-loop
    /// re-issue to the request whose completion/rejection spawned it.
    Arrival {
        tenant: usize,
        client: bool,
        reissue_of: Option<usize>,
    },
    /// Re-dispatch request `req` after a retry backoff.
    Retry { req: usize },
    /// A partial batch's wait window may have expired on this instance.
    BatchTimer { instance: usize, token: u64 },
    /// The batch running on `instance` (its `running` set) finishes.
    /// `epoch` is the instance's crash epoch at launch: a crash bumps the
    /// epoch, so completions of batches killed by a crash are ignored.
    Complete { instance: usize, epoch: u32 },
    /// Attempt `token` of request `req` has been in flight for the
    /// timeout window; if still live it is cancelled (and retried or
    /// failed).
    Timeout { req: usize, token: u32 },
    /// Hedge trigger: if attempt `token` of `req` is still live, issue a
    /// duplicate attempt on another instance.
    Hedge { req: usize, token: u32 },
    /// A fault-plan event hits `instance`.
    Fault { instance: usize, kind: FaultKind },
}

struct Entry<T> {
    cycle: u64,
    seq: u64,
    payload: T,
}

// Manual impls: order by (cycle, seq) only — reversed so the std max-heap
// pops the earliest event first.
impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cycle == other.cycle && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.cycle, other.seq).cmp(&(self.cycle, self.seq))
    }
}

/// Min-heap of `(cycle, payload)` events with deterministic FIFO
/// tie-breaking at equal cycles.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `payload` at `cycle`. Events at the same cycle pop in push
    /// order.
    pub fn push(&mut self, cycle: u64, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry {
            cycle,
            seq,
            payload,
        });
    }

    /// Pop the earliest event as `(cycle, payload)`.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        self.heap.pop().map(|e| (e.cycle, e.payload))
    }

    /// Cycle of the earliest pending event.
    pub fn peek_cycle(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.cycle)
    }

    /// Batched drain: append every event scheduled at exactly `cycle` to
    /// `out`, in FIFO (push) order. The serving loop processes one
    /// timestamp per drain; events pushed *while* processing the batch —
    /// even at the same cycle — carry higher `seq`s, so the caller's next
    /// drain picks them up in exactly the order one-at-a-time popping
    /// would have (pinned by `drain_matches_pop_order`).
    pub fn drain_cycle(&mut self, cycle: u64, out: &mut Vec<T>) {
        while let Some(e) = self.heap.peek() {
            if e.cycle != cycle {
                break;
            }
            out.push(self.heap.pop().expect("peeked entry exists").payload);
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_cycle_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.peek_cycle(), Some(10));
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn equal_cycles_pop_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100usize {
            q.push(7, i);
        }
        for i in 0..100usize {
            assert_eq!(q.pop(), Some((7, i)));
        }
    }

    #[test]
    fn drain_matches_pop_order() {
        // The batched drain must yield exactly what repeated pops would.
        let mut a = EventQueue::new();
        let mut b = EventQueue::new();
        for (cycle, v) in [(5u64, 0usize), (3, 1), (5, 2), (3, 3), (4, 4), (3, 5)] {
            a.push(cycle, v);
            b.push(cycle, v);
        }
        let mut drained: Vec<(u64, usize)> = Vec::new();
        let mut batch = Vec::new();
        while let Some(cycle) = a.peek_cycle() {
            a.drain_cycle(cycle, &mut batch);
            for v in batch.drain(..) {
                drained.push((cycle, v));
            }
        }
        let mut popped = Vec::new();
        while let Some(e) = b.pop() {
            popped.push(e);
        }
        assert_eq!(drained, popped);
        assert_eq!(drained, vec![(3, 1), (3, 3), (3, 5), (4, 4), (5, 0), (5, 2)]);
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(5, 0usize);
        q.push(1, 1);
        assert_eq!(q.pop(), Some((1, 1)));
        q.push(3, 2);
        q.push(3, 3);
        assert_eq!(q.pop(), Some((3, 2)));
        assert_eq!(q.pop(), Some((3, 3)));
        assert_eq!(q.pop(), Some((5, 0)));
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }
}
