//! Deterministic discrete-event queue for the serving simulator.
//!
//! A binary min-heap keyed by `(cycle, seq)` where `seq` is a monotone
//! insertion counter: two events scheduled for the same cycle pop in the
//! order they were pushed, so the simulation is a pure function of the
//! spec and seed — no iteration-order or wall-clock nondeterminism can
//! leak in. Payloads need no ordering of their own.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<T> {
    cycle: u64,
    seq: u64,
    payload: T,
}

// Manual impls: order by (cycle, seq) only — reversed so the std max-heap
// pops the earliest event first.
impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cycle == other.cycle && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.cycle, other.seq).cmp(&(self.cycle, self.seq))
    }
}

/// Min-heap of `(cycle, payload)` events with deterministic FIFO
/// tie-breaking at equal cycles.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `payload` at `cycle`. Events at the same cycle pop in push
    /// order.
    pub fn push(&mut self, cycle: u64, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry {
            cycle,
            seq,
            payload,
        });
    }

    /// Pop the earliest event as `(cycle, payload)`.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        self.heap.pop().map(|e| (e.cycle, e.payload))
    }

    /// Cycle of the earliest pending event.
    pub fn peek_cycle(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.cycle)
    }

    /// Batched drain: append every event scheduled at exactly `cycle` to
    /// `out`, in FIFO (push) order. The serving loop processes one
    /// timestamp per drain; events pushed *while* processing the batch —
    /// even at the same cycle — carry higher `seq`s, so the caller's next
    /// drain picks them up in exactly the order one-at-a-time popping
    /// would have (pinned by `drain_matches_pop_order`).
    pub fn drain_cycle(&mut self, cycle: u64, out: &mut Vec<T>) {
        while let Some(e) = self.heap.peek() {
            if e.cycle != cycle {
                break;
            }
            out.push(self.heap.pop().expect("peeked entry exists").payload);
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_cycle_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.peek_cycle(), Some(10));
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn equal_cycles_pop_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100usize {
            q.push(7, i);
        }
        for i in 0..100usize {
            assert_eq!(q.pop(), Some((7, i)));
        }
    }

    #[test]
    fn drain_matches_pop_order() {
        // The batched drain must yield exactly what repeated pops would.
        let mut a = EventQueue::new();
        let mut b = EventQueue::new();
        for (cycle, v) in [(5u64, 0usize), (3, 1), (5, 2), (3, 3), (4, 4), (3, 5)] {
            a.push(cycle, v);
            b.push(cycle, v);
        }
        let mut drained: Vec<(u64, usize)> = Vec::new();
        let mut batch = Vec::new();
        while let Some(cycle) = a.peek_cycle() {
            a.drain_cycle(cycle, &mut batch);
            for v in batch.drain(..) {
                drained.push((cycle, v));
            }
        }
        let mut popped = Vec::new();
        while let Some(e) = b.pop() {
            popped.push(e);
        }
        assert_eq!(drained, popped);
        assert_eq!(drained, vec![(3, 1), (3, 3), (3, 5), (4, 4), (5, 0), (5, 2)]);
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(5, 0usize);
        q.push(1, 1);
        assert_eq!(q.pop(), Some((1, 1)));
        q.push(3, 2);
        q.push(3, 3);
        assert_eq!(q.pop(), Some((3, 2)));
        assert_eq!(q.pop(), Some((3, 3)));
        assert_eq!(q.pop(), Some((5, 0)));
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }
}
