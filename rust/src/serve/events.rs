//! Deterministic discrete-event queue and event vocabulary for the
//! serving simulator.
//!
//! Events are totally ordered by `(cycle, seq)` where `seq` is a monotone
//! insertion counter: two events scheduled for the same cycle pop in the
//! order they were pushed, so the simulation is a pure function of the
//! spec and seed — no iteration-order or wall-clock nondeterminism can
//! leak in. Payloads need no ordering of their own.
//!
//! ## Implementation: a calendar queue
//!
//! [`EventQueue`] is a *calendar queue* (Brown 1988): a power-of-two ring
//! of unsorted buckets, each spanning `2^width_bits` cycles per wheel
//! rotation. An event at `cycle` lives in bucket
//! `(cycle >> width_bits) & mask`; finding the minimum scans bucket-days
//! forward from a cursor that only ever chases the earliest pending
//! event. With the bucket width sized to the mean event gap (re-estimated
//! on resize), push/pop/drain are O(1) amortized — the O(log n) heap
//! reshuffles that dominated large-fleet runs are gone. The previous
//! implementation is kept as [`BinaryHeapQueue`] and pinned byte-identical
//! by a differential storm test (`tests/serve.rs`): both structures
//! realize the same `(cycle, seq)` total order, so they are observably
//! interchangeable.
//!
//! ## Same-cycle tie-break contract
//!
//! Ties at one cycle resolve strictly in **push order**, which the
//! simulation exploits to pin a *pessimistic* resolution order
//! (`tests/serve.rs` holds the property tests):
//!
//! 1. **Fault-plan events first.** The seeded crash/recover/straggler
//!    timeline ([`super::faults::generate_plan`]) is enqueued before the
//!    arrival processes are seeded, so a crash at cycle `c` carries a
//!    lower `seq` than *any* event scheduled during the run for `c` — a
//!    batch completing exactly when its instance crashes is killed and
//!    re-homed, not completed.
//! 2. **Timeouts beat completions.** A per-attempt [`ServeEvent::Timeout`]
//!    is pushed at dispatch time, before the batch containing the attempt
//!    is launched (and thus before its [`ServeEvent::Complete`] exists);
//!    an attempt whose timeout lands exactly on its completion cycle is
//!    timed out.
//! 3. Among run-scheduled events, causal push order wins — identical to
//!    one-at-a-time popping even under `drain_cycle` batching (pinned by
//!    `drain_matches_pop_order`).

use super::faults::FaultKind;
use crate::sim::sdc::SdcSite;
use std::cell::Cell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// The serving simulator's event vocabulary. Ordering between same-cycle
/// events is purely push order (see the module docs); the variants carry
/// no priority of their own.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// A request arrives. `client` marks closed-loop re-issue chains
    /// (unused under open-loop traffic); `reissue_of` links a closed-loop
    /// re-issue to the request whose completion/rejection spawned it.
    Arrival {
        tenant: usize,
        client: bool,
        reissue_of: Option<usize>,
    },
    /// Re-dispatch request `req` after a retry backoff.
    Retry { req: usize },
    /// A partial batch's wait window may have expired on this instance.
    BatchTimer { instance: usize, token: u64 },
    /// The batch running on `instance` (its `running` set) finishes.
    /// `epoch` is the instance's crash epoch at launch: a crash bumps the
    /// epoch, so completions of batches killed by a crash are ignored.
    Complete { instance: usize, epoch: u32 },
    /// Attempt `token` of request `req` has been in flight for the
    /// timeout window; if still live it is cancelled (and retried or
    /// failed).
    Timeout { req: usize, token: u32 },
    /// Hedge trigger: if attempt `token` of `req` is still live, issue a
    /// duplicate attempt on another instance.
    Hedge { req: usize, token: u32 },
    /// A fault-plan event hits `instance`.
    Fault { instance: usize, kind: FaultKind },
    /// A planned silent-data-corruption flip lands on `instance`
    /// (ISSUE 10). `site` is the taxonomy site; `roll` is the pre-drawn
    /// detection uniform compared against the coverage model when the
    /// flip is consequential.
    Sdc {
        instance: usize,
        site: SdcSite,
        roll: f32,
    },
    /// Periodic resident-weight scrub fires on `instance` (protected
    /// runs only): latent weight corruption is detected here and cleared
    /// by re-verifying/reloading the weight image.
    Scrub { instance: usize },
}

struct Entry<T> {
    cycle: u64,
    seq: u64,
    payload: T,
}

// Manual impls: order by (cycle, seq) only — reversed so the std max-heap
// of [`BinaryHeapQueue`] pops the earliest event first.
impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cycle == other.cycle && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.cycle, other.seq).cmp(&(self.cycle, self.seq))
    }
}

/// Starting bucket count (power of two; resizes re-estimate from `len`).
const INITIAL_BUCKETS: usize = 64;
/// Starting log2 cycles-per-bucket (resizes re-estimate from the span).
const INITIAL_WIDTH_BITS: u32 = 16;
/// Bucket-count ceiling: 2^20 buckets ≈ 8 MB of headers, far above any
/// realistic pending-event population.
const MAX_BUCKETS: usize = 1 << 20;

/// Calendar queue of `(cycle, payload)` events with deterministic FIFO
/// tie-breaking at equal cycles — a drop-in replacement for the binary
/// heap ([`BinaryHeapQueue`]) with O(1) amortized operations.
pub struct EventQueue<T> {
    /// `buckets[(cycle >> width_bits) & mask]` holds the events of every
    /// *day* `cycle >> width_bits` congruent to that slot (unsorted).
    buckets: Vec<Vec<Entry<T>>>,
    /// `buckets.len() - 1`; the bucket count is a power of two.
    mask: u64,
    /// log2 of the cycle span of one bucket-day.
    width_bits: u32,
    /// Lower bound on the day of the earliest pending event. A `Cell` so
    /// `peek_cycle(&self)` can advance it past proven-empty days; it only
    /// moves backward when a push lands on an earlier day.
    day: Cell<u64>,
    len: usize,
    seq: u64,
    /// Empty-day scan work accrued since the last rebuild; when it
    /// outgrows the queue the widths are re-estimated, so sparse
    /// far-apart schedules stay cheap too.
    scan_debt: Cell<u64>,
    /// Scratch for `drain_cycle` (kept to stay allocation-free per drain).
    drain_buf: Vec<Entry<T>>,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue {
            buckets: (0..INITIAL_BUCKETS).map(|_| Vec::new()).collect(),
            mask: (INITIAL_BUCKETS - 1) as u64,
            width_bits: INITIAL_WIDTH_BITS,
            day: Cell::new(0),
            len: 0,
            seq: 0,
            scan_debt: Cell::new(0),
            drain_buf: Vec::new(),
        }
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `payload` at `cycle`. Events at the same cycle pop in push
    /// order.
    pub fn push(&mut self, cycle: u64, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        let day = cycle >> self.width_bits;
        if self.len == 0 || day < self.day.get() {
            self.day.set(day);
        }
        let bidx = (day & self.mask) as usize;
        self.buckets[bidx].push(Entry {
            cycle,
            seq,
            payload,
        });
        self.len += 1;
        self.maybe_rebuild();
    }

    /// Pop the earliest event as `(cycle, payload)` — the entry with the
    /// minimal `(cycle, seq)` key.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        self.maybe_rebuild();
        let cycle = self.find_min()?;
        let bidx = ((cycle >> self.width_bits) & self.mask) as usize;
        let b = &mut self.buckets[bidx];
        let mut pos = 0usize;
        let mut best_seq = u64::MAX;
        for (j, e) in b.iter().enumerate() {
            if e.cycle == cycle && e.seq < best_seq {
                best_seq = e.seq;
                pos = j;
            }
        }
        debug_assert!(best_seq != u64::MAX, "find_min pointed at an empty day");
        let e = b.swap_remove(pos);
        self.len -= 1;
        Some((e.cycle, e.payload))
    }

    /// Cycle of the earliest pending event.
    pub fn peek_cycle(&self) -> Option<u64> {
        self.find_min()
    }

    /// Batched drain: append every event scheduled at exactly `cycle` to
    /// `out`, in FIFO (push) order. The serving loop processes one
    /// timestamp per drain; events pushed *while* processing the batch —
    /// even at the same cycle — carry higher `seq`s, so the caller's next
    /// drain picks them up in exactly the order one-at-a-time popping
    /// would have (pinned by `drain_matches_pop_order`).
    pub fn drain_cycle(&mut self, cycle: u64, out: &mut Vec<T>) {
        if self.len == 0 {
            return;
        }
        let bidx = ((cycle >> self.width_bits) & self.mask) as usize;
        let bucket = &mut self.buckets[bidx];
        let batch = &mut self.drain_buf;
        let mut j = 0;
        while j < bucket.len() {
            if bucket[j].cycle == cycle {
                batch.push(bucket.swap_remove(j));
            } else {
                j += 1;
            }
        }
        if batch.is_empty() {
            return;
        }
        self.len -= batch.len();
        batch.sort_unstable_by_key(|e| e.seq);
        out.extend(batch.drain(..).map(|e| e.payload));
        self.maybe_rebuild();
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Cycle of the earliest pending event, advancing the day cursor past
    /// proven-empty days. A fruitless full rotation (everything pending is
    /// far in the future) falls back to a content scan and jumps the
    /// cursor straight to the earliest day.
    fn find_min(&self) -> Option<u64> {
        if self.len == 0 {
            return None;
        }
        let nb = self.buckets.len() as u64;
        let mut day = self.day.get();
        let mut skipped = 0u64;
        while skipped < nb {
            if let Some(cycle) = self.day_min(day) {
                self.day.set(day);
                self.scan_debt.set(self.scan_debt.get() + skipped);
                return Some(cycle);
            }
            day += 1;
            skipped += 1;
        }
        self.scan_debt.set(self.scan_debt.get() + skipped);
        let mut min_day = u64::MAX;
        for b in &self.buckets {
            for e in b {
                min_day = min_day.min(e.cycle >> self.width_bits);
            }
        }
        debug_assert!(min_day != u64::MAX, "non-empty queue with no entries");
        self.day.set(min_day);
        self.day_min(min_day)
    }

    /// Minimal cycle among `day`'s entries (its bucket also holds other
    /// days congruent modulo the ring size, which are filtered out).
    fn day_min(&self, day: u64) -> Option<u64> {
        let b = &self.buckets[(day & self.mask) as usize];
        let mut best: Option<u64> = None;
        for e in b {
            if e.cycle >> self.width_bits == day {
                let better = match best {
                    None => true,
                    Some(c) => e.cycle < c,
                };
                if better {
                    best = Some(e.cycle);
                }
            }
        }
        best
    }

    /// Resize/re-width when the population outgrew (or far undershot) the
    /// bucket count, or when empty-day scan debt says the width is stale.
    fn maybe_rebuild(&mut self) {
        let nb = self.buckets.len();
        let grow = self.len > nb * 2;
        let shrink = nb > INITIAL_BUCKETS && self.len * 8 < nb;
        let stale_width = self.scan_debt.get() > 8 * (self.len as u64 + nb as u64);
        if grow || shrink || stale_width {
            self.rebuild();
        }
    }

    /// Re-hash every entry into a ring sized to the current population,
    /// with the bucket width re-estimated from the pending cycle span
    /// (≈ 2× the mean inter-event gap per bucket-day, so one rotation
    /// covers the whole pending window and days hold O(1) events).
    fn rebuild(&mut self) {
        let target = (self.len.max(1) * 2)
            .next_power_of_two()
            .clamp(INITIAL_BUCKETS, MAX_BUCKETS);
        let mut min_c = u64::MAX;
        let mut max_c = 0u64;
        for b in &self.buckets {
            for e in b {
                min_c = min_c.min(e.cycle);
                max_c = max_c.max(e.cycle);
            }
        }
        if self.len >= 2 && max_c > min_c {
            let gap = ((max_c - min_c) / self.len as u64).max(1);
            let floor_log2 = 63 - gap.leading_zeros();
            self.width_bits = (floor_log2 + 1).min(40);
        }
        let old = std::mem::take(&mut self.buckets);
        self.buckets = (0..target).map(|_| Vec::new()).collect();
        self.mask = (target - 1) as u64;
        for bucket in old {
            for e in bucket {
                let bidx = ((e.cycle >> self.width_bits) & self.mask) as usize;
                self.buckets[bidx].push(e);
            }
        }
        self.day
            .set(if self.len == 0 { 0 } else { min_c >> self.width_bits });
        self.scan_debt.set(0);
    }
}

/// The original binary-heap event queue, kept as the executable
/// specification of the `(cycle, seq)` order: `tests/serve.rs` feeds
/// identical storms to both implementations and asserts byte-identical
/// pop sequences. Same API as [`EventQueue`].
pub struct BinaryHeapQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for BinaryHeapQueue<T> {
    fn default() -> Self {
        BinaryHeapQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }
}

impl<T> BinaryHeapQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `payload` at `cycle` (FIFO among equal cycles).
    pub fn push(&mut self, cycle: u64, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry {
            cycle,
            seq,
            payload,
        });
    }

    /// Pop the earliest event as `(cycle, payload)`.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        self.heap.pop().map(|e| (e.cycle, e.payload))
    }

    /// Cycle of the earliest pending event.
    pub fn peek_cycle(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.cycle)
    }

    /// Batched drain of every event at exactly `cycle`, in push order.
    pub fn drain_cycle(&mut self, cycle: u64, out: &mut Vec<T>) {
        while let Some(e) = self.heap.peek() {
            if e.cycle != cycle {
                break;
            }
            out.push(self.heap.pop().expect("peeked entry exists").payload);
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn pops_in_cycle_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.peek_cycle(), Some(10));
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn equal_cycles_pop_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100usize {
            q.push(7, i);
        }
        for i in 0..100usize {
            assert_eq!(q.pop(), Some((7, i)));
        }
    }

    #[test]
    fn drain_matches_pop_order() {
        // The batched drain must yield exactly what repeated pops would.
        let mut a = EventQueue::new();
        let mut b = EventQueue::new();
        for (cycle, v) in [(5u64, 0usize), (3, 1), (5, 2), (3, 3), (4, 4), (3, 5)] {
            a.push(cycle, v);
            b.push(cycle, v);
        }
        let mut drained: Vec<(u64, usize)> = Vec::new();
        let mut batch = Vec::new();
        while let Some(cycle) = a.peek_cycle() {
            a.drain_cycle(cycle, &mut batch);
            for v in batch.drain(..) {
                drained.push((cycle, v));
            }
        }
        let mut popped = Vec::new();
        while let Some(e) = b.pop() {
            popped.push(e);
        }
        assert_eq!(drained, popped);
        assert_eq!(drained, vec![(3, 1), (3, 3), (3, 5), (4, 4), (5, 0), (5, 2)]);
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(5, 0usize);
        q.push(1, 1);
        assert_eq!(q.pop(), Some((1, 1)));
        q.push(3, 2);
        q.push(3, 3);
        assert_eq!(q.pop(), Some((3, 2)));
        assert_eq!(q.pop(), Some((3, 3)));
        assert_eq!(q.pop(), Some((5, 0)));
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn far_future_gaps_rotate_and_jump() {
        // Events many wheel rotations apart exercise the fruitless-
        // rotation fallback (content scan + cursor jump).
        let mut q = EventQueue::new();
        q.push(0, 0usize);
        q.push(1 << 30, 1);
        q.push(1 << 45, 2);
        q.push(1, 3);
        assert_eq!(q.pop(), Some((0, 0)));
        assert_eq!(q.pop(), Some((1, 3)));
        assert_eq!(q.peek_cycle(), Some(1 << 30));
        // Pushing below the cursor after it jumped forward still works.
        q.push(2, 4);
        assert_eq!(q.pop(), Some((2, 4)));
        assert_eq!(q.pop(), Some((1 << 30, 1)));
        assert_eq!(q.pop(), Some((1 << 45, 2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn grow_shrink_stress_keeps_total_order() {
        // Force several rebuilds (grow past 64*2, then shrink) and check
        // the full pop sequence is sorted by (cycle, push order).
        let mut rng = Pcg32::new(2022_05, 1);
        let mut q = EventQueue::new();
        let mut pushed: Vec<(u64, usize)> = Vec::new();
        for i in 0..5_000usize {
            // Clustered cycles: plenty of exact ties.
            let cycle = (rng.below(1 << 20) as u64) & !0x3f;
            q.push(cycle, i);
            pushed.push((cycle, i));
        }
        pushed.sort_by_key(|&(c, i)| (c, i));
        let mut popped = Vec::new();
        while let Some(e) = q.pop() {
            popped.push(e);
        }
        assert_eq!(popped, pushed);
        assert!(q.is_empty());
    }

    #[test]
    fn calendar_matches_heap_reference_on_mixed_ops() {
        // Same op sequence against both implementations, interleaving
        // pushes, pops and whole-cycle drains.
        let mut rng = Pcg32::new(77, 3);
        let mut cal = EventQueue::new();
        let mut heap = BinaryHeapQueue::new();
        let mut id = 0usize;
        let mut cal_out: Vec<(u64, usize)> = Vec::new();
        let mut heap_out: Vec<(u64, usize)> = Vec::new();
        for _round in 0..200 {
            for _ in 0..rng.below(16) {
                let cycle = rng.below(1 << 14) as u64 / 3;
                cal.push(cycle, id);
                heap.push(cycle, id);
                id += 1;
            }
            match rng.below(3) {
                0 => {
                    if let Some(e) = cal.pop() {
                        cal_out.push(e);
                    }
                    if let Some(e) = heap.pop() {
                        heap_out.push(e);
                    }
                }
                1 => {
                    assert_eq!(cal.peek_cycle(), heap.peek_cycle());
                    if let Some(cycle) = cal.peek_cycle() {
                        let mut a = Vec::new();
                        let mut b = Vec::new();
                        cal.drain_cycle(cycle, &mut a);
                        heap.drain_cycle(cycle, &mut b);
                        assert_eq!(a, b);
                        cal_out.extend(a.into_iter().map(|v| (cycle, v)));
                        heap_out.extend(b.into_iter().map(|v| (cycle, v)));
                    }
                }
                _ => {}
            }
            assert_eq!(cal.len(), heap.len());
        }
        while let Some(e) = heap.pop() {
            heap_out.push(e);
        }
        while let Some(e) = cal.pop() {
            cal_out.push(e);
        }
        assert_eq!(cal_out, heap_out);
    }
}
