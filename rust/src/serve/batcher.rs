//! Dynamic batching: coalesce same-tenant requests so one weight-side
//! CVF stream is amortized across the whole batch (PR 3's traffic model:
//! weights stay resident in the weight SRAM while only activations stream
//! per image).
//!
//! Classic size-or-deadline window: a batch launches as soon as
//! `max_batch` same-tenant requests are queued, or when the oldest one
//! has waited `max_wait_cycles` — whichever comes first. `max_batch = 1`
//! degenerates to no batching (the naive baseline). Batches never mix
//! tenants: a batch shares one set of weights by construction.

use std::collections::VecDeque;

/// Batching window parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Largest batch a single launch may take (>= 1; 1 = no batching).
    pub max_batch: usize,
    /// Longest a queued request may wait for its batch to fill before the
    /// partial batch launches anyway.
    pub max_wait_cycles: u64,
}

impl BatchPolicy {
    /// No batching: every request launches alone, immediately.
    pub fn none() -> BatchPolicy {
        BatchPolicy {
            max_batch: 1,
            max_wait_cycles: 0,
        }
    }
}

/// One queued request.
#[derive(Debug, Clone, Copy)]
struct Pending {
    req: usize,
    arrival: u64,
}

/// Per-instance batching queues, one FIFO per tenant.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queues: Vec<VecDeque<Pending>>,
    queued: usize,
}

impl Batcher {
    pub fn new(policy: BatchPolicy, tenants: usize) -> Batcher {
        assert!(policy.max_batch >= 1, "max_batch must be >= 1");
        Batcher {
            policy,
            queues: vec![VecDeque::new(); tenants],
            queued: 0,
        }
    }

    /// Total requests waiting across all tenant queues.
    pub fn queued(&self) -> usize {
        self.queued
    }

    /// Enqueue a request of `tenant` that arrived at `arrival`.
    pub fn push(&mut self, tenant: usize, req: usize, arrival: u64) {
        self.queues[tenant].push_back(Pending { req, arrival });
        self.queued += 1;
    }

    /// The tenant whose queue is launchable at `now` — full to `max_batch`
    /// or with its head past the wait window — preferring the oldest head
    /// (ties: lowest tenant index). `None` if nothing is ready yet.
    fn ready_tenant(&self, now: u64) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for (t, q) in self.queues.iter().enumerate() {
            let Some(head) = q.front() else { continue };
            let full = q.len() >= self.policy.max_batch;
            let expired = now >= head.arrival.saturating_add(self.policy.max_wait_cycles);
            let better = match best {
                None => true,
                Some(b) => (head.arrival, t) < b,
            };
            if (full || expired) && better {
                best = Some((head.arrival, t));
            }
        }
        best.map(|(_, t)| t)
    }

    /// Pop a launchable batch at `now`: `(tenant, request ids)` in FIFO
    /// order, at most `max_batch` long. `None` if no queue is ready.
    pub fn take_ready(&mut self, now: u64) -> Option<(usize, Vec<usize>)> {
        let tenant = self.ready_tenant(now)?;
        let q = &mut self.queues[tenant];
        let n = q.len().min(self.policy.max_batch);
        let batch: Vec<usize> = q.drain(..n).map(|p| p.req).collect();
        self.queued -= batch.len();
        Some((tenant, batch))
    }

    /// Remove one queued request (a cancelled attempt: timeout, or the
    /// losing side of a hedge). Returns `true` if it was still queued —
    /// `false` means the request already launched in a batch and the
    /// in-flight work can only be discarded at completion.
    pub fn remove(&mut self, tenant: usize, req: usize) -> bool {
        let q = &mut self.queues[tenant];
        if let Some(pos) = q.iter().position(|p| p.req == req) {
            q.remove(pos);
            self.queued -= 1;
            true
        } else {
            false
        }
    }

    /// Drain every queued request (crash re-homing): `(tenant, request)`
    /// pairs in tenant-index order, FIFO within each tenant — a pinned,
    /// deterministic re-dispatch order.
    pub fn drain_all(&mut self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.queued);
        for (t, q) in self.queues.iter_mut().enumerate() {
            out.extend(q.drain(..).map(|p| (t, p.req)));
        }
        self.queued = 0;
        out
    }

    /// Earliest cycle at which a currently-queued partial batch becomes
    /// launchable by deadline (its head's arrival + wait window). `None`
    /// when every queue is empty. If something is already launchable this
    /// returns a cycle <= `now`.
    pub fn next_deadline(&self) -> Option<u64> {
        self.queues
            .iter()
            .filter_map(|q| q.front())
            .map(|p| p.arrival.saturating_add(self.policy.max_wait_cycles))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, wait: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait_cycles: wait,
        }
    }

    #[test]
    fn full_batch_launches_immediately() {
        let mut b = Batcher::new(policy(2, 1000), 2);
        b.push(0, 10, 5);
        assert_eq!(b.take_ready(5), None); // partial, window open
        b.push(0, 11, 6);
        let (t, reqs) = b.take_ready(6).unwrap();
        assert_eq!((t, reqs), (0, vec![10, 11]));
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn deadline_launches_partial_batch() {
        let mut b = Batcher::new(policy(4, 100), 1);
        b.push(0, 1, 50);
        assert_eq!(b.next_deadline(), Some(150));
        assert_eq!(b.take_ready(149), None);
        let (t, reqs) = b.take_ready(150).unwrap();
        assert_eq!((t, reqs), (0, vec![1]));
        assert_eq!(b.next_deadline(), None);
    }

    #[test]
    fn no_batching_is_immediate_and_single() {
        let mut b = Batcher::new(BatchPolicy::none(), 1);
        b.push(0, 7, 0);
        b.push(0, 8, 0);
        assert_eq!(b.take_ready(0).unwrap().1, vec![7]);
        assert_eq!(b.take_ready(0).unwrap().1, vec![8]);
        assert_eq!(b.take_ready(0), None);
    }

    #[test]
    fn oldest_head_wins_across_tenants() {
        let mut b = Batcher::new(policy(1, 0), 3);
        b.push(2, 20, 10);
        b.push(0, 30, 20);
        assert_eq!(b.take_ready(20).unwrap(), (2, vec![20]));
        assert_eq!(b.take_ready(20).unwrap(), (0, vec![30]));
    }

    #[test]
    fn batches_never_mix_tenants() {
        let mut b = Batcher::new(policy(8, 0), 2);
        b.push(0, 1, 0);
        b.push(1, 2, 0);
        b.push(0, 3, 0);
        let (t, reqs) = b.take_ready(0).unwrap();
        assert_eq!((t, reqs), (0, vec![1, 3]));
        let (t, reqs) = b.take_ready(0).unwrap();
        assert_eq!((t, reqs), (1, vec![2]));
    }

    #[test]
    fn remove_cancels_queued_but_not_launched() {
        let mut b = Batcher::new(policy(4, 1000), 2);
        b.push(0, 1, 0);
        b.push(0, 2, 0);
        b.push(1, 3, 0);
        assert!(b.remove(0, 2));
        assert_eq!(b.queued(), 2);
        assert!(!b.remove(0, 2), "already removed");
        assert!(!b.remove(1, 99), "never queued");
        // The remaining entries are intact and FIFO.
        let (t, reqs) = b.take_ready(1_000).unwrap();
        assert_eq!((t, reqs), (0, vec![1]));
        assert!(!b.remove(0, 1), "launched requests are not queued");
    }

    #[test]
    fn drain_all_is_tenant_order_fifo() {
        let mut b = Batcher::new(policy(8, 1000), 3);
        b.push(2, 20, 0);
        b.push(0, 1, 1);
        b.push(2, 21, 2);
        b.push(0, 2, 3);
        let drained = b.drain_all();
        assert_eq!(drained, vec![(0, 1), (0, 2), (2, 20), (2, 21)]);
        assert_eq!(b.queued(), 0);
        assert_eq!(b.next_deadline(), None);
        assert!(b.drain_all().is_empty());
    }

    #[test]
    fn oversized_queue_drains_in_max_batch_chunks() {
        let mut b = Batcher::new(policy(3, 0), 1);
        for i in 0..7 {
            b.push(0, i, 0);
        }
        assert_eq!(b.take_ready(0).unwrap().1.len(), 3);
        assert_eq!(b.take_ready(0).unwrap().1.len(), 3);
        assert_eq!(b.take_ready(0).unwrap().1.len(), 1);
        assert_eq!(b.queued(), 0);
    }
}
