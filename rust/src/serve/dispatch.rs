//! Dispatch policies: which accelerator instance admits an arriving
//! request.
//!
//! * **Round-robin** — the naive baseline: instances in rotation,
//!   regardless of load or which network's weights they hold. Rejects if
//!   the chosen instance is full (no second try), like a dumb L4 balancer.
//! * **Least-loaded** — the instance with the smallest backlog (estimated
//!   queued service cycles plus remaining busy time) that still has queue
//!   space; ties break on the lowest index.
//! * **Network-affinity** — each network is sharded onto a *home* subset
//!   of instances, so an instance mostly re-serves the network whose
//!   compiled weights ([`crate::engine::PreparedNetwork`], shared through
//!   the compile cache) it already streamed — avoiding the weight-reload
//!   switch penalty and giving the batcher same-tenant runs to coalesce.
//!   Within the home set the least-loaded instance wins; if every home
//!   queue is full the request spills to the global least-loaded instance
//!   rather than being rejected outright.
//!
//! All policies are **failure-aware** (ISSUE 6): a `Down` (crashed)
//! instance admits nothing — even naive round-robin cannot route to a
//! dead chip. Least-loaded and affinity additionally avoid `Degraded`
//! (straggling / breaker-open) instances whenever an `Up` instance with
//! queue space exists, so limping chips only absorb overflow.

use super::faults::Health;
use anyhow::{bail, Result};

/// A dispatcher's view of one instance at admission time.
#[derive(Debug, Clone, Copy)]
pub struct InstanceLoad {
    /// Requests waiting in the instance's queues (all tenants).
    pub queued: usize,
    /// Estimated cycles to drain: queued marginal service + remaining busy.
    pub backlog_cycles: u64,
    /// Whether the instance can admit another request (queue cap).
    pub has_space: bool,
    /// Crash/straggler/breaker state; `Down` never admits, `Degraded` is
    /// a last resort for the load-aware policies.
    pub health: Health,
}

/// Admission policy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    RoundRobin,
    LeastLoaded,
    NetworkAffinity,
}

impl DispatchPolicy {
    /// Parse a CLI flag value.
    pub fn parse(s: &str) -> Result<DispatchPolicy> {
        Ok(match s {
            "round-robin" | "rr" => DispatchPolicy::RoundRobin,
            "least-loaded" | "ll" => DispatchPolicy::LeastLoaded,
            "affinity" | "network-affinity" => DispatchPolicy::NetworkAffinity,
            other => bail!(
                "unknown dispatch policy '{other}' \
                 (known: round-robin, least-loaded, affinity)"
            ),
        })
    }

    /// Label used in reports and cache keys.
    pub fn label(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::LeastLoaded => "least-loaded",
            DispatchPolicy::NetworkAffinity => "affinity",
        }
    }
}

/// Stateful dispatcher over a fixed fleet.
#[derive(Debug)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    rr_cursor: usize,
    /// Home instance set per network id (affinity policy only).
    homes: Vec<Vec<usize>>,
}

impl Dispatcher {
    /// `nets` is the number of distinct networks in the mix; `instances`
    /// the fleet size. Affinity homes are a deterministic partition: net
    /// `i` owns a contiguous run of `ceil(instances / nets)` instances
    /// starting at `i * instances / nets` (wrapping), so every instance
    /// serves at most a couple of networks and every network has a home.
    pub fn new(policy: DispatchPolicy, nets: usize, instances: usize) -> Dispatcher {
        assert!(instances > 0, "empty fleet");
        let per_net = instances.div_ceil(nets.max(1)).max(1);
        let homes = (0..nets)
            .map(|i| {
                let start = i * instances / nets.max(1);
                (0..per_net).map(|j| (start + j) % instances).collect()
            })
            .collect();
        Dispatcher {
            policy,
            rr_cursor: 0,
            homes,
        }
    }

    /// Home instances of a network (affinity sharding), for reports.
    pub fn home_of(&self, net_id: usize) -> &[usize] {
        &self.homes[net_id]
    }

    /// Pick the instance that admits a request of network `net_id`, or
    /// `None` to reject. `loads` is indexed by instance.
    pub fn choose(&mut self, net_id: usize, loads: &[InstanceLoad]) -> Option<usize> {
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let i = self.rr_cursor % loads.len();
                self.rr_cursor = (self.rr_cursor + 1) % loads.len();
                (loads[i].has_space && loads[i].health != Health::Down).then_some(i)
            }
            DispatchPolicy::LeastLoaded => least_loaded(loads, None),
            DispatchPolicy::NetworkAffinity => {
                least_loaded(loads, Some(&self.homes[net_id]))
                    .or_else(|| least_loaded(loads, None))
            }
        }
    }
}

/// Least-backlog instance with queue space, optionally restricted to a
/// candidate subset. `Down` instances are never eligible; `Degraded`
/// ones lose to any healthy candidate (the comparison key leads with the
/// degraded bit), so limping chips only take traffic when every `Up`
/// queue is full. Ties break on the lowest instance index (candidate
/// lists are built in ascending order by construction).
fn least_loaded(loads: &[InstanceLoad], among: Option<&[usize]>) -> Option<usize> {
    let mut best: Option<usize> = None;
    let key =
        |l: InstanceLoad, i: usize| (l.health == Health::Degraded, l.backlog_cycles, l.queued, i);
    let consider = |i: usize, best: &mut Option<usize>| {
        if !loads[i].has_space || loads[i].health == Health::Down {
            return;
        }
        match *best {
            None => *best = Some(i),
            Some(b) => {
                if key(loads[i], i) < key(loads[b], b) {
                    *best = Some(i);
                }
            }
        }
    };
    match among {
        Some(set) => {
            for &i in set {
                consider(i, &mut best);
            }
        }
        None => {
            for i in 0..loads.len() {
                consider(i, &mut best);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(backlog: u64, queued: usize, space: bool) -> InstanceLoad {
        InstanceLoad {
            queued,
            backlog_cycles: backlog,
            has_space: space,
            health: Health::Up,
        }
    }

    #[test]
    fn parse_and_label_round_trip() {
        for (s, p) in [
            ("round-robin", DispatchPolicy::RoundRobin),
            ("least-loaded", DispatchPolicy::LeastLoaded),
            ("affinity", DispatchPolicy::NetworkAffinity),
        ] {
            assert_eq!(DispatchPolicy::parse(s).unwrap(), p);
            assert_eq!(DispatchPolicy::parse(p.label()).unwrap(), p);
        }
        assert!(DispatchPolicy::parse("bogus").is_err());
    }

    #[test]
    fn round_robin_rotates_and_rejects_on_full() {
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin, 2, 3);
        let mut loads = vec![load(0, 0, true); 3];
        assert_eq!(d.choose(0, &loads), Some(0));
        assert_eq!(d.choose(1, &loads), Some(1));
        assert_eq!(d.choose(0, &loads), Some(2));
        assert_eq!(d.choose(0, &loads), Some(0));
        loads[1].has_space = false;
        // Naive: lands on the full instance and rejects, no retry.
        assert_eq!(d.choose(0, &loads), None);
    }

    #[test]
    fn least_loaded_prefers_smallest_backlog_with_space() {
        let mut d = Dispatcher::new(DispatchPolicy::LeastLoaded, 2, 3);
        let loads = vec![load(500, 2, true), load(100, 1, false), load(200, 1, true)];
        assert_eq!(d.choose(0, &loads), Some(2));
        let empty = vec![load(0, 0, false); 3];
        assert_eq!(d.choose(0, &empty), None);
    }

    #[test]
    fn no_policy_routes_to_a_down_instance() {
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::NetworkAffinity,
        ] {
            let mut d = Dispatcher::new(policy, 1, 2);
            let mut loads = vec![load(0, 0, true); 2];
            loads[0].health = Health::Down;
            for _ in 0..4 {
                if let Some(i) = d.choose(0, &loads) {
                    assert_eq!(i, 1, "{policy:?} routed to a dead instance");
                }
            }
            // Whole fleet down: every policy rejects.
            loads[1].health = Health::Down;
            for _ in 0..4 {
                assert_eq!(d.choose(0, &loads), None, "{policy:?} admits to a dead fleet");
            }
        }
    }

    #[test]
    fn degraded_instance_is_a_last_resort_for_load_aware_policies() {
        let mut d = Dispatcher::new(DispatchPolicy::LeastLoaded, 1, 3);
        // The degraded instance has the smallest backlog but loses to any
        // healthy instance with space.
        let mut loads = vec![load(10, 1, true), load(500, 3, true), load(900, 4, true)];
        loads[0].health = Health::Degraded;
        assert_eq!(d.choose(0, &loads), Some(1));
        // Healthy queues full: the limping instance absorbs the overflow
        // rather than the request being rejected.
        loads[1].has_space = false;
        loads[2].has_space = false;
        assert_eq!(d.choose(0, &loads), Some(0));
    }

    #[test]
    fn affinity_homes_partition_and_spill() {
        let mut d = Dispatcher::new(DispatchPolicy::NetworkAffinity, 3, 4);
        // Every net has at least one home; homes are within range.
        for net in 0..3 {
            assert!(!d.home_of(net).is_empty());
            assert!(d.home_of(net).iter().all(|&i| i < 4));
        }
        // Different nets prefer different instances when idle.
        let loads = vec![load(0, 0, true); 4];
        let picks: Vec<usize> = (0..3).map(|n| d.choose(n, &loads).unwrap()).collect();
        assert!(picks.windows(2).any(|w| w[0] != w[1]), "picks {picks:?}");
        // Home full -> spills to a non-home instance instead of rejecting.
        let home = d.home_of(0).to_vec();
        let mut loads = vec![load(0, 0, true); 4];
        for &h in &home {
            loads[h].has_space = false;
        }
        let spill = d.choose(0, &loads).unwrap();
        assert!(!home.contains(&spill));
        // Everything full -> reject.
        let full = vec![load(0, 0, false); 4];
        assert_eq!(d.choose(0, &full), None);
    }
}
