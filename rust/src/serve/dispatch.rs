//! Dispatch policies: which accelerator instance admits an arriving
//! request.
//!
//! * **Round-robin** — the naive baseline: instances in rotation,
//!   regardless of load or which network's weights they hold. Rejects if
//!   the chosen instance is full (no second try), like a dumb L4 balancer.
//! * **Least-loaded** — the instance with the smallest backlog (estimated
//!   queued service cycles plus remaining busy time) that still has queue
//!   space; ties break on the lowest index.
//! * **Network-affinity** — each network is sharded onto a *home* subset
//!   of instances, so an instance mostly re-serves the network whose
//!   compiled weights ([`crate::engine::PreparedNetwork`], shared through
//!   the compile cache) it already streamed — avoiding the weight-reload
//!   switch penalty and giving the batcher same-tenant runs to coalesce.
//!   Within the home set the least-loaded instance wins; if every home
//!   queue is full the request spills to the global least-loaded instance
//!   rather than being rejected outright.
//! * **Hierarchical** — the 10k-instance policy: cluster → rack →
//!   instance. Power-of-two-choices over lazily-maintained per-rack load
//!   summaries picks a rack, power-of-two-choices within the rack picks
//!   an instance (same comparison key as least-loaded), and a bounded
//!   spiral over the remaining racks absorbs the full/dead corner cases —
//!   O(log n) routing instead of the O(n) scan, at the cost of a seeded
//!   candidate stream (deterministic per `(seed, call sequence)`).
//!
//! ## Load snapshots ([`FleetLoads`])
//!
//! The legacy loop rebuilt an `InstanceLoad` vector from scratch on
//! *every* dispatch — O(fleet) per request. [`FleetLoads`] instead caches
//! one entry per instance holding the **raw** time-independent fields
//! (queue depth, queued cycles, busy-until, crash/straggler/breaker
//! state); the event loop updates exactly the entries whose instances
//! changed (launch, completion, crash, recovery, timeout, cancellation —
//! the completion/crash-epoch invalidation points), and the policies
//! evaluate the time-*dependent* key (remaining busy cycles, breaker
//! expiry) lazily at choose time. The evaluated key is mathematically
//! identical to the rebuilt snapshot's, so cached dispatch decisions are
//! byte-identical to the legacy scan's.
//!
//! All policies are **failure-aware** (ISSUE 6): a `Down` (crashed)
//! instance admits nothing — even naive round-robin cannot route to a
//! dead chip. Least-loaded and affinity additionally avoid `Degraded`
//! (straggling / breaker-open) instances whenever an `Up` instance with
//! queue space exists, so limping chips only absorb overflow.

use super::faults::Health;
use crate::util::rng::Pcg32;
use anyhow::{bail, Result};
use std::ops::Range;

/// PCG32 stream id of the hierarchical policy's candidate draws. Distinct
/// from the arrival stream (1), the traffic-modulation stream (2), the
/// per-request fault stream (7) and the per-instance fault-plan streams
/// (0x0F00+), so the legacy policies — which draw nothing from it — keep
/// their exact event sequences.
pub const DISPATCH_STREAM: u64 = 3;

/// A dispatcher's view of one instance: raw load fields cached by the
/// event loop (see the module docs). Time-dependent quantities are
/// derived at choose time via [`InstanceLoad::backlog_at`] and
/// [`InstanceLoad::health`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InstanceLoad {
    /// Requests waiting in the instance's queues (all tenants).
    pub queued: usize,
    /// Estimated marginal service cycles queued but not launched.
    pub queued_cycles: u64,
    /// The running batch occupies the chip until this cycle.
    pub busy_until: u64,
    /// Whether the queue has room under the cap.
    pub has_space: bool,
    /// Crashed (never admits).
    pub down: bool,
    /// In a straggler episode (`slowdown > 1`).
    pub slow: bool,
    /// Timeout breaker open until this cycle (`Degraded` before it).
    pub breaker_until: u64,
}

impl InstanceLoad {
    /// A fresh, idle, healthy instance.
    pub fn idle() -> InstanceLoad {
        InstanceLoad {
            queued: 0,
            queued_cycles: 0,
            busy_until: 0,
            has_space: true,
            down: false,
            slow: false,
            breaker_until: 0,
        }
    }

    /// Crash/straggler/breaker state as dispatch sees it at `now`.
    pub fn health(&self, now: u64) -> Health {
        if self.down {
            Health::Down
        } else if self.slow || self.breaker_until > now {
            Health::Degraded
        } else {
            Health::Up
        }
    }

    /// Estimated cycles to drain at `now`: queued marginal service plus
    /// remaining busy time.
    pub fn backlog_at(&self, now: u64) -> u64 {
        self.queued_cycles + self.busy_until.saturating_sub(now)
    }
}

/// Aggregated load of one rack — maintained incrementally by
/// [`FleetLoads::update`] so rack selection never scans instances.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RackLoad {
    /// Total queued requests across the rack.
    pub queued: usize,
    /// Instances that are up (not crashed).
    pub up: usize,
    /// Up instances with queue space.
    pub space: usize,
}

/// Per-instance load cache plus per-rack and fleet-level summaries, all
/// maintained in O(1) per instance change.
#[derive(Debug)]
pub struct FleetLoads {
    loads: Vec<InstanceLoad>,
    /// Instances per rack (the last rack may be smaller).
    rack_len: usize,
    racks: Vec<RackLoad>,
    total_queued: usize,
    alive: usize,
}

impl FleetLoads {
    /// A fleet of `instances` idle instances split into `racks` contiguous
    /// racks (clamped to at least one; more racks than instances degrade
    /// to one instance per rack).
    pub fn new(instances: usize, racks: usize) -> FleetLoads {
        assert!(instances > 0, "empty fleet");
        let rack_len = instances.div_ceil(racks.max(1)).max(1);
        let nracks = instances.div_ceil(rack_len);
        let mut f = FleetLoads {
            loads: vec![InstanceLoad::idle(); instances],
            rack_len,
            racks: vec![RackLoad::default(); nracks],
            total_queued: 0,
            alive: instances,
        };
        for i in 0..instances {
            let r = i / rack_len;
            f.racks[r].up += 1;
            f.racks[r].space += 1;
        }
        f
    }

    pub fn len(&self) -> usize {
        self.loads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.loads.is_empty()
    }

    /// The cached load of instance `i`.
    pub fn get(&self, i: usize) -> InstanceLoad {
        self.loads[i]
    }

    /// The rack summaries, indexed by rack id.
    pub fn racks(&self) -> &[RackLoad] {
        &self.racks
    }

    /// Instance index range of rack `r`.
    pub fn rack_range(&self, r: usize) -> Range<usize> {
        let start = r * self.rack_len;
        start..(start + self.rack_len).min(self.loads.len())
    }

    /// Queued requests across instances that are up. Crashed instances
    /// always cache `queued == 0` (a crash drains the queue and a down
    /// instance admits nothing), so this equals the alive-only scan.
    pub fn total_queued(&self) -> usize {
        self.total_queued
    }

    /// Instances that are up (not crashed).
    pub fn alive(&self) -> usize {
        self.alive
    }

    /// Replace instance `i`'s cached load, folding the delta into its
    /// rack's and the fleet's summaries — O(1).
    pub fn update(&mut self, i: usize, new: InstanceLoad) {
        let old = self.loads[i];
        let rack = &mut self.racks[i / self.rack_len];
        rack.queued = rack.queued + new.queued - old.queued;
        self.total_queued = self.total_queued + new.queued - old.queued;
        if old.down != new.down {
            if new.down {
                rack.up -= 1;
                self.alive -= 1;
            } else {
                rack.up += 1;
                self.alive += 1;
            }
        }
        let old_space = !old.down && old.has_space;
        let new_space = !new.down && new.has_space;
        if old_space != new_space {
            if new_space {
                rack.space += 1;
            } else {
                rack.space -= 1;
            }
        }
        self.loads[i] = new;
    }

    /// Verify every summary against a full recount (debug/test harness
    /// for the lazy maintenance).
    pub fn assert_consistent(&self) {
        let mut total = 0usize;
        let mut alive = 0usize;
        for (r, rl) in self.racks.iter().enumerate() {
            let range = self.rack_range(r);
            let queued: usize = range.clone().map(|i| self.loads[i].queued).sum();
            let up = range.clone().filter(|&i| !self.loads[i].down).count();
            let space = range
                .clone()
                .filter(|&i| !self.loads[i].down && self.loads[i].has_space)
                .count();
            assert_eq!(rl.queued, queued, "rack {r} queued summary is stale");
            assert_eq!(rl.up, up, "rack {r} up summary is stale");
            assert_eq!(rl.space, space, "rack {r} space summary is stale");
            total += queued;
            alive += up;
        }
        assert_eq!(self.total_queued, total, "fleet queued summary is stale");
        assert_eq!(self.alive, alive, "fleet alive summary is stale");
    }
}

/// Admission policy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    RoundRobin,
    LeastLoaded,
    NetworkAffinity,
    Hierarchical,
}

impl DispatchPolicy {
    /// Parse a CLI flag value.
    pub fn parse(s: &str) -> Result<DispatchPolicy> {
        Ok(match s {
            "round-robin" | "rr" => DispatchPolicy::RoundRobin,
            "least-loaded" | "ll" => DispatchPolicy::LeastLoaded,
            "affinity" | "network-affinity" => DispatchPolicy::NetworkAffinity,
            "hier" | "hierarchical" | "p2c" => DispatchPolicy::Hierarchical,
            other => bail!(
                "unknown dispatch policy '{other}' \
                 (known: round-robin, least-loaded, affinity, hierarchical)"
            ),
        })
    }

    /// Label used in reports and cache keys.
    pub fn label(&self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::LeastLoaded => "least-loaded",
            DispatchPolicy::NetworkAffinity => "affinity",
            DispatchPolicy::Hierarchical => "hierarchical",
        }
    }
}

/// Stateful dispatcher over a fixed fleet.
#[derive(Debug)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    rr_cursor: usize,
    /// Home instance set per network id (affinity policy only).
    homes: Vec<Vec<usize>>,
    /// Candidate draws for the hierarchical policy (dedicated stream;
    /// untouched by the legacy policies).
    rng: Pcg32,
}

impl Dispatcher {
    /// `nets` is the number of distinct networks in the mix; `instances`
    /// the fleet size. Affinity homes are a deterministic partition: net
    /// `i` owns a contiguous run of `ceil(instances / nets)` instances
    /// starting at `i * instances / nets` (wrapping), so every instance
    /// serves at most a couple of networks and every network has a home.
    pub fn new(policy: DispatchPolicy, nets: usize, instances: usize, seed: u64) -> Dispatcher {
        assert!(instances > 0, "empty fleet");
        let per_net = instances.div_ceil(nets.max(1)).max(1);
        let homes = (0..nets)
            .map(|i| {
                let start = i * instances / nets.max(1);
                (0..per_net).map(|j| (start + j) % instances).collect()
            })
            .collect();
        Dispatcher {
            policy,
            rr_cursor: 0,
            homes,
            rng: Pcg32::new(seed, DISPATCH_STREAM),
        }
    }

    /// Home instances of a network (affinity sharding), for reports.
    pub fn home_of(&self, net_id: usize) -> &[usize] {
        &self.homes[net_id]
    }

    /// Pick the instance that admits a request of network `net_id`, or
    /// `None` to reject. `avoid` lists instances this request must not
    /// land on (a hedge races on a different chip than its live twin);
    /// it is empty on every non-hedge dispatch.
    pub fn choose(
        &mut self,
        net_id: usize,
        fleet: &FleetLoads,
        now: u64,
        avoid: &[usize],
    ) -> Option<usize> {
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let n = fleet.len();
                let i = self.rr_cursor % n;
                self.rr_cursor = (self.rr_cursor + 1) % n;
                let l = fleet.get(i);
                (l.has_space && !l.down && !avoid.contains(&i)).then_some(i)
            }
            DispatchPolicy::LeastLoaded => least_loaded(fleet, None, now, avoid),
            DispatchPolicy::NetworkAffinity => {
                least_loaded(fleet, Some(&self.homes[net_id]), now, avoid)
                    .or_else(|| least_loaded(fleet, None, now, avoid))
            }
            DispatchPolicy::Hierarchical => self.choose_hierarchical(fleet, now, avoid),
        }
    }

    /// Cluster → rack → instance. Two random racks compete on their
    /// summaries (admitting racks first, then mean queue depth); within
    /// the winner two random instances compete on the least-loaded key;
    /// if both candidates are ineligible a rack-local scan decides, and
    /// if the whole rack is full the search spirals to the next rack.
    /// Work per dispatch is O(rack) worst case, O(1) typical.
    fn choose_hierarchical(
        &mut self,
        fleet: &FleetLoads,
        now: u64,
        avoid: &[usize],
    ) -> Option<usize> {
        let nr = fleet.racks().len();
        let a = self.rng.below(nr as u32) as usize;
        let b = self.rng.below(nr as u32) as usize;
        let rack_key = |r: usize| {
            let rl = fleet.racks()[r];
            // Racks with no admitting instance lose outright; otherwise
            // compare mean queue depth (scaled to dodge integer division).
            (rl.space == 0, rl.queued * 1024 / rl.up.max(1), r)
        };
        let start = if rack_key(a) <= rack_key(b) { a } else { b };
        let eligible = |i: usize| {
            let l = fleet.get(i);
            l.has_space && !l.down && !avoid.contains(&i)
        };
        let key = |i: usize| {
            let l = fleet.get(i);
            (l.health(now) == Health::Degraded, l.backlog_at(now), l.queued, i)
        };
        for k in 0..nr {
            let r = (start + k) % nr;
            if fleet.racks()[r].space == 0 {
                continue;
            }
            let range = fleet.rack_range(r);
            let len = range.len() as u32;
            let c1 = range.start + self.rng.below(len) as usize;
            let c2 = range.start + self.rng.below(len) as usize;
            let pick = match (eligible(c1), eligible(c2)) {
                (true, true) => Some(if key(c1) <= key(c2) { c1 } else { c2 }),
                (true, false) => Some(c1),
                (false, true) => Some(c2),
                // Both candidates full/down/avoided: scan the rack (its
                // summary says someone in it admits — unless `avoid`
                // covers them, in which case spiral on).
                (false, false) => range.filter(|&i| eligible(i)).min_by_key(|&i| key(i)),
            };
            if pick.is_some() {
                return pick;
            }
        }
        None
    }
}

/// Least-backlog instance with queue space, optionally restricted to a
/// candidate subset. `Down` instances are never eligible; `Degraded`
/// ones lose to any healthy candidate (the comparison key leads with the
/// degraded bit), so limping chips only take traffic when every `Up`
/// queue is full. Ties break on the lowest instance index (candidate
/// lists are built in ascending order by construction).
fn least_loaded(
    fleet: &FleetLoads,
    among: Option<&[usize]>,
    now: u64,
    avoid: &[usize],
) -> Option<usize> {
    let mut best: Option<usize> = None;
    let key = |l: InstanceLoad, i: usize| {
        (
            l.health(now) == Health::Degraded,
            l.backlog_at(now),
            l.queued,
            i,
        )
    };
    let consider = |i: usize, best: &mut Option<usize>| {
        let l = fleet.get(i);
        if !l.has_space || l.down || avoid.contains(&i) {
            return;
        }
        match *best {
            None => *best = Some(i),
            Some(b) => {
                if key(l, i) < key(fleet.get(b), b) {
                    *best = Some(i);
                }
            }
        }
    };
    match among {
        Some(set) => {
            for &i in set {
                consider(i, &mut best);
            }
        }
        None => {
            for i in 0..fleet.len() {
                consider(i, &mut best);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(backlog: u64, queued: usize, space: bool) -> InstanceLoad {
        InstanceLoad {
            queued,
            queued_cycles: backlog,
            busy_until: 0,
            has_space: space,
            down: false,
            slow: false,
            breaker_until: 0,
        }
    }

    fn fleet_of(loads: Vec<InstanceLoad>, racks: usize) -> FleetLoads {
        let mut f = FleetLoads::new(loads.len(), racks);
        for (i, l) in loads.into_iter().enumerate() {
            f.update(i, l);
        }
        f.assert_consistent();
        f
    }

    #[test]
    fn parse_and_label_round_trip() {
        for (s, p) in [
            ("round-robin", DispatchPolicy::RoundRobin),
            ("least-loaded", DispatchPolicy::LeastLoaded),
            ("affinity", DispatchPolicy::NetworkAffinity),
            ("hier", DispatchPolicy::Hierarchical),
        ] {
            assert_eq!(DispatchPolicy::parse(s).unwrap(), p);
            assert_eq!(DispatchPolicy::parse(p.label()).unwrap(), p);
        }
        assert!(DispatchPolicy::parse("bogus").is_err());
    }

    #[test]
    fn round_robin_rotates_and_rejects_on_full() {
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin, 2, 3, 0);
        let f = fleet_of(vec![load(0, 0, true); 3], 1);
        assert_eq!(d.choose(0, &f, 0, &[]), Some(0));
        assert_eq!(d.choose(1, &f, 0, &[]), Some(1));
        assert_eq!(d.choose(0, &f, 0, &[]), Some(2));
        assert_eq!(d.choose(0, &f, 0, &[]), Some(0));
        let mut f = f;
        f.update(1, load(0, 0, false));
        // Naive: lands on the full instance and rejects, no retry.
        assert_eq!(d.choose(0, &f, 0, &[]), None);
    }

    #[test]
    fn least_loaded_prefers_smallest_backlog_with_space() {
        let mut d = Dispatcher::new(DispatchPolicy::LeastLoaded, 2, 3, 0);
        let f = fleet_of(
            vec![load(500, 2, true), load(100, 1, false), load(200, 1, true)],
            1,
        );
        assert_eq!(d.choose(0, &f, 0, &[]), Some(2));
        let empty = fleet_of(vec![load(0, 0, false); 3], 1);
        assert_eq!(d.choose(0, &empty, 0, &[]), None);
    }

    #[test]
    fn backlog_decays_with_now_exactly_like_the_rebuilt_snapshot() {
        // The cached entry stores busy_until raw; the key derives the
        // remaining busy cycles at choose time, matching what a fresh
        // per-arrival rebuild would have computed.
        let mut l = load(100, 1, true);
        l.busy_until = 1_000;
        assert_eq!(l.backlog_at(0), 1_100);
        assert_eq!(l.backlog_at(400), 700);
        assert_eq!(l.backlog_at(2_000), 100, "busy part saturates at zero");
        let mut b = load(0, 0, true);
        b.breaker_until = 500;
        assert_eq!(b.health(499), Health::Degraded);
        assert_eq!(b.health(500), Health::Up, "breaker closes on expiry");
    }

    #[test]
    fn no_policy_routes_to_a_down_instance() {
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::NetworkAffinity,
            DispatchPolicy::Hierarchical,
        ] {
            let mut d = Dispatcher::new(policy, 1, 2, 11);
            let mut f = fleet_of(vec![load(0, 0, true); 2], 1);
            let mut dead = load(0, 0, true);
            dead.down = true;
            f.update(0, dead);
            for _ in 0..4 {
                if let Some(i) = d.choose(0, &f, 0, &[]) {
                    assert_eq!(i, 1, "{policy:?} routed to a dead instance");
                }
            }
            // Whole fleet down: every policy rejects.
            f.update(1, dead);
            for _ in 0..4 {
                assert_eq!(d.choose(0, &f, 0, &[]), None, "{policy:?} admits to a dead fleet");
            }
        }
    }

    #[test]
    fn degraded_instance_is_a_last_resort_for_load_aware_policies() {
        let mut d = Dispatcher::new(DispatchPolicy::LeastLoaded, 1, 3, 0);
        // The degraded instance has the smallest backlog but loses to any
        // healthy instance with space.
        let mut limping = load(10, 1, true);
        limping.slow = true;
        let mut f = fleet_of(vec![limping, load(500, 3, true), load(900, 4, true)], 1);
        assert_eq!(d.choose(0, &f, 0, &[]), Some(1));
        // Healthy queues full: the limping instance absorbs the overflow
        // rather than the request being rejected.
        f.update(1, load(500, 3, false));
        f.update(2, load(900, 4, false));
        assert_eq!(d.choose(0, &f, 0, &[]), Some(0));
    }

    #[test]
    fn affinity_homes_partition_and_spill() {
        let mut d = Dispatcher::new(DispatchPolicy::NetworkAffinity, 3, 4, 0);
        // Every net has at least one home; homes are within range.
        for net in 0..3 {
            assert!(!d.home_of(net).is_empty());
            assert!(d.home_of(net).iter().all(|&i| i < 4));
        }
        // Different nets prefer different instances when idle.
        let f = fleet_of(vec![load(0, 0, true); 4], 1);
        let picks: Vec<usize> = (0..3).map(|n| d.choose(n, &f, 0, &[]).unwrap()).collect();
        assert!(picks.windows(2).any(|w| w[0] != w[1]), "picks {picks:?}");
        // Home full -> spills to a non-home instance instead of rejecting.
        let home = d.home_of(0).to_vec();
        let mut f = fleet_of(vec![load(0, 0, true); 4], 1);
        for &h in &home {
            f.update(h, load(0, 0, false));
        }
        let spill = d.choose(0, &f, 0, &[]).unwrap();
        assert!(!home.contains(&spill));
        // Everything full -> reject.
        let full = fleet_of(vec![load(0, 0, false); 4], 1);
        assert_eq!(d.choose(0, &full, 0, &[]), None);
    }

    #[test]
    fn avoid_list_excludes_live_hedge_instances() {
        let mut d = Dispatcher::new(DispatchPolicy::LeastLoaded, 1, 3, 0);
        let f = fleet_of(
            vec![load(10, 1, true), load(500, 2, true), load(900, 3, true)],
            1,
        );
        assert_eq!(d.choose(0, &f, 0, &[]), Some(0));
        assert_eq!(d.choose(0, &f, 0, &[0]), Some(1), "hedge skips the twin");
        assert_eq!(d.choose(0, &f, 0, &[0, 1]), Some(2));
        assert_eq!(d.choose(0, &f, 0, &[0, 1, 2]), None);
    }

    #[test]
    fn fleet_loads_maintains_rack_and_fleet_summaries() {
        let mut f = FleetLoads::new(8, 2);
        assert_eq!(f.racks().len(), 2);
        assert_eq!(f.rack_range(0), 0..4);
        assert_eq!(f.rack_range(1), 4..8);
        assert_eq!(f.alive(), 8);
        f.update(0, load(100, 3, true));
        f.update(5, load(50, 2, false));
        let mut dead = load(0, 0, true);
        dead.down = true;
        f.update(6, dead);
        assert_eq!(f.total_queued(), 5);
        assert_eq!(f.alive(), 7);
        assert_eq!(f.racks()[0].queued, 3);
        assert_eq!(f.racks()[1].queued, 2);
        assert_eq!(f.racks()[1].up, 3);
        assert_eq!(f.racks()[1].space, 2, "full and down both leave space");
        f.assert_consistent();
        // Recovery restores the summaries.
        f.update(6, load(0, 0, true));
        assert_eq!(f.alive(), 8);
        f.assert_consistent();
    }

    #[test]
    fn uneven_last_rack_is_sized_correctly() {
        let f = FleetLoads::new(10, 3);
        // ceil(10/3) = 4 per rack -> racks of 4, 4, 2.
        assert_eq!(f.racks().len(), 3);
        assert_eq!(f.rack_range(0), 0..4);
        assert_eq!(f.rack_range(2), 8..10);
        assert_eq!(f.racks()[2].up, 2);
        f.assert_consistent();
    }

    #[test]
    fn hierarchical_skips_dead_racks_and_is_deterministic() {
        let mut f = FleetLoads::new(8, 2);
        let mut dead = load(0, 0, true);
        dead.down = true;
        for i in 0..4 {
            f.update(i, dead); // rack 0 entirely down
        }
        let mut d1 = Dispatcher::new(DispatchPolicy::Hierarchical, 1, 8, 42);
        let mut d2 = Dispatcher::new(DispatchPolicy::Hierarchical, 1, 8, 42);
        let mut picks = Vec::new();
        for _ in 0..32 {
            let p1 = d1.choose(0, &f, 0, &[]);
            let p2 = d2.choose(0, &f, 0, &[]);
            assert_eq!(p1, p2, "same seed, same candidate sequence");
            let i = p1.expect("rack 1 admits");
            assert!((4..8).contains(&i), "routed into the dead rack");
            picks.push(i);
        }
        assert!(
            picks.iter().any(|&i| i != picks[0]),
            "p2c should spread load across the rack"
        );
        // Whole fleet full: reject.
        for i in 4..8 {
            f.update(i, load(0, 0, false));
        }
        assert_eq!(d1.choose(0, &f, 0, &[]), None);
    }

    #[test]
    fn hierarchical_prefers_the_emptier_candidate() {
        // Single rack of two instances. Whenever p2c draws two distinct
        // candidates the least-loaded key picks the idle one; only the
        // (0,0) double-draw (~1/4 of calls) can land on the loaded chip,
        // so the idle instance wins a clear majority.
        let f = fleet_of(vec![load(10_000, 8, true), load(0, 0, true)], 1);
        let mut d = Dispatcher::new(DispatchPolicy::Hierarchical, 1, 2, 5);
        let idle_picks = (0..64)
            .filter(|_| d.choose(0, &f, 0, &[]) == Some(1))
            .count();
        assert!(idle_picks > 40, "idle instance won only {idle_picks}/64");
    }
}
