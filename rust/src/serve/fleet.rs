//! The fleet simulator: a heterogeneous set of VSCNN accelerator
//! instances driven by a request stream through dispatch and batching.
//!
//! ## Service model
//!
//! Serving is simulated in the *cycle domain* on top of the engine's
//! memory-aware timing (PR 3). Each `(tenant, instance-config)` pair is
//! profiled **once** by actually compiling the tenant's network (through
//! the shared compile cache of [`crate::experiments::workload::prepared`])
//! and running one synthetic image through [`crate::engine::Engine`]; the
//! resulting [`ServiceProfile`] decomposes the measured cycle count into:
//!
//! * `single_cycles` — the full engine cycles for one image, weight
//!   streaming included. The latency floor: no served request can beat it.
//! * `marginal_cycles` — the cost of one *additional* image in a warm
//!   batch: `max(compute_cycles, single - weight_stream)`. With weights
//!   resident in the weight SRAM only activations stream per image, but
//!   the PE arrays still do all the compute.
//! * `switch_cycles` — the weight-side DRAM stream charged when an
//!   instance picks up a batch of a *different* network than the one it
//!   last served (the compiled CVF weights must be re-streamed).
//!
//! A batch of `n` same-tenant requests therefore costs
//! `switch? + single + (n-1) * marginal` cycles — batching strictly
//! amortizes the weight side, never the compute side. Under
//! [`MemModel::Ideal`] transfer is free, so `marginal = single` and
//! `switch = 0` (nothing to amortize, nothing to reload).
//!
//! ## Determinism
//!
//! The event loop is single-threaded and totally ordered by
//! [`super::events::EventQueue`]; all randomness comes from seeded
//! [`Pcg32`] streams; engine cycle counts are thread-count-invariant.
//! A `(spec, seed)` pair therefore produces a bit-identical
//! [`super::report::ServeReport`] regardless of the host thread budget —
//! pinned by `tests/serve.rs`.

use super::batcher::{BatchPolicy, Batcher};
use super::dispatch::{DispatchPolicy, Dispatcher, InstanceLoad};
use super::events::EventQueue;
use super::traffic::{exp_interarrival, RequestMix, Tenant, TrafficModel};
use crate::engine::{Engine, FunctionalBackend, NetworkReport, RunOptions};
use crate::experiments::ExpContext;
use crate::model::init::synthetic_image;
use crate::sim::config::{MemModel, SimConfig};
use crate::util::rng::Pcg32;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// One accelerator instance in the fleet: a PE geometry + memory model.
#[derive(Debug, Clone, Copy)]
pub struct InstanceSpec {
    pub config: SimConfig,
}

impl InstanceSpec {
    /// Label used in reports, e.g. `[8,7,3]/tiled`.
    pub fn label(&self) -> String {
        format!("{}/{}", self.config.pe.label(), self.config.mem_model.label())
    }
}

/// The default heterogeneous fleet: both paper geometries under the tiled
/// (memory-aware) model plus one of each under the ideal model, repeated
/// cyclically to `n` instances.
pub fn default_fleet(n: usize) -> Vec<InstanceSpec> {
    let mut templates = vec![
        SimConfig::paper_4_14_3(),
        SimConfig::paper_8_7_3(),
        SimConfig::paper_4_14_3(),
        SimConfig::paper_8_7_3(),
    ];
    templates[2].mem_model = MemModel::Ideal;
    templates[3].mem_model = MemModel::Ideal;
    (0..n.max(1))
        .map(|i| InstanceSpec {
            config: templates[i % templates.len()],
        })
        .collect()
}

/// Full serving scenario specification.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub tenants: Vec<Tenant>,
    pub instances: Vec<InstanceSpec>,
    pub traffic: TrafficModel,
    pub policy: DispatchPolicy,
    pub batch: BatchPolicy,
    /// Per-instance queue capacity; arrivals beyond it are rejected.
    pub queue_cap: usize,
    /// Simulated horizon in cycles: arrivals stop here and events past it
    /// are not executed (late completions stay in flight).
    pub duration_cycles: u64,
    /// Serving clock in MHz (converts rps and latency to the cycle
    /// domain; matches `SimConfig::freq_mhz` by default).
    pub clock_mhz: f64,
    pub seed: u64,
}

impl ServeSpec {
    /// Cycles per second of the serving clock.
    pub fn clock_hz(&self) -> f64 {
        self.clock_mhz * 1e6
    }

    /// Convert a cycle count to milliseconds under the serving clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_mhz * 1e3)
    }
}

/// Cycle-domain service profile of one tenant on one instance config
/// (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceProfile {
    pub single_cycles: u64,
    pub marginal_cycles: u64,
    pub switch_cycles: u64,
}

/// Profile one tenant on one instance configuration: compile through the
/// shared workload cache, run one synthetic image, decompose the cycles.
/// Results are memoized per `(net, res, seed, config)` process-wide, so a
/// capacity sweep re-profiles nothing.
pub fn service_profile(
    tenant: &Tenant,
    cfg: &SimConfig,
    seed: u64,
    threads: usize,
) -> Result<ServiceProfile> {
    static CACHE: OnceLock<Mutex<BTreeMap<String, ServiceProfile>>> = OnceLock::new();
    // Every cycle-affecting config field takes part in the key (freq_mhz
    // is reporting-only and threads never change cycle counts).
    let key = format!(
        "{} res{} seed{} {} mem:{} bw{} cs{} sram{}/{}/{}/{}/{}",
        tenant.net,
        tenant.res,
        seed,
        cfg.pe.label(),
        cfg.mem_model.label(),
        cfg.dram_bytes_per_cycle,
        cfg.context_switch_cycles,
        cfg.sram.input_bytes,
        cfg.sram.weight_bytes,
        cfg.sram.psum_bytes,
        cfg.sram.output_bytes,
        cfg.sram.bytes_per_elem,
    );
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    if let Some(hit) = cache.lock().unwrap().get(&key) {
        return Ok(*hit);
    }

    let ctx = ExpContext {
        net: tenant.net.clone(),
        res: tenant.res,
        images: 1,
        threads,
        mem_model: cfg.mem_model,
        seed,
        ..ExpContext::default()
    };
    let prepared = crate::experiments::workload::prepared(&ctx)?;
    let img = synthetic_image(prepared.net.input_shape, seed ^ 0x5EA7);
    let mut sim = *cfg;
    sim.threads = threads;
    let opts = RunOptions {
        sim,
        backend: FunctionalBackend::Im2colMt(threads.max(1)),
        verify_dataflow: false,
    };
    let report = Engine::new(prepared).run_image(&img, &opts)?;
    let profile = profile_from_report(&report, cfg);
    cache.lock().unwrap().insert(key, profile);
    Ok(profile)
}

/// Decompose one engine run into a cycle-domain service profile — the
/// cache-free core of [`service_profile`] (exposed so tests can profile
/// with explicit thread budgets past the memoizer).
pub fn profile_from_report(report: &NetworkReport, cfg: &SimConfig) -> ServiceProfile {
    let single = report.totals.cycles.max(1);
    match cfg.mem_model {
        // Ideal memory: weights move for free, so there is nothing to
        // amortize across a batch and nothing to reload on a switch.
        MemModel::Ideal => ServiceProfile {
            single_cycles: single,
            marginal_cycles: single,
            switch_cycles: 0,
        },
        MemModel::Tiled => {
            let weight_stream = report.weight_stream_cycles(cfg.dram_bytes_per_cycle);
            let marginal = report
                .totals
                .compute_cycles
                .max(single.saturating_sub(weight_stream))
                .clamp(1, single);
            ServiceProfile {
                single_cycles: single,
                marginal_cycles: marginal,
                switch_cycles: weight_stream.min(single),
            }
        }
    }
}

/// Profiles for a whole spec, indexed `[tenant][instance]`.
///
/// Tenants are independent networks, so they profile concurrently on the
/// persistent pool (the thread budget splits across tenant workers; each
/// tenant's instance configs run sequentially so the per-config memoizer
/// dedupes engine runs instead of racing them). Results are identical to
/// the sequential loop — profiles are cycle counts, thread-invariant.
pub fn build_profiles(spec: &ServeSpec, threads: usize) -> Result<Vec<Vec<ServiceProfile>>> {
    let workers = spec.tenants.len().min(threads.max(1)).max(1);
    let inner_threads = (threads / workers).max(1);
    let chunks: Result<Vec<Vec<Vec<ServiceProfile>>>> =
        crate::util::par_chunk_map(spec.tenants.len(), workers, |range| {
            spec.tenants[range]
                .iter()
                .map(|t| {
                    spec.instances
                        .iter()
                        .map(|inst| service_profile(t, &inst.config, spec.seed, inner_threads))
                        .collect()
                })
                .collect()
        })
        .into_iter()
        .collect();
    Ok(chunks?.into_iter().flatten().collect())
}

/// One request's lifecycle (admitted or rejected).
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub tenant: usize,
    /// Admitting instance (`None` = rejected).
    pub instance: Option<usize>,
    pub arrival: u64,
    /// Batch launch cycle (admitted requests whose batch launched).
    pub start: Option<u64>,
    /// Completion cycle (`None` = rejected or still in flight at the end).
    pub completion: Option<u64>,
    /// Size of the batch this request completed in.
    pub batch_size: usize,
}

impl RequestRecord {
    /// End-to-end latency in cycles (completed requests only).
    pub fn latency(&self) -> Option<u64> {
        self.completion.map(|c| c - self.arrival)
    }
}

/// Per-instance counters accumulated by the simulation.
#[derive(Debug, Clone, Default)]
pub struct InstanceStats {
    pub label: String,
    /// Busy cycles within the simulated horizon.
    pub busy_cycles: u64,
    pub batches: u64,
    /// Batches that paid the network-switch weight reload.
    pub switches: u64,
    pub completed: u64,
    pub max_queue: usize,
    /// Time-integral of queue depth (cycles × requests), for mean depth.
    pub queue_area: u64,
}

impl InstanceStats {
    /// Busy fraction of the simulated horizon.
    pub fn utilization(&self, duration_cycles: u64) -> f64 {
        self.busy_cycles as f64 / duration_cycles.max(1) as f64
    }

    /// Time-averaged queue depth.
    pub fn mean_queue_depth(&self, duration_cycles: u64) -> f64 {
        self.queue_area as f64 / duration_cycles.max(1) as f64
    }

    /// Mean completed batch size.
    pub fn avg_batch(&self) -> f64 {
        self.completed as f64 / self.batches.max(1) as f64
    }
}

/// Everything the simulation measured; [`super::report::ServeReport`]
/// renders it.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub offered: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// Discrete events executed by the loop (arrivals + timers +
    /// completions) — the denominator of the bench's events/s metric.
    pub events_processed: u64,
    pub records: Vec<RequestRecord>,
    pub instances: Vec<InstanceStats>,
}

impl ServeOutcome {
    /// Requests admitted but not completed within the horizon (queued or
    /// mid-batch when the simulation stopped).
    pub fn in_flight(&self) -> u64 {
        self.admitted - self.completed
    }
}

enum Event {
    /// A request arrives. `client` marks closed-loop re-issue chains
    /// (unused under open-loop traffic).
    Arrival { tenant: usize, client: bool },
    /// A partial batch's wait window may have expired on this instance.
    BatchTimer { instance: usize, token: u64 },
    /// The batch holding these request ids finishes on this instance.
    Complete { instance: usize, reqs: Vec<usize> },
}

struct Instance {
    batcher: Batcher,
    /// Busy until this cycle; idle when `busy_until <= now`.
    busy_until: u64,
    /// Network id whose weights are resident in the weight SRAM.
    resident_net: Option<usize>,
    /// Invalidation token for pending batch timers.
    timer_token: u64,
    /// Estimated marginal cycles queued (for least-loaded dispatch).
    backlog_cycles: u64,
    last_queue_change: u64,
    stats: InstanceStats,
}

impl Instance {
    /// Account the time-integral of queue depth up to `now`.
    fn note_queue(&mut self, now: u64, horizon: u64) {
        let until = now.min(horizon);
        let since = self.last_queue_change.min(horizon);
        self.stats.queue_area += self.batcher.queued() as u64 * (until - since);
        self.last_queue_change = now;
    }
}

/// The running simulation state (one `simulate` call).
struct Sim<'a> {
    spec: &'a ServeSpec,
    profiles: &'a [Vec<ServiceProfile>],
    /// Distinct-network id per tenant (affinity shard key).
    net_ids: Vec<usize>,
    dispatcher: Dispatcher,
    mix: RequestMix,
    rng: Pcg32,
    instances: Vec<Instance>,
    events: EventQueue<Event>,
    records: Vec<RequestRecord>,
    /// Reusable dispatch-snapshot buffer (hot: one refill per arrival
    /// instead of one allocation per arrival).
    loads: Vec<InstanceLoad>,
    offered: u64,
    admitted: u64,
    rejected: u64,
    completed: u64,
}

impl<'a> Sim<'a> {
    fn new(spec: &'a ServeSpec, profiles: &'a [Vec<ServiceProfile>]) -> Sim<'a> {
        assert_eq!(profiles.len(), spec.tenants.len(), "profiles per tenant");
        assert!(!spec.instances.is_empty(), "empty fleet");

        // Distinct networks, in first-appearance order.
        let mut nets: Vec<&str> = Vec::new();
        let mut net_ids = Vec::with_capacity(spec.tenants.len());
        for t in &spec.tenants {
            let id = match nets.iter().position(|n| *n == t.net) {
                Some(i) => i,
                None => {
                    nets.push(&t.net);
                    nets.len() - 1
                }
            };
            net_ids.push(id);
        }

        let instances = spec
            .instances
            .iter()
            .map(|is| Instance {
                batcher: Batcher::new(spec.batch, spec.tenants.len()),
                busy_until: 0,
                resident_net: None,
                timer_token: 0,
                backlog_cycles: 0,
                last_queue_change: 0,
                stats: InstanceStats {
                    label: is.label(),
                    ..InstanceStats::default()
                },
            })
            .collect();

        Sim {
            dispatcher: Dispatcher::new(spec.policy, nets.len(), spec.instances.len()),
            mix: RequestMix::new(&spec.tenants),
            rng: Pcg32::new(spec.seed, 1),
            net_ids,
            spec,
            profiles,
            loads: Vec::with_capacity(instances.len()),
            instances,
            events: EventQueue::new(),
            records: Vec::new(),
            offered: 0,
            admitted: 0,
            rejected: 0,
            completed: 0,
        }
    }

    fn horizon(&self) -> u64 {
        self.spec.duration_cycles
    }

    /// Schedule an arrival `mean_cycles` (exponentially distributed) after
    /// `now`, unless it would fall past the horizon.
    fn schedule_arrival(&mut self, now: u64, mean_cycles: f64, client: bool) {
        let at = now + exp_interarrival(&mut self.rng, mean_cycles);
        if at <= self.horizon() {
            let tenant = self.mix.sample(&mut self.rng);
            self.events.push(at, Event::Arrival { tenant, client });
        }
    }

    /// Launch a batch on instance `i` if one is ready, else arm the wait
    /// window timer. Called whenever the instance might have become able
    /// to start work (arrival while idle, completion, timer expiry).
    fn try_launch(&mut self, i: usize, now: u64) {
        let horizon = self.horizon();
        let inst = &mut self.instances[i];
        if inst.busy_until > now {
            return;
        }
        inst.note_queue(now, horizon);
        if let Some((tenant, reqs)) = inst.batcher.take_ready(now) {
            let prof = self.profiles[tenant][i];
            let net = self.net_ids[tenant];
            let switch = if inst.resident_net == Some(net) {
                0
            } else {
                prof.switch_cycles
            };
            if switch > 0 {
                inst.stats.switches += 1;
            }
            inst.resident_net = Some(net);
            let n = reqs.len() as u64;
            let duration = switch + prof.single_cycles + (n - 1) * prof.marginal_cycles;
            let end = now + duration;
            inst.busy_until = end;
            inst.stats.batches += 1;
            inst.stats.busy_cycles += end.min(horizon) - now.min(horizon);
            inst.backlog_cycles = inst.backlog_cycles.saturating_sub(n * prof.marginal_cycles);
            for &r in &reqs {
                self.records[r].start = Some(now);
                self.records[r].batch_size = reqs.len();
            }
            self.events.push(end, Event::Complete { instance: i, reqs });
        } else if inst.batcher.queued() > 0 {
            // Partial batches only: wake up when the oldest one expires.
            if let Some(deadline) = inst.batcher.next_deadline() {
                inst.timer_token += 1;
                let token = inst.timer_token;
                let at = deadline.max(now + 1);
                self.events.push(at, Event::BatchTimer { instance: i, token });
            }
        }
    }

    fn on_arrival(&mut self, now: u64, tenant: usize, client: bool) {
        self.offered += 1;
        let queue_cap = self.spec.queue_cap;
        self.loads.clear();
        self.loads.extend(self.instances.iter().map(|inst| InstanceLoad {
            queued: inst.batcher.queued(),
            backlog_cycles: inst.backlog_cycles + inst.busy_until.saturating_sub(now),
            has_space: inst.batcher.queued() < queue_cap,
        }));
        let choice = self.dispatcher.choose(self.net_ids[tenant], &self.loads);
        let req_id = self.records.len();
        self.records.push(RequestRecord {
            tenant,
            instance: choice,
            arrival: now,
            start: None,
            completion: None,
            batch_size: 0,
        });
        match choice {
            Some(i) => {
                self.admitted += 1;
                let horizon = self.horizon();
                let marginal = self.profiles[tenant][i].marginal_cycles;
                let inst = &mut self.instances[i];
                inst.note_queue(now, horizon);
                inst.batcher.push(tenant, req_id, now);
                inst.backlog_cycles += marginal;
                inst.stats.max_queue = inst.stats.max_queue.max(inst.batcher.queued());
                self.try_launch(i, now);
            }
            None => {
                self.rejected += 1;
                // A rejected closed-loop client retries after a think gap.
                if client {
                    if let TrafficModel::ClosedLoop { think_cycles, .. } = self.spec.traffic {
                        self.schedule_arrival(now, think_cycles.max(1) as f64, true);
                    }
                }
            }
        }
        // Open loop: the Poisson process marches on regardless of state.
        if let TrafficModel::OpenLoop { rps } = self.spec.traffic {
            let mean = self.spec.clock_hz() / rps.max(1e-9);
            self.schedule_arrival(now, mean, false);
        }
    }

    fn on_complete(&mut self, now: u64, instance: usize, reqs: Vec<usize>) {
        let n = reqs.len() as u64;
        self.completed += n;
        self.instances[instance].stats.completed += n;
        for r in reqs {
            self.records[r].completion = Some(now);
        }
        // Closed-loop clients re-issue after their think time. Client
        // identity is not tracked through batches — the population size
        // is what matters — so each completion spawns one successor.
        if let TrafficModel::ClosedLoop { think_cycles, .. } = self.spec.traffic {
            for _ in 0..n {
                self.schedule_arrival(now, think_cycles.max(1) as f64, true);
            }
        }
        self.try_launch(instance, now);
    }

    fn run(mut self) -> ServeOutcome {
        // Seed the arrival processes.
        match self.spec.traffic {
            TrafficModel::OpenLoop { rps } => {
                let mean = self.spec.clock_hz() / rps.max(1e-9);
                self.schedule_arrival(0, mean, false);
            }
            TrafficModel::ClosedLoop { clients, think_cycles } => {
                for _ in 0..clients {
                    self.schedule_arrival(0, think_cycles.max(1) as f64, true);
                }
            }
        }

        // Batched draining: all events of one timestamp come out of the
        // heap in one sweep and execute back to back. Handlers that push
        // same-cycle events (e.g. zero-gap arrivals) enqueue with higher
        // seqs, so the next sweep runs them — exactly the order
        // one-at-a-time popping produced (`events::drain_matches_pop_order`).
        let mut batch: Vec<Event> = Vec::new();
        let mut events_processed = 0u64;
        while let Some(now) = self.events.peek_cycle() {
            if now > self.horizon() {
                break; // heap order: everything left is at or after `now`
            }
            self.events.drain_cycle(now, &mut batch);
            for ev in batch.drain(..) {
                events_processed += 1;
                match ev {
                    Event::Arrival { tenant, client } => self.on_arrival(now, tenant, client),
                    Event::BatchTimer { instance, token } => {
                        if self.instances[instance].timer_token == token {
                            self.try_launch(instance, now);
                        }
                    }
                    Event::Complete { instance, reqs } => self.on_complete(now, instance, reqs),
                }
            }
        }

        // Close the queue-depth integrals at the horizon.
        let horizon = self.horizon();
        for inst in self.instances.iter_mut() {
            inst.note_queue(horizon, horizon);
        }

        ServeOutcome {
            offered: self.offered,
            admitted: self.admitted,
            rejected: self.rejected,
            completed: self.completed,
            events_processed,
            records: self.records,
            instances: self.instances.into_iter().map(|i| i.stats).collect(),
        }
    }
}

/// Run the discrete-event simulation. `profiles` comes from
/// [`build_profiles`]; the loop itself never touches the engine, so a
/// multi-point capacity sweep is pure event processing after one
/// profiling pass.
pub fn simulate(spec: &ServeSpec, profiles: &[Vec<ServiceProfile>]) -> ServeOutcome {
    Sim::new(spec, profiles).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic profile set: no engine needed for event-loop tests.
    fn toy_spec(
        policy: DispatchPolicy,
        batch: BatchPolicy,
        rps: f64,
    ) -> (ServeSpec, Vec<Vec<ServiceProfile>>) {
        let tenants = vec![
            Tenant::new("vgg16", 32, 0.5),
            Tenant::new("alexnet", 32, 0.5),
        ];
        let instances = vec![
            InstanceSpec {
                config: SimConfig::paper_4_14_3(),
            },
            InstanceSpec {
                config: SimConfig::paper_8_7_3(),
            },
        ];
        let spec = ServeSpec {
            tenants,
            instances,
            traffic: TrafficModel::OpenLoop { rps },
            policy,
            batch,
            queue_cap: 8,
            duration_cycles: 50_000_000,
            clock_mhz: 500.0,
            seed: 42,
        };
        let prof = ServiceProfile {
            single_cycles: 1_000_000,
            marginal_cycles: 600_000,
            switch_cycles: 400_000,
        };
        let profiles = vec![vec![prof; 2]; 2];
        (spec, profiles)
    }

    fn window(max_batch: usize, max_wait_cycles: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait_cycles,
        }
    }

    #[test]
    fn conservation_holds_on_toy_fleet() {
        for rps in [50.0, 500.0, 5_000.0, 50_000.0] {
            let (spec, profiles) = toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), rps);
            let out = simulate(&spec, &profiles);
            assert_eq!(
                out.offered,
                out.completed + out.rejected + out.in_flight(),
                "rps {rps}"
            );
            // Every offered request was one arrival event; completions
            // and batch timers add more.
            assert!(out.events_processed >= out.offered, "rps {rps}");
            let rec_completed = out.records.iter().filter(|r| r.completion.is_some()).count();
            assert_eq!(rec_completed as u64, out.completed);
            let rec_rejected = out.records.iter().filter(|r| r.instance.is_none()).count();
            assert_eq!(rec_rejected as u64, out.rejected);
        }
    }

    #[test]
    fn latency_never_beats_single_image_cycles() {
        let (spec, profiles) =
            toy_spec(DispatchPolicy::NetworkAffinity, window(8, 200_000), 2_000.0);
        let out = simulate(&spec, &profiles);
        assert!(out.completed > 0);
        for r in &out.records {
            if let Some(lat) = r.latency() {
                let i = r.instance.unwrap();
                assert!(
                    lat >= profiles[r.tenant][i].single_cycles,
                    "latency {lat} < single"
                );
            }
        }
    }

    #[test]
    fn batching_forms_batches_under_load() {
        let (spec, profiles) =
            toy_spec(DispatchPolicy::NetworkAffinity, window(8, 500_000), 20_000.0);
        let out = simulate(&spec, &profiles);
        let max_batch = out.records.iter().map(|r| r.batch_size).max().unwrap_or(0);
        assert!(max_batch > 1, "no batch formed (max {max_batch})");
        // Stats are self-consistent.
        let sum: u64 = out.instances.iter().map(|i| i.completed).sum();
        assert_eq!(sum, out.completed);
        for i in &out.instances {
            assert!(i.utilization(spec.duration_cycles) <= 1.0 + 1e-12);
            assert!(i.mean_queue_depth(spec.duration_cycles) <= spec.queue_cap as f64);
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let (spec, profiles) = toy_spec(DispatchPolicy::RoundRobin, window(4, 100_000), 3_000.0);
        let a = simulate(&spec, &profiles);
        let b = simulate(&spec, &profiles);
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.completion, y.completion);
            assert_eq!(x.instance, y.instance);
        }
    }

    #[test]
    fn closed_loop_self_throttles() {
        let (mut spec, profiles) = toy_spec(DispatchPolicy::LeastLoaded, BatchPolicy::none(), 0.0);
        spec.traffic = TrafficModel::ClosedLoop {
            clients: 3,
            think_cycles: 100_000,
        };
        let out = simulate(&spec, &profiles);
        assert!(out.offered > 0);
        // With 3 clients at >= 1M cycles per turn over 50M cycles, the
        // offered load is bounded by the client population.
        assert!(out.offered <= 3 * 50 + 3, "offered {}", out.offered);
        assert_eq!(out.offered, out.completed + out.rejected + out.in_flight());
    }

    #[test]
    fn affinity_switches_less_than_round_robin() {
        let mk = |policy| {
            let (spec, profiles) = toy_spec(policy, BatchPolicy::none(), 5_000.0);
            let out = simulate(&spec, &profiles);
            out.instances.iter().map(|i| i.switches).sum::<u64>()
        };
        let rr = mk(DispatchPolicy::RoundRobin);
        let aff = mk(DispatchPolicy::NetworkAffinity);
        assert!(aff < rr, "affinity switches {aff} !< round-robin {rr}");
    }

    #[test]
    fn default_fleet_mixes_geometries_and_memory_models() {
        let fleet = default_fleet(4);
        assert_eq!(fleet.len(), 4);
        let labels: Vec<String> = fleet.iter().map(|f| f.label()).collect();
        assert!(labels.iter().any(|l| l.contains("tiled")));
        assert!(labels.iter().any(|l| l.contains("ideal")));
        assert!(labels.iter().any(|l| l.contains("[4,14,3]")));
        assert!(labels.iter().any(|l| l.contains("[8,7,3]")));
        // Replication wraps.
        assert_eq!(default_fleet(6).len(), 6);
        assert_eq!(default_fleet(0).len(), 1);
    }
}
