//! The fleet simulator: a heterogeneous set of VSCNN accelerator
//! instances driven by a request stream through dispatch and batching.
//!
//! ## Service model
//!
//! Serving is simulated in the *cycle domain* on top of the engine's
//! memory-aware timing (PR 3). Each `(tenant, instance-config)` pair is
//! profiled **once** by actually compiling the tenant's network (through
//! the shared compile cache of [`crate::experiments::workload::prepared`])
//! and running one synthetic image through [`crate::engine::Engine`]; the
//! resulting [`ServiceProfile`] decomposes the measured cycle count into:
//!
//! * `single_cycles` — the full engine cycles for one image, weight
//!   streaming included. The latency floor: no served request can beat it.
//! * `marginal_cycles` — the cost of one *additional* image in a warm
//!   batch: `max(compute_cycles, single - weight_stream)`. With weights
//!   resident in the weight SRAM only activations stream per image, but
//!   the PE arrays still do all the compute.
//! * `switch_cycles` — the weight-side DRAM stream charged when an
//!   instance picks up a batch of a *different* network than the one it
//!   last served (the compiled CVF weights must be re-streamed).
//!
//! A batch of `n` same-tenant requests therefore costs
//! `switch? + single + (n-1) * marginal` cycles — batching strictly
//! amortizes the weight side, never the compute side. Under
//! [`MemModel::Ideal`] transfer is free, so `marginal = single` and
//! `switch = 0` (nothing to amortize, nothing to reload).
//!
//! ## Resilience (ISSUE 6)
//!
//! The loop optionally threads a seeded fault plan
//! ([`super::faults::generate_plan`]) and a client-side
//! [`RobustnessPolicy`] through the same event queue:
//!
//! * **Crashes** kill the running batch (crash-epoch bump invalidates its
//!   pending [`ServeEvent::Complete`]) and drain the queue; both are
//!   re-homed onto surviving instances for free (no retry budget spent).
//!   Recovery brings the instance back cold.
//! * **Stragglers** multiply the duration of batches launched during the
//!   episode; dispatch sees the instance as `Degraded` and avoids it.
//! * **Timeouts** cancel an attempt after `timeout_cycles` in flight
//!   (queueing counts); launched work completes but its result is
//!   discarded as a *stale completion*. Consecutive timeouts open a
//!   per-instance breaker that marks it `Degraded` for a cooldown.
//! * **Retries** re-dispatch a failed attempt (capacity, timeout, or
//!   execution fault) with exponential backoff, up to `max_retries`.
//! * **Hedges** duplicate a still-unfinished request onto a second
//!   instance after `hedge_cycles`; the first completion wins and the
//!   loser is cancelled (de-queued, or left to go stale if launched).
//! * **Shedding** rejects the lowest-priority tenants at admission when
//!   queue occupancy over the surviving fleet crosses their threshold.
//!
//! Every request ends in exactly one [`Outcome`] bucket, so the ledger
//! `offered = completed + rejected + timed_out + shed + in_flight` holds
//! under any interleaving — hedge duplicates and crash re-homes are
//! *attempts* of one request, never new requests (pinned by
//! `tests/serve.rs`).
//!
//! ## Scale (ISSUE 7)
//!
//! The fleet is organized into [`ServeSpec::racks`] contiguous racks.
//! Per-instance load and health live in a [`FleetLoads`] cache updated in
//! O(1) at exactly the points where they change (launch, queue churn,
//! crash, recovery, timeout, cancellation, straggler episodes), with
//! per-rack and fleet-level aggregates maintained incrementally — so
//! admission control and the hierarchical dispatch policy never scan the
//! fleet, and together with the calendar-queue
//! [`super::events::EventQueue`] the loop drives 10k-instance fleets at
//! interactive speed. The cached fields are the raw time-independent
//! quantities; policies evaluate the time-dependent key lazily, so cached
//! decisions are byte-identical to the per-arrival rebuild they replace.
//!
//! ## Determinism
//!
//! The event loop is single-threaded and totally ordered by
//! [`super::events::EventQueue`]; all randomness comes from seeded
//! [`Pcg32`] streams; engine cycle counts are thread-count-invariant.
//! A `(spec, seed)` pair therefore produces a bit-identical
//! [`super::report::ServeReport`] regardless of the host thread budget —
//! pinned by `tests/serve.rs`. The fault plan and per-request fault draws
//! use dedicated streams — as do the non-stationary traffic envelopes and
//! the hierarchical policy's candidate draws — so the zero-fault,
//! flat-topology configuration consumes the exact RNG sequence — and
//! emits the exact event sequence — of the pre-fault simulator: its
//! reports stay bit-identical.

use super::batcher::{BatchPolicy, Batcher};
use super::dispatch::{DispatchPolicy, Dispatcher, FleetLoads, InstanceLoad};
use super::events::{EventQueue, ServeEvent};
use super::faults::{generate_plan, FaultKind, FaultSpec, RobustnessPolicy, REQ_FAULT_STREAM};
use super::traffic::{exp_interarrival, ArrivalProcess, RequestMix, Tenant, TrafficModel};
use crate::engine::{Engine, FunctionalBackend, NetworkReport, RunOptions};
use crate::experiments::ExpContext;
use crate::model::init::synthetic_image;
use crate::sim::config::{MemModel, SimConfig};
use crate::sim::sdc::{coverage, generate_sdc_plan, protected_cycles, SdcSite, SdcSpec};
use crate::util::rng::Pcg32;
use crate::util::trace_span::{self, CYCLES_PID};
use crate::util::{metrics, trace_span::Arg};
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// Consecutive per-attempt timeouts on one instance that open its
/// breaker (dispatch then treats it as `Degraded`).
const BREAKER_STREAK: u32 = 3;
/// Breaker cooldown, in units of the attempt timeout.
const BREAKER_COOLDOWN_TIMEOUTS: u64 = 8;

/// One accelerator instance in the fleet: a PE geometry + memory model.
#[derive(Debug, Clone, Copy)]
pub struct InstanceSpec {
    pub config: SimConfig,
}

impl InstanceSpec {
    /// Label used in reports, e.g. `[8,7,3]/tiled`.
    pub fn label(&self) -> String {
        format!("{}/{}", self.config.pe.label(), self.config.mem_model.label())
    }
}

/// The default heterogeneous fleet: both paper geometries under the tiled
/// (memory-aware) model plus one of each under the ideal model, repeated
/// cyclically to `n` instances.
pub fn default_fleet(n: usize) -> Vec<InstanceSpec> {
    let mut templates = vec![
        SimConfig::paper_4_14_3(),
        SimConfig::paper_8_7_3(),
        SimConfig::paper_4_14_3(),
        SimConfig::paper_8_7_3(),
    ];
    templates[2].mem_model = MemModel::Ideal;
    templates[3].mem_model = MemModel::Ideal;
    (0..n.max(1))
        .map(|i| InstanceSpec {
            config: templates[i % templates.len()],
        })
        .collect()
}

/// Full serving scenario specification.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub tenants: Vec<Tenant>,
    pub instances: Vec<InstanceSpec>,
    pub traffic: TrafficModel,
    pub policy: DispatchPolicy,
    pub batch: BatchPolicy,
    /// Per-instance queue capacity; arrivals beyond it are rejected.
    pub queue_cap: usize,
    /// Fleet topology: instances split into this many contiguous racks
    /// (1 = flat, the legacy layout). Rack aggregates feed the
    /// hierarchical dispatch policy and keep failure-aware routing O(1).
    pub racks: usize,
    /// Simulated horizon in cycles: arrivals stop here and events past it
    /// are not executed (late completions stay in flight).
    pub duration_cycles: u64,
    /// Serving clock in MHz (converts rps and latency to the cycle
    /// domain; matches `SimConfig::freq_mhz` by default).
    pub clock_mhz: f64,
    pub seed: u64,
    /// Injected fault mix ([`FaultSpec::none`] = the legacy simulator).
    pub faults: FaultSpec,
    /// Client-side robustness knobs ([`RobustnessPolicy::none`] = legacy
    /// fail-fast behavior).
    pub robust: RobustnessPolicy,
    /// Injected silent-data-corruption mix + protection knobs
    /// ([`SdcSpec::none`] = the pre-SDC simulator, bit-identical).
    pub sdc: SdcSpec,
}

impl ServeSpec {
    /// Cycles per second of the serving clock.
    pub fn clock_hz(&self) -> f64 {
        self.clock_mhz * 1e6
    }

    /// Convert a cycle count to milliseconds under the serving clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_mhz * 1e3)
    }

    /// True when the run exercises the resilience layer at all — any
    /// fault source or any robustness mechanism. Gates the extra report
    /// sections so zero-fault output stays bit-identical to the
    /// pre-fault simulator.
    pub fn resilience_active(&self) -> bool {
        !self.faults.is_none() || self.robust.active()
    }

    /// True when SDC injection fires at all. Gates the integrity report
    /// section, the scrub schedule, and the protection overhead, so
    /// zero-SDC runs stay bit-identical to the pre-SDC simulator.
    pub fn sdc_active(&self) -> bool {
        !self.sdc.is_none()
    }

    /// Weight-scrub period in cycles under the serving clock.
    pub fn scrub_period_cycles(&self) -> u64 {
        ((self.sdc.scrub_ms * self.clock_mhz * 1e3) as u64).max(1)
    }
}

/// Parse a `--topology` CLI value into a rack count for a fleet of
/// `instances`: `flat` (one rack) or `racks:R`.
pub fn parse_topology(s: &str, instances: usize) -> Result<usize> {
    if s == "flat" {
        return Ok(1);
    }
    let Some(r) = s.strip_prefix("racks:") else {
        bail!("unknown topology '{s}' (known: flat, racks:R)");
    };
    let racks: usize = r
        .parse()
        .map_err(|_| anyhow::anyhow!("topology rack count '{r}' is not a number"))?;
    ensure!(
        racks >= 1,
        "topology needs at least one rack, got racks:{racks}"
    );
    ensure!(
        racks <= instances.max(1),
        "topology racks:{racks} exceeds the fleet of {instances} instances"
    );
    Ok(racks)
}

/// Cycle-domain service profile of one tenant on one instance config
/// (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceProfile {
    pub single_cycles: u64,
    pub marginal_cycles: u64,
    pub switch_cycles: u64,
}

/// Profile one tenant on one instance configuration: compile through the
/// shared workload cache, run one synthetic image, decompose the cycles.
/// Results are memoized per `(net, res, seed, config)` process-wide, so a
/// capacity sweep re-profiles nothing.
pub fn service_profile(
    tenant: &Tenant,
    cfg: &SimConfig,
    seed: u64,
    threads: usize,
) -> Result<ServiceProfile> {
    static CACHE: OnceLock<Mutex<BTreeMap<String, ServiceProfile>>> = OnceLock::new();
    // Every cycle-affecting config field takes part in the key (freq_mhz
    // is reporting-only and threads never change cycle counts).
    let key = format!(
        "{} res{} seed{} {} mem:{} bw{} cs{} sram{}/{}/{}/{}/{}",
        tenant.net,
        tenant.res,
        seed,
        cfg.pe.label(),
        cfg.mem_model.label(),
        cfg.dram_bytes_per_cycle,
        cfg.context_switch_cycles,
        cfg.sram.input_bytes,
        cfg.sram.weight_bytes,
        cfg.sram.psum_bytes,
        cfg.sram.output_bytes,
        cfg.sram.bytes_per_elem,
    );
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    if let Some(hit) = cache.lock().unwrap().get(&key) {
        return Ok(*hit);
    }

    let ctx = ExpContext {
        net: tenant.net.clone(),
        res: tenant.res,
        images: 1,
        threads,
        mem_model: cfg.mem_model,
        seed,
        ..ExpContext::default()
    };
    let prepared = crate::experiments::workload::prepared(&ctx)?;
    let img = synthetic_image(prepared.net.input_shape, seed ^ 0x5EA7);
    let mut sim = *cfg;
    sim.threads = threads;
    let opts = RunOptions {
        sim,
        backend: FunctionalBackend::Im2colMt(threads.max(1)),
        verify_dataflow: false,
        fuse: false,
        sdc: None,
    };
    let report = Engine::new(prepared).run_image(&img, &opts)?;
    let profile = profile_from_report(&report, cfg);
    cache.lock().unwrap().insert(key, profile);
    Ok(profile)
}

/// Decompose one engine run into a cycle-domain service profile — the
/// cache-free core of [`service_profile`] (exposed so tests can profile
/// with explicit thread budgets past the memoizer).
pub fn profile_from_report(report: &NetworkReport, cfg: &SimConfig) -> ServiceProfile {
    let single = report.totals.cycles.max(1);
    match cfg.mem_model {
        // Ideal memory: weights move for free, so there is nothing to
        // amortize across a batch and nothing to reload on a switch.
        MemModel::Ideal => ServiceProfile {
            single_cycles: single,
            marginal_cycles: single,
            switch_cycles: 0,
        },
        MemModel::Tiled => {
            let weight_stream = report.weight_stream_cycles(cfg.dram_bytes_per_cycle);
            let marginal = report
                .totals
                .compute_cycles
                .max(single.saturating_sub(weight_stream))
                .clamp(1, single);
            ServiceProfile {
                single_cycles: single,
                marginal_cycles: marginal,
                switch_cycles: weight_stream.min(single),
            }
        }
    }
}

/// Profiles for a whole spec, indexed `[tenant][instance]`.
///
/// Tenants are independent networks, so they profile concurrently on the
/// persistent pool (the thread budget splits across tenant workers; each
/// tenant's instance configs run sequentially so the per-config memoizer
/// dedupes engine runs instead of racing them). Results are identical to
/// the sequential loop — profiles are cycle counts, thread-invariant.
pub fn build_profiles(spec: &ServeSpec, threads: usize) -> Result<Vec<Vec<ServiceProfile>>> {
    let workers = spec.tenants.len().min(threads.max(1)).max(1);
    let inner_threads = (threads / workers).max(1);
    let chunks: Result<Vec<Vec<Vec<ServiceProfile>>>> =
        crate::util::par_chunk_map(spec.tenants.len(), workers, |range| {
            spec.tenants[range]
                .iter()
                .map(|t| {
                    spec.instances
                        .iter()
                        .map(|inst| service_profile(t, &inst.config, spec.seed, inner_threads))
                        .collect()
                })
                .collect()
        })
        .into_iter()
        .collect();
    Ok(chunks?.into_iter().flatten().collect())
}

/// Terminal (or not-yet-terminal) state of one request — exactly one
/// bucket per request, so the conservation ledger
/// `offered = completed + rejected + timed_out + shed + in_flight`
/// holds by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Still queued, running, hedged, or awaiting a retry at the horizon.
    InFlight,
    /// A (non-faulted) attempt completed; first completion wins.
    Completed,
    /// Dropped for capacity or after exhausting retries on execution
    /// faults — uniformly counted for open- and closed-loop traffic
    /// (closed-loop clients additionally re-issue a *new* request).
    Rejected,
    /// Final attempt timed out with no retry budget left.
    TimedOut,
    /// Refused at admission by SLO-aware load shedding.
    Shed,
}

/// One request's lifecycle (admitted or rejected).
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub tenant: usize,
    /// Instance that served (or last held) the request; `None` = never
    /// admitted anywhere.
    pub instance: Option<usize>,
    pub arrival: u64,
    /// Batch launch cycle (admitted requests whose batch launched).
    pub start: Option<u64>,
    /// Completion cycle (`None` = not completed within the horizon).
    pub completion: Option<u64>,
    /// Size of the batch this request completed in.
    pub batch_size: usize,
    /// Where the request ended up (see [`Outcome`]).
    pub outcome: Outcome,
    /// Dispatch attempts that consumed retry budget (first try included;
    /// crash re-homes and hedges are free).
    pub attempts: u32,
    /// A hedge duplicate was placed for this request.
    pub hedged: bool,
    /// The hedge attempt (not the primary) completed first.
    pub hedge_won: bool,
    /// Closed-loop lineage: the request whose completion/rejection
    /// spawned this one (`None` for fresh arrivals).
    pub reissue_of: Option<usize>,
}

impl RequestRecord {
    /// End-to-end latency in cycles (completed requests only).
    pub fn latency(&self) -> Option<u64> {
        self.completion.map(|c| c - self.arrival)
    }
}

/// Per-instance counters accumulated by the simulation.
#[derive(Debug, Clone, Default)]
pub struct InstanceStats {
    pub label: String,
    /// Busy cycles within the simulated horizon (work killed by a crash
    /// is un-counted — the chip never finished it).
    pub busy_cycles: u64,
    pub batches: u64,
    /// Batches that paid the network-switch weight reload.
    pub switches: u64,
    pub completed: u64,
    pub max_queue: usize,
    /// Time-integral of queue depth (cycles × requests), for mean depth.
    pub queue_area: u64,
    /// Crash events that hit this instance.
    pub crashes: u64,
    /// Cycles spent down (crashed) within the horizon.
    pub down_cycles: u64,
}

impl InstanceStats {
    /// Busy fraction of the simulated horizon.
    pub fn utilization(&self, duration_cycles: u64) -> f64 {
        self.busy_cycles as f64 / duration_cycles.max(1) as f64
    }

    /// Time-averaged queue depth.
    pub fn mean_queue_depth(&self, duration_cycles: u64) -> f64 {
        self.queue_area as f64 / duration_cycles.max(1) as f64
    }

    /// Mean completed batch size.
    pub fn avg_batch(&self) -> f64 {
        self.completed as f64 / self.batches.max(1) as f64
    }

    /// Fraction of the horizon this instance was up.
    pub fn availability(&self, duration_cycles: u64) -> f64 {
        1.0 - self.down_cycles as f64 / duration_cycles.max(1) as f64
    }
}

/// Everything the simulation measured; [`super::report::ServeReport`]
/// renders it.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub offered: u64,
    /// Requests that were admitted somewhere at least once.
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// Requests whose final attempt timed out (terminal).
    pub timed_out: u64,
    /// Requests refused at admission by load shedding.
    pub shed: u64,
    /// Requests not yet terminal at the horizon (queued, running, hedged,
    /// or awaiting a retry backoff). Counted from per-record [`Outcome`]s
    /// — with retries a request can be admitted more than once, so
    /// `admitted - completed` is no longer the right derivation.
    pub in_flight: u64,
    /// Retry re-dispatches scheduled (attempt-level, not per request).
    pub retries: u64,
    /// Hedge duplicates actually placed on a second instance.
    pub hedges: u64,
    /// Requests whose hedge attempt beat the primary.
    pub hedge_wins: u64,
    /// Attempts re-dispatched onto a surviving instance after a crash.
    pub rehomed: u64,
    /// Per-request execution faults injected at completion.
    pub faulted: u64,
    /// Completions of cancelled attempts (timed out, hedged-out, or
    /// killed) whose results were discarded.
    pub stale_completions: u64,
    pub crashes: u64,
    pub recoveries: u64,
    /// Total crash-to-recover cycles over completed recoveries (MTTR
    /// numerator; `recoveries` is the denominator).
    pub recovery_cycles: u64,
    /// Total instance-down cycles within the horizon, all instances.
    pub down_cycles: u64,
    /// SDC flips injected by the plan (ISSUE 10).
    pub sdc_injected: u64,
    /// Flips that landed in dead state (down chip, no resident weights,
    /// idle activation/accumulator path) — architecturally masked,
    /// excluded from the detection-rate denominator.
    pub sdc_masked: u64,
    /// Consequential flips the protection stack caught.
    pub sdc_detected: u64,
    /// Detected flips repaired (batch re-execution or weight scrub);
    /// `detected - corrected` escalated into the retry path instead.
    pub sdc_corrected: u64,
    /// Consequential flips that escaped every detector.
    pub sdc_silent: u64,
    /// Requests served from corrupted state — wrong answers delivered as
    /// successes (the quantity protection exists to drive to zero).
    pub silent_completions: u64,
    /// Weight-scrub passes executed.
    pub scrubs: u64,
    /// Instances permanently removed after crossing the
    /// detected-corruption threshold.
    pub quarantined: u64,
    /// Discrete events executed by the loop (arrivals + timers +
    /// completions + fault/robustness events) — the denominator of the
    /// bench's events/s metric.
    pub events_processed: u64,
    pub records: Vec<RequestRecord>,
    pub instances: Vec<InstanceStats>,
}

/// One live dispatch of a request onto an instance. A request has one
/// live attempt normally, two while a hedge races, zero while it waits
/// out a retry backoff.
#[derive(Debug, Clone, Copy)]
struct Attempt {
    /// Per-request monotone id; `Timeout`/`Hedge` events and the
    /// instance's running set name attempts by token, so cancelled
    /// attempts go *stale* instead of being chased through the queues.
    token: u32,
    instance: usize,
    hedge: bool,
}

/// Why an attempt (and possibly its request) failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FailCause {
    /// No instance could admit it (queue caps / whole fleet down).
    Capacity,
    /// The attempt timeout expired.
    TimedOut,
    /// Injected per-request execution fault at completion.
    ExecFault,
}

/// Mutable per-request simulation state (parallel to `records`).
struct ReqState {
    live: Vec<Attempt>,
    next_token: u32,
    /// Closed-loop client chain: terminal outcomes re-issue.
    client: bool,
}

/// Launch record of the batch currently executing — kept on the instance
/// (not the completion event) so the timeline can attribute the interval
/// to `exec` on completion or `killed` when a crash invalidates it.
struct LaunchInfo {
    start: u64,
    tenant: usize,
    n: usize,
    switch: u64,
    /// Service duration charged at launch — what one bounded
    /// re-execution of the batch costs again (conservative: the switch
    /// and any straggler stretch are re-paid).
    duration: u64,
}

struct Instance {
    batcher: Batcher,
    /// Busy until this cycle; idle when `busy_until <= now`.
    busy_until: u64,
    /// Network id whose weights are resident in the weight SRAM.
    resident_net: Option<usize>,
    /// Invalidation token for pending batch timers.
    timer_token: u64,
    /// Estimated marginal cycles queued (for least-loaded dispatch).
    backlog_cycles: u64,
    last_queue_change: u64,
    /// Crash epoch: bumped on crash so pending `Complete` events of
    /// killed batches are ignored.
    epoch: u32,
    /// The launched batch as `(req, attempt token)` pairs — owned by the
    /// instance (not the event) so a crash can kill and re-home it.
    running: Vec<(usize, u32)>,
    /// Service-time multiplier (> 1 during a straggler episode).
    slowdown: f64,
    /// Crash cycle while down; `None` = up.
    down_since: Option<u64>,
    /// Breaker: treated as `Degraded` until this cycle.
    breaker_until: u64,
    /// Consecutive attempt timeouts (resets on a served completion).
    timeout_streak: u32,
    /// Trace attribution for the running batch (`None` when idle).
    launch: Option<LaunchInfo>,
    /// Latent flips in the resident weights that escaped detection (or
    /// run unprotected): every batch served reads corrupted weights
    /// until a cold reload (switch, crash) clears them.
    weight_corrupt: u32,
    /// Detected weight flips awaiting the next scrub pass, which repairs
    /// them by forcing a weight re-stream.
    weight_pending: u32,
    /// Detected in-batch (activation/accumulator) flips on the running
    /// batch — triggers bounded re-execution at completion.
    batch_detected: u32,
    /// The running batch absorbed an undetected in-batch flip: its
    /// completions are silently wrong.
    batch_corrupt: bool,
    /// Re-executions already spent on the running batch.
    reexec_used: u32,
    /// Lifetime detected-corruption count (the quarantine trigger).
    sdc_detected_count: u32,
    /// Permanently removed by the integrity quarantine.
    quarantined: bool,
    stats: InstanceStats,
}

impl Instance {
    /// Account the time-integral of queue depth up to `now`.
    fn note_queue(&mut self, now: u64, horizon: u64) {
        let until = now.min(horizon);
        let since = self.last_queue_change.min(horizon);
        self.stats.queue_area += self.batcher.queued() as u64 * (until - since);
        self.last_queue_change = now;
    }
}

/// The running simulation state (one `simulate` call).
struct Sim<'a> {
    spec: &'a ServeSpec,
    profiles: &'a [Vec<ServiceProfile>],
    /// Distinct-network id per tenant (affinity shard key).
    net_ids: Vec<usize>,
    dispatcher: Dispatcher,
    mix: RequestMix,
    rng: Pcg32,
    /// Per-request execution-fault draws — a dedicated stream so the
    /// arrival sequence is untouched by fault injection.
    fault_rng: Pcg32,
    instances: Vec<Instance>,
    events: EventQueue<ServeEvent>,
    records: Vec<RequestRecord>,
    req_state: Vec<ReqState>,
    /// Cached per-instance loads + rack/fleet aggregates, refreshed via
    /// [`Sim::sync_load`] only when an instance actually changes (the
    /// satellite fix for the per-arrival O(fleet) snapshot rebuild).
    loads: FleetLoads,
    /// Open-loop-family arrival sampler (`None` = closed loop).
    arrivals: Option<ArrivalProcess>,
    offered: u64,
    admitted: u64,
    rejected: u64,
    completed: u64,
    timed_out: u64,
    shed: u64,
    retries: u64,
    hedges: u64,
    hedge_wins: u64,
    rehomed: u64,
    faulted: u64,
    stale_completions: u64,
    crashes: u64,
    recoveries: u64,
    recovery_cycles: u64,
    sdc_injected: u64,
    sdc_masked: u64,
    sdc_detected: u64,
    sdc_corrected: u64,
    sdc_silent: u64,
    silent_completions: u64,
    scrubs: u64,
    quarantined: u64,
}

impl<'a> Sim<'a> {
    fn new(spec: &'a ServeSpec, profiles: &'a [Vec<ServiceProfile>]) -> Sim<'a> {
        assert_eq!(profiles.len(), spec.tenants.len(), "profiles per tenant");
        assert!(!spec.instances.is_empty(), "empty fleet");

        // Distinct networks, in first-appearance order.
        let mut nets: Vec<&str> = Vec::new();
        let mut net_ids = Vec::with_capacity(spec.tenants.len());
        for t in &spec.tenants {
            let id = match nets.iter().position(|n| *n == t.net) {
                Some(i) => i,
                None => {
                    nets.push(&t.net);
                    nets.len() - 1
                }
            };
            net_ids.push(id);
        }

        let instances = spec
            .instances
            .iter()
            .map(|is| Instance {
                batcher: Batcher::new(spec.batch, spec.tenants.len()),
                busy_until: 0,
                resident_net: None,
                timer_token: 0,
                backlog_cycles: 0,
                last_queue_change: 0,
                epoch: 0,
                running: Vec::new(),
                slowdown: 1.0,
                down_since: None,
                breaker_until: 0,
                timeout_streak: 0,
                launch: None,
                weight_corrupt: 0,
                weight_pending: 0,
                batch_detected: 0,
                batch_corrupt: false,
                reexec_used: 0,
                sdc_detected_count: 0,
                quarantined: false,
                stats: InstanceStats {
                    label: is.label(),
                    ..InstanceStats::default()
                },
            })
            .collect();

        // Serve timeline: one cycle-domain track per instance, tid ==
        // instance index (deterministic — same-seed traced runs are
        // byte-identical; `cmd_serve` enables cycles-only tracing after
        // profiling, so these are the only cycle tracks).
        if trace_span::cycles_enabled() {
            trace_span::reserve_cycle_tracks(0, spec.instances.len() as u64);
            for (i, is) in spec.instances.iter().enumerate() {
                trace_span::name_track(CYCLES_PID, i as u64, format!("inst{i:03} {}", is.label()));
            }
        }

        Sim {
            dispatcher: Dispatcher::new(spec.policy, nets.len(), spec.instances.len(), spec.seed),
            mix: RequestMix::new(&spec.tenants),
            rng: Pcg32::new(spec.seed, 1),
            fault_rng: Pcg32::new(spec.seed, REQ_FAULT_STREAM),
            net_ids,
            loads: FleetLoads::new(spec.instances.len(), spec.racks),
            arrivals: ArrivalProcess::for_model(&spec.traffic, spec.clock_hz(), spec.seed),
            spec,
            profiles,
            instances,
            events: EventQueue::new(),
            records: Vec::new(),
            req_state: Vec::new(),
            offered: 0,
            admitted: 0,
            rejected: 0,
            completed: 0,
            timed_out: 0,
            shed: 0,
            retries: 0,
            hedges: 0,
            hedge_wins: 0,
            rehomed: 0,
            faulted: 0,
            stale_completions: 0,
            crashes: 0,
            recoveries: 0,
            recovery_cycles: 0,
            sdc_injected: 0,
            sdc_masked: 0,
            sdc_detected: 0,
            sdc_corrected: 0,
            sdc_silent: 0,
            silent_completions: 0,
            scrubs: 0,
            quarantined: 0,
        }
    }

    fn horizon(&self) -> u64 {
        self.spec.duration_cycles
    }

    /// Refresh instance `i`'s cached [`FleetLoads`] entry from its ground
    /// truth. Called at every point where dispatch-visible state changes;
    /// the entry stores raw fields, so nothing here depends on `now`.
    fn sync_load(&mut self, i: usize) {
        let inst = &self.instances[i];
        self.loads.update(
            i,
            InstanceLoad {
                queued: inst.batcher.queued(),
                queued_cycles: inst.backlog_cycles,
                busy_until: inst.busy_until,
                has_space: inst.batcher.queued() < self.spec.queue_cap,
                down: inst.down_since.is_some(),
                slow: inst.slowdown > 1.0,
                breaker_until: inst.breaker_until,
            },
        );
    }

    /// Verify every cached load entry (and the rack/fleet aggregates)
    /// against ground truth — O(fleet), debug builds only.
    #[cfg(debug_assertions)]
    fn assert_loads_consistent(&self) {
        for (i, inst) in self.instances.iter().enumerate() {
            let l = self.loads.get(i);
            assert_eq!(l.queued, inst.batcher.queued(), "instance {i}: queued");
            assert_eq!(l.queued_cycles, inst.backlog_cycles, "instance {i}: backlog");
            assert_eq!(l.busy_until, inst.busy_until, "instance {i}: busy_until");
            assert_eq!(
                l.has_space,
                inst.batcher.queued() < self.spec.queue_cap,
                "instance {i}: has_space"
            );
            assert_eq!(l.down, inst.down_since.is_some(), "instance {i}: down");
            assert_eq!(l.slow, inst.slowdown > 1.0, "instance {i}: slow");
            assert_eq!(l.breaker_until, inst.breaker_until, "instance {i}: breaker");
        }
        self.loads.assert_consistent();
    }

    /// Schedule an arrival `mean_cycles` (exponentially distributed) after
    /// `now`, unless it would fall past the horizon. `reissue_of` links a
    /// closed-loop re-issue to the request that spawned it.
    fn schedule_arrival(
        &mut self,
        now: u64,
        mean_cycles: f64,
        client: bool,
        reissue_of: Option<usize>,
    ) {
        let at = now + exp_interarrival(&mut self.rng, mean_cycles);
        if at <= self.horizon() {
            let tenant = self.mix.sample(&mut self.rng);
            self.events.push(
                at,
                ServeEvent::Arrival {
                    tenant,
                    client,
                    reissue_of,
                },
            );
        }
    }

    /// Schedule the next open-loop-family arrival (Poisson, diurnal, or
    /// MMPP — a no-op for closed-loop traffic, which re-issues off
    /// completions instead). Plain Poisson draws exactly what the legacy
    /// inline sampler drew, so pre-topology event sequences are
    /// untouched; the non-stationary models add draws only from their
    /// dedicated modulation stream.
    fn schedule_next_open(&mut self, now: u64) {
        let Some(proc_) = self.arrivals.as_mut() else {
            return;
        };
        let at = proc_.next_at(now, &mut self.rng);
        if at <= self.spec.duration_cycles {
            let tenant = self.mix.sample(&mut self.rng);
            self.events.push(
                at,
                ServeEvent::Arrival {
                    tenant,
                    client: false,
                    reissue_of: None,
                },
            );
        }
    }

    /// Closed-loop chain: a client whose request reached a terminal
    /// outcome re-issues after a think gap (uniform across completion,
    /// rejection, timeout, and shed — the satellite-2 fix: open-loop
    /// failures are counted, closed-loop failures re-issue, and both land
    /// in exactly one ledger bucket).
    fn reissue_if_client(&mut self, now: u64, req: usize) {
        if self.req_state[req].client {
            if let TrafficModel::ClosedLoop { think_cycles, .. } = self.spec.traffic {
                self.schedule_arrival(now, think_cycles.max(1) as f64, true, Some(req));
            }
        }
    }

    /// Remove attempt `token` from `req`'s live set; false if already
    /// cancelled (stale).
    fn remove_live_token(&mut self, req: usize, token: u32) -> bool {
        let live = &mut self.req_state[req].live;
        match live.iter().position(|a| a.token == token) {
            Some(pos) => {
                live.remove(pos);
                true
            }
            None => false,
        }
    }

    /// Remove `req`'s live attempt on `instance` (crash queue drain);
    /// false if it had none there.
    fn remove_live_on(&mut self, req: usize, instance: usize) -> bool {
        let live = &mut self.req_state[req].live;
        match live.iter().position(|a| a.instance == instance) {
            Some(pos) => {
                live.remove(pos);
                true
            }
            None => false,
        }
    }

    /// SLO-aware admission control: shed `tenant` when queue occupancy
    /// over the surviving fleet crosses its priority threshold (a dead
    /// fleet sheds everyone). O(1) off the [`FleetLoads`] aggregates —
    /// down instances always cache `queued == 0` (a crash drains the
    /// queue and a down chip admits nothing), so the fleet total equals
    /// the legacy alive-only scan exactly.
    fn should_shed(&self, tenant: usize) -> bool {
        let alive = self.loads.alive();
        if alive == 0 {
            return true;
        }
        let queued = self.loads.total_queued();
        let load = queued as f64 / (alive * self.spec.queue_cap.max(1)) as f64;
        load >= RobustnessPolicy::shed_threshold(self.spec.tenants[tenant].priority)
    }

    /// Try to place one attempt of `req` on the fleet. `free` attempts
    /// (crash re-homes, hedges) don't consume retry budget; `hedge`
    /// attempts must land on an instance without a live attempt of the
    /// same request. Returns false if no instance admits it.
    fn dispatch_attempt(&mut self, req: usize, now: u64, free: bool, hedge: bool) -> bool {
        let tenant = self.records[req].tenant;
        // No snapshot rebuild: the cached FleetLoads already hold every
        // instance's raw load fields. A hedge must race on a *different*
        // chip, which the avoid list expresses without touching the cache
        // (identical eligibility to the legacy has_space mask).
        let avoid: Vec<usize> = if hedge {
            self.req_state[req]
                .live
                .iter()
                .map(|a| a.instance)
                .collect()
        } else {
            Vec::new()
        };
        let choice = self
            .dispatcher
            .choose(self.net_ids[tenant], &self.loads, now, &avoid);
        if !free {
            self.records[req].attempts += 1;
        }
        let Some(i) = choice else {
            return false;
        };
        if self.records[req].instance.is_none() {
            self.admitted += 1;
        }
        self.records[req].instance = Some(i);
        let token = self.req_state[req].next_token;
        self.req_state[req].next_token += 1;
        self.req_state[req].live.push(Attempt {
            token,
            instance: i,
            hedge,
        });
        // Robustness events go in *before* the batch can launch, so a
        // timeout landing exactly on the completion cycle wins the
        // same-cycle tie (see `events` module docs).
        let rb = self.spec.robust;
        if rb.timeout_cycles > 0 {
            self.events
                .push(now + rb.timeout_cycles, ServeEvent::Timeout { req, token });
        }
        if rb.hedge_cycles > 0 && !hedge && !self.records[req].hedged {
            self.events
                .push(now + rb.hedge_cycles, ServeEvent::Hedge { req, token });
        }
        let horizon = self.horizon();
        let marginal = self.profiles[tenant][i].marginal_cycles;
        let inst = &mut self.instances[i];
        inst.note_queue(now, horizon);
        inst.batcher.push(tenant, req, now);
        inst.backlog_cycles += marginal;
        inst.stats.max_queue = inst.stats.max_queue.max(inst.batcher.queued());
        metrics::add("serve.dispatched", 1);
        trace_span::counter_cycles(
            CYCLES_PID,
            format!("inst{i:03}.queue"),
            now,
            "queued",
            inst.batcher.queued() as u64,
        );
        self.sync_load(i);
        self.try_launch(i, now);
        true
    }

    /// An attempt failed with no other attempt still racing: retry with
    /// backoff if budget remains, else settle the request's terminal
    /// outcome (and re-issue the closed-loop chain).
    fn fail_attempt(&mut self, req: usize, now: u64, cause: FailCause) {
        if !self.req_state[req].live.is_empty() {
            return; // a hedge twin is still in flight
        }
        let rb = self.spec.robust;
        let attempts = self.records[req].attempts;
        if rb.max_retries > 0 && attempts <= rb.max_retries {
            self.retries += 1;
            let at = now + rb.backoff_for(attempts);
            // Past-horizon retries never execute: the request simply
            // stays in flight at the end, which the ledger counts.
            self.events.push(at, ServeEvent::Retry { req });
            return;
        }
        let outcome = match cause {
            FailCause::TimedOut => {
                self.timed_out += 1;
                Outcome::TimedOut
            }
            FailCause::Capacity | FailCause::ExecFault => {
                self.rejected += 1;
                Outcome::Rejected
            }
        };
        self.records[req].outcome = outcome;
        self.reissue_if_client(now, req);
    }

    /// Cancel a losing attempt: de-queue it if it hasn't launched (its
    /// completion would otherwise be stale anyway — this just frees the
    /// slot earlier).
    fn cancel_queued_attempt(&mut self, req: usize, att: Attempt, now: u64) {
        let tenant = self.records[req].tenant;
        let horizon = self.horizon();
        let marginal = self.profiles[tenant][att.instance].marginal_cycles;
        let inst = &mut self.instances[att.instance];
        inst.note_queue(now, horizon);
        if inst.batcher.remove(tenant, req) {
            inst.backlog_cycles = inst.backlog_cycles.saturating_sub(marginal);
            self.sync_load(att.instance);
        }
    }

    /// Launch a batch on instance `i` if one is ready, else arm the wait
    /// window timer. Called whenever the instance might have become able
    /// to start work (arrival while idle, completion, timer expiry).
    fn try_launch(&mut self, i: usize, now: u64) {
        let horizon = self.horizon();
        let inst = &mut self.instances[i];
        if inst.down_since.is_some() || inst.busy_until > now {
            return;
        }
        inst.note_queue(now, horizon);
        if let Some((tenant, reqs)) = inst.batcher.take_ready(now) {
            let prof = self.profiles[tenant][i];
            let net = self.net_ids[tenant];
            let switch = if inst.resident_net == Some(net) {
                0
            } else {
                prof.switch_cycles
            };
            if switch > 0 {
                inst.stats.switches += 1;
                // The re-streamed weight image replaces the resident
                // one: latent or pending-scrub corruption goes with it.
                inst.weight_corrupt = 0;
                inst.weight_pending = 0;
            }
            inst.resident_net = Some(net);
            let n = reqs.len() as u64;
            let mut duration = switch + prof.single_cycles + (n - 1) * prof.marginal_cycles;
            if inst.slowdown > 1.0 {
                // Straggler episode: everything on the chip runs slow.
                duration = ((duration as f64) * inst.slowdown).ceil() as u64;
            }
            if self.spec.sdc_active() && self.spec.sdc.protect {
                // The integrity stack's honest price: checksum rows,
                // validation walks, and scrub interference.
                duration = protected_cycles(duration, self.spec.sdc.overhead_frac);
            }
            let end = now + duration;
            inst.busy_until = end;
            inst.stats.batches += 1;
            inst.stats.busy_cycles += end.min(horizon) - now.min(horizon);
            inst.backlog_cycles = inst.backlog_cycles.saturating_sub(n * prof.marginal_cycles);
            // Per-batch integrity state starts clean (no-ops without SDC).
            inst.batch_detected = 0;
            inst.batch_corrupt = false;
            inst.reexec_used = 0;
            inst.launch = Some(LaunchInfo {
                start: now,
                tenant,
                n: reqs.len(),
                switch,
                duration,
            });
            metrics::add("serve.batches", 1);
            metrics::observe("serve.batch_size", n);
            trace_span::counter_cycles(
                CYCLES_PID,
                format!("inst{i:03}.queue"),
                now,
                "queued",
                inst.batcher.queued() as u64,
            );
            let epoch = inst.epoch;
            inst.running.clear();
            for &r in &reqs {
                self.records[r].start = Some(now);
                self.records[r].batch_size = reqs.len();
                // Every queued request has a live attempt on this
                // instance (timeouts de-queue when they cancel).
                let token = self.req_state[r]
                    .live
                    .iter()
                    .find(|a| a.instance == i)
                    .map(|a| a.token)
                    .unwrap_or(u32::MAX);
                inst.running.push((r, token));
            }
            self.events.push(end, ServeEvent::Complete { instance: i, epoch });
            self.sync_load(i);
        } else if inst.batcher.queued() > 0 {
            // Partial batches only: wake up when the oldest one expires.
            if let Some(deadline) = inst.batcher.next_deadline() {
                inst.timer_token += 1;
                let token = inst.timer_token;
                let at = deadline.max(now + 1);
                self.events.push(at, ServeEvent::BatchTimer { instance: i, token });
            }
        }
    }

    fn on_arrival(&mut self, now: u64, tenant: usize, client: bool, reissue_of: Option<usize>) {
        self.offered += 1;
        let req_id = self.records.len();
        self.records.push(RequestRecord {
            tenant,
            instance: None,
            arrival: now,
            start: None,
            completion: None,
            batch_size: 0,
            outcome: Outcome::InFlight,
            attempts: 0,
            hedged: false,
            hedge_won: false,
            reissue_of,
        });
        self.req_state.push(ReqState {
            live: Vec::new(),
            next_token: 0,
            client,
        });
        if self.spec.robust.shed && self.should_shed(tenant) {
            self.records[req_id].outcome = Outcome::Shed;
            self.shed += 1;
            self.reissue_if_client(now, req_id);
        } else if !self.dispatch_attempt(req_id, now, false, false) {
            self.fail_attempt(req_id, now, FailCause::Capacity);
        }
        // Open-loop family: the arrival process marches on regardless of
        // fleet state (no-op under closed loop).
        self.schedule_next_open(now);
    }

    fn on_retry(&mut self, now: u64, req: usize) {
        if self.records[req].outcome != Outcome::InFlight {
            return; // settled while the backoff ran
        }
        if !self.req_state[req].live.is_empty() {
            return; // a crash re-home beat the backoff to it
        }
        if !self.dispatch_attempt(req, now, false, false) {
            self.fail_attempt(req, now, FailCause::Capacity);
        }
    }

    fn on_timeout(&mut self, now: u64, req: usize, token: u32) {
        // A stale token means the attempt already completed, was
        // cancelled, or was re-homed (re-homes mint fresh tokens).
        let live = &self.req_state[req].live;
        let Some(pos) = live.iter().position(|a| a.token == token) else {
            return;
        };
        let i = live[pos].instance;
        self.req_state[req].live.remove(pos);
        let tenant = self.records[req].tenant;
        let horizon = self.horizon();
        let marginal = self.profiles[tenant][i].marginal_cycles;
        let inst = &mut self.instances[i];
        inst.note_queue(now, horizon);
        // De-queue if it never launched; launched work runs to completion
        // and is discarded as stale. Either way the attempt timed out on
        // this chip and charges its breaker.
        if inst.batcher.remove(tenant, req) {
            inst.backlog_cycles = inst.backlog_cycles.saturating_sub(marginal);
        }
        inst.timeout_streak += 1;
        if inst.timeout_streak >= BREAKER_STREAK {
            inst.breaker_until = now + BREAKER_COOLDOWN_TIMEOUTS * self.spec.robust.timeout_cycles;
        }
        self.sync_load(i);
        if self.req_state[req].live.is_empty() {
            self.fail_attempt(req, now, FailCause::TimedOut);
        }
    }

    fn on_hedge(&mut self, now: u64, req: usize, token: u32) {
        if self.records[req].hedged {
            return; // one hedge per request
        }
        // Only hedge an attempt that is still live (not completed, timed
        // out, or re-homed — a re-home already changed instances).
        if !self.req_state[req].live.iter().any(|a| a.token == token) {
            return;
        }
        if self.dispatch_attempt(req, now, true, true) {
            self.hedges += 1;
            self.records[req].hedged = true;
        }
    }

    fn on_crash(&mut self, now: u64, i: usize) {
        if self.instances[i].quarantined {
            return; // already permanently out; nothing left to kill
        }
        self.crashes += 1;
        metrics::add("serve.crashes", 1);
        self.instances[i].stats.crashes += 1;
        self.take_down(now, i, "crash");
    }

    /// Take instance `i` out of service: kill and re-home its running
    /// batch and queue, mark it down, reset its integrity state (a cold
    /// reload clears resident-weight corruption). Shared by crashes
    /// (which recover) and integrity quarantine (which never does).
    fn take_down(&mut self, now: u64, i: usize, label: &'static str) {
        let horizon = self.horizon();
        let (killed, drained) = {
            let inst = &mut self.instances[i];
            // Timeline: the in-flight batch dies here — close its
            // interval as `killed`, mark the instant, zero the
            // queue counter (the queue is drained below for re-homing).
            if let Some(l) = inst.launch.take() {
                trace_span::complete_cycles(
                    CYCLES_PID,
                    i as u64,
                    "killed",
                    format!("killed t{} x{}", l.tenant, l.n),
                    l.start,
                    now - l.start,
                    vec![("batch", Arg::U(l.n as u64))],
                );
            }
            trace_span::instant_cycles(CYCLES_PID, i as u64, "fault", label, now);
            trace_span::counter_cycles(CYCLES_PID, format!("inst{i:03}.queue"), now, "queued", 0);
            inst.note_queue(now, horizon);
            inst.epoch = inst.epoch.wrapping_add(1);
            inst.down_since = Some(now);
            inst.resident_net = None;
            inst.timer_token += 1; // orphan any pending batch timer
            inst.timeout_streak = 0;
            inst.breaker_until = 0;
            // Cold reload: resident-weight corruption (latent or pending
            // scrub) is gone with the weights; the running batch's
            // in-flight flips died with the batch.
            inst.weight_corrupt = 0;
            inst.weight_pending = 0;
            inst.batch_detected = 0;
            inst.batch_corrupt = false;
            inst.reexec_used = 0;
            // Un-count the busy cycles the chip will never serve.
            let unserved = inst.busy_until.min(horizon).saturating_sub(now.min(horizon));
            inst.stats.busy_cycles = inst.stats.busy_cycles.saturating_sub(unserved);
            inst.busy_until = now;
            inst.backlog_cycles = 0;
            (std::mem::take(&mut inst.running), inst.batcher.drain_all())
        };
        // The takedown is visible to dispatch *before* re-homing starts,
        // so no victim can be re-homed onto the chip that just died.
        self.sync_load(i);
        // Re-home, killed batch first (dispatched earliest), then the
        // queue in tenant-FIFO order — a pinned, deterministic order.
        for (req, token) in killed {
            if self.remove_live_token(req, token) {
                self.rehome(req, now);
            }
        }
        for (_tenant, req) in drained {
            if self.remove_live_on(req, i) {
                self.rehome(req, now);
            }
        }
    }

    /// Re-dispatch a crash victim onto the surviving fleet — free (no
    /// retry budget), unless a hedge twin is still racing elsewhere.
    fn rehome(&mut self, req: usize, now: u64) {
        if self.records[req].outcome != Outcome::InFlight {
            return;
        }
        if !self.req_state[req].live.is_empty() {
            return;
        }
        if self.dispatch_attempt(req, now, true, false) {
            self.rehomed += 1;
        } else {
            self.fail_attempt(req, now, FailCause::Capacity);
        }
    }

    fn on_recover(&mut self, now: u64, i: usize) {
        if self.instances[i].quarantined {
            return; // quarantine is permanent: fault-plan recovery ignored
        }
        self.recoveries += 1;
        metrics::add("serve.recoveries", 1);
        let horizon = self.horizon();
        let inst = &mut self.instances[i];
        if let Some(since) = inst.down_since.take() {
            let d = now.min(horizon).saturating_sub(since.min(horizon));
            inst.stats.down_cycles += d;
            self.recovery_cycles += now - since;
            trace_span::complete_cycles(
                CYCLES_PID,
                i as u64,
                "down",
                "down",
                since,
                now - since,
                Vec::new(),
            );
            trace_span::instant_cycles(CYCLES_PID, i as u64, "fault", "recover", now);
        }
        // Back cold: empty queue, no resident net; new arrivals route in.
        inst.last_queue_change = now;
        self.sync_load(i);
    }

    /// A planned SDC flip lands (ISSUE 10). The ledger is settled here —
    /// every flip becomes exactly one of masked / detected / silent, so
    /// `injected = masked + detected + silent` holds at any horizon —
    /// while the *consequences* (re-execution, scrub repair, corrupted
    /// completions) play out through the flags this sets.
    fn on_sdc(&mut self, now: u64, i: usize, site: SdcSite, roll: f32) {
        self.sdc_injected += 1;
        metrics::add("integrity.injected", 1);
        if self.instances[i].down_since.is_some() {
            // A dead chip holds no live state to corrupt.
            self.sdc_masked += 1;
            metrics::add("integrity.masked", 1);
            return;
        }
        let consequential = match site {
            // Weight flips need a resident weight image.
            SdcSite::Weight => self.instances[i].resident_net.is_some(),
            // Transient sites need a batch in flight.
            SdcSite::Activation | SdcSite::Accumulator => {
                !self.instances[i].running.is_empty()
            }
        };
        if !consequential {
            self.sdc_masked += 1;
            metrics::add("integrity.masked", 1);
            return;
        }
        trace_span::instant_cycles(CYCLES_PID, i as u64, "integrity", site.label(), now);
        let caught = self.spec.sdc.protect && roll < coverage(site) as f32;
        if caught {
            self.sdc_detected += 1;
            metrics::add("integrity.detected", 1);
            self.instances[i].sdc_detected_count += 1;
            match site {
                // Latent until the scrubber walks the weights.
                SdcSite::Weight => self.instances[i].weight_pending += 1,
                // Caught by ABFT / structural validation at completion.
                SdcSite::Activation | SdcSite::Accumulator => {
                    self.instances[i].batch_detected += 1
                }
            }
            self.quarantine_check(now, i);
        } else {
            self.sdc_silent += 1;
            metrics::add("integrity.silent", 1);
            match site {
                SdcSite::Weight => self.instances[i].weight_corrupt += 1,
                SdcSite::Activation | SdcSite::Accumulator => {
                    self.instances[i].batch_corrupt = true
                }
            }
        }
    }

    /// Periodic weight scrub (protected runs): re-verifies the resident
    /// weight image, repairing detected latent flips by forcing a weight
    /// re-stream (the next batch pays the switch cost again).
    fn on_scrub(&mut self, now: u64, i: usize) {
        // Re-arm first so the cadence is stable regardless of findings.
        let next = now + self.spec.scrub_period_cycles();
        if next <= self.horizon() {
            self.events.push(next, ServeEvent::Scrub { instance: i });
        }
        if self.instances[i].down_since.is_some() {
            return; // nothing resident to verify
        }
        self.scrubs += 1;
        metrics::add("integrity.scrubs", 1);
        let pending = self.instances[i].weight_pending;
        if pending > 0 {
            self.instances[i].weight_pending = 0;
            self.sdc_corrected += pending as u64;
            metrics::add("integrity.corrected", pending as u64);
            // Repair = reload: drop residency so the weights re-stream.
            self.instances[i].resident_net = None;
            trace_span::instant_cycles(CYCLES_PID, i as u64, "integrity", "scrub-fix", now);
        }
    }

    /// Quarantine: a chip whose lifetime detected-corruption count
    /// crosses the threshold is permanently removed (its SRAM is
    /// presumed failing — detected flips are the observable symptom).
    fn quarantine_check(&mut self, now: u64, i: usize) {
        let threshold = self.spec.sdc.quarantine;
        if threshold == 0 || self.instances[i].quarantined {
            return;
        }
        if self.instances[i].sdc_detected_count >= threshold {
            self.instances[i].quarantined = true;
            self.quarantined += 1;
            metrics::add("integrity.quarantined", 1);
            trace_span::instant_cycles(CYCLES_PID, i as u64, "integrity", "quarantine", now);
            self.take_down(now, i, "quarantine");
        }
    }

    fn on_complete(&mut self, now: u64, i: usize, epoch: u32) {
        if self.instances[i].epoch != epoch {
            return; // batch was killed by a crash; work already re-homed
        }
        // ISSUE 10: the integrity stack flagged this batch mid-flight.
        // Re-execute from the retained inputs while budget remains; past
        // the budget the batch cannot produce a trusted answer and its
        // requests fail into the `RobustnessPolicy` retry path.
        if self.instances[i].batch_detected > 0 {
            if self.instances[i].reexec_used < self.spec.sdc.reexec_budget {
                let redo = self.instances[i].launch.as_ref().map_or(1, |l| l.duration.max(1));
                let horizon = self.horizon();
                let inst = &mut self.instances[i];
                inst.reexec_used += 1;
                let fixed = inst.batch_detected as u64;
                inst.batch_detected = 0;
                // The re-run starts from clean inputs: any silent
                // corruption this batch absorbed is re-done too.
                inst.batch_corrupt = false;
                let end = now + redo;
                inst.stats.busy_cycles += end.min(horizon) - now.min(horizon);
                inst.busy_until = end;
                self.sdc_corrected += fixed;
                metrics::add("integrity.corrected", fixed);
                trace_span::instant_cycles(CYCLES_PID, i as u64, "integrity", "reexec", now);
                self.events.push(end, ServeEvent::Complete { instance: i, epoch });
                self.sync_load(i);
                return;
            }
            let launch = self.instances[i].launch.take();
            let running = std::mem::take(&mut self.instances[i].running);
            self.instances[i].batch_detected = 0;
            self.instances[i].batch_corrupt = false;
            for (req, token) in running {
                if self.remove_live_token(req, token) {
                    self.fail_attempt(req, now, FailCause::ExecFault);
                } else {
                    self.stale_completions += 1;
                }
            }
            if let Some(l) = launch {
                trace_span::complete_cycles(
                    CYCLES_PID,
                    i as u64,
                    "exec",
                    format!("sdc-fail t{} x{}", l.tenant, l.n),
                    l.start,
                    now - l.start,
                    vec![("batch", Arg::U(l.n as u64))],
                );
            }
            self.try_launch(i, now);
            return;
        }
        let launch = self.instances[i].launch.take();
        let running = std::mem::take(&mut self.instances[i].running);
        self.instances[i].timeout_streak = 0;
        let mut done = 0u64;
        let mut respawn: Vec<usize> = Vec::new();
        let fault_prob = self.spec.faults.req_fault_prob;
        for (req, token) in running {
            let pos = self.req_state[req].live.iter().position(|a| a.token == token);
            let Some(pos) = pos else {
                // Cancelled while running (timed out / lost a hedge):
                // the work finished but the result is discarded.
                self.stale_completions += 1;
                continue;
            };
            // The fault stream is only consulted when faults can fire,
            // so the zero-fault path draws nothing from it.
            if fault_prob > 0.0 && self.fault_rng.bernoulli(fault_prob as f32) {
                self.faulted += 1;
                self.req_state[req].live.remove(pos);
                if self.req_state[req].live.is_empty() {
                    self.fail_attempt(req, now, FailCause::ExecFault);
                }
                continue;
            }
            // Winner: settle the request, cancel any losing twin.
            let was_hedge = self.req_state[req].live[pos].hedge;
            let mut losers = std::mem::take(&mut self.req_state[req].live);
            losers.remove(pos);
            for att in losers {
                self.cancel_queued_attempt(req, att, now);
            }
            self.records[req].completion = Some(now);
            self.records[req].outcome = Outcome::Completed;
            self.records[req].instance = Some(i);
            if was_hedge {
                self.hedge_wins += 1;
                self.records[req].hedge_won = true;
            }
            done += 1;
            if self.req_state[req].client {
                respawn.push(req);
            }
        }
        self.completed += done;
        self.instances[i].stats.completed += done;
        // Responses served from corrupted state (an undetected in-batch
        // flip, or latent weight corruption — escaped or still awaiting
        // its scrub) are wrong answers delivered as successes.
        if done > 0
            && (self.instances[i].batch_corrupt
                || self.instances[i].weight_corrupt > 0
                || self.instances[i].weight_pending > 0)
        {
            self.silent_completions += done;
            metrics::add("integrity.silent_served", done);
        }
        self.instances[i].batch_corrupt = false;
        if let Some(l) = launch {
            trace_span::complete_cycles(
                CYCLES_PID,
                i as u64,
                "exec",
                format!("exec t{} x{}", l.tenant, l.n),
                l.start,
                now - l.start,
                vec![
                    ("batch", Arg::U(l.n as u64)),
                    ("switch_cycles", Arg::U(l.switch)),
                    ("served", Arg::U(done)),
                ],
            );
        }
        // Closed-loop clients re-issue after their think time. Client
        // identity is not tracked through batches — the population size
        // is what matters — so each served completion spawns one
        // successor (failures re-issue through `fail_attempt`).
        if let TrafficModel::ClosedLoop { think_cycles, .. } = self.spec.traffic {
            for req in respawn {
                self.schedule_arrival(now, think_cycles.max(1) as f64, true, Some(req));
            }
        }
        self.try_launch(i, now);
    }

    fn run(mut self) -> ServeOutcome {
        // The fault plan goes in *first*: at any shared cycle its events
        // carry the lowest seqs, so a crash beats the completions,
        // timeouts, and arrivals of that cycle (the pessimistic order —
        // see the `events` module docs). Empty when faults are off: the
        // legacy event sequence is untouched.
        let plan = generate_plan(
            &self.spec.faults,
            self.spec.instances.len(),
            self.horizon(),
            self.spec.clock_hz(),
            self.spec.seed,
        );
        for e in plan {
            self.events.push(
                e.cycle,
                ServeEvent::Fault {
                    instance: e.instance,
                    kind: e.kind,
                },
            );
        }
        // The SDC flip plan rides its own dedicated streams and goes in
        // right after the fault plan — still ahead of every arrival, so
        // a flip at cycle `c` lands before that cycle's completions
        // (pessimistic: a flip racing a completion corrupts it). Empty
        // when SDC is off: the pre-SDC event sequence is untouched.
        if self.spec.sdc_active() {
            let sdc_plan = generate_sdc_plan(
                &self.spec.sdc,
                self.spec.instances.len(),
                self.horizon(),
                self.spec.clock_hz(),
                self.spec.seed,
            );
            for e in sdc_plan {
                self.events.push(
                    e.cycle,
                    ServeEvent::Sdc {
                        instance: e.instance,
                        site: e.site,
                        roll: e.roll,
                    },
                );
            }
            // Protected runs scrub resident weights on a fixed cadence;
            // each pass re-arms the next.
            if self.spec.sdc.protect {
                let period = self.spec.scrub_period_cycles();
                if period <= self.horizon() {
                    for i in 0..self.spec.instances.len() {
                        self.events.push(period, ServeEvent::Scrub { instance: i });
                    }
                }
            }
        }

        // Seed the load caches (handles degenerate specs like
        // queue_cap == 0, where even an idle instance has no space).
        for i in 0..self.instances.len() {
            self.sync_load(i);
        }

        // Seed the arrival processes.
        match self.spec.traffic {
            TrafficModel::ClosedLoop {
                clients,
                think_cycles,
            } => {
                for _ in 0..clients {
                    self.schedule_arrival(0, think_cycles.max(1) as f64, true, None);
                }
            }
            _ => self.schedule_next_open(0),
        }

        // Batched draining: all events of one timestamp come out of the
        // heap in one sweep and execute back to back. Handlers that push
        // same-cycle events (e.g. zero-gap arrivals) enqueue with higher
        // seqs, so the next sweep runs them — exactly the order
        // one-at-a-time popping produced (`events::drain_matches_pop_order`).
        let mut batch: Vec<ServeEvent> = Vec::new();
        let mut events_processed = 0u64;
        while let Some(now) = self.events.peek_cycle() {
            if now > self.horizon() {
                break; // heap order: everything left is at or after `now`
            }
            self.events.drain_cycle(now, &mut batch);
            for ev in batch.drain(..) {
                events_processed += 1;
                match ev {
                    ServeEvent::Arrival {
                        tenant,
                        client,
                        reissue_of,
                    } => self.on_arrival(now, tenant, client, reissue_of),
                    ServeEvent::Retry { req } => self.on_retry(now, req),
                    ServeEvent::BatchTimer { instance, token } => {
                        if self.instances[instance].timer_token == token {
                            self.try_launch(instance, now);
                        }
                    }
                    ServeEvent::Complete { instance, epoch } => {
                        self.on_complete(now, instance, epoch)
                    }
                    ServeEvent::Timeout { req, token } => self.on_timeout(now, req, token),
                    ServeEvent::Hedge { req, token } => self.on_hedge(now, req, token),
                    ServeEvent::Fault { instance, kind } => match kind {
                        FaultKind::Crash => self.on_crash(now, instance),
                        FaultKind::Recover => self.on_recover(now, instance),
                        FaultKind::SlowStart(x) => {
                            self.instances[instance].slowdown = x;
                            self.sync_load(instance);
                        }
                        FaultKind::SlowEnd => {
                            self.instances[instance].slowdown = 1.0;
                            self.sync_load(instance);
                        }
                    },
                    ServeEvent::Sdc {
                        instance,
                        site,
                        roll,
                    } => self.on_sdc(now, instance, site, roll),
                    ServeEvent::Scrub { instance } => self.on_scrub(now, instance),
                }
            }
        }

        // The lazily-maintained load caches must agree with ground truth
        // after any event interleaving (O(fleet), debug builds only; runs
        // before the horizon close mutates instance state untracked).
        #[cfg(debug_assertions)]
        self.assert_loads_consistent();

        // Close the queue-depth and downtime integrals at the horizon,
        // and close still-open timeline intervals (a batch running past
        // the horizon, an instance still down) so the export has no
        // dangling state.
        let horizon = self.horizon();
        for (i, inst) in self.instances.iter_mut().enumerate() {
            inst.note_queue(horizon, horizon);
            if let Some(l) = inst.launch.take() {
                trace_span::complete_cycles(
                    CYCLES_PID,
                    i as u64,
                    "exec",
                    format!("exec t{} x{} (past horizon)", l.tenant, l.n),
                    l.start,
                    horizon.saturating_sub(l.start),
                    vec![("batch", Arg::U(l.n as u64))],
                );
            }
            if let Some(since) = inst.down_since.take() {
                inst.stats.down_cycles += horizon.saturating_sub(since.min(horizon));
                trace_span::complete_cycles(
                    CYCLES_PID,
                    i as u64,
                    "down",
                    "down (past horizon)",
                    since.min(horizon),
                    horizon.saturating_sub(since.min(horizon)),
                    Vec::new(),
                );
            }
        }

        let in_flight = self
            .records
            .iter()
            .filter(|r| r.outcome == Outcome::InFlight)
            .count() as u64;
        let down_cycles = self.instances.iter().map(|i| i.stats.down_cycles).sum();
        ServeOutcome {
            offered: self.offered,
            admitted: self.admitted,
            rejected: self.rejected,
            completed: self.completed,
            timed_out: self.timed_out,
            shed: self.shed,
            in_flight,
            retries: self.retries,
            hedges: self.hedges,
            hedge_wins: self.hedge_wins,
            rehomed: self.rehomed,
            faulted: self.faulted,
            stale_completions: self.stale_completions,
            crashes: self.crashes,
            recoveries: self.recoveries,
            recovery_cycles: self.recovery_cycles,
            down_cycles,
            sdc_injected: self.sdc_injected,
            sdc_masked: self.sdc_masked,
            sdc_detected: self.sdc_detected,
            sdc_corrected: self.sdc_corrected,
            sdc_silent: self.sdc_silent,
            silent_completions: self.silent_completions,
            scrubs: self.scrubs,
            quarantined: self.quarantined,
            events_processed,
            records: self.records,
            instances: self.instances.into_iter().map(|i| i.stats).collect(),
        }
    }
}

/// Run the discrete-event simulation. `profiles` comes from
/// [`build_profiles`]; the loop itself never touches the engine, so a
/// multi-point capacity sweep is pure event processing after one
/// profiling pass.
pub fn simulate(spec: &ServeSpec, profiles: &[Vec<ServiceProfile>]) -> ServeOutcome {
    Sim::new(spec, profiles).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic profile set: no engine needed for event-loop tests.
    fn toy_spec(
        policy: DispatchPolicy,
        batch: BatchPolicy,
        rps: f64,
    ) -> (ServeSpec, Vec<Vec<ServiceProfile>>) {
        let tenants = vec![
            Tenant::new("vgg16", 32, 0.5),
            Tenant::new("alexnet", 32, 0.5),
        ];
        let instances = vec![
            InstanceSpec {
                config: SimConfig::paper_4_14_3(),
            },
            InstanceSpec {
                config: SimConfig::paper_8_7_3(),
            },
        ];
        let spec = ServeSpec {
            tenants,
            instances,
            traffic: TrafficModel::OpenLoop { rps },
            policy,
            batch,
            queue_cap: 8,
            racks: 1,
            duration_cycles: 50_000_000,
            clock_mhz: 500.0,
            seed: 42,
            faults: FaultSpec::none(),
            robust: RobustnessPolicy::none(),
            sdc: SdcSpec::none(),
        };
        let prof = ServiceProfile {
            single_cycles: 1_000_000,
            marginal_cycles: 600_000,
            switch_cycles: 400_000,
        };
        let profiles = vec![vec![prof; 2]; 2];
        (spec, profiles)
    }

    fn window(max_batch: usize, max_wait_cycles: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait_cycles,
        }
    }

    /// The five-bucket ledger, checked both by counter and by record.
    fn assert_conserved(out: &ServeOutcome, tag: &str) {
        assert_eq!(
            out.offered,
            out.completed + out.rejected + out.timed_out + out.shed + out.in_flight,
            "{tag}: ledger"
        );
        assert_eq!(out.offered as usize, out.records.len(), "{tag}: records");
        let count = |o: Outcome| out.records.iter().filter(|r| r.outcome == o).count() as u64;
        assert_eq!(count(Outcome::Completed), out.completed, "{tag}: completed");
        assert_eq!(count(Outcome::Rejected), out.rejected, "{tag}: rejected");
        assert_eq!(count(Outcome::TimedOut), out.timed_out, "{tag}: timed_out");
        assert_eq!(count(Outcome::Shed), out.shed, "{tag}: shed");
        assert_eq!(count(Outcome::InFlight), out.in_flight, "{tag}: in_flight");
    }

    #[test]
    fn conservation_holds_on_toy_fleet() {
        for rps in [50.0, 500.0, 5_000.0, 50_000.0] {
            let (spec, profiles) = toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), rps);
            let out = simulate(&spec, &profiles);
            assert_conserved(&out, &format!("rps {rps}"));
            // Every offered request was one arrival event; completions
            // and batch timers add more.
            assert!(out.events_processed >= out.offered, "rps {rps}");
            let rec_completed = out.records.iter().filter(|r| r.completion.is_some()).count();
            assert_eq!(rec_completed as u64, out.completed);
            let rec_rejected = out.records.iter().filter(|r| r.instance.is_none()).count();
            assert_eq!(rec_rejected as u64, out.rejected);
        }
    }

    #[test]
    fn zero_fault_path_has_legacy_counters() {
        let (spec, profiles) = toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 3_000.0);
        assert!(!spec.resilience_active());
        let out = simulate(&spec, &profiles);
        // No resilience machinery fires, and the legacy in-flight
        // derivation still holds exactly.
        assert_eq!(out.in_flight, out.admitted - out.completed);
        for (v, name) in [
            (out.timed_out, "timed_out"),
            (out.shed, "shed"),
            (out.retries, "retries"),
            (out.hedges, "hedges"),
            (out.hedge_wins, "hedge_wins"),
            (out.rehomed, "rehomed"),
            (out.faulted, "faulted"),
            (out.stale_completions, "stale_completions"),
            (out.crashes, "crashes"),
            (out.recoveries, "recoveries"),
            (out.down_cycles, "down_cycles"),
            (out.sdc_injected, "sdc_injected"),
            (out.sdc_masked, "sdc_masked"),
            (out.sdc_detected, "sdc_detected"),
            (out.sdc_corrected, "sdc_corrected"),
            (out.sdc_silent, "sdc_silent"),
            (out.silent_completions, "silent_completions"),
            (out.scrubs, "scrubs"),
            (out.quarantined, "quarantined"),
        ] {
            assert_eq!(v, 0, "zero-fault run has nonzero {name}");
        }
        assert!(out.records.iter().all(|r| r.attempts <= 1 && !r.hedged));
        assert!(out
            .records
            .iter()
            .all(|r| r.reissue_of.is_none()), "open loop never re-issues");
    }

    #[test]
    fn latency_never_beats_single_image_cycles() {
        let (spec, profiles) =
            toy_spec(DispatchPolicy::NetworkAffinity, window(8, 200_000), 2_000.0);
        let out = simulate(&spec, &profiles);
        assert!(out.completed > 0);
        for r in &out.records {
            if let Some(lat) = r.latency() {
                let i = r.instance.unwrap();
                assert!(
                    lat >= profiles[r.tenant][i].single_cycles,
                    "latency {lat} < single"
                );
            }
        }
    }

    #[test]
    fn batching_forms_batches_under_load() {
        let (spec, profiles) =
            toy_spec(DispatchPolicy::NetworkAffinity, window(8, 500_000), 20_000.0);
        let out = simulate(&spec, &profiles);
        let max_batch = out.records.iter().map(|r| r.batch_size).max().unwrap_or(0);
        assert!(max_batch > 1, "no batch formed (max {max_batch})");
        // Stats are self-consistent.
        let sum: u64 = out.instances.iter().map(|i| i.completed).sum();
        assert_eq!(sum, out.completed);
        for i in &out.instances {
            assert!(i.utilization(spec.duration_cycles) <= 1.0 + 1e-12);
            assert!(i.mean_queue_depth(spec.duration_cycles) <= spec.queue_cap as f64);
            assert_eq!(i.availability(spec.duration_cycles), 1.0);
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let (spec, profiles) = toy_spec(DispatchPolicy::RoundRobin, window(4, 100_000), 3_000.0);
        let a = simulate(&spec, &profiles);
        let b = simulate(&spec, &profiles);
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.completion, y.completion);
            assert_eq!(x.instance, y.instance);
        }
    }

    #[test]
    fn closed_loop_self_throttles() {
        let (mut spec, profiles) = toy_spec(DispatchPolicy::LeastLoaded, BatchPolicy::none(), 0.0);
        spec.traffic = TrafficModel::ClosedLoop {
            clients: 3,
            think_cycles: 100_000,
        };
        let out = simulate(&spec, &profiles);
        assert!(out.offered > 0);
        // With 3 clients at >= 1M cycles per turn over 50M cycles, the
        // offered load is bounded by the client population.
        assert!(out.offered <= 3 * 50 + 3, "offered {}", out.offered);
        assert_conserved(&out, "closed loop");
        // Every non-seed arrival is a re-issue linked to its spawner.
        let fresh = out.records.iter().filter(|r| r.reissue_of.is_none()).count();
        assert!(fresh <= 3, "only the 3 seeded clients arrive unlinked");
        assert!(out
            .records
            .iter()
            .filter_map(|r| r.reissue_of)
            .all(|p| p < out.records.len()));
    }

    #[test]
    fn affinity_switches_less_than_round_robin() {
        let mk = |policy| {
            let (spec, profiles) = toy_spec(policy, BatchPolicy::none(), 5_000.0);
            let out = simulate(&spec, &profiles);
            out.instances.iter().map(|i| i.switches).sum::<u64>()
        };
        let rr = mk(DispatchPolicy::RoundRobin);
        let aff = mk(DispatchPolicy::NetworkAffinity);
        assert!(aff < rr, "affinity switches {aff} !< round-robin {rr}");
    }

    #[test]
    fn crashes_rehome_work_and_close_the_ledger() {
        let (mut spec, profiles) =
            toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 1_200.0);
        spec.faults = FaultSpec::parse("crash:100,mttr:2").unwrap();
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "crashy");
        assert!(out.crashes > 0, "crash rate high enough to fire");
        assert_eq!(
            out.crashes,
            out.instances.iter().map(|i| i.crashes).sum::<u64>()
        );
        assert!(out.recoveries <= out.crashes);
        assert!(out.down_cycles > 0);
        // Some victims found a new home; completions still happened.
        assert!(out.rehomed > 0, "no work re-homed");
        assert!(out.completed > 0);
        for i in &out.instances {
            assert!(i.availability(spec.duration_cycles) < 1.0);
            assert!(i.availability(spec.duration_cycles) >= 0.0);
        }
        // Replays are bit-identical.
        let again = simulate(&spec, &profiles);
        assert_eq!(out.crashes, again.crashes);
        assert_eq!(out.completed, again.completed);
        assert_eq!(out.rehomed, again.rehomed);
    }

    #[test]
    fn stragglers_stretch_latency() {
        let (clean_spec, profiles) =
            toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 1_000.0);
        let mut slow_spec = clean_spec.clone();
        slow_spec.faults = FaultSpec::parse("straggler:200,slow:8,slowms:5").unwrap();
        let mean_lat = |out: &ServeOutcome| {
            let lats: Vec<u64> = out.records.iter().filter_map(|r| r.latency()).collect();
            lats.iter().sum::<u64>() as f64 / lats.len().max(1) as f64
        };
        let clean = simulate(&clean_spec, &profiles);
        let slow = simulate(&slow_spec, &profiles);
        assert_conserved(&slow, "straggler");
        assert_eq!(slow.crashes, 0);
        assert!(slow.events_processed > clean.events_processed, "no episodes fired");
        assert!(
            mean_lat(&slow) > mean_lat(&clean),
            "8x straggler episodes did not stretch mean latency"
        );
    }

    #[test]
    fn timeouts_cancel_and_retries_spend_budget() {
        // Timeout shorter than a single image: nothing can ever complete.
        let (mut spec, profiles) = toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 500.0);
        spec.robust.timeout_cycles = 500_000;
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "timeout");
        assert_eq!(out.completed, 0, "nothing beats a sub-service timeout");
        assert!(out.timed_out > 0);
        assert!(out.stale_completions > 0, "launched work finishes stale");
        assert_eq!(out.retries, 0);

        // With retries the budget is spent, but the outcome is the same.
        let mut retry_spec = spec.clone();
        retry_spec.robust.max_retries = 2;
        retry_spec.robust.backoff_cycles = 10_000;
        let retried = simulate(&retry_spec, &profiles);
        assert_conserved(&retried, "timeout+retry");
        assert!(retried.retries > 0);
        assert!(retried.records.iter().all(|r| r.attempts <= 3));
        assert!(
            retried
                .records
                .iter()
                .any(|r| r.outcome == Outcome::TimedOut && r.attempts == 3),
            "some request exhausted its full retry budget"
        );
    }

    #[test]
    fn hedges_race_but_never_double_count() {
        let (mut spec, profiles) = toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 800.0);
        spec.robust.hedge_cycles = 300_000;
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "hedged");
        assert!(out.hedges > 0, "hedge delay short enough to fire");
        assert!(out.hedge_wins <= out.hedges);
        let hedged_records = out.records.iter().filter(|r| r.hedged).count() as u64;
        assert_eq!(hedged_records, out.hedges, "one hedge per request");
        assert_eq!(
            out.records.iter().filter(|r| r.hedge_won).count() as u64,
            out.hedge_wins
        );
        // A request completes exactly once even when both twins finish.
        assert_eq!(
            out.records.iter().filter(|r| r.completion.is_some()).count() as u64,
            out.completed
        );
    }

    #[test]
    fn exec_faults_fail_requests_without_retries() {
        let (mut spec, profiles) = toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 500.0);
        spec.faults.req_fault_prob = 0.5;
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "reqfault");
        assert!(out.faulted > 0, "p=0.5 faults must fire");
        assert!(out.rejected >= out.faulted, "faulted requests fail-fast into rejected");
        assert!(out.completed > 0, "p=0.5 lets half through");
    }

    #[test]
    fn shedding_protects_high_priority_tenants() {
        let (mut spec, profiles) =
            toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 5_000.0);
        spec.tenants[1] = Tenant::new("alexnet", 32, 0.5).with_priority(2);
        spec.robust.shed = true;
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "shedding");
        assert!(out.shed > 0, "overload must shed");
        let shed_of = |t: usize| {
            out.records
                .iter()
                .filter(|r| r.tenant == t && r.outcome == Outcome::Shed)
                .count()
        };
        assert!(
            shed_of(1) > shed_of(0),
            "low-priority tenant must shed first ({} vs {})",
            shed_of(1),
            shed_of(0)
        );
    }

    #[test]
    fn parse_topology_accepts_flat_and_racks() {
        assert_eq!(parse_topology("flat", 4).unwrap(), 1);
        assert_eq!(parse_topology("racks:4", 16).unwrap(), 4);
        assert_eq!(parse_topology("racks:1", 1).unwrap(), 1);
        assert!(parse_topology("racks:0", 4).is_err());
        assert!(parse_topology("racks:5", 4).is_err());
        assert!(parse_topology("racks:abc", 4).is_err());
        assert!(parse_topology("mesh", 4).is_err());
    }

    #[test]
    fn hierarchical_racked_fleet_serves_and_conserves() {
        let (mut spec, _) = toy_spec(DispatchPolicy::Hierarchical, window(4, 100_000), 4_000.0);
        // Widen the toy fleet to 16 instances in 4 racks.
        spec.instances = default_fleet(16);
        spec.racks = 4;
        let prof = ServiceProfile {
            single_cycles: 1_000_000,
            marginal_cycles: 600_000,
            switch_cycles: 400_000,
        };
        let profiles = vec![vec![prof; 16]; 2];
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "hierarchical racked");
        assert!(out.completed > 0, "racked fleet must serve");
        // p2c spreads work across racks: every rack sees some traffic at
        // 4k rps over 50M cycles.
        let rack_completed: Vec<u64> = (0..4)
            .map(|r| (r * 4..r * 4 + 4).map(|i| out.instances[i].completed).sum())
            .collect();
        assert!(
            rack_completed.iter().all(|&c| c > 0),
            "a rack sat idle: {rack_completed:?}"
        );
        // Replays stay bit-identical (the p2c draws are seeded).
        let again = simulate(&spec, &profiles);
        assert_eq!(out.completed, again.completed);
        for (x, y) in out.records.iter().zip(&again.records) {
            assert_eq!(x.instance, y.instance);
            assert_eq!(x.completion, y.completion);
        }
    }

    #[test]
    fn hierarchical_with_crashes_avoids_dead_racks_and_closes_ledger() {
        let (mut spec, _) = toy_spec(DispatchPolicy::Hierarchical, window(4, 100_000), 2_000.0);
        spec.instances = default_fleet(12);
        spec.racks = 3;
        spec.faults = FaultSpec::parse("crash:100,mttr:2").unwrap();
        let prof = ServiceProfile {
            single_cycles: 1_000_000,
            marginal_cycles: 600_000,
            switch_cycles: 400_000,
        };
        let profiles = vec![vec![prof; 12]; 2];
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "hierarchical crashy");
        assert!(out.crashes > 0);
        assert!(out.completed > 0);
    }

    #[test]
    fn mmpp_traffic_conserves_and_out_bursts_poisson() {
        let (mut spec, profiles) =
            toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 1_000.0);
        spec.traffic = TrafficModel::Mmpp {
            rps: 1_000.0,
            burst_x: 8.0,
            mean_high_cycles: 500_000,
            mean_low_cycles: 5_000_000,
        };
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "mmpp");
        assert!(out.offered > 0);
        let (poisson_spec, _) = toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 1_000.0);
        let base = simulate(&poisson_spec, &profiles);
        // Bursts at 8x for ~9% of the time lift the offered load well
        // above the plain-Poisson run at the same base rate.
        assert!(
            out.offered > base.offered,
            "mmpp offered {} <= poisson {}",
            out.offered,
            base.offered
        );
    }

    #[test]
    fn diurnal_traffic_conserves() {
        let (mut spec, profiles) =
            toy_spec(DispatchPolicy::NetworkAffinity, window(4, 100_000), 2_000.0);
        spec.traffic = TrafficModel::Diurnal {
            rps: 2_000.0,
            amplitude: 0.8,
            period_cycles: 10_000_000,
        };
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "diurnal");
        assert!(out.completed > 0);
        let again = simulate(&spec, &profiles);
        assert_eq!(out.offered, again.offered, "thinning draws are seeded");
    }

    #[test]
    fn default_fleet_mixes_geometries_and_memory_models() {
        let fleet = default_fleet(4);
        assert_eq!(fleet.len(), 4);
        let labels: Vec<String> = fleet.iter().map(|f| f.label()).collect();
        assert!(labels.iter().any(|l| l.contains("tiled")));
        assert!(labels.iter().any(|l| l.contains("ideal")));
        assert!(labels.iter().any(|l| l.contains("[4,14,3]")));
        assert!(labels.iter().any(|l| l.contains("[8,7,3]")));
        // Replication wraps.
        assert_eq!(default_fleet(6).len(), 6);
        assert_eq!(default_fleet(0).len(), 1);
    }

    #[test]
    fn sdc_unprotected_flips_serve_silent_wrong_answers() {
        let (mut spec, profiles) =
            toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 3_000.0);
        spec.sdc = SdcSpec::parse("flip:2000").unwrap();
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "sdc unprotected");
        assert!(out.sdc_injected > 100, "rate must fire: {}", out.sdc_injected);
        assert_eq!(out.sdc_detected, 0, "nothing detects without protection");
        assert_eq!(out.sdc_corrected, 0);
        assert_eq!(out.scrubs, 0);
        assert_eq!(
            out.sdc_masked + out.sdc_silent,
            out.sdc_injected,
            "every flip is masked or silent"
        );
        assert!(out.silent_completions > 0, "corrupted answers ship as successes");
        assert!(out.completed >= out.silent_completions);
        // Replays are bit-identical.
        let again = simulate(&spec, &profiles);
        assert_eq!(out.sdc_injected, again.sdc_injected);
        assert_eq!(out.silent_completions, again.silent_completions);
        assert_eq!(out.completed, again.completed);
    }

    #[test]
    fn sdc_protected_detects_ninety_percent_and_repairs() {
        let (mut spec, profiles) =
            toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 3_000.0);
        spec.sdc = SdcSpec::parse("flip:2000,protect,scrub:2,budget:2").unwrap();
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "sdc protected");
        assert!(out.sdc_injected > 100);
        assert_eq!(
            out.sdc_masked + out.sdc_detected + out.sdc_silent,
            out.sdc_injected,
            "flip ledger: masked + detected + silent = injected"
        );
        let consequential = out.sdc_injected - out.sdc_masked;
        assert!(consequential > 50, "fleet busy enough: {consequential}");
        let rate = out.sdc_detected as f64 / consequential as f64;
        assert!(
            rate >= 0.9,
            "detection rate {rate:.3} < 0.9 ({} / {consequential})",
            out.sdc_detected
        );
        assert!(out.sdc_corrected > 0, "re-execution and scrubbing repair");
        assert!(out.sdc_corrected <= out.sdc_detected);
        assert!(out.scrubs > 0, "the scrubber runs");
        // Protection shrinks the silent-wrong-answer surface.
        let mut unprot_spec = spec.clone();
        unprot_spec.sdc = SdcSpec::parse("flip:2000").unwrap();
        let unprot = simulate(&unprot_spec, &profiles);
        assert!(
            out.silent_completions < unprot.silent_completions,
            "protected {} !< unprotected {}",
            out.silent_completions,
            unprot.silent_completions
        );
    }

    #[test]
    fn sdc_quarantine_removes_flaky_chips_permanently() {
        let (mut spec, profiles) =
            toy_spec(DispatchPolicy::LeastLoaded, window(4, 100_000), 3_000.0);
        spec.sdc = SdcSpec::parse("flip:5000,protect,quarantine:10").unwrap();
        let out = simulate(&spec, &profiles);
        assert_conserved(&out, "sdc quarantine");
        assert!(out.quarantined > 0, "500 flips/chip must cross 10 detections");
        assert!(out.quarantined <= spec.instances.len() as u64);
        assert!(out.down_cycles > 0, "quarantined chips accrue downtime");
        assert_eq!(out.recoveries, 0, "quarantine never recovers");
        // Replays are bit-identical.
        let again = simulate(&spec, &profiles);
        assert_eq!(out.quarantined, again.quarantined);
        assert_eq!(out.completed, again.completed);
    }
}
