//! Multi-accelerator serving simulator: traffic, batching, sharding and
//! tail latency for a fleet of VSCNN instances.
//!
//! The paper evaluates one chip on one image at a time; the ROADMAP's
//! north star is serving heavy traffic. This subsystem bridges the two:
//! a deterministic discrete-event simulation (cycle domain, seeded PRNG)
//! drives a heterogeneous fleet of accelerator instances — each a
//! compiled [`crate::engine::PreparedNetwork`] under its own
//! [`crate::sim::config::SimConfig`] — with open-loop Poisson or
//! closed-loop traffic over a multi-tenant request mix, and reports what
//! per-chip speedup numbers cannot: p50/p95/p99 latency, per-instance
//! utilization, queue depths, rejections, and where the capacity knee
//! sits.
//!
//! The simulator scales to 10k-instance fleets (ISSUE 7): the event
//! queue is a calendar queue with O(1) expected operations, dispatch is
//! hierarchical (cluster → rack → instance over incrementally-maintained
//! rack load summaries), and the traffic layer adds non-stationary
//! arrivals (diurnal envelopes, MMPP flash crowds) on dedicated PCG32
//! streams so small-fleet runs stay bit-identical.
//!
//! Module map:
//!
//! * [`events`] — the deterministic event queue (cycle, FIFO ties),
//!   implemented as a calendar queue; the reference `BinaryHeap` is kept
//!   as `BinaryHeapQueue` for differential tests.
//! * [`traffic`] — tenants, request mixes; Poisson / closed-loop /
//!   diurnal / MMPP arrivals.
//! * [`dispatch`] — round-robin / least-loaded / network-affinity /
//!   hierarchical admission over cached [`dispatch::FleetLoads`]
//!   (failure-aware: never routes to a dead instance).
//! * [`batcher`] — size-or-deadline dynamic batching windows.
//! * [`faults`] — seeded fault plans (crash/recover, stragglers,
//!   execution faults) and client-side robustness knobs (timeouts,
//!   retries, hedging, load shedding).
//! * silent-data-corruption injection (ISSUE 10) threads through
//!   [`fleet`]: seeded bit-flip plans ([`crate::sim::sdc`]), periodic
//!   weight scrubbing, detected-vs-silent accounting, and quarantine of
//!   chips whose detected-corruption count crosses a threshold.
//! * [`fleet`] — service profiles from real engine runs + the simulator
//!   (rack topology via [`fleet::parse_topology`]).
//! * [`report`] — [`report::ServeReport`]: percentiles, utilization,
//!   JSON/text (plus a resilience section when faults/robustness are on).
//!
//! Entry points: [`fleet::build_profiles`] → [`fleet::simulate`] →
//! [`report::ServeReport::new`]; the `vscnn serve` CLI subcommand and the
//! `exp serve` / `exp serve-faults` / `exp serve-scale` experiments wrap
//! them.

pub mod batcher;
pub mod dispatch;
pub mod events;
pub mod faults;
pub mod fleet;
pub mod report;
pub mod traffic;

pub use batcher::BatchPolicy;
pub use dispatch::DispatchPolicy;
pub use faults::{FaultSpec, Health, RobustnessPolicy};
pub use fleet::{
    build_profiles, default_fleet, parse_topology, profile_from_report, simulate, InstanceSpec,
    Outcome, ServeOutcome, ServeSpec, ServiceProfile,
};
pub use report::{IntegritySummary, ServeReport};
pub use traffic::{default_mix, Tenant, TrafficModel};
