//! Multi-accelerator serving simulator: traffic, batching, sharding and
//! tail latency for a fleet of VSCNN instances.
//!
//! The paper evaluates one chip on one image at a time; the ROADMAP's
//! north star is serving heavy traffic. This subsystem bridges the two:
//! a deterministic discrete-event simulation (cycle domain, seeded PRNG)
//! drives a heterogeneous fleet of accelerator instances — each a
//! compiled [`crate::engine::PreparedNetwork`] under its own
//! [`crate::sim::config::SimConfig`] — with open-loop Poisson or
//! closed-loop traffic over a multi-tenant request mix, and reports what
//! per-chip speedup numbers cannot: p50/p95/p99 latency, per-instance
//! utilization, queue depths, rejections, and where the capacity knee
//! sits.
//!
//! Module map:
//!
//! * [`events`] — the deterministic event queue (cycle, FIFO ties).
//! * [`traffic`] — tenants, request mixes, Poisson/closed-loop arrivals.
//! * [`dispatch`] — round-robin / least-loaded / network-affinity
//!   admission (failure-aware: never routes to a dead instance).
//! * [`batcher`] — size-or-deadline dynamic batching windows.
//! * [`faults`] — seeded fault plans (crash/recover, stragglers,
//!   execution faults) and client-side robustness knobs (timeouts,
//!   retries, hedging, load shedding).
//! * [`fleet`] — service profiles from real engine runs + the simulator.
//! * [`report`] — [`report::ServeReport`]: percentiles, utilization,
//!   JSON/text (plus a resilience section when faults/robustness are on).
//!
//! Entry points: [`fleet::build_profiles`] → [`fleet::simulate`] →
//! [`report::ServeReport::new`]; the `vscnn serve` CLI subcommand and the
//! `exp serve` / `exp serve-faults` experiments wrap them.

pub mod batcher;
pub mod dispatch;
pub mod events;
pub mod faults;
pub mod fleet;
pub mod report;
pub mod traffic;

pub use batcher::BatchPolicy;
pub use dispatch::DispatchPolicy;
pub use faults::{FaultSpec, Health, RobustnessPolicy};
pub use fleet::{
    build_profiles, default_fleet, profile_from_report, simulate, InstanceSpec, Outcome,
    ServeOutcome, ServeSpec, ServiceProfile,
};
pub use report::ServeReport;
pub use traffic::{default_mix, Tenant, TrafficModel};
