//! [`ServeReport`]: the rendered results of one serving simulation —
//! request accounting, per-request latency percentiles, per-tenant and
//! per-instance breakdowns — as deterministic JSON (bit-identical for a
//! fixed `(spec, seed)` regardless of host threads) and a text block.
//!
//! When the run exercises the resilience layer
//! ([`ServeSpec::resilience_active`]) the report grows a `resilience`
//! section (retries, hedge wins, MTTR, availability, the five-bucket
//! ledger) plus per-tenant goodput/timed-out/shed and per-instance
//! crash/availability keys. A zero-fault run emits **no** new keys and
//! no new text lines: its output is bit-identical to the pre-fault
//! simulator (pinned by `tests/serve.rs`). An SDC run
//! ([`ServeSpec::sdc_active`], ISSUE 10) likewise grows a gated
//! `integrity` section (flip ledger, detection/escape rates, scrub and
//! quarantine counts) under the same zero-impact discipline.

use super::fleet::{Outcome, ServeOutcome, ServeSpec};
use crate::util::json::Json;
use crate::util::stats::{mean, percentile};

/// Version of the [`ServeReport::to_json`] document layout, bumped
/// whenever a key is added, removed or renamed (pinned by a golden-key
/// test so observability additions can't silently break parsers).
pub const SERVE_REPORT_SCHEMA_VERSION: usize = 1;

/// Latency summary in cycles (converted to ms by the clock at render
/// time).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
    pub count: u64,
}

impl LatencySummary {
    fn from_cycles(latencies: &[f64]) -> LatencySummary {
        LatencySummary {
            p50: percentile(latencies, 50.0),
            p95: percentile(latencies, 95.0),
            p99: percentile(latencies, 99.0),
            mean: mean(latencies),
            max: latencies.iter().cloned().fold(0.0, f64::max),
            count: latencies.len() as u64,
        }
    }

    fn to_json(self, cycles_per_ms: f64) -> Json {
        let mut o = Json::obj();
        o.set("count", self.count)
            .set("p50_cycles", self.p50)
            .set("p95_cycles", self.p95)
            .set("p99_cycles", self.p99)
            .set("mean_cycles", self.mean)
            .set("max_cycles", self.max)
            .set("p50_ms", self.p50 / cycles_per_ms)
            .set("p95_ms", self.p95 / cycles_per_ms)
            .set("p99_ms", self.p99 / cycles_per_ms)
            .set("mean_ms", self.mean / cycles_per_ms)
            .set("max_ms", self.max / cycles_per_ms);
        o
    }
}

/// Per-tenant serving summary. `rejected` counts terminal
/// [`Outcome::Rejected`] requests uniformly for open- and closed-loop
/// traffic (the satellite-2 fix — closed-loop re-issues are *new*
/// offered requests, so nothing vanishes from the ledger).
#[derive(Debug, Clone)]
pub struct TenantSummary {
    pub name: String,
    pub offered: u64,
    pub completed: u64,
    pub rejected: u64,
    pub timed_out: u64,
    pub shed: u64,
    pub latency: LatencySummary,
}

/// Per-instance serving summary.
#[derive(Debug, Clone)]
pub struct InstanceSummary {
    pub label: String,
    pub utilization: f64,
    pub batches: u64,
    pub avg_batch: f64,
    pub switches: u64,
    pub completed: u64,
    pub mean_queue_depth: f64,
    pub max_queue: usize,
    pub crashes: u64,
    /// Fraction of the horizon the instance was up.
    pub availability: f64,
}

/// Fleet-level resilience summary — present only when the run injected
/// faults or enabled any robustness mechanism.
#[derive(Debug, Clone)]
pub struct ResilienceSummary {
    /// Injected fault mix label ([`super::faults::FaultSpec::label`]).
    pub faults: String,
    pub timeout_cycles: u64,
    pub max_retries: u32,
    pub backoff_cycles: u64,
    pub hedge_cycles: u64,
    pub shed_enabled: bool,
    pub retries: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
    pub rehomed: u64,
    pub faulted: u64,
    pub stale_completions: u64,
    pub crashes: u64,
    pub recoveries: u64,
    /// Mean time to recover over completed recoveries, in ms.
    pub mttr_ms: f64,
    /// Up-time fraction over the whole fleet and horizon.
    pub availability: f64,
}

impl ResilienceSummary {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("faults", self.faults.as_str())
            .set("timeout_cycles", self.timeout_cycles)
            .set("max_retries", self.max_retries as u64)
            .set("backoff_cycles", self.backoff_cycles)
            .set("hedge_cycles", self.hedge_cycles)
            .set("shed_enabled", self.shed_enabled)
            .set("retries", self.retries)
            .set("hedges", self.hedges)
            .set("hedge_wins", self.hedge_wins)
            .set("rehomed", self.rehomed)
            .set("faulted", self.faulted)
            .set("stale_completions", self.stale_completions)
            .set("crashes", self.crashes)
            .set("recoveries", self.recoveries)
            .set("mttr_ms", self.mttr_ms)
            .set("availability", self.availability);
        o
    }
}

/// Fleet-level data-integrity summary (ISSUE 10) — present only when
/// the run injected SDC flips ([`ServeSpec::sdc_active`]), so zero-SDC
/// output stays bit-identical to the pre-SDC report.
#[derive(Debug, Clone)]
pub struct IntegritySummary {
    /// Injected SDC mix label ([`crate::sim::sdc::SdcSpec::label`]).
    pub sdc: String,
    pub protected: bool,
    pub injected: u64,
    pub masked: u64,
    pub detected: u64,
    pub corrected: u64,
    pub silent: u64,
    /// Detected fraction of consequential (non-masked) flips.
    pub detection_rate: f64,
    /// Silent fraction of consequential flips — the escape rate.
    pub escape_rate: f64,
    /// Wrong answers delivered as successes.
    pub silent_completions: u64,
    pub scrubs: u64,
    pub quarantined: u64,
    /// Fractional service-time overhead charged for protection.
    pub overhead_frac: f64,
}

impl IntegritySummary {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("sdc", self.sdc.as_str())
            .set("protected", self.protected)
            .set("injected", self.injected)
            .set("masked", self.masked)
            .set("detected", self.detected)
            .set("corrected", self.corrected)
            .set("silent", self.silent)
            .set("detection_rate", self.detection_rate)
            .set("escape_rate", self.escape_rate)
            .set("silent_completions", self.silent_completions)
            .set("scrubs", self.scrubs)
            .set("quarantined", self.quarantined)
            .set("overhead_frac", self.overhead_frac);
        o
    }
}

/// The full rendered report of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: String,
    pub traffic: String,
    pub max_batch: usize,
    pub max_wait_cycles: u64,
    pub queue_cap: usize,
    /// Fleet topology (1 = flat). A `racks` JSON key and a topology text
    /// line appear only for racked fleets, so flat-topology output stays
    /// bit-identical to the pre-topology report.
    pub racks: usize,
    pub clock_mhz: f64,
    pub duration_cycles: u64,
    pub seed: u64,
    pub offered: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub timed_out: u64,
    pub shed: u64,
    pub in_flight: u64,
    pub latency: LatencySummary,
    pub tenants: Vec<TenantSummary>,
    pub instances: Vec<InstanceSummary>,
    /// `Some` only when the run exercised the resilience layer; gates
    /// every new JSON key and text line so zero-fault output is
    /// bit-identical to the pre-fault report.
    pub resilience: Option<ResilienceSummary>,
    /// `Some` only when the run injected SDC flips; gated the same way
    /// so zero-SDC output is bit-identical to the pre-SDC report.
    pub integrity: Option<IntegritySummary>,
}

impl ServeReport {
    /// Render the outcome of [`super::fleet::simulate`] under its spec.
    pub fn new(spec: &ServeSpec, outcome: &ServeOutcome) -> ServeReport {
        let all: Vec<f64> = outcome
            .records
            .iter()
            .filter_map(|r| r.latency())
            .map(|l| l as f64)
            .collect();

        let tenants = spec
            .tenants
            .iter()
            .enumerate()
            .map(|(ti, t)| {
                let lat: Vec<f64> = outcome
                    .records
                    .iter()
                    .filter(|r| r.tenant == ti)
                    .filter_map(|r| r.latency())
                    .map(|l| l as f64)
                    .collect();
                let count = |o: Outcome| {
                    outcome
                        .records
                        .iter()
                        .filter(|r| r.tenant == ti && r.outcome == o)
                        .count() as u64
                };
                TenantSummary {
                    name: t.name.clone(),
                    offered: outcome.records.iter().filter(|r| r.tenant == ti).count() as u64,
                    completed: lat.len() as u64,
                    rejected: count(Outcome::Rejected),
                    timed_out: count(Outcome::TimedOut),
                    shed: count(Outcome::Shed),
                    latency: LatencySummary::from_cycles(&lat),
                }
            })
            .collect();

        let instances = outcome
            .instances
            .iter()
            .map(|i| InstanceSummary {
                label: i.label.clone(),
                utilization: i.utilization(spec.duration_cycles),
                batches: i.batches,
                avg_batch: i.avg_batch(),
                switches: i.switches,
                completed: i.completed,
                mean_queue_depth: i.mean_queue_depth(spec.duration_cycles),
                max_queue: i.max_queue,
                crashes: i.crashes,
                availability: i.availability(spec.duration_cycles),
            })
            .collect();

        let resilience = spec.resilience_active().then(|| {
            let fleet_cycles = spec.duration_cycles.max(1) * spec.instances.len().max(1) as u64;
            ResilienceSummary {
                faults: spec.faults.label(),
                timeout_cycles: spec.robust.timeout_cycles,
                max_retries: spec.robust.max_retries,
                backoff_cycles: spec.robust.backoff_cycles,
                hedge_cycles: spec.robust.hedge_cycles,
                shed_enabled: spec.robust.shed,
                retries: outcome.retries,
                hedges: outcome.hedges,
                hedge_wins: outcome.hedge_wins,
                rehomed: outcome.rehomed,
                faulted: outcome.faulted,
                stale_completions: outcome.stale_completions,
                crashes: outcome.crashes,
                recoveries: outcome.recoveries,
                mttr_ms: spec.cycles_to_ms(outcome.recovery_cycles)
                    / outcome.recoveries.max(1) as f64,
                availability: 1.0 - outcome.down_cycles as f64 / fleet_cycles as f64,
            }
        });

        let integrity = spec.sdc_active().then(|| {
            let consequential = outcome.sdc_injected.saturating_sub(outcome.sdc_masked).max(1);
            IntegritySummary {
                sdc: spec.sdc.label(),
                protected: spec.sdc.protect,
                injected: outcome.sdc_injected,
                masked: outcome.sdc_masked,
                detected: outcome.sdc_detected,
                corrected: outcome.sdc_corrected,
                silent: outcome.sdc_silent,
                detection_rate: outcome.sdc_detected as f64 / consequential as f64,
                escape_rate: outcome.sdc_silent as f64 / consequential as f64,
                silent_completions: outcome.silent_completions,
                scrubs: outcome.scrubs,
                quarantined: outcome.quarantined,
                overhead_frac: if spec.sdc.protect {
                    spec.sdc.overhead_frac
                } else {
                    0.0
                },
            }
        });

        ServeReport {
            policy: spec.policy.label().to_string(),
            traffic: spec.traffic.label(),
            max_batch: spec.batch.max_batch,
            max_wait_cycles: spec.batch.max_wait_cycles,
            queue_cap: spec.queue_cap,
            racks: spec.racks.max(1),
            clock_mhz: spec.clock_mhz,
            duration_cycles: spec.duration_cycles,
            seed: spec.seed,
            offered: outcome.offered,
            admitted: outcome.admitted,
            rejected: outcome.rejected,
            completed: outcome.completed,
            timed_out: outcome.timed_out,
            shed: outcome.shed,
            in_flight: outcome.in_flight,
            latency: LatencySummary::from_cycles(&all),
            tenants,
            instances,
            resilience,
            integrity,
        }
    }

    /// Simulated horizon in seconds.
    pub fn duration_secs(&self) -> f64 {
        self.duration_cycles as f64 / (self.clock_mhz * 1e6)
    }

    /// Completed requests per second of simulated time — under faults
    /// this is the fleet's *goodput* (served work only; timed-out, shed,
    /// and faulted requests don't count).
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.duration_secs().max(1e-12)
    }

    /// Offered (generated) requests per second of simulated time.
    pub fn offered_rps(&self) -> f64 {
        self.offered as f64 / self.duration_secs().max(1e-12)
    }

    /// p99 latency in milliseconds.
    pub fn p99_ms(&self) -> f64 {
        self.latency.p99 / (self.clock_mhz * 1e3)
    }

    pub fn to_json(&self) -> Json {
        let cycles_per_ms = self.clock_mhz * 1e3;
        let resilient = self.resilience.is_some();
        let duration_secs = self.duration_secs().max(1e-12);
        let mut o = Json::obj();
        o.set("schema_version", SERVE_REPORT_SCHEMA_VERSION)
            .set("policy", self.policy.as_str())
            .set("traffic", self.traffic.as_str())
            .set("max_batch", self.max_batch)
            .set("max_wait_cycles", self.max_wait_cycles)
            .set("queue_cap", self.queue_cap);
        if self.racks > 1 {
            o.set("racks", self.racks);
        }
        o.set("clock_mhz", self.clock_mhz)
            .set("duration_cycles", self.duration_cycles)
            .set("seed", self.seed)
            .set("offered", self.offered)
            .set("admitted", self.admitted)
            .set("rejected", self.rejected)
            .set("completed", self.completed);
        if resilient {
            o.set("timed_out", self.timed_out).set("shed", self.shed);
        }
        o.set("in_flight", self.in_flight)
            .set("offered_rps", self.offered_rps())
            .set("throughput_rps", self.throughput_rps())
            .set("latency", self.latency.to_json(cycles_per_ms))
            .set(
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            let mut to = Json::obj();
                            to.set("name", t.name.as_str())
                                .set("offered", t.offered)
                                .set("completed", t.completed)
                                .set("rejected", t.rejected);
                            if resilient {
                                to.set("timed_out", t.timed_out)
                                    .set("shed", t.shed)
                                    .set("goodput_rps", t.completed as f64 / duration_secs);
                            }
                            to.set("latency", t.latency.to_json(cycles_per_ms));
                            to
                        })
                        .collect(),
                ),
            )
            .set(
                "instances",
                Json::Arr(
                    self.instances
                        .iter()
                        .map(|i| {
                            let mut io = Json::obj();
                            io.set("label", i.label.as_str())
                                .set("utilization", i.utilization)
                                .set("batches", i.batches)
                                .set("avg_batch", i.avg_batch)
                                .set("switches", i.switches)
                                .set("completed", i.completed)
                                .set("mean_queue_depth", i.mean_queue_depth)
                                .set("max_queue", i.max_queue);
                            if resilient {
                                io.set("crashes", i.crashes)
                                    .set("availability", i.availability);
                            }
                            io
                        })
                        .collect(),
                ),
            );
        if let Some(res) = &self.resilience {
            o.set("resilience", res.to_json());
        }
        if let Some(integ) = &self.integrity {
            o.set("integrity", integ.to_json());
        }
        o
    }

    /// Human-readable summary block.
    pub fn text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "serve: {} | {} | batch<=:{} wait:{}cyc | queue cap {} | {:.0} MHz | {:.1} ms horizon | seed {}\n",
            self.policy,
            self.traffic,
            self.max_batch,
            self.max_wait_cycles,
            self.queue_cap,
            self.clock_mhz,
            self.duration_secs() * 1e3,
            self.seed,
        ));
        if self.racks > 1 {
            s.push_str(&format!(
                "topology: {} instances in {} racks ({} per rack)\n",
                self.instances.len(),
                self.racks,
                self.instances.len().div_ceil(self.racks),
            ));
        }
        match &self.resilience {
            None => s.push_str(&format!(
                "requests: offered {} ({:.1} rps) = completed {} ({:.1} rps) + rejected {} + in-flight {}\n",
                self.offered,
                self.offered_rps(),
                self.completed,
                self.throughput_rps(),
                self.rejected,
                self.in_flight,
            )),
            Some(res) => {
                s.push_str(&format!(
                    "requests: offered {} ({:.1} rps) = completed {} ({:.1} rps goodput) + rejected {} + timed-out {} + shed {} + in-flight {}\n",
                    self.offered,
                    self.offered_rps(),
                    self.completed,
                    self.throughput_rps(),
                    self.rejected,
                    self.timed_out,
                    self.shed,
                    self.in_flight,
                ));
                s.push_str(&format!(
                    "resilience: faults {} | timeout {} cyc | retries<= {} | hedge {} cyc | shed {}\n",
                    res.faults,
                    res.timeout_cycles,
                    res.max_retries,
                    res.hedge_cycles,
                    if res.shed_enabled { "on" } else { "off" },
                ));
                s.push_str(&format!(
                    "recovery: crashes {} recovered {} (mttr {:.2} ms) | availability {:.4} | re-homed {} | retries {} | hedges {} (wins {}) | faulted {} | stale {}\n",
                    res.crashes,
                    res.recoveries,
                    res.mttr_ms,
                    res.availability,
                    res.rehomed,
                    res.retries,
                    res.hedges,
                    res.hedge_wins,
                    res.faulted,
                    res.stale_completions,
                ));
            }
        }
        if let Some(integ) = &self.integrity {
            s.push_str(&format!(
                "integrity: sdc {} | injected {} = masked {} + detected {} + silent {} | corrected {}\n",
                integ.sdc,
                integ.injected,
                integ.masked,
                integ.detected,
                integ.silent,
                integ.corrected,
            ));
            s.push_str(&format!(
                "integrity: detection {:.4} | escape {:.4} | silent completions {} | scrubs {} | quarantined {} | overhead {:.1}%\n",
                integ.detection_rate,
                integ.escape_rate,
                integ.silent_completions,
                integ.scrubs,
                integ.quarantined,
                100.0 * integ.overhead_frac,
            ));
        }
        let cpm = self.clock_mhz * 1e3;
        s.push_str(&format!(
            "latency: p50 {:.3} ms | p95 {:.3} ms | p99 {:.3} ms | mean {:.3} ms (n={})\n",
            self.latency.p50 / cpm,
            self.latency.p95 / cpm,
            self.latency.p99 / cpm,
            self.latency.mean / cpm,
            self.latency.count,
        ));
        for t in &self.tenants {
            s.push_str(&format!(
                "  tenant {:16} completed {:6} rejected {:6} | p50 {:.3} ms p99 {:.3} ms\n",
                t.name,
                t.completed,
                t.rejected,
                t.latency.p50 / cpm,
                t.latency.p99 / cpm,
            ));
        }
        for i in &self.instances {
            s.push_str(&format!(
                "  inst {:16} util {:5.1}% | batches {:5} (avg {:.2}) | switches {:4} | queue mean {:.2} max {:2} | done {}\n",
                i.label,
                100.0 * i.utilization,
                i.batches,
                i.avg_batch,
                i.switches,
                i.mean_queue_depth,
                i.max_queue,
                i.completed,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batcher::BatchPolicy;
    use crate::serve::dispatch::DispatchPolicy;
    use crate::serve::faults::{FaultSpec, RobustnessPolicy};
    use crate::serve::fleet::{simulate, InstanceSpec, ServeSpec, ServiceProfile};
    use crate::serve::traffic::{Tenant, TrafficModel};
    use crate::sim::config::SimConfig;
    use crate::sim::sdc::SdcSpec;

    fn toy_spec() -> (ServeSpec, Vec<Vec<ServiceProfile>>) {
        let spec = ServeSpec {
            tenants: vec![
                Tenant::new("vgg16", 32, 0.6),
                Tenant::new("resnet10", 16, 0.4),
            ],
            instances: vec![
                InstanceSpec {
                    config: SimConfig::paper_8_7_3(),
                },
                InstanceSpec {
                    config: SimConfig::paper_4_14_3(),
                },
            ],
            traffic: TrafficModel::OpenLoop { rps: 2_000.0 },
            policy: DispatchPolicy::NetworkAffinity,
            batch: BatchPolicy {
                max_batch: 4,
                max_wait_cycles: 100_000,
            },
            queue_cap: 16,
            racks: 1,
            duration_cycles: 100_000_000,
            clock_mhz: 500.0,
            seed: 9,
            faults: FaultSpec::none(),
            robust: RobustnessPolicy::none(),
            sdc: SdcSpec::none(),
        };
        let prof = ServiceProfile {
            single_cycles: 800_000,
            marginal_cycles: 500_000,
            switch_cycles: 300_000,
        };
        let profiles = vec![vec![prof; 2]; 2];
        (spec, profiles)
    }

    fn toy_report() -> ServeReport {
        let (spec, profiles) = toy_spec();
        let out = simulate(&spec, &profiles);
        ServeReport::new(&spec, &out)
    }

    fn faulty_report() -> ServeReport {
        let (mut spec, profiles) = toy_spec();
        spec.faults = FaultSpec::parse("crash:60,mttr:2").unwrap();
        spec.robust.timeout_cycles = 5_000_000;
        spec.robust.max_retries = 2;
        spec.robust.backoff_cycles = 10_000;
        let out = simulate(&spec, &profiles);
        ServeReport::new(&spec, &out)
    }

    #[test]
    fn report_is_consistent_and_renders() {
        let r = toy_report();
        assert_eq!(r.offered, r.completed + r.rejected + r.in_flight);
        assert!(r.latency.p50 <= r.latency.p95 && r.latency.p95 <= r.latency.p99);
        assert!(r.latency.p99 <= r.latency.max);
        assert!(r.throughput_rps() > 0.0);
        assert!(r.p99_ms() > 0.0);
        assert!(r.resilience.is_none());
        let text = r.text();
        assert!(text.contains("latency: p50"));
        assert!(text.contains("tenant"));
        assert!(text.contains("inst"));
        assert!(!text.contains("resilience:"), "no resilience line off-path");
    }

    #[test]
    fn json_round_trips_and_has_key_fields() {
        let r = toy_report();
        let j = r.to_json();
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
        assert!(j.get("latency").unwrap().get("p99_ms").is_some());
        assert_eq!(j.get("tenants").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("instances").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("offered").unwrap().as_usize().unwrap() as u64,
            r.offered
        );
    }

    #[test]
    fn json_is_bit_identical_across_runs() {
        let a = toy_report().to_json().pretty();
        let b = toy_report().to_json().pretty();
        assert_eq!(a, b);
    }

    #[test]
    fn zero_fault_json_emits_no_resilience_keys() {
        let j = toy_report().to_json();
        assert!(j.get("resilience").is_none());
        assert!(j.get("integrity").is_none());
        assert!(j.get("timed_out").is_none());
        assert!(j.get("shed").is_none());
        for t in j.get("tenants").unwrap().as_arr().unwrap() {
            assert!(t.get("timed_out").is_none());
            assert!(t.get("goodput_rps").is_none());
        }
        for i in j.get("instances").unwrap().as_arr().unwrap() {
            assert!(i.get("crashes").is_none());
            assert!(i.get("availability").is_none());
        }
    }

    #[test]
    fn faulted_report_grows_the_resilience_section() {
        let r = faulty_report();
        assert_eq!(
            r.offered,
            r.completed + r.rejected + r.timed_out + r.shed + r.in_flight
        );
        let res = r.resilience.as_ref().expect("resilience summary present");
        assert!(res.crashes > 0);
        assert!(res.availability < 1.0 && res.availability > 0.0);
        assert!(res.mttr_ms > 0.0);
        // Per-tenant buckets sum to the fleet buckets.
        assert_eq!(r.tenants.iter().map(|t| t.timed_out).sum::<u64>(), r.timed_out);
        assert_eq!(r.tenants.iter().map(|t| t.shed).sum::<u64>(), r.shed);
        let j = r.to_json();
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
        assert!(j.get("resilience").unwrap().get("mttr_ms").is_some());
        assert!(j.get("timed_out").is_some());
        for i in j.get("instances").unwrap().as_arr().unwrap() {
            assert!(i.get("availability").is_some());
        }
        let text = r.text();
        assert!(text.contains("resilience:"));
        assert!(text.contains("recovery:"));
        assert!(text.contains("timed-out"));
    }

    #[test]
    fn faulted_json_is_bit_identical_across_runs() {
        let a = faulty_report().to_json().pretty();
        let b = faulty_report().to_json().pretty();
        assert_eq!(a, b);
    }

    /// Golden-key pin: the full `ServeReport` JSON key set, zero-fault
    /// and faulted. Adding, removing or renaming a key must come with a
    /// `SERVE_REPORT_SCHEMA_VERSION` bump and an update here.
    #[test]
    fn serve_report_json_golden_keys() {
        let keys = |o: &Json| -> Vec<String> {
            o.as_obj().expect("object").keys().cloned().collect()
        };
        let latency_keys = [
            "count",
            "max_cycles",
            "max_ms",
            "mean_cycles",
            "mean_ms",
            "p50_cycles",
            "p50_ms",
            "p95_cycles",
            "p95_ms",
            "p99_cycles",
            "p99_ms",
        ];

        let j = toy_report().to_json();
        assert_eq!(j.get("schema_version").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            keys(&j),
            [
                "admitted",
                "clock_mhz",
                "completed",
                "duration_cycles",
                "in_flight",
                "instances",
                "latency",
                "max_batch",
                "max_wait_cycles",
                "offered",
                "offered_rps",
                "policy",
                "queue_cap",
                "rejected",
                "schema_version",
                "seed",
                "tenants",
                "throughput_rps",
                "traffic",
            ]
        );
        assert_eq!(keys(j.get("latency").unwrap()), latency_keys);
        assert_eq!(
            keys(j.get("tenants").unwrap().at(0).unwrap()),
            ["completed", "latency", "name", "offered", "rejected"]
        );
        assert_eq!(
            keys(j.get("instances").unwrap().at(0).unwrap()),
            [
                "avg_batch",
                "batches",
                "completed",
                "label",
                "max_queue",
                "mean_queue_depth",
                "switches",
                "utilization",
            ]
        );

        let f = faulty_report().to_json();
        assert_eq!(
            keys(&f),
            [
                "admitted",
                "clock_mhz",
                "completed",
                "duration_cycles",
                "in_flight",
                "instances",
                "latency",
                "max_batch",
                "max_wait_cycles",
                "offered",
                "offered_rps",
                "policy",
                "queue_cap",
                "rejected",
                "resilience",
                "schema_version",
                "seed",
                "shed",
                "tenants",
                "throughput_rps",
                "timed_out",
                "traffic",
            ]
        );
        assert_eq!(
            keys(f.get("resilience").unwrap()),
            [
                "availability",
                "backoff_cycles",
                "crashes",
                "faulted",
                "faults",
                "hedge_cycles",
                "hedge_wins",
                "hedges",
                "max_retries",
                "mttr_ms",
                "recoveries",
                "rehomed",
                "retries",
                "shed_enabled",
                "stale_completions",
                "timeout_cycles",
            ]
        );
        assert_eq!(
            keys(f.get("tenants").unwrap().at(0).unwrap()),
            [
                "completed",
                "goodput_rps",
                "latency",
                "name",
                "offered",
                "rejected",
                "shed",
                "timed_out",
            ]
        );
        assert_eq!(
            keys(f.get("instances").unwrap().at(0).unwrap()),
            [
                "availability",
                "avg_batch",
                "batches",
                "completed",
                "crashes",
                "label",
                "max_queue",
                "mean_queue_depth",
                "switches",
                "utilization",
            ]
        );
    }

    /// SDC-on report: the gated `integrity` section, its golden key set,
    /// and the text lines. Zero-SDC output (every other test here) emits
    /// none of this.
    #[test]
    fn sdc_report_grows_the_integrity_section() {
        let (mut spec, profiles) = toy_spec();
        spec.sdc = SdcSpec::parse("flip:2000,protect,scrub:2").unwrap();
        let out = simulate(&spec, &profiles);
        let r = ServeReport::new(&spec, &out);
        let integ = r.integrity.as_ref().expect("integrity summary present");
        assert!(integ.protected);
        assert!(integ.injected > 0);
        assert_eq!(
            integ.masked + integ.detected + integ.silent,
            integ.injected,
            "flip ledger closes"
        );
        assert!(integ.detection_rate >= 0.9, "rate {}", integ.detection_rate);
        assert!((integ.detection_rate + integ.escape_rate - 1.0).abs() < 1e-9);
        let j = r.to_json();
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
        let keys: Vec<String> = j
            .get("integrity")
            .unwrap()
            .as_obj()
            .unwrap()
            .keys()
            .cloned()
            .collect();
        assert_eq!(
            keys,
            [
                "corrected",
                "detected",
                "detection_rate",
                "escape_rate",
                "injected",
                "masked",
                "overhead_frac",
                "protected",
                "quarantined",
                "scrubs",
                "sdc",
                "silent",
                "silent_completions",
            ]
        );
        // No resilience section: SDC alone does not fabricate one.
        assert!(j.get("resilience").is_none());
        let text = r.text();
        assert!(text.contains("integrity: sdc"));
        assert!(text.contains("detection"));
        // Bit-identical replays.
        let again = ServeReport::new(&spec, &simulate(&spec, &profiles));
        assert_eq!(j.pretty(), again.to_json().pretty());
    }

    #[test]
    fn flat_topology_emits_no_racks_key_but_racked_does() {
        let flat = toy_report();
        assert!(flat.to_json().get("racks").is_none());
        assert!(!flat.text().contains("topology:"));

        let (mut spec, profiles) = toy_spec();
        spec.policy = DispatchPolicy::Hierarchical;
        spec.racks = 2;
        let out = simulate(&spec, &profiles);
        let racked = ServeReport::new(&spec, &out);
        let j = racked.to_json();
        assert_eq!(j.get("racks").unwrap().as_usize().unwrap(), 2);
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
        assert!(racked.text().contains("topology: 2 instances in 2 racks"));
    }
}
