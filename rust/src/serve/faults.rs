//! Fault injection and recovery for the serving fleet: what the capacity
//! curves look like when instances crash, limp, and drop requests.
//!
//! VSCNN's pitch is one hardware path that survives both dense and sparse
//! regimes; at fleet scale the serving story must survive regime changes
//! too — faults are the steady state at thousands of instances. This
//! module supplies the *deterministic* ingredients the event loop
//! ([`super::fleet`]) threads through:
//!
//! * [`FaultSpec`] — the injected fault mix, parsed from the CLI
//!   `--faults` grammar (`crash:RATE,mttr:MS,straggler:RATE,slow:X,
//!   slowms:MS,reqfault:P`).
//! * [`generate_plan`] — a seeded, pre-materialized timeline of
//!   crash/recover and straggler start/end events per instance, drawn
//!   from dedicated [`Pcg32`] streams so the arrival stream (and thus the
//!   zero-fault simulation) is untouched: replays are bit-reproducible
//!   and the no-fault configuration stays bit-identical to the pre-fault
//!   simulator.
//! * [`Health`] — the per-instance state dispatch consults: `Up`,
//!   `Degraded` (straggling, or breaker open after consecutive
//!   timeouts), `Down` (crashed, queue drained and re-homed).
//! * [`RobustnessPolicy`] — the client-side knobs: per-attempt timeout,
//!   bounded retry with exponential backoff, hedged requests (duplicate
//!   to a second instance after a delay, first completion wins, loser
//!   cancelled), and SLO-aware load shedding (lowest-priority tenants
//!   rejected first when surviving capacity drops below offered load).
//!
//! All cycle arithmetic is integral; all randomness is seeded PCG32. A
//! `(spec, seed)` pair reproduces the exact fault timeline, pinned by
//! `tests/serve.rs`.

use super::traffic::exp_interarrival;
use crate::util::rng::Pcg32;
use anyhow::{bail, Context, Result};

/// Base PCG32 stream id for fault-plan draws. Instance `i` uses streams
/// `BASE + 2i` (crashes) and `BASE + 2i + 1` (stragglers); per-request
/// execution faults use [`REQ_FAULT_STREAM`]. The arrival process owns
/// stream 1, so fault injection never perturbs the arrival sequence.
const FAULT_STREAM_BASE: u64 = 0x0F00;

/// PCG32 stream id for per-request execution-fault draws.
pub const REQ_FAULT_STREAM: u64 = 7;

/// Injected fault mix for one serving run. All rates are per instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Crash arrivals per instance-second (Poisson). 0 = never.
    pub crash_per_sec: f64,
    /// Mean time to recover from a crash, in milliseconds (exponential).
    pub mttr_ms: f64,
    /// Straggler-episode arrivals per instance-second (Poisson). 0 = never.
    pub straggler_per_sec: f64,
    /// Service-time multiplier while an instance straggles (>= 1).
    pub slowdown: f64,
    /// Mean straggler-episode length in milliseconds (exponential).
    pub straggler_ms: f64,
    /// Per-request execution-fault probability in [0, 1): the batch
    /// finishes but this request's result is corrupt and must be retried.
    pub req_fault_prob: f64,
}

impl FaultSpec {
    /// No injected faults: the zero-fault configuration, bit-identical to
    /// the pre-fault simulator.
    pub fn none() -> FaultSpec {
        FaultSpec {
            crash_per_sec: 0.0,
            mttr_ms: 5.0,
            straggler_per_sec: 0.0,
            slowdown: 4.0,
            straggler_ms: 2.0,
            req_fault_prob: 0.0,
        }
    }

    /// True when no fault source is active (rates and probabilities all
    /// zero) — the plan is empty and the simulation takes the legacy path.
    pub fn is_none(&self) -> bool {
        self.crash_per_sec == 0.0 && self.straggler_per_sec == 0.0 && self.req_fault_prob == 0.0
    }

    /// Parse the CLI `--faults` grammar: comma-separated `key:value`
    /// pairs. Keys: `crash` (crashes per instance-second), `mttr` (ms),
    /// `straggler` (episodes per instance-second), `slow` (multiplier,
    /// >= 1), `slowms` (episode ms), `reqfault` (probability in [0, 1)).
    /// Unspecified keys keep the [`FaultSpec::none`] defaults.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::none();
        if s.trim().is_empty() {
            bail!("--faults spec is empty (example: crash:0.5,mttr:5,straggler:1,slow:4)");
        }
        for part in s.split(',') {
            let Some((key, val)) = part.split_once(':') else {
                bail!("--faults: '{part}' is not key:value (example: crash:0.5)");
            };
            let num: f64 = val
                .parse()
                .with_context(|| format!("--faults {key}: cannot parse '{val}'"))?;
            if !num.is_finite() {
                bail!("--faults {key}: '{val}' is not finite");
            }
            match key {
                "crash" => {
                    anyhow::ensure!(num >= 0.0, "--faults crash: rate must be >= 0, got {num}");
                    spec.crash_per_sec = num;
                }
                "mttr" => {
                    anyhow::ensure!(num > 0.0, "--faults mttr: must be > 0 ms, got {num}");
                    spec.mttr_ms = num;
                }
                "straggler" => {
                    anyhow::ensure!(num >= 0.0, "--faults straggler: rate must be >= 0, got {num}");
                    spec.straggler_per_sec = num;
                }
                "slow" => {
                    anyhow::ensure!(num >= 1.0, "--faults slow: multiplier must be >= 1, got {num}");
                    spec.slowdown = num;
                }
                "slowms" => {
                    anyhow::ensure!(num > 0.0, "--faults slowms: must be > 0 ms, got {num}");
                    spec.straggler_ms = num;
                }
                "reqfault" => {
                    anyhow::ensure!(
                        (0.0..1.0).contains(&num),
                        "--faults reqfault: probability must be in [0, 1), got {num}"
                    );
                    spec.req_fault_prob = num;
                }
                other => bail!(
                    "--faults: unknown key '{other}' \
                     (known: crash, mttr, straggler, slow, slowms, reqfault)"
                ),
            }
        }
        Ok(spec)
    }

    /// Short label for reports.
    pub fn label(&self) -> String {
        if self.is_none() {
            return "none".to_string();
        }
        let mut parts = Vec::new();
        if self.crash_per_sec > 0.0 {
            parts.push(format!(
                "crash {}/s mttr {}ms",
                self.crash_per_sec, self.mttr_ms
            ));
        }
        if self.straggler_per_sec > 0.0 {
            parts.push(format!(
                "straggler {}/s x{} {}ms",
                self.straggler_per_sec, self.slowdown, self.straggler_ms
            ));
        }
        if self.req_fault_prob > 0.0 {
            parts.push(format!("reqfault {}", self.req_fault_prob));
        }
        parts.join(" | ")
    }
}

/// Client-side robustness knobs (all off by default = legacy behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RobustnessPolicy {
    /// Per-attempt timeout in cycles, measured from dispatch (queueing
    /// counts). 0 = no timeouts.
    pub timeout_cycles: u64,
    /// Dispatch retries after a failed attempt (timeout, queue-full, or
    /// execution fault). 0 = fail fast, the legacy behavior.
    pub max_retries: u32,
    /// Base retry backoff in cycles; doubles per retry (exponential).
    pub backoff_cycles: u64,
    /// Hedge delay in cycles: if the primary attempt has not completed
    /// after this long, duplicate the request onto a second instance.
    /// First completion wins; the loser is cancelled. 0 = no hedging.
    pub hedge_cycles: u64,
    /// SLO-aware load shedding: reject the lowest-priority tenants first
    /// when queue occupancy over the surviving (non-crashed) instances
    /// crosses their admission threshold.
    pub shed: bool,
}

impl RobustnessPolicy {
    /// Everything off: the legacy fail-fast client.
    pub fn none() -> RobustnessPolicy {
        RobustnessPolicy {
            timeout_cycles: 0,
            max_retries: 0,
            backoff_cycles: 0,
            hedge_cycles: 0,
            shed: false,
        }
    }

    /// True when any robustness mechanism is on.
    pub fn active(&self) -> bool {
        self.timeout_cycles > 0 || self.max_retries > 0 || self.hedge_cycles > 0 || self.shed
    }

    /// Backoff before retry number `retry` (1-based): exponential with a
    /// capped shift, at least one cycle so time always advances.
    pub fn backoff_for(&self, retry: u32) -> u64 {
        let shift = (retry.saturating_sub(1)).min(16);
        (self.backoff_cycles << shift).max(1)
    }

    /// Shedding admission threshold for a tenant priority (0 = highest):
    /// priority `p` is admitted while the alive-fleet queue occupancy is
    /// below `1 - 0.3 * min(p, 3)` — lowest priorities are shed first as
    /// surviving capacity fills up.
    pub fn shed_threshold(priority: u8) -> f64 {
        1.0 - 0.3 * priority.min(3) as f64
    }
}

/// Per-instance health as seen by dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// Serving normally.
    Up,
    /// Limping: straggling (slowdown > 1) or breaker open after
    /// consecutive timeouts. Dispatch avoids it when an `Up` instance
    /// with queue space exists.
    Degraded,
    /// Crashed: accepts nothing until its recover event.
    Down,
}

/// One scheduled fault-plan event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Instance dies: running batch killed, queue drained and re-homed.
    Crash,
    /// Instance returns, cold (no resident network, healthy).
    Recover,
    /// Straggler episode begins: service times multiply by the factor.
    SlowStart(f64),
    /// Straggler episode ends.
    SlowEnd,
}

/// A fault-plan entry: `kind` hits `instance` at `cycle`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub cycle: u64,
    pub instance: usize,
    pub kind: FaultKind,
}

/// Materialize the seeded fault timeline for a fleet of `instances` over
/// `horizon` cycles at `clock_hz` cycles/sec. Crash/recover pairs and
/// straggler episodes alternate per instance (exponential gaps, at least
/// one cycle, so pairs never collide); events are returned sorted by
/// `(cycle, instance)` with starts before ends, ready to enqueue ahead of
/// the arrival process. Deterministic per `(spec, seed)`.
pub fn generate_plan(
    spec: &FaultSpec,
    instances: usize,
    horizon: u64,
    clock_hz: f64,
    seed: u64,
) -> Vec<FaultEvent> {
    let mut plan: Vec<FaultEvent> = Vec::new();
    if spec.is_none() {
        return plan;
    }
    let cycles_per_ms = clock_hz / 1e3;
    for i in 0..instances {
        if spec.crash_per_sec > 0.0 {
            let mut rng = Pcg32::new(seed, FAULT_STREAM_BASE + 2 * i as u64);
            let mean_gap = clock_hz / spec.crash_per_sec;
            let mean_repair = spec.mttr_ms * cycles_per_ms;
            let mut t = 0u64;
            loop {
                t += exp_interarrival(&mut rng, mean_gap);
                if t > horizon {
                    break;
                }
                plan.push(FaultEvent {
                    cycle: t,
                    instance: i,
                    kind: FaultKind::Crash,
                });
                t += exp_interarrival(&mut rng, mean_repair.max(1.0));
                if t > horizon {
                    break; // stays down; availability accounting closes it
                }
                plan.push(FaultEvent {
                    cycle: t,
                    instance: i,
                    kind: FaultKind::Recover,
                });
            }
        }
        if spec.straggler_per_sec > 0.0 {
            let mut rng = Pcg32::new(seed, FAULT_STREAM_BASE + 2 * i as u64 + 1);
            let mean_gap = clock_hz / spec.straggler_per_sec;
            let mean_episode = spec.straggler_ms * cycles_per_ms;
            let mut t = 0u64;
            loop {
                t += exp_interarrival(&mut rng, mean_gap);
                if t > horizon {
                    break;
                }
                plan.push(FaultEvent {
                    cycle: t,
                    instance: i,
                    kind: FaultKind::SlowStart(spec.slowdown),
                });
                t += exp_interarrival(&mut rng, mean_episode.max(1.0));
                if t > horizon {
                    break;
                }
                plan.push(FaultEvent {
                    cycle: t,
                    instance: i,
                    kind: FaultKind::SlowEnd,
                });
            }
        }
    }
    // Per-instance streams are monotone; a stable sort by (cycle,
    // instance) pins the global interleaving.
    plan.sort_by_key(|e| (e.cycle, e.instance));
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let s = FaultSpec::parse("crash:0.5,mttr:8,straggler:2,slow:6,slowms:3,reqfault:0.01")
            .unwrap();
        assert_eq!(s.crash_per_sec, 0.5);
        assert_eq!(s.mttr_ms, 8.0);
        assert_eq!(s.straggler_per_sec, 2.0);
        assert_eq!(s.slowdown, 6.0);
        assert_eq!(s.straggler_ms, 3.0);
        assert_eq!(s.req_fault_prob, 0.01);
        assert!(!s.is_none());
        assert!(s.label().contains("crash"));
    }

    #[test]
    fn parse_partial_keeps_defaults() {
        let s = FaultSpec::parse("crash:0.01").unwrap();
        assert_eq!(s.crash_per_sec, 0.01);
        assert_eq!(s.mttr_ms, FaultSpec::none().mttr_ms);
        assert_eq!(s.straggler_per_sec, 0.0);
        assert!(!s.is_none());
    }

    #[test]
    fn parse_errors_are_specific() {
        for (input, needle) in [
            ("", "empty"),
            ("crash", "key:value"),
            ("crash:abc", "cannot parse"),
            ("crash:-1", ">= 0"),
            ("slow:0.5", ">= 1"),
            ("reqfault:1.5", "[0, 1)"),
            ("mttr:0", "> 0"),
            ("bogus:1", "unknown key"),
        ] {
            let err = FaultSpec::parse(input).unwrap_err();
            assert!(
                format!("{err:#}").contains(needle),
                "input '{input}': expected '{needle}' in '{err:#}'"
            );
        }
    }

    #[test]
    fn none_spec_has_empty_plan() {
        let plan = generate_plan(&FaultSpec::none(), 8, 1_000_000_000, 5e8, 42);
        assert!(plan.is_empty());
        assert!(FaultSpec::none().is_none());
        assert_eq!(FaultSpec::none().label(), "none");
    }

    #[test]
    fn plan_is_deterministic_and_sorted() {
        let spec = FaultSpec::parse("crash:50,mttr:2,straggler:100,slow:4,slowms:1").unwrap();
        let a = generate_plan(&spec, 4, 500_000_000, 5e8, 9);
        let b = generate_plan(&spec, 4, 500_000_000, 5e8, 9);
        assert_eq!(a, b, "same (spec, seed) must replay bit-identically");
        assert!(!a.is_empty(), "rates high enough to fire within horizon");
        assert!(a.windows(2).all(|w| (w[0].cycle, w[0].instance) <= (w[1].cycle, w[1].instance)));
        let c = generate_plan(&spec, 4, 500_000_000, 5e8, 10);
        assert_ne!(a, c, "different seeds produce different timelines");
    }

    #[test]
    fn plan_alternates_crash_recover_per_instance() {
        let spec = FaultSpec::parse("crash:100,mttr:1").unwrap();
        let plan = generate_plan(&spec, 3, 1_000_000_000, 5e8, 3);
        for i in 0..3 {
            let mut down = false;
            for e in plan.iter().filter(|e| e.instance == i) {
                match e.kind {
                    FaultKind::Crash => {
                        assert!(!down, "crash while down (instance {i})");
                        down = true;
                    }
                    FaultKind::Recover => {
                        assert!(down, "recover while up (instance {i})");
                        down = false;
                    }
                    _ => panic!("unexpected straggler event"),
                }
            }
        }
    }

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let r = RobustnessPolicy {
            backoff_cycles: 100,
            ..RobustnessPolicy::none()
        };
        assert_eq!(r.backoff_for(1), 100);
        assert_eq!(r.backoff_for(2), 200);
        assert_eq!(r.backoff_for(3), 400);
        // Shift is capped, not overflowing.
        assert!(r.backoff_for(60) >= r.backoff_for(17));
        let zero = RobustnessPolicy::none();
        assert_eq!(zero.backoff_for(1), 1, "backoff always advances time");
    }

    #[test]
    fn shed_thresholds_order_priorities() {
        let t0 = RobustnessPolicy::shed_threshold(0);
        let t1 = RobustnessPolicy::shed_threshold(1);
        let t3 = RobustnessPolicy::shed_threshold(3);
        let t9 = RobustnessPolicy::shed_threshold(9);
        assert_eq!(t0, 1.0, "highest priority is shed last");
        assert!(t0 > t1 && t1 > t3, "lower priority sheds earlier");
        assert_eq!(t3, t9, "priorities past 3 share the floor");
        assert!(t3 > 0.0);
    }

    #[test]
    fn robustness_active_flags() {
        assert!(!RobustnessPolicy::none().active());
        let mut r = RobustnessPolicy::none();
        r.timeout_cycles = 10;
        assert!(r.active());
        let mut h = RobustnessPolicy::none();
        h.hedge_cycles = 5;
        assert!(h.active());
        let mut s = RobustnessPolicy::none();
        s.shed = true;
        assert!(s.active());
    }
}
