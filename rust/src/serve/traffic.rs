//! Request traffic: the multi-tenant request mix and the arrival models.
//!
//! A *tenant* is one served workload — a zoo network at a fixed input
//! resolution with a share of the traffic. Arrivals come from one of two
//! classic models:
//!
//! * **Open loop** (`rps`): a Poisson process — exponential inter-arrival
//!   times, independent of the fleet's state. What a datacenter sees from
//!   millions of uncoordinated users; overload shows up as queueing and
//!   rejections, not back-pressure.
//! * **Closed loop** (`clients`, `think_cycles`): each client issues one
//!   request, waits for its completion plus a think time, then issues the
//!   next. Self-throttling; overload shows up as lower per-client rates.
//!
//! All randomness is a seeded [`Pcg32`] stream, so a `(spec, seed)` pair
//! reproduces the exact arrival sequence.

use crate::util::rng::Pcg32;

/// One served workload: a zoo network at one input resolution, with a
/// relative traffic share.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Display name (unique within a mix), e.g. `vgg16@64`.
    pub name: String,
    /// Zoo network name (`crate::model::zoo::by_name`).
    pub net: String,
    /// Input resolution.
    pub res: usize,
    /// Relative traffic share (normalized over the mix).
    pub weight: f64,
    /// Shedding priority: 0 = highest (shed last). Only consulted when
    /// SLO-aware load shedding is on
    /// ([`super::faults::RobustnessPolicy::shed`]); admission is
    /// priority-blind otherwise.
    pub priority: u8,
}

impl Tenant {
    pub fn new(net: &str, res: usize, weight: f64) -> Tenant {
        Tenant {
            name: format!("{net}@{res}"),
            net: net.to_string(),
            res,
            weight,
            priority: 0,
        }
    }

    /// Builder: set the shedding priority (0 = highest, shed last).
    pub fn with_priority(mut self, priority: u8) -> Tenant {
        self.priority = priority;
        self
    }
}

/// The default serving mix: the three zoo CNNs at mixed resolutions
/// (`resnet10` runs at half resolution — its stride-2 trunk serves
/// smaller inputs in practice). `res` must be a multiple of 32.
/// Shedding priorities rank the tenants vgg16 > alexnet > resnet10, so
/// under load shedding the smallest workload is sacrificed first; with
/// shedding off (the default) priorities are inert.
pub fn default_mix(res: usize) -> Vec<Tenant> {
    vec![
        Tenant::new("vgg16", res, 0.4),
        Tenant::new("alexnet", res, 0.3).with_priority(1),
        Tenant::new("resnet10", (res / 2).max(16), 0.3).with_priority(2),
    ]
}

/// Cumulative-weight sampler over a tenant mix.
#[derive(Debug, Clone)]
pub struct RequestMix {
    cumulative: Vec<f64>,
}

impl RequestMix {
    pub fn new(tenants: &[Tenant]) -> RequestMix {
        assert!(!tenants.is_empty(), "empty tenant mix");
        let total: f64 = tenants.iter().map(|t| t.weight.max(0.0)).sum();
        assert!(total > 0.0, "tenant mix has no positive weight");
        let mut acc = 0.0;
        let cumulative = tenants
            .iter()
            .map(|t| {
                acc += t.weight.max(0.0) / total;
                acc
            })
            .collect();
        RequestMix { cumulative }
    }

    /// Sample a tenant index proportionally to the weights.
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.f32() as f64;
        self.cumulative
            .iter()
            .position(|&c| u < c)
            .unwrap_or(self.cumulative.len() - 1)
    }
}

/// Arrival model (see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TrafficModel {
    /// Poisson arrivals at `rps` requests per second (converted to the
    /// cycle domain by the fleet clock).
    OpenLoop { rps: f64 },
    /// `clients` closed-loop clients, each re-issuing `think_cycles` after
    /// its previous request completes (or is rejected).
    ClosedLoop { clients: usize, think_cycles: u64 },
}

impl TrafficModel {
    /// Short label for reports.
    pub fn label(&self) -> String {
        match self {
            TrafficModel::OpenLoop { rps } => format!("open-loop {rps} rps"),
            TrafficModel::ClosedLoop {
                clients,
                think_cycles,
            } => format!("closed-loop {clients} clients (think {think_cycles} cyc)"),
        }
    }
}

/// Sample an exponential inter-arrival gap with the given mean, in whole
/// cycles (at least 1 so time always advances).
pub fn exp_interarrival(rng: &mut Pcg32, mean_cycles: f64) -> u64 {
    assert!(mean_cycles > 0.0, "non-positive mean inter-arrival");
    // 1 - f32() is in (0, 1]; ln of it is finite and <= 0.
    let u = 1.0 - rng.f32() as f64;
    let gap = -u.ln() * mean_cycles;
    (gap.ceil() as u64).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_samples_proportionally() {
        let tenants = vec![
            Tenant::new("vgg16", 32, 3.0),
            Tenant::new("alexnet", 32, 1.0),
        ];
        let mix = RequestMix::new(&tenants);
        let mut rng = Pcg32::seeded(7);
        let n = 20_000;
        let hits = (0..n).filter(|_| mix.sample(&mut rng) == 0).count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "share {frac}");
    }

    #[test]
    fn exp_interarrival_has_the_right_mean() {
        let mut rng = Pcg32::seeded(11);
        let mean = 1000.0;
        let n = 50_000;
        let sum: u64 = (0..n).map(|_| exp_interarrival(&mut rng, mean)).sum();
        let avg = sum as f64 / n as f64;
        // Ceil-rounding biases up by < 1 cycle.
        assert!((avg - mean).abs() < mean * 0.03, "mean {avg}");
    }

    #[test]
    fn exp_interarrival_always_advances() {
        let mut rng = Pcg32::seeded(13);
        for _ in 0..10_000 {
            assert!(exp_interarrival(&mut rng, 0.001) >= 1);
        }
    }

    #[test]
    fn default_mix_is_valid_and_varies_resolution() {
        let mix = default_mix(64);
        assert_eq!(mix.len(), 3);
        assert!(mix.iter().any(|t| t.res != 64), "resolutions should vary");
        let _ = RequestMix::new(&mix); // weights normalize
        let tiny = default_mix(32);
        assert!(tiny.iter().all(|t| t.res >= 16));
        // Shedding priorities: vgg16 is protected longest, resnet10 shed
        // first; plain construction stays highest priority.
        assert_eq!(mix[0].priority, 0);
        assert!(mix[1].priority < mix[2].priority);
        assert_eq!(Tenant::new("vgg16", 32, 1.0).priority, 0);
        assert_eq!(Tenant::new("vgg16", 32, 1.0).with_priority(3).priority, 3);
    }

    #[test]
    fn labels_render() {
        assert!(TrafficModel::OpenLoop { rps: 10.0 }.label().contains("rps"));
        assert!(TrafficModel::ClosedLoop {
            clients: 4,
            think_cycles: 100
        }
        .label()
        .contains("clients"));
    }
}
