//! Request traffic: the multi-tenant request mix and the arrival models.
//!
//! A *tenant* is one served workload — a zoo network at a fixed input
//! resolution with a share of the traffic. Arrivals come from one of four
//! models:
//!
//! * **Open loop** (`rps`): a Poisson process — exponential inter-arrival
//!   times, independent of the fleet's state. What a datacenter sees from
//!   millions of uncoordinated users; overload shows up as queueing and
//!   rejections, not back-pressure.
//! * **Closed loop** (`clients`, `think_cycles`): each client issues one
//!   request, waits for its completion plus a think time, then issues the
//!   next. Self-throttling; overload shows up as lower per-client rates.
//! * **Diurnal** (`rps`, `amplitude`, `period_cycles`): a Poisson process
//!   whose rate follows a sinusoidal envelope
//!   `rps · (1 + amplitude · sin(2πt/period))` — the day/night swing every
//!   planet-scale service provisions for, compressed to simulation time.
//!   Sampled exactly by Lewis–Shedler thinning at the peak rate.
//! * **MMPP / flash crowd** (`rps`, `burst_x`, dwell times): a two-state
//!   Markov-modulated Poisson process — baseline `rps` punctuated by
//!   exponentially-dwelling bursts at `burst_x × rps`. The bursty tail
//!   that breaks dispatch policies which only balance averages. Sampled
//!   exactly via memorylessness: a gap that crosses the state boundary is
//!   truncated there and redrawn at the new state's rate.
//!
//! All randomness is seeded [`Pcg32`] streams: the base arrival gaps stay
//! on the fleet's legacy stream, while envelope thinning and state dwells
//! draw from a dedicated modulation stream ([`TRAFFIC_MOD_STREAM`]) — so
//! plain open-loop runs reproduce the exact pre-topology event sequence,
//! and a `(spec, seed)` pair reproduces the exact arrival sequence under
//! every model.

use crate::util::rng::Pcg32;
use anyhow::{bail, ensure, Result};

/// PCG32 stream id for traffic modulation (diurnal thinning accepts and
/// MMPP state dwells). Distinct from the arrival stream (1), the dispatch
/// candidate stream (3), the per-request fault stream (7) and the
/// per-instance fault-plan streams (0x0F00+); never drawn by the plain
/// open-loop or closed-loop models.
pub const TRAFFIC_MOD_STREAM: u64 = 2;

/// One served workload: a zoo network at one input resolution, with a
/// relative traffic share.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Display name (unique within a mix), e.g. `vgg16@64`.
    pub name: String,
    /// Zoo network name (`crate::model::zoo::by_name`).
    pub net: String,
    /// Input resolution.
    pub res: usize,
    /// Relative traffic share (normalized over the mix).
    pub weight: f64,
    /// Shedding priority: 0 = highest (shed last). Only consulted when
    /// SLO-aware load shedding is on
    /// ([`super::faults::RobustnessPolicy::shed`]); admission is
    /// priority-blind otherwise.
    pub priority: u8,
}

impl Tenant {
    pub fn new(net: &str, res: usize, weight: f64) -> Tenant {
        Tenant {
            name: format!("{net}@{res}"),
            net: net.to_string(),
            res,
            weight,
            priority: 0,
        }
    }

    /// Builder: set the shedding priority (0 = highest, shed last).
    pub fn with_priority(mut self, priority: u8) -> Tenant {
        self.priority = priority;
        self
    }
}

/// The default serving mix: the three zoo CNNs at mixed resolutions
/// (`resnet10` runs at half resolution — its stride-2 trunk serves
/// smaller inputs in practice). `res` must be a multiple of 32.
/// Shedding priorities rank the tenants vgg16 > alexnet > resnet10, so
/// under load shedding the smallest workload is sacrificed first; with
/// shedding off (the default) priorities are inert.
pub fn default_mix(res: usize) -> Vec<Tenant> {
    vec![
        Tenant::new("vgg16", res, 0.4),
        Tenant::new("alexnet", res, 0.3).with_priority(1),
        Tenant::new("resnet10", (res / 2).max(16), 0.3).with_priority(2),
    ]
}

/// Cumulative-weight sampler over a tenant mix.
#[derive(Debug, Clone)]
pub struct RequestMix {
    cumulative: Vec<f64>,
}

impl RequestMix {
    pub fn new(tenants: &[Tenant]) -> RequestMix {
        assert!(!tenants.is_empty(), "empty tenant mix");
        let total: f64 = tenants.iter().map(|t| t.weight.max(0.0)).sum();
        assert!(total > 0.0, "tenant mix has no positive weight");
        let mut acc = 0.0;
        let cumulative = tenants
            .iter()
            .map(|t| {
                acc += t.weight.max(0.0) / total;
                acc
            })
            .collect();
        RequestMix { cumulative }
    }

    /// Sample a tenant index proportionally to the weights.
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.f32() as f64;
        self.cumulative
            .iter()
            .position(|&c| u < c)
            .unwrap_or(self.cumulative.len() - 1)
    }
}

/// Arrival model (see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TrafficModel {
    /// Poisson arrivals at `rps` requests per second (converted to the
    /// cycle domain by the fleet clock).
    OpenLoop { rps: f64 },
    /// `clients` closed-loop clients, each re-issuing `think_cycles` after
    /// its previous request completes (or is rejected).
    ClosedLoop { clients: usize, think_cycles: u64 },
    /// Poisson with a sinusoidal rate envelope: mean rate `rps`, swinging
    /// by `±amplitude` (0..=1) over `period_cycles`.
    Diurnal {
        rps: f64,
        amplitude: f64,
        period_cycles: u64,
    },
    /// Two-state MMPP: `rps` in the low state, `rps · burst_x` during
    /// bursts; exponential dwell times with the given means.
    Mmpp {
        rps: f64,
        burst_x: f64,
        mean_high_cycles: u64,
        mean_low_cycles: u64,
    },
}

impl TrafficModel {
    /// Short label for reports.
    pub fn label(&self) -> String {
        match self {
            TrafficModel::OpenLoop { rps } => format!("open-loop {rps} rps"),
            TrafficModel::ClosedLoop {
                clients,
                think_cycles,
            } => format!("closed-loop {clients} clients (think {think_cycles} cyc)"),
            TrafficModel::Diurnal {
                rps,
                amplitude,
                period_cycles,
            } => format!("diurnal {rps} rps ±{amplitude} (period {period_cycles} cyc)"),
            TrafficModel::Mmpp {
                rps,
                burst_x,
                mean_high_cycles,
                mean_low_cycles,
            } => format!(
                "mmpp {rps} rps x{burst_x} bursts (high {mean_high_cycles} cyc / low {mean_low_cycles} cyc)"
            ),
        }
    }

    /// Parse a `--traffic` CLI value into an open-loop-family model at the
    /// given base rate. Grammar: `kind[,key:value,...]` —
    ///
    /// * `poisson` (or `open-loop`, or empty): plain Poisson.
    /// * `diurnal[,amp:A][,period-ms:P]`: sinusoidal envelope, amplitude
    ///   `A` in 0..=1 (default 0.5), period `P` milliseconds of simulated
    ///   time (default 20).
    /// * `flash` (or `mmpp`)`[,x:X][,high-ms:H][,low-ms:L]`: bursts at
    ///   `X × rps` (default 8) dwelling ~`H` ms (default 1) between calm
    ///   stretches of ~`L` ms (default 10).
    pub fn parse(s: &str, rps: f64, clock_mhz: f64) -> Result<TrafficModel> {
        let ms_to_cycles = |ms: f64| ((ms * clock_mhz * 1e3) as u64).max(1);
        let mut parts = s.split(',');
        let kind = parts.next().unwrap_or("").trim();
        let mut opts: Vec<(&str, f64)> = Vec::new();
        for p in parts {
            let p = p.trim();
            if p.is_empty() {
                continue;
            }
            let Some((k, v)) = p.split_once(':') else {
                bail!("traffic option '{p}' is not key:value");
            };
            let v: f64 = v
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("traffic option '{p}' has a non-numeric value"))?;
            opts.push((k.trim(), v));
        }
        let take = |key: &str, default: f64| -> f64 {
            opts.iter()
                .find(|(k, _)| *k == key)
                .map(|&(_, v)| v)
                .unwrap_or(default)
        };
        let model = match kind {
            "" | "poisson" | "open-loop" => TrafficModel::OpenLoop { rps },
            "diurnal" => {
                let amplitude = take("amp", 0.5);
                ensure!(
                    (0.0..=1.0).contains(&amplitude),
                    "diurnal amp must be in [0, 1], got {amplitude}"
                );
                let period_ms = take("period-ms", 20.0);
                ensure!(period_ms > 0.0, "diurnal period-ms must be > 0");
                TrafficModel::Diurnal {
                    rps,
                    amplitude,
                    period_cycles: ms_to_cycles(period_ms),
                }
            }
            "flash" | "mmpp" => {
                let burst_x = take("x", 8.0);
                ensure!(burst_x >= 1.0, "mmpp burst factor x must be >= 1");
                let high_ms = take("high-ms", 1.0);
                let low_ms = take("low-ms", 10.0);
                ensure!(high_ms > 0.0 && low_ms > 0.0, "mmpp dwell times must be > 0");
                TrafficModel::Mmpp {
                    rps,
                    burst_x,
                    mean_high_cycles: ms_to_cycles(high_ms),
                    mean_low_cycles: ms_to_cycles(low_ms),
                }
            }
            other => bail!("unknown traffic model '{other}' (known: poisson, diurnal, flash)"),
        };
        // Every provided key must belong to the chosen model.
        let known: &[&str] = match model {
            TrafficModel::OpenLoop { .. } => &[],
            TrafficModel::Diurnal { .. } => &["amp", "period-ms"],
            TrafficModel::Mmpp { .. } => &["x", "high-ms", "low-ms"],
            TrafficModel::ClosedLoop { .. } => unreachable!(),
        };
        for (k, _) in &opts {
            ensure!(known.contains(k), "traffic model '{kind}' has no option '{k}'");
        }
        Ok(model)
    }
}

/// Sample an exponential inter-arrival gap with the given mean, in whole
/// cycles (at least 1 so time always advances).
pub fn exp_interarrival(rng: &mut Pcg32, mean_cycles: f64) -> u64 {
    assert!(mean_cycles > 0.0, "non-positive mean inter-arrival");
    // 1 - f32() is in (0, 1]; ln of it is finite and <= 0.
    let u = 1.0 - rng.f32() as f64;
    let gap = -u.ln() * mean_cycles;
    (gap.ceil() as u64).max(1)
}

/// State of one open-loop-family arrival process.
#[derive(Debug, Clone, Copy)]
enum Kind {
    Poisson {
        mean_cycles: f64,
    },
    Diurnal {
        base_rps: f64,
        amplitude: f64,
        period_cycles: f64,
        clock_hz: f64,
    },
    Mmpp {
        /// Mean gap in the calm state (cycles).
        mean_low: f64,
        /// Mean gap during a burst (cycles).
        mean_high: f64,
        dwell_low: f64,
        dwell_high: f64,
        /// Currently bursting?
        high: bool,
        /// Current state holds until this cycle.
        until: u64,
    },
}

/// Stateful arrival sampler for the open-loop traffic family. Base gap
/// draws come from the caller's legacy arrival stream (so plain Poisson
/// reproduces the pre-topology sequence exactly); envelope thinning and
/// dwell draws come from the process's own modulation stream.
#[derive(Debug)]
pub struct ArrivalProcess {
    kind: Kind,
    mod_rng: Pcg32,
}

impl ArrivalProcess {
    /// Build the sampler for a model, or `None` for closed-loop traffic
    /// (which is driven by per-client completion events instead).
    pub fn for_model(model: &TrafficModel, clock_hz: f64, seed: u64) -> Option<ArrivalProcess> {
        let kind = match *model {
            TrafficModel::ClosedLoop { .. } => return None,
            TrafficModel::OpenLoop { rps } => Kind::Poisson {
                mean_cycles: clock_hz / rps.max(1e-9),
            },
            TrafficModel::Diurnal {
                rps,
                amplitude,
                period_cycles,
            } => Kind::Diurnal {
                base_rps: rps.max(1e-9),
                amplitude,
                period_cycles: period_cycles.max(1) as f64,
                clock_hz,
            },
            TrafficModel::Mmpp {
                rps,
                burst_x,
                mean_high_cycles,
                mean_low_cycles,
            } => Kind::Mmpp {
                mean_low: clock_hz / rps.max(1e-9),
                mean_high: clock_hz / (rps.max(1e-9) * burst_x.max(1.0)),
                dwell_low: mean_low_cycles.max(1) as f64,
                dwell_high: mean_high_cycles.max(1) as f64,
                // Nominally "in a burst" that expired at cycle 0, so the
                // first transition lands the process in the calm state.
                high: true,
                until: 0,
            },
        };
        Some(ArrivalProcess {
            kind,
            mod_rng: Pcg32::new(seed, TRAFFIC_MOD_STREAM),
        })
    }

    /// The next arrival cycle strictly after `now`. `gap_rng` is the
    /// fleet's arrival stream.
    pub fn next_at(&mut self, now: u64, gap_rng: &mut Pcg32) -> u64 {
        match self.kind {
            Kind::Poisson { mean_cycles } => now + exp_interarrival(gap_rng, mean_cycles),
            Kind::Diurnal {
                base_rps,
                amplitude,
                period_cycles,
                clock_hz,
            } => {
                // Lewis–Shedler thinning: propose at the peak rate, accept
                // proportionally to the instantaneous rate.
                let peak = base_rps * (1.0 + amplitude);
                let peak_mean = clock_hz / peak;
                let mut t = now;
                loop {
                    t = t.saturating_add(exp_interarrival(gap_rng, peak_mean));
                    let phase = t as f64 / period_cycles * std::f64::consts::TAU;
                    let rate = base_rps * (1.0 + amplitude * phase.sin());
                    if (self.mod_rng.f32() as f64) * peak <= rate {
                        return t;
                    }
                }
            }
            Kind::Mmpp { .. } => self.next_mmpp(now, gap_rng),
        }
    }

    /// Exact two-state MMPP sampling. Thanks to memorylessness a gap drawn
    /// at the current state's rate that crosses the state boundary can be
    /// truncated at the boundary and redrawn at the new rate without
    /// biasing the process.
    fn next_mmpp(&mut self, now: u64, gap_rng: &mut Pcg32) -> u64 {
        let Kind::Mmpp {
            mean_low,
            mean_high,
            dwell_low,
            dwell_high,
            mut high,
            mut until,
        } = self.kind
        else {
            unreachable!("next_mmpp on a non-MMPP process");
        };
        let mut t = now;
        let at = loop {
            if t >= until {
                high = !high;
                let dwell = if high { dwell_high } else { dwell_low };
                until = t.saturating_add(exp_interarrival(&mut self.mod_rng, dwell));
                continue;
            }
            let mean = if high { mean_high } else { mean_low };
            let gap = exp_interarrival(gap_rng, mean);
            if t.saturating_add(gap) <= until {
                break t.saturating_add(gap);
            }
            // Gap crosses the state flip: advance to the boundary and
            // redraw at the new state's rate.
            t = until;
        };
        self.kind = Kind::Mmpp {
            mean_low,
            mean_high,
            dwell_low,
            dwell_high,
            high,
            until,
        };
        at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_samples_proportionally() {
        let tenants = vec![
            Tenant::new("vgg16", 32, 3.0),
            Tenant::new("alexnet", 32, 1.0),
        ];
        let mix = RequestMix::new(&tenants);
        let mut rng = Pcg32::seeded(7);
        let n = 20_000;
        let hits = (0..n).filter(|_| mix.sample(&mut rng) == 0).count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "share {frac}");
    }

    #[test]
    fn exp_interarrival_has_the_right_mean() {
        let mut rng = Pcg32::seeded(11);
        let mean = 1000.0;
        let n = 50_000;
        let sum: u64 = (0..n).map(|_| exp_interarrival(&mut rng, mean)).sum();
        let avg = sum as f64 / n as f64;
        // Ceil-rounding biases up by < 1 cycle.
        assert!((avg - mean).abs() < mean * 0.03, "mean {avg}");
    }

    #[test]
    fn exp_interarrival_always_advances() {
        let mut rng = Pcg32::seeded(13);
        for _ in 0..10_000 {
            assert!(exp_interarrival(&mut rng, 0.001) >= 1);
        }
    }

    #[test]
    fn default_mix_is_valid_and_varies_resolution() {
        let mix = default_mix(64);
        assert_eq!(mix.len(), 3);
        assert!(mix.iter().any(|t| t.res != 64), "resolutions should vary");
        let _ = RequestMix::new(&mix); // weights normalize
        let tiny = default_mix(32);
        assert!(tiny.iter().all(|t| t.res >= 16));
        // Shedding priorities: vgg16 is protected longest, resnet10 shed
        // first; plain construction stays highest priority.
        assert_eq!(mix[0].priority, 0);
        assert!(mix[1].priority < mix[2].priority);
        assert_eq!(Tenant::new("vgg16", 32, 1.0).priority, 0);
        assert_eq!(Tenant::new("vgg16", 32, 1.0).with_priority(3).priority, 3);
    }

    #[test]
    fn labels_render() {
        assert!(TrafficModel::OpenLoop { rps: 10.0 }.label().contains("rps"));
        assert!(TrafficModel::ClosedLoop {
            clients: 4,
            think_cycles: 100
        }
        .label()
        .contains("clients"));
        assert!(TrafficModel::Diurnal {
            rps: 10.0,
            amplitude: 0.5,
            period_cycles: 1000
        }
        .label()
        .contains("diurnal"));
        assert!(TrafficModel::Mmpp {
            rps: 10.0,
            burst_x: 8.0,
            mean_high_cycles: 10,
            mean_low_cycles: 100
        }
        .label()
        .contains("mmpp"));
    }

    #[test]
    fn parse_covers_the_grammar_and_rejects_junk() {
        let m = TrafficModel::parse("poisson", 100.0, 500.0).unwrap();
        assert_eq!(m, TrafficModel::OpenLoop { rps: 100.0 });
        assert_eq!(
            TrafficModel::parse("", 50.0, 500.0).unwrap(),
            TrafficModel::OpenLoop { rps: 50.0 }
        );
        let d = TrafficModel::parse("diurnal,amp:0.8,period-ms:40", 100.0, 500.0).unwrap();
        match d {
            TrafficModel::Diurnal {
                rps,
                amplitude,
                period_cycles,
            } => {
                assert_eq!(rps, 100.0);
                assert_eq!(amplitude, 0.8);
                // 40 ms at 500 MHz = 20M cycles.
                assert_eq!(period_cycles, 20_000_000);
            }
            other => panic!("parsed {other:?}"),
        }
        let f = TrafficModel::parse("flash,x:4,high-ms:2,low-ms:8", 100.0, 500.0).unwrap();
        match f {
            TrafficModel::Mmpp {
                burst_x,
                mean_high_cycles,
                mean_low_cycles,
                ..
            } => {
                assert_eq!(burst_x, 4.0);
                assert_eq!(mean_high_cycles, 1_000_000);
                assert_eq!(mean_low_cycles, 4_000_000);
            }
            other => panic!("parsed {other:?}"),
        }
        // Defaults fill unset keys.
        assert!(matches!(
            TrafficModel::parse("flash", 10.0, 500.0).unwrap(),
            TrafficModel::Mmpp { burst_x, .. } if burst_x == 8.0
        ));
        // Junk is rejected.
        assert!(TrafficModel::parse("stampede", 10.0, 500.0).is_err());
        assert!(TrafficModel::parse("diurnal,amp:1.5", 10.0, 500.0).is_err());
        assert!(TrafficModel::parse("diurnal,x:4", 10.0, 500.0).is_err());
        assert!(TrafficModel::parse("flash,x:abc", 10.0, 500.0).is_err());
        assert!(TrafficModel::parse("flash,x", 10.0, 500.0).is_err());
        assert!(TrafficModel::parse("poisson,amp:0.5", 10.0, 500.0).is_err());
    }

    #[test]
    fn plain_poisson_process_matches_bare_exp_interarrival() {
        // The ArrivalProcess wrapper must not perturb the legacy stream:
        // one gap draw per arrival, nothing from the modulation stream.
        let model = TrafficModel::OpenLoop { rps: 1000.0 };
        let clock_hz = 500e6;
        let mut proc_ = ArrivalProcess::for_model(&model, clock_hz, 9).unwrap();
        let mut a = Pcg32::new(9, 1);
        let mut b = Pcg32::new(9, 1);
        let mut t = 0u64;
        let mut u = 0u64;
        for _ in 0..1000 {
            t = proc_.next_at(t, &mut a);
            u += exp_interarrival(&mut b, clock_hz / 1000.0);
            assert_eq!(t, u);
        }
    }

    #[test]
    fn closed_loop_has_no_arrival_process() {
        let model = TrafficModel::ClosedLoop {
            clients: 4,
            think_cycles: 100,
        };
        assert!(ArrivalProcess::for_model(&model, 500e6, 1).is_none());
    }

    #[test]
    fn mmpp_bursts_raise_the_rate_and_stay_deterministic() {
        let clock_hz = 500e6;
        let rps = 1000.0;
        let model = TrafficModel::Mmpp {
            rps,
            burst_x: 10.0,
            mean_high_cycles: 500_000,
            mean_low_cycles: 5_000_000,
        };
        let run = |seed: u64| {
            let mut proc_ = ArrivalProcess::for_model(&model, clock_hz, seed).unwrap();
            let mut rng = Pcg32::new(seed, 1);
            let mut t = 0u64;
            let mut arrivals = Vec::new();
            for _ in 0..20_000 {
                t = proc_.next_at(t, &mut rng);
                arrivals.push(t);
            }
            arrivals
        };
        let a = run(3);
        assert_eq!(a, run(3), "same seed, same arrival sequence");
        // Mean rate sits strictly between the calm and burst rates, well
        // above plain Poisson at `rps`: with ~10% of time bursting at
        // 10x, the long-run rate is ~1.9x the base.
        let horizon = *a.last().unwrap();
        let mean_rate = a.len() as f64 / (horizon as f64 / clock_hz);
        assert!(
            mean_rate > rps * 1.3 && mean_rate < rps * 10.0,
            "long-run mmpp rate {mean_rate} vs base {rps}"
        );
        // Gaps are strictly advancing.
        assert!(a.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn diurnal_keeps_the_base_mean_rate() {
        let clock_hz = 500e6;
        let rps = 2000.0;
        let model = TrafficModel::Diurnal {
            rps,
            amplitude: 0.9,
            // Many full periods over the sampled horizon so the sinusoid
            // averages out.
            period_cycles: 2_000_000,
        };
        let mut proc_ = ArrivalProcess::for_model(&model, clock_hz, 21).unwrap();
        let mut rng = Pcg32::new(21, 1);
        let mut t = 0u64;
        let n = 30_000;
        for _ in 0..n {
            t = proc_.next_at(t, &mut rng);
        }
        let mean_rate = n as f64 / (t as f64 / clock_hz);
        assert!(
            (mean_rate - rps).abs() < rps * 0.05,
            "diurnal long-run rate {mean_rate} vs base {rps}"
        );
    }
}
