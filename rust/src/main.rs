//! `vscnn` — leader binary: runs the paper's experiments, one-off
//! simulations, and diagnostics from the command line.
//!
//! ```text
//! vscnn exp <id|all> [--net vgg16|alexnet|resnet10|mixed] [--res N]
//!                    [--images N] [--seed S] [--pjrt DIR] [--out DIR]
//!                    [--bias-shift X] [--threads N] [--mem-model ideal|tiled]
//!                    [--max-fleet N] [--precision f32|int16|int8] [--fuse]
//!                    [--metrics-out FILE] [--trace-out FILE] [--trace-limit N]
//! vscnn simulate     [--config 4,14,3|8,7,3] [--net NAME] [--res N]
//!                    [--density D] [--mem-model ideal|tiled]
//!                    [--metrics-out FILE] [--trace-out FILE] [--trace-limit N]
//!                    [--pe-trace N] ...
//! vscnn serve        [--rps N] [--duration-ms N] [--seed S] [--res N]
//!                    [--net NAME] [--fleet N] [--topology flat|racks:R]
//!                    [--policy P] [--traffic poisson|diurnal|flash[,k:v..]]
//!                    [--max-batch N] [--batch-wait-us N] [--queue-cap N]
//!                    [--clients N] [--think-ms N] [--out FILE]
//!                    [--faults SPEC] [--timeout-us N] [--retries N]
//!                    [--backoff-us N] [--hedge-us N] [--shed] [--sdc SPEC]
//!                    [--metrics-out FILE] [--trace-out FILE] [--trace-limit N]
//! vscnn runtime-info [--artifacts DIR]
//! vscnn list
//! ```

use anyhow::{bail, Context, Result};
use vscnn::cli::Cli;
use vscnn::experiments::{self, ExpContext};
use vscnn::{log_info, log_warn};

fn main() {
    vscnn::util::logging::init_from_env();
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&cli) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cli: &Cli) -> Result<()> {
    match cli.command.as_str() {
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        "list" => {
            println!("experiments:");
            for id in experiments::list() {
                println!("  {id}");
            }
            println!("networks (--net):");
            for name in vscnn::model::zoo::names() {
                println!("  {name}");
            }
            Ok(())
        }
        "exp" => cmd_exp(cli),
        "simulate" => cmd_simulate(cli),
        "serve" => cmd_serve(cli),
        "runtime-info" => cmd_runtime_info(cli),
        other => bail!("unknown command '{other}' (try `vscnn help`)"),
    }
}

fn print_help() {
    println!(
        "vscnn {} — VSCNN accelerator reproduction (cs.AR 2022, arXiv:2205.02271)\n\n\
         commands:\n\
         \x20 exp <id|all>    run a paper experiment ({})\n\
         \x20 simulate        one-off simulation of a pruned zoo network\n\
         \x20 serve           serve a multi-tenant request mix on a fleet of accelerators\n\
         \x20 runtime-info    check the PJRT runtime + artifacts\n\
         \x20 list            list experiment ids and zoo network names\n\n\
         common flags: --net {} --res N (default 224)\n\
         \x20 --images N --seed S --bias-shift X --pjrt DIR --out DIR\n\
         \x20 --threads N (host worker threads; 0 = auto, one per core — the default)\n\
         \x20 --mem-model ideal|tiled (tiled = SRAM/DRAM-aware cycle accounting, default)\n\
         \x20 --precision f32|int16|int8 (CVF payload precision; fixed point halves/quarters traffic)\n\
         \x20 --fuse (keep conv→conv strips SRAM-resident where they fit; tiled model only)\n\
         serve flags: --rps N --duration-ms N --fleet N (alias --instances)\n\
         \x20 --topology flat|racks:R (racked fleets default to hierarchical dispatch)\n\
         \x20 --policy round-robin|least-loaded|affinity|hierarchical\n\
         \x20 --traffic poisson | diurnal[,amp:A,period-ms:P] | flash[,x:X,high-ms:H,low-ms:L]\n\
         \x20 --max-batch N --batch-wait-us N --queue-cap N --clients N --think-ms N --out FILE\n\
         \x20 --faults crash:RATE,mttr:MS,straggler:RATE,slow:X,slowms:MS,reqfault:P (per-instance rates)\n\
         \x20 --timeout-us N (per-attempt timeout) --retries N --backoff-us N --hedge-us N --shed\n\
         \x20 --sdc flip:RATE,weight:F,act:F,acc:F,protect,scrub:MS,quarantine:N,ovh:F,budget:N (bit-flip injection)\n\
         observability (exp/simulate/serve):\n\
         \x20 --metrics-out FILE (process metrics registry snapshot as JSON)\n\
         \x20 --trace-out FILE (Chrome/Perfetto trace; open in ui.perfetto.dev)\n\
         \x20 --trace-limit N (trace event cap, default 200000; excess is counted, not stored)\n\
         \x20 --pe-trace N (simulate only: per-cycle PE issue-event budget, default 20000; 0 = off)",
        vscnn::VERSION,
        experiments::list().join(", "),
        vscnn::model::zoo::names().join("|"),
    );
}

/// Parse the shared observability flags. Turns the metrics registry on
/// when `--metrics-out` is given; callers enable span tracing themselves
/// because the right moment differs per command (`serve` waits until
/// after profiling so its trace is cycles-only and deterministic).
/// Returns `(metrics_out, trace_out, trace_limit)`.
fn obs_flags(cli: &Cli) -> Result<(Option<String>, Option<String>, usize)> {
    let metrics_out = cli.get_value("metrics-out")?.map(str::to_string);
    let trace_out = cli.get_value("trace-out")?.map(str::to_string);
    let limit: usize = cli.get_num("trace-limit", 200_000)?;
    anyhow::ensure!(limit >= 1, "--trace-limit must be >= 1");
    if metrics_out.is_some() {
        vscnn::util::metrics::set_enabled(true);
    }
    Ok((metrics_out, trace_out, limit))
}

/// Write the observability outputs a command collected.
fn obs_finish(metrics_out: Option<&String>, trace_out: Option<&String>) -> Result<()> {
    if let Some(path) = metrics_out {
        std::fs::write(path, vscnn::util::metrics::snapshot().pretty())
            .with_context(|| format!("writing {path}"))?;
        log_info!("wrote {path}");
    }
    if let Some(path) = trace_out {
        let dropped = vscnn::util::trace_span::dropped();
        if dropped > 0 {
            log_warn!("trace buffer full: {dropped} events dropped (raise --trace-limit)");
        }
        vscnn::util::trace_span::write_chrome_trace(path)
            .with_context(|| format!("writing {path}"))?;
        log_info!("wrote {path} (open in https://ui.perfetto.dev)");
    }
    Ok(())
}

fn ctx_from(cli: &Cli) -> Result<ExpContext> {
    let default = ExpContext::default();
    let mem_model = match cli.get_value("mem-model")? {
        None => default.mem_model,
        Some(s) => vscnn::sim::config::MemModel::parse(s)
            .ok_or_else(|| anyhow::anyhow!("--mem-model must be 'ideal' or 'tiled', got '{s}'"))?,
    };
    let precision = match cli.get_value("precision")? {
        None => default.precision,
        Some(s) => vscnn::sim::config::Precision::parse(s).ok_or_else(|| {
            anyhow::anyhow!("--precision must be 'f32', 'int16' or 'int8', got '{s}'")
        })?,
    };
    // `--threads 0` means auto (one worker per available core), matching
    // `SimConfig::threads == 0` — resolved here so every consumer (the
    // im2col backend included) sees a concrete count.
    let threads = vscnn::util::resolve_threads(cli.get_num("threads", default.threads)?);
    Ok(ExpContext {
        net: cli.get_value("net")?.unwrap_or(&default.net).to_string(),
        res: cli.get_num("res", default.res)?,
        seed: cli.get_num("seed", default.seed)?,
        images: cli.get_num("images", default.images)?,
        bias_shift: cli.get_num("bias-shift", default.bias_shift)?,
        threads,
        artifacts_dir: cli.get_value("pjrt")?.map(|s| s.to_string()),
        mem_model,
        max_fleet: match cli.get_num::<usize>("max-fleet", 0)? {
            0 => None,
            n => Some(n),
        },
        precision,
        fuse: cli.get_bool("fuse"),
    })
}

fn cmd_exp(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "net",
        "res",
        "seed",
        "images",
        "bias-shift",
        "threads",
        "pjrt",
        "out",
        "mem-model",
        "max-fleet",
        "precision",
        "fuse",
        "metrics-out",
        "trace-out",
        "trace-limit",
    ])?;
    let Some(id) = cli.positional.first() else {
        bail!("usage: vscnn exp <id|all>; ids: {:?}", experiments::list());
    };
    let ctx = ctx_from(cli)?;
    let (metrics_out, trace_out, trace_limit) = obs_flags(cli)?;
    if trace_out.is_some() {
        vscnn::util::trace_span::enable(trace_limit, true, true);
    }
    let out_dir = cli.get_value("out")?.unwrap_or("reports");
    std::fs::create_dir_all(out_dir).with_context(|| format!("creating {out_dir}"))?;

    let outputs = if id == "all" {
        experiments::run_all(&ctx)?
    } else {
        vec![experiments::run(id, &ctx)?]
    };
    for out in outputs {
        let json_path = format!("{out_dir}/{}.json", out.id);
        let text_path = format!("{out_dir}/{}.txt", out.id);
        std::fs::write(&json_path, out.json.pretty())?;
        std::fs::write(&text_path, &out.text)?;
        println!("== {} ==\n{}", out.id, out.text);
        log_info!("wrote {json_path} and {text_path}");
    }
    obs_finish(metrics_out.as_ref(), trace_out.as_ref())?;
    Ok(())
}

fn cmd_simulate(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "net",
        "res",
        "seed",
        "images",
        "bias-shift",
        "threads",
        "pjrt",
        "config",
        "density",
        "mem-model",
        "precision",
        "fuse",
        "metrics-out",
        "trace-out",
        "trace-limit",
        "pe-trace",
    ])?;
    let ctx = ctx_from(cli)?;
    let (metrics_out, trace_out, trace_limit) = obs_flags(cli)?;
    if trace_out.is_some() {
        vscnn::util::trace_span::enable(trace_limit, true, true);
        // Promote the per-cycle PE issue trace (Table I) into the export,
        // budgeted because it forces the slow sequential dataflow walk.
        // `--pe-trace 0` keeps the trace but skips the issue events.
        vscnn::util::trace_span::set_pe_budget(cli.get_num("pe-trace", 20_000u64)?);
    }
    let cfg = match cli.get_value("config")?.unwrap_or("8,7,3") {
        "4,14,3" => vscnn::sim::config::SimConfig::paper_4_14_3(),
        "8,7,3" => vscnn::sim::config::SimConfig::paper_8_7_3(),
        other => {
            let parts: Vec<usize> = other
                .split(',')
                .map(|p| p.parse().context("config must be B,R,C"))
                .collect::<Result<_>>()?;
            anyhow::ensure!(parts.len() == 3, "config must be B,R,C");
            let mut c = vscnn::sim::config::SimConfig::paper_4_14_3();
            c.pe.arrays = parts[0];
            c.pe.rows = parts[1];
            c.pe.cols = parts[2];
            c
        }
    };

    let (coord, images, achieved) = if let Some(d) = cli.get_value("density")? {
        let density =
            vscnn::pruning::sensitivity::checked_density(d.parse().context("--density")?)?;
        let net = vscnn::model::zoo::by_name(&ctx.net, ctx.res)?;
        let mut params =
            vscnn::model::init::synthetic_params(&net, ctx.seed, ctx.bias_shift);
        let sched = vscnn::pruning::sensitivity::flat_schedule(&net, density);
        let achieved = vscnn::pruning::prune_network_vectors(&mut params, &sched);
        let images =
            vscnn::model::init::synthetic_batch(net.input_shape, ctx.images, ctx.seed ^ 0xDEAD);
        (
            vscnn::coordinator::Coordinator::new(net, params),
            images,
            achieved,
        )
    } else {
        vscnn::experiments::workload::prepare(&ctx)?
    };
    log_info!("weight density after pruning: {achieved:.3}");

    let opts = vscnn::experiments::workload::options(&ctx, cfg)?;
    for (i, img) in images.iter().enumerate() {
        let t0 = std::time::Instant::now();
        let report = coord.run(img, &opts)?;
        let series = report.overall_series();
        println!(
            "image {i}: {} mem[{}] cycles {} dense {} speedup {:.3}x (ideal vec {:.3}x fine {:.3}x) mem-bound {:.0}% bw-util {:.1}% wall {:?}",
            cfg.pe.label(),
            report.mem_model.label(),
            report.totals.cycles,
            report.total_dense_cycles,
            series.ours,
            series.ideal_vector,
            series.ideal_fine,
            100.0 * report.memory_bound_layer_frac(),
            100.0 * report.effective_bw_util(),
            t0.elapsed()
        );
    }
    obs_finish(metrics_out.as_ref(), trace_out.as_ref())?;
    Ok(())
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "net",
        "res",
        "rps",
        "duration-ms",
        "seed",
        "threads",
        "instances",
        "fleet",
        "topology",
        "traffic",
        "policy",
        "max-batch",
        "batch-wait-us",
        "queue-cap",
        "clients",
        "think-ms",
        "out",
        "faults",
        "timeout-us",
        "retries",
        "backoff-us",
        "hedge-us",
        "shed",
        "sdc",
        "metrics-out",
        "trace-out",
        "trace-limit",
    ])?;
    use vscnn::serve::{
        build_profiles, default_fleet, default_mix, parse_topology, simulate, BatchPolicy,
        DispatchPolicy, FaultSpec, RobustnessPolicy, ServeReport, ServeSpec, Tenant, TrafficModel,
    };

    let defaults = ExpContext::default();
    // Serving defaults favor quick turnarounds: the mix compiles three
    // networks, so the default resolution is the smallest the full mix
    // supports scaled up one notch (override with --res).
    let res: usize = cli.get_num("res", 64)?;
    let seed: u64 = cli.get_num("seed", defaults.seed)?;
    // --threads 0 = auto, same convention as `exp`/`simulate`.
    let threads: usize = vscnn::util::resolve_threads(cli.get_num("threads", defaults.threads)?);
    let rps: f64 = cli.get_num("rps", 200.0)?;
    anyhow::ensure!(rps > 0.0, "--rps must be positive, got {rps}");
    let duration_ms: f64 = cli.get_num("duration-ms", 100.0)?;
    anyhow::ensure!(duration_ms > 0.0, "--duration-ms must be positive");
    let instances: usize = cli.get_num("instances", 4)?;
    // --fleet is the scale-era spelling of --instances; when both are
    // given, --fleet wins (it defaults to the --instances value).
    let fleet_n: usize = cli.get_num("fleet", instances)?;
    anyhow::ensure!(fleet_n >= 1, "--fleet must be >= 1");
    let racks = match cli.get_value("topology")? {
        Some(s) => parse_topology(s, fleet_n)?,
        None => 1,
    };
    // Racked fleets default to hierarchical dispatch; an explicit
    // --policy always wins. Flat fleets keep the legacy affinity default
    // so existing runs stay bit-identical.
    let policy = match cli.get_value("policy")? {
        Some(s) => DispatchPolicy::parse(s)?,
        None if racks > 1 => DispatchPolicy::Hierarchical,
        None => DispatchPolicy::parse("affinity")?,
    };
    let max_batch: usize = cli.get_num("max-batch", 8)?;
    anyhow::ensure!(max_batch >= 1, "--max-batch must be >= 1");
    let batch_wait_us: f64 = cli.get_num("batch-wait-us", 100.0)?;
    let queue_cap: usize = cli.get_num("queue-cap", 32)?;
    let clients: usize = cli.get_num("clients", 0)?;
    let think_ms: f64 = cli.get_num("think-ms", 1.0)?;

    let clock_mhz = 500.0; // matches SimConfig::freq_mhz
    // Fault injection + client-side robustness (all off by default, so the
    // plain `vscnn serve` path stays bit-identical to the pre-fault sim).
    let faults = match cli.get_value("faults")? {
        Some(s) => FaultSpec::parse(s)?,
        None => FaultSpec::none(),
    };
    // Silent-data-corruption injection (ISSUE 10): same off-by-default
    // discipline as --faults — no --sdc means zero injected flips and a
    // byte-identical report.
    let sdc = match cli.get_value("sdc")? {
        Some(s) => vscnn::sim::sdc::SdcSpec::parse(s)?,
        None => vscnn::sim::sdc::SdcSpec::none(),
    };
    let timeout_us: f64 = cli.get_num("timeout-us", 0.0)?;
    anyhow::ensure!(timeout_us >= 0.0, "--timeout-us must be >= 0");
    let retries: u32 = cli.get_num("retries", 0)?;
    let backoff_us: f64 = cli.get_num("backoff-us", 50.0)?;
    anyhow::ensure!(backoff_us >= 0.0, "--backoff-us must be >= 0");
    let hedge_us: f64 = cli.get_num("hedge-us", 0.0)?;
    anyhow::ensure!(hedge_us >= 0.0, "--hedge-us must be >= 0");
    anyhow::ensure!(
        retries == 0 || timeout_us > 0.0,
        "--retries needs --timeout-us > 0 (retries trigger on attempt timeout)"
    );
    let robust = RobustnessPolicy {
        timeout_cycles: (timeout_us * clock_mhz) as u64,
        max_retries: retries,
        backoff_cycles: ((backoff_us * clock_mhz) as u64).max(1),
        hedge_cycles: (hedge_us * clock_mhz) as u64,
        shed: cli.get_bool("shed"),
    };
    let tenants = match cli.get_value("net")? {
        Some(net) => vec![Tenant::new(net, res, 1.0)],
        None => default_mix(res),
    };
    let traffic = if clients > 0 {
        anyhow::ensure!(
            cli.get_value("traffic")?.is_none(),
            "--traffic is open-loop only; drop --clients to use it"
        );
        TrafficModel::ClosedLoop {
            clients,
            think_cycles: (think_ms * clock_mhz * 1e3) as u64,
        }
    } else {
        match cli.get_value("traffic")? {
            Some(s) => TrafficModel::parse(s, rps, clock_mhz)?,
            None => TrafficModel::OpenLoop { rps },
        }
    };
    let spec = ServeSpec {
        tenants,
        instances: default_fleet(fleet_n),
        traffic,
        policy,
        batch: BatchPolicy {
            max_batch,
            max_wait_cycles: ((batch_wait_us * clock_mhz) as u64).max(1),
        },
        queue_cap,
        racks,
        duration_cycles: ((duration_ms * clock_mhz * 1e3) as u64).max(1),
        clock_mhz,
        seed,
        faults,
        robust,
        sdc,
    };

    log_info!(
        "profiling {} tenants on {} instances (compile cache shared)",
        spec.tenants.len(),
        spec.instances.len()
    );
    let (metrics_out, trace_out, trace_limit) = obs_flags(cli)?;
    let profiles = build_profiles(&spec, threads)?;
    // Tracing goes live only after profiling, and cycles-only: every
    // serve event is stamped in deterministic sim cycles with tid ==
    // instance index, so two same-seed traced runs export byte-identical
    // timelines (pinned by tests/observability.rs and the CI smoke).
    if trace_out.is_some() {
        vscnn::util::trace_span::enable(trace_limit, false, true);
    }
    let outcome = simulate(&spec, &profiles);
    let report = ServeReport::new(&spec, &outcome);
    print!("{}", report.text());
    if let Some(path) = cli.get_value("out")? {
        std::fs::write(path, report.to_json().pretty())
            .with_context(|| format!("writing {path}"))?;
        log_info!("wrote {path}");
    }
    obs_finish(metrics_out.as_ref(), trace_out.as_ref())?;
    Ok(())
}

fn cmd_runtime_info(cli: &Cli) -> Result<()> {
    cli.check_known(&["artifacts"])?;
    let dir = cli.get_value("artifacts")?.unwrap_or("artifacts");
    let rt = vscnn::runtime::Runtime::new(dir)?;
    println!("platform: {}", rt.platform());
    println!("artifacts ({}):", rt.manifest().artifacts.len());
    for a in &rt.manifest().artifacts {
        println!(
            "  {:30} [C={},H={},W={}] -> K={}",
            a.name, a.c_in, a.h, a.w, a.c_out
        );
    }
    Ok(())
}
